"""Pod-local training with deferred cross-pod sync (the keep_lock_local
analogue for the optimizer, DiLoCo-style).

Each pod trains *independently* on its own batch shard — all per-step
collectives stay on ICI — and parameters are averaged across pods only every
``sync_every`` steps (the secondary-queue flush: one DCN crossing amortised
over K local handovers).  DCN bytes drop by K× versus per-step sync, at the
cost of K steps of inter-pod parameter drift (bounded by the sync period —
the same throughput↔staleness dial as the paper's fairness threshold).

Implementation: the pod axis is realised as a *leading array axis* of size
n_pods on the whole train state, sharded over the mesh's ``pod`` axis; the
train step is vmapped over it (so each pod's update sees only its slice) and
the periodic sync is a mean over that axis — which GSPMD lowers to exactly
one all-reduce over the pod axis (the DCN collective we are rationing).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.sharding import current_ctx, spec_for
from repro.training.step import make_train_step


def replicate_for_pods(state, n_pods: int):
    """state -> per-pod stacked state (leading axis n_pods, sharded 'pod')."""
    stacked = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n_pods,) + x.shape), state)
    ctx = current_ctx()
    if ctx is not None and "pod" in ctx.mesh.axis_names:
        from jax.sharding import NamedSharding, PartitionSpec as P

        def shard_leaf(x):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(ctx.mesh, P("pod", *([None] * (x.ndim - 1))))
            )

        stacked = jax.tree.map(shard_leaf, stacked)
    return stacked


def pod_average(state):
    """Average params/opt across the pod axis (ONE all-reduce over 'pod')."""
    return jax.tree.map(lambda x: jnp.broadcast_to(jnp.mean(x, 0, keepdims=True), x.shape)
                        if jnp.issubdtype(x.dtype, jnp.floating) else x, state)


def make_local_train_step(model, cfg, *, sync_every: int, lr_fn=None, **kw):
    """-> step(state_stacked, batch_stacked) with deferred pod sync.

    ``batch_stacked`` leaves have shape (n_pods, per_pod_batch, ...).  The
    sync fires when (step % sync_every == 0); between syncs there is no
    cross-pod communication at all."""
    base_step = make_train_step(model, cfg, lr_fn=lr_fn, **kw)
    vstep = jax.vmap(base_step)

    def step(state, batch):
        state, metrics = vstep(state, batch)
        do_sync = jnp.max(state["step"]) % sync_every == 0
        state = jax.lax.cond(do_sync, pod_average, lambda s: s, state)
        metrics = jax.tree.map(lambda m: jnp.mean(m, 0), metrics)
        metrics["synced"] = do_sync
        return state, metrics

    return step


def pod_drift(state) -> jax.Array:
    """Max parameter divergence across pods (monitoring the staleness dial)."""
    def leaf_drift(x):
        if not jnp.issubdtype(x.dtype, jnp.floating) or x.shape[0] < 2:
            return jnp.zeros(())
        x = x.astype(jnp.float32)
        return jnp.max(jnp.abs(x - jnp.mean(x, 0, keepdims=True)))
    return jax.tree.reduce(jnp.maximum, jax.tree.map(leaf_drift, state["params"]))
