"""mixtral-8x22b [moe]: 56L d=6144 48H (GQA kv=8) d_ff=16384, 8 experts top-2,
sliding-window attention (arXiv:2401.04088).  8 experts do not divide the
16-way mesh axes, so experts are tensor-sharded (TP) rather than
expert-parallel — recorded in DESIGN.md §Arch-applicability."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv=8, d_ff=16384, vocab=32768,
    mlp="swiglu", window=4096, n_experts=8, top_k=2, capacity_factor=1.25,
    accum=8,
)

def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                          vocab=512, window=32, n_experts=4, top_k=2, accum=1,
                          attn_chunk=32)
