"""Decoder-LM assembly: dense / MoE / hybrid (RG-LRU) / SSM / VLM families.

The layer stack is compiled as a list of *segments*:

  * ``("scan", name, kinds, n_rep)`` — ``n_rep`` repetitions of the block-kind
    cycle ``kinds`` (usually a single kind), stacked params scanned with
    ``lax.scan`` (+ remat) so HLO size is O(1) in depth — 96-layer nemotron
    compiles as fast as 2-layer smoke configs.
  * ``("unroll", name, kind)`` — a single materialised layer (hybrid pattern
    remainders, deepseek's first dense layer).

Block kinds: ``attn`` (attention + dense FFN), ``moe`` (attention + MoE FFN),
``rec`` (RG-LRU recurrent block + dense FFN), ``ssd`` (Mamba-2 block).

Decode keeps the KV/recurrent cache *in the scan carry* (updated with
``dynamic_update_index_in_dim``) so XLA aliases it in place — 1x cache
residency rather than the 2x of the xs/ys formulation.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from .attention import attn_decode, attention
from .common import ParamBuilder, apply_rope, cross_entropy, embed_lookup, norm, rope_angles
from .mlp import declare_mlp, mlp_apply
from .moe import declare_moe, moe_apply
from .rglru import declare_rglru, rglru_block, rglru_block_step
from .sharding import shard
from .ssm import declare_ssd, ssd_block, ssd_block_step


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Segment:
    mode: str              # "scan" | "unroll"
    name: str
    kinds: tuple[str, ...]  # block kind per position in the cycle
    n_rep: int = 1


def layer_kinds(cfg) -> list[str]:
    if cfg.family == "ssm":
        return ["ssd"] * cfg.n_layers
    kinds = []
    for i, k in enumerate(cfg.blocks):
        if k == "rec":
            kinds.append("rec")
        elif cfg.n_experts and i >= cfg.first_k_dense:
            kinds.append("moe")
        else:
            kinds.append("attn")
    return kinds


def build_segments(cfg) -> list[Segment]:
    kinds = layer_kinds(cfg)
    segs: list[Segment] = []
    i = 0
    # leading unrolled layers (deepseek first-k-dense)
    while i < len(kinds) and cfg.first_k_dense and i < cfg.first_k_dense:
        segs.append(Segment("unroll", f"layer{i}", (kinds[i],)))
        i += 1
    rest = kinds[i:]
    if not rest:
        return segs
    if len(set(rest)) == 1:
        segs.append(Segment("scan", "blocks", (rest[0],), len(rest)))
        return segs
    p = len(cfg.block_pattern)
    n_full = len(rest) // p
    if n_full:
        segs.append(Segment("scan", "cyc", tuple(rest[:p]), n_full))
    for j in range(n_full * p, len(rest)):
        segs.append(Segment("unroll", f"tail{j}", (rest[j],)))
    return segs


# ---------------------------------------------------------------------------
# per-block param declaration
# ---------------------------------------------------------------------------

def declare_block(pb: ParamBuilder, prefix: str, cfg, kind: str, stack: int = 0):
    lead = (stack,) if stack else ()
    lax_ = ("layers",) if stack else ()
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ln_bias = cfg.norm == "layernorm"

    def decl_norm(n):
        pb.declare(f"{prefix}/{n}", lead + (d,), lax_ + (None,), init="zeros")
        if ln_bias:
            pb.declare(f"{prefix}/{n}_b", lead + (d,), lax_ + (None,), init="zeros")

    decl_norm("ln1")
    if kind in ("attn", "moe"):
        pb.declare(f"{prefix}/wq", lead + (d, h, hd), lax_ + ("fsdp", "heads", None))
        pb.declare(f"{prefix}/wk", lead + (d, kv, hd), lax_ + ("fsdp", "kv_heads", None))
        pb.declare(f"{prefix}/wv", lead + (d, kv, hd), lax_ + ("fsdp", "kv_heads", None))
        pb.declare(f"{prefix}/wo", lead + (h, hd, d), lax_ + ("heads", None, "fsdp"))
        decl_norm("ln2")
        if kind == "moe":
            declare_moe(pb, f"{prefix}/moe", cfg, stack)
        else:
            declare_mlp(pb, f"{prefix}/mlp", d, cfg.d_ff, cfg.mlp, stack)
    elif kind == "rec":
        declare_rglru(pb, f"{prefix}/rec", d, cfg.lru_width or d, cfg.conv_width, stack)
        decl_norm("ln2")
        declare_mlp(pb, f"{prefix}/mlp", d, cfg.d_ff, cfg.mlp, stack)
    elif kind == "ssd":
        declare_ssd(pb, f"{prefix}/ssd", cfg, stack)
    else:
        raise ValueError(kind)


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _norm(params, name, x, cfg):
    return norm(cfg.norm, x, params[name], params.get(f"{name}_b"))


def _attn_full(params, x, cfg, rope_cs, *, causal=True, window=None, cross_kv=None):
    """Attention sublayer, full-sequence mode.  Returns (x_out, (k, v))."""
    h = _norm(params, "ln1" if cross_kv is None else "lnx", x, cfg)
    q = jnp.einsum("bsd,dhk->bshk", h, params["wq" if cross_kv is None else "wxq"])
    if cross_kv is None:
        k = jnp.einsum("bsd,dhk->bshk", h, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, params["wv"])
        if rope_cs is not None:
            cos, sin = rope_cs
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
    else:
        k, v = cross_kv
    w = cfg.window if window is None else window
    o = attention(
        q, k, v,
        impl=cfg.attn_impl, causal=causal, window=w, chunk=cfg.attn_chunk,
    )
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo" if cross_kv is None else "wxo"])
    return x + shard(out, "batch", "seq", "embed"), (k, v)


def _rope_pos(pos):
    """pos: () or (B,) -> positions shaped for rope_angles broadcasting."""
    p = jnp.asarray(pos)
    return p[None, None] if p.ndim == 0 else p[:, None]


def _write_kv(cache: jax.Array, new: jax.Array, slot) -> jax.Array:
    """Write (B, 1, kv, hd) into (B, S, kv, hd) at ``slot`` (scalar or (B,))."""
    slot = jnp.asarray(slot)
    if slot.ndim == 0:
        return jax.lax.dynamic_update_slice(cache, new.astype(cache.dtype), (0, slot, 0, 0))
    return jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice(c, n.astype(c.dtype), (s, 0, 0))
    )(cache, new, slot)


def _attn_step(params, x_t, cfg, pos, cache, *, ring: bool, cross_kv=None):
    """Attention sublayer, one-token decode.  cache = (k_cache, v_cache),
    READ-ONLY here: the new token's (k, v) slice is returned for the caller
    to write into the cache once, outside the layer scan — keeping the big
    cache an xs input the partitioner never copies or rewrites per layer.

    ``pos`` is () for lockstep decode (dry-run shapes) or (B,) for the
    continuous-batching engine (per-slot positions)."""
    h = _norm(params, "ln1" if cross_kv is None else "lnx", x_t, cfg)
    q = jnp.einsum("bsd,dhk->bshk", h, params["wq" if cross_kv is None else "wxq"])
    if cross_kv is None:
        k_cache, v_cache = cache
        k = jnp.einsum("bsd,dhk->bshk", h, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, params["wv"])
        if cfg.pos == "rope":
            cos, sin = rope_angles(_rope_pos(pos), cfg.hd, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        o = attn_decode(
            q, k_cache, v_cache, jnp.asarray(pos), window=cfg.window, ring=ring,
            extra_kv=(k.astype(k_cache.dtype), v.astype(v_cache.dtype)),
        )
        new_kv = (k.astype(k_cache.dtype), v.astype(v_cache.dtype))
    else:
        k_cache, v_cache = cross_kv
        o = attn_decode(q, k_cache, v_cache, k_cache.shape[1], ring=False)
        new_kv = None
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo" if cross_kv is None else "wxo"])
    return x_t + out, new_kv


def _to_ring(k: jax.Array, window: int) -> jax.Array:
    """Convert a full-sequence KV (B,S,kv,hd) into the ring layout decode
    expects for sliding-window archs: slot i%window holds token i, keeping the
    last ``window`` tokens.  Without this, continuing decode from a prefill
    whose prompt length != window mis-places cache entries (caught by the
    decode-matches-prefill tests)."""
    b, s, kv, hd = k.shape
    if s <= window:
        return jnp.pad(k, ((0, 0), (0, window - s), (0, 0), (0, 0)))
    tail = k[:, -window:]                                # tokens s-window..s-1
    slots = jnp.mod(jnp.arange(s - window, s), window)
    return jnp.zeros((b, window, kv, hd), k.dtype).at[:, slots].set(tail)


def block_full(params, x, cfg, kind, rope_cs, *, causal=True):
    """Full-sequence block.  Returns (x, aux, cache)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "moe"):
        x, (k, v) = _attn_full(params, x, cfg, rope_cs, causal=causal)
        h = _norm(params, "ln2", x, cfg)
        if kind == "moe":
            if cfg.moe_impl == "ep":
                from .moe_ep import moe_apply_ep

                y, aux = moe_apply_ep(params["moe"], h, cfg)
            else:
                y, aux = moe_apply(params["moe"], h, cfg, n_domains=cfg.cna_domains)
        else:
            y = mlp_apply(params["mlp"], h, cfg.mlp)
        x = x + y
        cdt = cfg_cache_dtype(cfg)
        if cfg.window > 0:
            k, v = _to_ring(k, cfg.window), _to_ring(v, cfg.window)
        cache = (k.astype(cdt), v.astype(cdt))
    elif kind == "rec":
        h = _norm(params, "ln1", x, cfg)
        y, state = rglru_block(params["rec"], h, scan_impl=cfg.rec_impl)
        x = x + y
        h = _norm(params, "ln2", x, cfg)
        x = x + mlp_apply(params["mlp"], h, cfg.mlp)
        cache = state
    elif kind == "ssd":
        h = _norm(params, "ln1", x, cfg)
        y, state = ssd_block(params["ssd"], h, cfg, intra_impl=cfg.ssd_impl)
        x = x + y
        cache = state
    else:
        raise ValueError(kind)
    return shard(x, "batch", "seq", "embed"), aux, cache


def block_step(params, x_t, cfg, kind, pos, cache):
    """One-token decode block.  Returns (x_t, new_cache)."""
    if kind in ("attn", "moe"):
        ring = cfg.window > 0
        x_t, new_attn = _attn_step(params, x_t, cfg, pos, cache, ring=ring)
        h = _norm(params, "ln2", x_t, cfg)
        if kind == "moe":
            y, _ = moe_apply(params["moe"], h, cfg, n_domains=cfg.cna_domains)
        else:
            y = mlp_apply(params["mlp"], h, cfg.mlp)
        return x_t + y, new_attn
    if kind == "rec":
        h = _norm(params, "ln1", x_t, cfg)
        y, new_state = rglru_block_step(params["rec"], h, cache)
        x_t = x_t + y
        h = _norm(params, "ln2", x_t, cfg)
        return x_t + mlp_apply(params["mlp"], h, cfg.mlp), new_state
    if kind == "ssd":
        h = _norm(params, "ln1", x_t, cfg)
        y, new_state = ssd_block_step(params["ssd"], h, cache, cfg)
        return x_t + y, new_state
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# packed / continuation prefill (continuous batching)
# ---------------------------------------------------------------------------

def _scatter_rows(cache: jax.Array, new: jax.Array, start, length) -> jax.Array:
    """Write ``new`` (B, T, kv, hd) into ``cache`` (B, S, kv, hd) at per-row
    column offsets: token t of row b lands at column ``start[b] + t``, and
    only ``t < length[b]`` commits (right-padded rows never touch the cache).
    Gather-then-select keeps this one fused ``where`` over the cache — the
    same masked-select idiom as ``DecoderLM._merge_kv`` — so no per-row
    dynamic slices fan out under the layer scan."""
    idx = jnp.arange(cache.shape[1])[None, :] - start[:, None]          # (B, S)
    valid = (idx >= 0) & (idx < length[:, None])
    take = jnp.clip(idx, 0, new.shape[1] - 1)[:, :, None, None]
    take = jnp.broadcast_to(take, idx.shape + new.shape[2:])
    g = jnp.take_along_axis(new, take, axis=1)
    return jnp.where(valid[:, :, None, None], g.astype(cache.dtype), cache)


def _attn_rows(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, start) -> jax.Array:
    """``attn_xla`` with a *per-row* query offset: query t of row b sits at
    position ``start[b] + t`` and attends causally over the position-ordered
    cache columns.  Op-for-op the same graph as ``attn_xla`` (grouped einsum,
    NEG_INF mask, ``jax.nn.softmax``, grouped PV einsum) — masked columns
    contribute exact zeros, which is what makes continuation prefill
    bitwise-equal to the from-scratch path (regression-tested)."""
    b, sq, h, hd = q.shape
    skv, hkv = k_cache.shape[1], k_cache.shape[2]
    scale = 1.0 / math.sqrt(hd)
    qg = attn_mod._group_q(q * jnp.asarray(scale, q.dtype), hkv)
    s = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg.astype(k_cache.dtype), k_cache,
        preferred_element_type=jnp.float32,
    )
    q_pos = start[:, None] + jnp.arange(sq)[None, :]                    # (B, Sq)
    mask = q_pos[:, :, None] - jnp.arange(skv)[None, None, :] >= 0      # (B, Sq, Skv)
    s = jnp.where(mask[:, None, None], s, attn_mod.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def _attn_cont(params, x, cfg, rope_cs, kv_cache, start, length):
    """Attention sublayer, suffix-continuation mode: the suffix K/V land in
    the (seeded) cache at per-row offsets first, then the suffix queries
    attend over the whole cache.  Returns (x_out, (k_cache, v_cache))."""
    h = _norm(params, "ln1", x, cfg)
    q = jnp.einsum("bsd,dhk->bshk", h, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, params["wv"])
    if rope_cs is not None:
        cos, sin = rope_cs
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    kc, vc = kv_cache
    kc = _scatter_rows(kc, k, start, length)
    vc = _scatter_rows(vc, v, start, length)
    o = _attn_rows(q, kc, vc, start)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    return x + shard(out, "batch", "seq", "embed"), (kc, vc)


def block_cont(params, x, cfg, kind, rope_cs, kv_cache, start, length):
    """Suffix-continuation block (attention kinds only — recurrent/SSM state
    absorbs padded positions, so those families never take this path).
    Returns (x, (k_cache, v_cache))."""
    if kind != "attn":
        raise ValueError(f"continuation prefill supports 'attn' blocks, got {kind!r}")
    x, kv = _attn_cont(params, x, cfg, rope_cs, kv_cache, start, length)
    h = _norm(params, "ln2", x, cfg)
    x = x + mlp_apply(params["mlp"], h, cfg.mlp)
    return shard(x, "batch", "seq", "embed"), kv


def cfg_cache_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# cache shape declarations
# ---------------------------------------------------------------------------

def block_cache_shape(cfg, kind: str, batch: int, cache_len: int):
    """Abstract cache shapes (no leading stack dim) for one block."""
    cdt = cfg_cache_dtype(cfg)
    if kind in ("attn", "moe"):
        s = min(cache_len, cfg.window) if cfg.window > 0 else cache_len
        kv = (batch, s, cfg.n_kv, cfg.hd)
        return (jax.ShapeDtypeStruct(kv, cdt), jax.ShapeDtypeStruct(kv, cdt))
    if kind == "rec":
        w = cfg.lru_width or cfg.d_model
        return (
            jax.ShapeDtypeStruct((batch, w), jnp.float32),
            jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, w), cdt),
        )
    if kind == "ssd":
        conv_ch = cfg.d_inner + 2 * cfg.ssm_state
        return (
            jax.ShapeDtypeStruct((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
            jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, conv_ch), cdt),
        )
    raise ValueError(kind)


def _stack_sds(sds: jax.ShapeDtypeStruct, n: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((n,) + sds.shape, sds.dtype)


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class DecoderLM:
    """Decoder-only LM over the segment stack.  Also carries the VLM variant
    (pixtral): precomputed patch embeddings (assignment stub) are projected
    and overwrite the leading ``n_patches`` positions of the token stream."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.segments = build_segments(cfg)
        self.pb = ParamBuilder(dtype=jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
        self._declare()
        self._logical_cache = self.pb.logical_tree()

    # -- params --------------------------------------------------------------
    def _declare(self):
        cfg, pb = self.cfg, self.pb
        pb.declare("embed", (cfg.padded_vocab, cfg.d_model), ("vocab", "fsdp"), init="normal", scale=0.02)
        if cfg.pos == "learned":
            pb.declare("pos_emb", (cfg.max_pos, cfg.d_model), (None, "fsdp"), init="normal", scale=0.02)
        if cfg.n_patches:
            pb.declare("patch_proj", (cfg.d_model, cfg.d_model), ("fsdp", None), init="normal")
        for seg in self.segments:
            if seg.mode == "scan":
                for j, kind in enumerate(seg.kinds):
                    name = seg.name if len(seg.kinds) == 1 else f"{seg.name}{j}"
                    declare_block(pb, name, cfg, kind, stack=seg.n_rep)
            else:
                declare_block(pb, seg.name, cfg, seg.kinds[0], stack=0)
        pb.declare("final_norm", (cfg.d_model,), (None,), init="zeros")
        if cfg.norm == "layernorm":
            pb.declare("final_norm_b", (cfg.d_model,), (None,), init="zeros")
        if not cfg.tie_embeddings:
            pb.declare("lm_head", (cfg.d_model, cfg.padded_vocab), ("fsdp", "vocab"), init="normal", scale=0.02)

    def init(self, key):
        return self.pb.init(key)

    def abstract_params(self):
        return self.pb.abstract()

    def logical_tree(self):
        return self.pb.logical_tree()

    def _seg_params(self, params, seg: Segment):
        if seg.mode == "scan":
            if len(seg.kinds) == 1:
                return (params[seg.name],)
            return tuple(params[f"{seg.name}{j}"] for j in range(len(seg.kinds)))
        return (params[seg.name],)

    def _seg_logical(self, seg: Segment):
        log = self._logical_cache
        if seg.mode == "scan":
            if len(seg.kinds) == 1:
                return (log[seg.name],)
            return tuple(log[f"{seg.name}{j}"] for j in range(len(seg.kinds)))
        return (log[seg.name],)

    @staticmethod
    def _constrain_sliced(p_layer, logical):
        """Re-pin a scan-sliced layer's params to their (fsdp x model) layout.

        Without this the partitioner hoists the FSDP all-gather of the whole
        stacked (L, ...) parameter out of the layer loop — materialising every
        layer's gathered weights at once (nemotron train_4k: 106 GB/device;
        EXPERIMENTS.md §Perf).  Constraining the *sliced* leaf keeps the
        gather inside the loop and lets the backward choose reduce-scatter
        for the per-layer grad."""
        return jax.tree.map(
            lambda a, l: shard(a, *l[1:]),
            p_layer,
            logical,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(i, (str, type(None))) for i in x),
        )

    # -- embedding / logits ----------------------------------------------------
    def _embed(self, params, tokens, patches=None, pos_offset=0):
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens)
        x = shard(x, "batch", "seq", "embed")
        if cfg.n_patches and patches is not None:
            pe = jnp.einsum("bpd,de->bpe", patches.astype(x.dtype), params["patch_proj"])
            n = min(cfg.n_patches, x.shape[1])
            x = jnp.concatenate([pe[:, :n], x[:, n:]], axis=1)
        if cfg.pos == "learned":
            off = jnp.asarray(pos_offset)
            pos = jnp.arange(x.shape[1]) + (off[:, None] if off.ndim else off)
            pe = jnp.take(params["pos_emb"], jnp.clip(pos, 0, cfg.max_pos - 1), axis=0)
            x = x + (pe if pe.ndim == 3 else pe[None])
        return x

    def _logits(self, params, x):
        cfg = self.cfg
        x = norm(cfg.norm, x, params["final_norm"], params.get("final_norm_b"))
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
        vmask = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, 0.0, attn_mod.NEG_INF)
        logits = logits + vmask.astype(logits.dtype)
        # vocab-parallel logits: 'seq' must NOT claim the model axis here, or
        # vocab falls back to replicated and the partitioner materialises an
        # unsharded fp32 lm_head copy in the accum-loop carry (18.8 GiB on
        # nemotron-340b; EXPERIMENTS.md §Perf)
        return shard(logits, "batch", None, "vocab")

    def _rope(self, seq_len, offset=0):
        if self.cfg.pos != "rope":
            return None
        return rope_angles(jnp.arange(seq_len) + offset, self.cfg.hd, self.cfg.rope_theta)

    # -- full pass -------------------------------------------------------------
    def _run_full(self, params, x, want_cache: bool):
        cfg = self.cfg
        rope_cs = self._rope(x.shape[1])
        aux_total = jnp.zeros((), jnp.float32)
        caches = {}

        for seg in self.segments:
            p = self._seg_params(params, seg)
            if seg.mode == "unroll":
                x, aux, cache = block_full(p[0], x, cfg, seg.kinds[0], rope_cs)
                aux_total += aux
                if want_cache:
                    caches[seg.name] = cache
                continue

            seg_log = self._seg_logical(seg)

            def body(carry, xs, _kinds=seg.kinds, _log=seg_log):
                xx = carry
                aux_sum = jnp.zeros((), jnp.float32)
                cs = []
                for j, kind in enumerate(_kinds):
                    p_j = self._constrain_sliced(xs[j], _log[j])
                    xx, aux, cache = block_full(p_j, xx, cfg, kind, rope_cs)
                    aux_sum += aux
                    cs.append(cache)
                return xx, (aux_sum, tuple(cs))

            fn = body
            if cfg.remat:
                fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
            x, (auxs, cs) = jax.lax.scan(fn, x, p)
            aux_total += jnp.sum(auxs)
            if want_cache:
                caches[seg.name] = cs
        return x, aux_total, caches if want_cache else None

    # -- public API --------------------------------------------------------------
    def loss(self, params, batch):
        """batch: {tokens (B,S), labels (B,S), [patches]} -> scalar loss."""
        x = self._embed(params, batch["tokens"], batch.get("patches"))
        x, aux, _ = self._run_full(params, x, want_cache=False)
        logits = self._logits(params, x)
        ce = cross_entropy(logits, batch["labels"], self.cfg.vocab, batch.get("mask"))
        return ce + aux

    def prefill(self, params, batch, *, cache_headroom: int = 8):
        """-> (last-token logits (B, Vpad), cache dict).

        Full-attention KV caches are emitted with ``cache_headroom`` spare
        slots: ``dynamic_update_slice`` silently *clamps* out-of-bounds
        writes, so a zero-headroom cache would corrupt its last entry on the
        first decode step (regression-tested).  Ring (sliding-window) and
        recurrent caches have fixed capacity and never need headroom."""
        x = self._embed(params, batch["tokens"], batch.get("patches"))
        x, _, caches = self._run_full(params, x, want_cache=True)
        if cache_headroom:
            caches = self._pad_caches(caches, cache_headroom)
        logits = self._logits(params, x[:, -1:])
        caches["pos"] = jnp.full((), x.shape[1], jnp.int32)
        return logits[:, 0], caches

    def _pad_caches(self, caches, headroom: int):
        if self.cfg.window > 0:
            return caches  # ring caches: slot = pos % window, always in bounds
        out = {}
        for seg in self.segments:
            per = caches[seg.name]
            if seg.mode == "unroll":
                per = (per,)
            new = []
            for j, kind in enumerate(seg.kinds):
                c = per[j]
                if kind in ("attn", "moe"):
                    ax = 2 if seg.mode == "scan" else 1  # (L,B,S,kv,hd) | (B,S,kv,hd)
                    c = tuple(
                        jnp.pad(t, [(0, headroom if d == ax else 0) for d in range(t.ndim)])
                        for t in c
                    )
                new.append(c)
            out[seg.name] = tuple(new) if seg.mode == "scan" else new[0]
        return out

    # -- packed / continuation prefill (continuous batching) --------------------
    def supports_packed_prefill(self, cache_len: int | None = None) -> bool:
        """Whether right-padded packed prefill is *bitwise-exact* for this
        arch.  Padding is invisible only when every block is plain dense
        attention: recurrent/SSM state and MoE capacity routing absorb padded
        positions, sliding-window ring caches place entries by absolute slot,
        and patch rows overwrite leading positions.  When ``cache_len`` is
        given, also require that every bucket the engine would use dispatches
        to the same ``attn_xla`` path as the per-request reference (a bucket
        above ``attn_chunk`` would stream while the reference doesn't)."""
        cfg = self.cfg
        ok = (
            cfg.window == 0
            and cfg.n_patches == 0
            and all(k == "attn" for seg in self.segments for k in seg.kinds)
        )
        if ok and cache_len is not None and cfg.attn_impl != "xla":
            ok = cache_len <= cfg.attn_chunk
        return ok

    def _mask_packed(self, caches, lengths):
        """Zero every KV position >= the row's true length.  Right-padded
        rows compute garbage K/V past the prompt; zeroing them matches the
        zero-padding of ``SlotCache._fit`` so a packed row is bitwise the
        per-request cache, not just equal on the valid span."""
        out = {}
        for seg in self.segments:
            per = caches[seg.name]
            if seg.mode == "unroll":
                per = (per,)
            new = []
            for c in per:  # (k, v): (L, B, S, kv, hd) scanned | (B, S, kv, hd)
                def z(t):
                    s = t.shape[2 if t.ndim == 5 else 1]
                    keep = jnp.arange(s)[None, :] < lengths[:, None]     # (B, S)
                    keep = keep[..., None, None]
                    if t.ndim == 5:
                        keep = keep[None]
                    return jnp.where(keep, t, jnp.zeros((), t.dtype))
                new.append(tuple(z(t) for t in c))
            out[seg.name] = tuple(new) if seg.mode == "scan" else new[0]
        return out

    def prefill_packed(self, params, tokens, lengths, *, cache_headroom: int = 8):
        """Packed prefill: ``tokens`` (B, S) right-padded prompt rows,
        ``lengths`` (B,) true lengths -> (per-row last-*real*-token logits
        (B, Vpad), cache with per-row ``pos``).  One trace serves every
        workload sharing (B, S): the batching layer buckets S to powers of
        two so trace count stays O(log cache_len).  On the ``attn_xla`` path
        each row is bitwise what ``prefill`` returns for that prompt alone —
        masked pad columns add exact zeros (regression-tested).  Rows with
        ``length == 0`` are dummies (pack remainder): their logits are
        garbage by contract and their KV/pos stay zero."""
        lengths = jnp.asarray(lengths, jnp.int32)
        x = self._embed(params, tokens)
        x, _, caches = self._run_full(params, x, want_cache=True)
        if cache_headroom:
            caches = self._pad_caches(caches, cache_headroom)
        caches = self._mask_packed(caches, lengths)
        idx = jnp.clip(lengths - 1, 0, x.shape[1] - 1)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        logits = self._logits(params, x_last)
        caches["pos"] = lengths
        return logits[:, 0], caches

    def prefill_cont(self, params, cache, tokens, lengths):
        """Continuation prefill: extend per-row seeded caches by whole
        right-padded suffixes in one call.  ``cache`` is a batched cache
        whose ``pos`` (B,) marks each row's seeded length (KV for positions
        < pos already written, zeros past it); ``tokens`` (B, T) are the
        suffixes, ``lengths`` (B,) their true lengths.  Replaces the
        one-``decode_step``-per-suffix-token resume loop — and unlike that
        loop it stays *bitwise-equal* to the from-scratch ``prefill`` of the
        full prompt (the decode path's two-part online softmax only agrees
        to cache-dtype resolution; this path replays ``attn_xla``'s exact op
        order over the position-ordered cache).  Rows with ``length == 0``
        pass through untouched."""
        cfg = self.cfg
        start = jnp.asarray(cache["pos"], jnp.int32)
        lengths = jnp.asarray(lengths, jnp.int32)
        x = self._embed(params, tokens, pos_offset=start)
        rope_cs = None
        if cfg.pos == "rope":
            pos = start[:, None] + jnp.arange(tokens.shape[1])[None, :]
            rope_cs = rope_angles(pos, cfg.hd, cfg.rope_theta)

        new_cache = dict(cache)
        for seg in self.segments:
            p = self._seg_params(params, seg)
            if seg.mode == "unroll":
                x, kv = block_cont(
                    p[0], x, cfg, seg.kinds[0], rope_cs, cache[seg.name],
                    start, lengths,
                )
                new_cache[seg.name] = kv
                continue

            seg_log = self._seg_logical(seg)

            def body(xx, xs, _kinds=seg.kinds, _log=seg_log):
                ps, cs = xs
                kvs = []
                for j, kind in enumerate(_kinds):
                    p_j = self._constrain_sliced(ps[j], _log[j])
                    xx, kv = block_cont(p_j, xx, cfg, kind, rope_cs, cs[j], start, lengths)
                    kvs.append(kv)
                return xx, tuple(kvs)

            x, ys = jax.lax.scan(body, x, (p, cache[seg.name]))
            new_cache[seg.name] = ys

        idx = jnp.clip(lengths - 1, 0, x.shape[1] - 1)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        logits = self._logits(params, x_last)
        new_cache["pos"] = start + lengths
        return logits[:, 0], new_cache

    def _merge_kv(self, old, new, pos):
        """Write the (…, B, 1, kv, hd) new-token slices into the cache at
        ``pos`` (ring slot for SWA archs), once per step.

        Implemented as a masked select over the (sharded) cache-seq axis
        rather than dynamic_update_slice: a dynamic-index DUS on a
        model-sharded dim makes GSPMD all-gather the whole cache to update it
        (measured +0.42 s collective on granite decode), while iota==slot
        select stays shard-local (each shard rewrites only its slice)."""
        s_max = old.shape[-3]
        slot = jnp.mod(pos, s_max) if self.cfg.window > 0 else jnp.asarray(pos)
        seq_iota = jnp.arange(s_max)
        if slot.ndim == 0:
            mask = seq_iota == slot                          # (S,)
            mask = mask[:, None, None]                       # (S, 1, 1)
        else:
            mask = seq_iota[None, :] == slot[:, None]        # (B, S)
            mask = mask[..., None, None]                     # (B, S, 1, 1)
            if old.ndim == 5:
                mask = mask[None]                            # (1, B, S, 1, 1)
        return jnp.where(mask, new.astype(old.dtype), old)

    def decode_step(self, params, cache, tokens):
        """tokens: (B, 1) -> (logits (B, Vpad), new cache).

        KV caches stay *read-only inside the layer scan* (pure xs); each
        layer emits only its new-token (k, v) slice as ys, and the cache is
        updated with ONE in-place write per segment after the scan.  Earlier
        designs measured on granite decode_32k: cache-in-carry -> XLA copies
        the whole stacked cache per layer (~170 GB/token); cache-as-ys ->
        2x cache residency (+ per-layer masked-select writes).  This one is
        1x residency, 1x read + one slice write (EXPERIMENTS.md §Perf)."""
        cfg = self.cfg
        pos = cache["pos"]
        x = self._embed(params, tokens, pos_offset=pos)
        new_cache = dict(cache)

        for seg in self.segments:
            p = self._seg_params(params, seg)
            if seg.mode == "unroll":
                kind = seg.kinds[0]
                x, out = block_step(p[0], x, cfg, kind, pos, cache[seg.name])
                if kind in ("attn", "moe"):
                    new_cache[seg.name] = tuple(
                        self._merge_kv(c, n, pos) for c, n in zip(cache[seg.name], out)
                    )
                else:
                    new_cache[seg.name] = jax.tree.map(
                        lambda n, c: n.astype(c.dtype), out, cache[seg.name]
                    )
                continue

            def body(xx, xs, _kinds=seg.kinds):
                ps, cs = xs
                outs = []
                for j, kind in enumerate(_kinds):
                    xx, out = block_step(ps[j], xx, cfg, kind, pos, cs[j])
                    if kind not in ("attn", "moe"):
                        out = jax.tree.map(lambda n, c: n.astype(c.dtype), out, cs[j])
                    outs.append(out)
                return xx, tuple(outs)

            x, ys = jax.lax.scan(body, x, (p, cache[seg.name]))
            merged = []
            for j, kind in enumerate(seg.kinds):
                if kind in ("attn", "moe"):
                    merged.append(tuple(
                        self._merge_kv(c, n, pos)
                        for c, n in zip(cache[seg.name][j], ys[j])
                    ))
                else:
                    merged.append(ys[j])
            new_cache[seg.name] = tuple(merged)

        logits = self._logits(params, x)
        new_cache["pos"] = pos + 1
        return logits[:, 0], new_cache

    # -- abstract cache / inputs -----------------------------------------------
    def cache_abstract(self, batch: int, cache_len: int):
        caches = {}
        for seg in self.segments:
            per_pos = tuple(
                jax.tree.map(lambda s: _stack_sds(s, seg.n_rep), block_cache_shape(self.cfg, k, batch, cache_len))
                if seg.mode == "scan"
                else block_cache_shape(self.cfg, k, batch, cache_len)
                for k in seg.kinds
            )
            caches[seg.name] = per_pos if seg.mode == "scan" else per_pos[0]
        caches["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
        return caches

    def cache_logical(self, cache_abstract):
        """Logical axes for every cache leaf (keyed by rank/meaning)."""
        def leaf_axes(path_sds):
            sds = path_sds
            r = len(sds.shape)
            if r >= 4 and sds.shape[-2:] == (self.cfg.n_kv, self.cfg.hd):
                base = ("batch", "kv_seq", "kv_heads", None)
            elif r >= 4:  # ssd state (B,H,P,N)
                base = ("batch", None, None, None)
            elif r == 3:  # conv tails (B,K-1,C)
                base = ("batch", None, "mlp")
            elif r == 2:  # rec h (B,W)
                base = ("batch", "mlp")
            else:
                base = ()
            if r == len(base) + 1:  # stacked
                base = ("layers",) + base
            return base[:r] if len(base) >= r else (None,) * r
        return jax.tree.map(leaf_axes, cache_abstract)
