"""Batched KV/recurrent cache slots for continuous batching.

The engine owns one cache pytree with a slot (decode-batch) axis.  Each slot
is independently claimable; inserting a prefilled (B=1) cache into slot ``i``
is a per-leaf ``dynamic_update_slice`` on that leaf's batch axis.  The batch
axis per leaf comes from the model's ``cache_logical`` tree (the position of
the "batch" logical axis), so attention KV (B,S,kv,hd), stacked KV
(L,B,S,kv,hd), RG-LRU state (B,W), SSD state (B,H,P,N) and encdec cross-KV
are all handled uniformly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


class SlotCache:
    """cache pytree + slot bookkeeping."""

    def __init__(self, cache, axes, n_slots: int):
        self.cache = cache
        self.axes = axes  # per-leaf batch-axis index (or None for pos)
        self.n_slots = n_slots
        self.free = list(range(n_slots))
        self.owner: dict[int, object] = {}

    @classmethod
    def zeros(cls, model, n_slots: int, cache_len: int):
        abs_cache = model.cache_abstract(n_slots, cache_len)
        logical = model.cache_logical(abs_cache)
        axes = jax.tree.map(
            lambda l: l.index("batch") if "batch" in l else None,
            logical,
            is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(i, (str, type(None))) for i in x),
        )
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), abs_cache)
        cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
        axes["pos"] = None
        return cls(cache, axes, n_slots)

    def claim(self, owner) -> int:
        slot = self.free.pop(0)
        self.owner[slot] = owner
        return slot

    def release(self, slot: int):
        self.owner.pop(slot, None)
        # A freed slot must not advertise a stale sequence: zeroing pos makes
        # the slot read as empty the moment it is reclaimed, so nothing can
        # attend over the previous owner's KV between claim and insert.
        self.cache["pos"] = self.cache["pos"].at[slot].set(0)
        self.free.append(slot)
        self.free.sort()

    @property
    def active(self) -> list[int]:
        return sorted(self.owner)

    def insert(self, slot: int, single_cache):
        """Insert a (batch=1) prefill cache into ``slot``."""

        def put(dst, src, ax):
            if ax is None:
                return dst
            idx = [0] * dst.ndim
            idx[ax] = slot
            src = jnp.asarray(src)
            src = _fit(src, dst, ax)
            return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), tuple(idx))

        new = {}
        for key in self.cache:
            if key == "pos":
                continue
            new[key] = jax.tree.map(put, self.cache[key], single_cache[key], self.axes[key])
        new["pos"] = self.cache["pos"].at[slot].set(jnp.asarray(single_cache["pos"], jnp.int32))
        self.cache = new


def _fit(src, dst, batch_ax: int):
    """Pad/trim src so every axis matches dst (batch axis forced to 1)."""
    target = tuple(1 if i == batch_ax else s for i, s in enumerate(dst.shape))
    if src.shape == target:
        return src
    pads = [(0, max(0, t - s)) for s, t in zip(src.shape, target)]
    src = jnp.pad(src, pads)
    return src[tuple(slice(0, t) for t in target)]
