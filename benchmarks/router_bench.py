"""Fleet routing: federated prefix homes vs round-robin / least-loaded,
plus priced KV shipping vs shed-and-re-prefill.

The router tier's claim, one level up from the serving scheduler's: on
shared-prefix Zipf traffic over N decode replicas with finite KV memory,
routing by *federated longest prefix match* (compact per-replica summaries,
CNA-disciplined dispatch, shed-before-stall) beats the standard baselines on

  * prefix locality (fraction of routed prompt tokens already cached on the
    serving replica),
  * re-prefill tokens (the fleet-level remote-miss bill), and
  * p99 admission stall (shorter services -> shorter queues, despite
    concentrating hot prefixes).

``kv_shipping`` sweeps the PR 5 claim on top: letting the router take
``min(re-prefill, ship)`` per dispatch (``repro.router.kvship``, priced by
fabric distance and bandwidth, serialized over one fabric pipe) strictly
reduces total admission-stall cycles (submit -> first token) versus the
shed-before-stall baseline at the default fabric bandwidth, degrades
gracefully as bandwidth shrinks (fewer ships, stall rising back toward the
baseline), and never loses to it — at worst the argmin always picks
re-prefill and the two runs coincide.

Everything runs on the jax-free discrete-event fleet simulator
(``repro.router.sim``), so this module sits in the CI smoke lane next to the
other simulator-backed benches.  The ``oracle_agreement`` section checks the
federation contract: a warm federation (fresh summaries, K >= working set)
routes like an oracle holding one global index, and ``sync_staleness`` shows
syncing *less* often degrades toward least-loaded — never below it, and
never to an error.
"""

from __future__ import annotations

import random

from repro.router import ShipCostModel, shared_prefix_sessions, simulate

from .common import ascii_plot, claim, smoke, table, zipf_draws

ARMS = ("federated", "round_robin", "least_loaded")


def _workload(n, n_prefixes, prefix_len, suffix_len, decode_len, skew, seed):
    rng = random.Random(seed)
    draws = zipf_draws(n, n_prefixes, skew, rng)
    return lambda: shared_prefix_sessions(draws, prefix_len, suffix_len, decode_len)


def fleet_routing(n_sessions=600, n_replicas=4, n_slots=4, cache_budget=500,
                  n_prefixes=12, prefix_len=96, suffix_len=16, decode_len=32,
                  skew=0.7, inter_arrival=16, seed=11):
    n_sessions = smoke(n_sessions, 150)
    mk = _workload(n_sessions, n_prefixes, prefix_len, suffix_len, decode_len, skew, seed)
    rows, res = [], {}
    for arm in ARMS:
        r = simulate(arm, mk(), n_replicas=n_replicas, n_slots=n_slots,
                     cache_budget=cache_budget, inter_arrival=inter_arrival, seed=seed)
        res[arm] = r
        rows.append([arm, r.reuse_fraction, r.reprefill_tokens, r.hit_rate,
                     r.stall_mean, r.stall_p99, r.ticks, r.sheds,
                     r.dispatch_locality, r.fairness_factor])
    table(
        f"fleet routing ({n_sessions} sessions, {n_replicas} replicas x "
        f"{n_slots} slots, {n_prefixes} prefixes, zipf {skew}, "
        f"kv budget {cache_budget} tok)",
        ["arm", "reuse_frac", "reprefill_tok", "hit_rate", "stall_mean",
         "stall_p99", "ticks", "sheds", "dispatch_loc", "fairness"],
        rows,
    )
    fed = res["federated"]
    best_base_reuse = max(res["round_robin"].reuse_fraction,
                          res["least_loaded"].reuse_fraction)
    worst_base_repre = min(res["round_robin"].reprefill_tokens,
                           res["least_loaded"].reprefill_tokens)
    claim("router: federated locality beats both baselines by >= 25%",
          fed.reuse_fraction > 1.25 * best_base_reuse,
          f"federated={fed.reuse_fraction:.3f} best_baseline={best_base_reuse:.3f}")
    claim("router: federated re-prefills < 80% of the best baseline's tokens",
          fed.reprefill_tokens < 0.8 * worst_base_repre,
          f"federated={fed.reprefill_tokens} best_baseline={worst_base_repre}")
    claim("router: federated p99 admission stall beats both baselines",
          fed.stall_p99 < res["round_robin"].stall_p99
          and fed.stall_p99 < res["least_loaded"].stall_p99,
          f"federated={fed.stall_p99:.0f} rr={res['round_robin'].stall_p99:.0f} "
          f"ll={res['least_loaded'].stall_p99:.0f}")
    return res


def oracle_agreement(n_sessions=400, n_replicas=4, n_slots=4, cache_budget=500,
                     n_prefixes=8, prefix_len=64, suffix_len=12, decode_len=24,
                     skew=0.8, seed=23):
    """Warm-federation contract: with fresh summaries and K covering the
    working set, ``FederatedPrefixIndex.route`` answers like an oracle that
    reads every replica's cache directly (one global index).  The exact
    single-holder equality is pinned by tests/test_router.py; here the claim
    runs on a live Zipf trace, where residual disagreement can only come
    from recency tie-breaks among equally-loaded co-holders."""
    from repro.router import FederatedPrefixIndex, SimReplica
    from repro.serving.prefixindex import PrefixIndex

    n_sessions = smoke(n_sessions, 120)
    rng = random.Random(seed)
    draws = zipf_draws(n_sessions, n_prefixes, skew, rng)
    sessions = shared_prefix_sessions(draws, prefix_len, suffix_len, decode_len)
    # warm a fleet's caches with a routed run
    replicas = [SimReplica(r, n_slots, cache_budget=cache_budget)
                for r in range(n_replicas)]
    from repro.router import make_router

    router = make_router("federated", replicas, seed=seed)
    for s in sessions:
        router.advance(router.now + 7)
        router.submit(s)
        # retire immediately so capacity never gates this warmup
        for sess, target, _dist in router.dispatch():
            replicas[target].finish(sess)
            router.complete(sess, ttft=1)
    for _ in range(len(replicas)):
        router.sync()
    # oracle: one global index over every replica's *actual* cache content
    occ = lambda: {r.rid: r.occupancy for r in replicas}
    oracle = PrefixIndex(n_domains=n_replicas, occupancy=occ)
    fed = FederatedPrefixIndex(n_replicas, occupancy=occ)
    for rep in replicas:
        full = rep.summary(top_k=1 << 20, now=router.now)
        fed.apply(full)
        for tokens, _ in reversed(full.prefixes):
            oracle.record(tokens, rep.rid)
    probe_draws = zipf_draws(200, n_prefixes, skew, rng)
    probes = shared_prefix_sessions(probe_draws, prefix_len, suffix_len, decode_len)
    agree = matched_agree = 0
    for p in probes:
        fr, fm = fed.route(p.prompt, now=router.now)
        orr, om = oracle.home(p.prompt)
        agree += fr == orr
        matched_agree += fm == om
    frac = agree / len(probes)
    mfrac = matched_agree / len(probes)
    table("warm federation vs global-index oracle",
          ["probes", "replica_agreement", "matched_len_agreement"],
          [[len(probes), frac, mfrac]])
    claim("router: warm federation routes like the global-index oracle (>=90%)",
          frac >= 0.9, f"agreement={frac:.3f}")
    claim("router: federated matched_len equals the oracle's (>=95%)",
          mfrac >= 0.95, f"agreement={mfrac:.3f}")
    return frac


def sync_staleness(n_sessions=500, seed=31):
    """Locality vs summary-sync period: syncing less often degrades reuse
    smoothly toward the no-federation floor (least-loaded), never below it —
    the graceful-degradation half of the federation contract."""
    n_sessions = smoke(n_sessions, 120)
    mk = _workload(n_sessions, 12, 96, 16, 32, 0.7, seed)
    periods = [8, 32, 128, 512, 2048]
    xs, ys = [], []
    for p in periods:
        r = simulate("federated", mk(), inter_arrival=16, seed=seed,
                     router_kwargs={"sync_every": p})
        xs.append(p)
        ys.append(r.reuse_fraction)
    ll = simulate("least_loaded", mk(), inter_arrival=16, seed=seed)
    table("federated reuse vs sync period (least_loaded floor last)",
          ["sync_every"] + [str(p) for p in periods] + ["least_loaded"],
          [["reuse_frac"] + [f"{y:.3f}" for y in ys] + [f"{ll.reuse_fraction:.3f}"]])
    ascii_plot("reuse_fraction vs sync period", xs,
               {"federated": ys, "ll_floor": [ll.reuse_fraction] * len(xs)})
    claim("router: reuse monotone-ish in sync freshness (freshest >= stalest)",
          ys[0] >= ys[-1] - 1e-9, f"{ys[0]:.3f} vs {ys[-1]:.3f}")
    claim("router: stale federation still >= least-loaded floor",
          min(ys) >= ll.reuse_fraction - 0.02,
          f"min federated={min(ys):.3f} least_loaded={ll.reuse_fraction:.3f}")


def kv_shipping(n_sessions=600, n_replicas=4, n_slots=4, cache_budget=500,
                n_prefixes=8, prefix_len=96, suffix_len=16, decode_len=32,
                inter_arrival=16, seed=11,
                bandwidths=(512, 256, 64, 16, 4)):
    """Ship-vs-reprefill over fabric bandwidths (bytes/tick).  The baseline
    arm is PR 4's federated router itself — shed-before-stall, every shed
    re-prefills — so the sweep isolates exactly what priced shipping adds.
    The default ``ShipCostModel`` bandwidth (64 B/tick at 64 B/token: one
    token per tick per hop, vs ``c_prefill`` 4 ticks/token) is the claimed
    operating point; the low end of the sweep prices shipping *worse* than
    re-prefill so the argmin must drive ships to zero and the curve must
    land back on the baseline."""
    n_sessions = smoke(n_sessions, 150)
    rng = random.Random(seed)
    draws = [rng.randrange(n_prefixes) for _ in range(n_sessions)]
    mk = lambda: shared_prefix_sessions(draws, prefix_len, suffix_len, decode_len)
    kw = dict(n_replicas=n_replicas, n_slots=n_slots, cache_budget=cache_budget,
              inter_arrival=inter_arrival, seed=seed)
    base = simulate("federated", mk(), **kw)
    default_bw = ShipCostModel().fabric_bytes_per_cycle
    rows = [["shed_baseline", "-", base.admission_stall_total,
             base.admission_stall_p99, 0, 0, 0, base.reprefill_tokens]]
    res = {}
    for bw in bandwidths:
        r = simulate("federated", mk(),
                     kv_ship=ShipCostModel(fabric_bytes_per_cycle=bw), **kw)
        res[bw] = r
        rows.append([f"ship@bw={bw}", bw, r.admission_stall_total,
                     r.admission_stall_p99, r.ships, r.shipped_tokens,
                     r.reprefill_avoided, r.reprefill_tokens])
    table(
        f"kv shipping vs re-prefill ({n_sessions} sessions, {n_replicas} "
        f"replicas x {n_slots} slots, {prefix_len}-token prefixes, "
        f"default fabric bw {default_bw} B/tick)",
        ["arm", "bw_B_per_tick", "stall_total", "stall_p99", "ships",
         "shipped_tok", "reprefill_avoided", "reprefill_tok"],
        rows,
    )
    xs = list(bandwidths)
    ascii_plot("admission stall (submit->first token) vs fabric bandwidth",
               xs,
               {"kv_ship": [res[bw].admission_stall_total for bw in xs],
                "shed_baseline": [base.admission_stall_total] * len(xs)})
    if default_bw not in res:
        res[default_bw] = simulate(
            "federated", mk(), kv_ship=ShipCostModel(), **kw)
    dflt = res[default_bw]
    claim("kvship: shipping strictly reduces total admission-stall cycles "
          "at the default fabric bandwidth",
          dflt.admission_stall_total < base.admission_stall_total
          and dflt.ships > 0,
          f"ship={dflt.admission_stall_total} baseline="
          f"{base.admission_stall_total} ships={dflt.ships}")
    stalls = [res[bw].admission_stall_total for bw in sorted(res, reverse=True)]
    claim("kvship: degrades gracefully — stall non-decreasing as bandwidth "
          "shrinks",
          all(a <= b for a, b in zip(stalls, stalls[1:])),
          f"stall by falling bw: {stalls}")
    claim("kvship: never loses to the shed-before-stall baseline at any "
          "bandwidth",
          all(r.admission_stall_total <= base.admission_stall_total
              for r in res.values()),
          f"worst={max(r.admission_stall_total for r in res.values())} "
          f"baseline={base.admission_stall_total}")
    slowest = min(res)
    claim("kvship: a fabric slower than re-prefill ships nothing and "
          "matches the baseline exactly",
          res[slowest].ships == 0
          and res[slowest].admission_stall_total == base.admission_stall_total,
          f"bw={slowest}: ships={res[slowest].ships} "
          f"stall={res[slowest].admission_stall_total} vs {base.admission_stall_total}")
    return res


def tracing_overhead(n_sessions=600, seed=11):
    """The observability tier's contract on this bench's own workload: a
    ``repro.obs.Tracer`` attached to the federated arm changes *nothing*
    (identical ``FleetResult``, to the integer) and costs bounded wall-clock.
    The deeper sweep (conservation law, exporters) lives in obs_bench."""
    import time
    from dataclasses import asdict

    from repro.obs import Tracer

    n_sessions = smoke(n_sessions, 150)
    mk = _workload(n_sessions, 12, 96, 16, 32, 0.7, seed)
    kw = dict(inter_arrival=16, seed=seed, kv_ship=ShipCostModel())
    t0 = time.perf_counter()
    off = simulate("federated", mk(), **kw)
    off_wall = time.perf_counter() - t0
    tr = Tracer()
    t0 = time.perf_counter()
    on = simulate("federated", mk(), tracer=tr, **kw)
    on_wall = time.perf_counter() - t0
    overhead = on_wall / max(off_wall, 1e-9)
    claim("router: tracer attached changes nothing (zero-cost-off)",
          asdict(off) == asdict(on), "")
    claim("router: tracing overhead bounded (<= 2.5x wall)",
          overhead <= 2.5, f"{overhead:.2f}x, {len(tr.spans)} spans")


def run_all():
    fleet_routing()
    oracle_agreement()
    sync_staleness()
    kv_shipping()
    tracing_overhead()
