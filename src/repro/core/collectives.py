"""CNA-inspired collective schedules for multi-pod gradient synchronisation.

The paper's locality principle, lifted to collectives: intra-pod ICI is the
"same socket" (cheap handover), inter-pod DCN is the "remote socket".  The
gradient-sync schedules below keep per-step traffic on ICI and treat the DCN
crossing the way CNA treats the secondary queue — make it rarer (deferred
sync every K steps = ``keep_lock_local`` threshold) and make each crossing
cheaper (int8 compression = a smaller cache line).

All functions are written to run *inside* ``shard_map`` over the production
mesh (axis names ``pod``, ``data``, ``model``), and are exercised on CPU in
tests via subprocess-spawned multi-device meshes.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from .jax_compat import axis_size, shard_map


# ---------------------------------------------------------------------------
# int8 gradient compression (the "smaller remote cache line")
# ---------------------------------------------------------------------------

def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantisation.  Deterministic round-to-nearest
    (tests bound the dequantisation error at scale/2 per element)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30).astype(jnp.float32)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# shard_map-level collective schedules
# ---------------------------------------------------------------------------

def hierarchical_grad_sync(g: jax.Array, *, intra_axes=("data",), pod_axis="pod"):
    """Baseline-but-better schedule: reduce-scatter on ICI, all-reduce the
    (1/N-sized) shards over DCN, all-gather on ICI.  Equivalent to a flat
    psum over (intra+pod) but moves 2x less data over the slow axis than a
    flat ring that includes the pod hop.

    Shapes: ``g`` is a per-device gradient shard; the first dim must divide
    by the intra-axis size.
    """
    g = jax.lax.psum_scatter(g, intra_axes, scatter_dimension=0, tiled=True)
    g = jax.lax.psum(g, pod_axis)
    g = jax.lax.all_gather(g, intra_axes, axis=0, tiled=True)
    return g


def compressed_pod_sum(g: jax.Array, *, pod_axis="pod"):
    """All-reduce over the pod axis with int8 payload on the wire.

    Ring exchange via ``ppermute``: each step sends the int8-quantised
    accumulator to the next pod and dequantises into a float accumulator.
    Exact for n_pods=2 up to one quantisation; for larger rings each hop
    requantises (error grows linearly with hops — documented, bounded in
    tests)."""
    n = axis_size(pod_axis)
    acc = g.astype(jnp.float32)
    send = g.astype(jnp.float32)
    idx = jax.lax.axis_index(pod_axis)
    del idx
    perm = None

    def body(i, carry):
        acc, send = carry
        q, scale = quantize_int8(send)
        q = jax.lax.ppermute(q, pod_axis, perm)
        scale = jax.lax.ppermute(scale, pod_axis, perm)
        recv = dequantize_int8(q, scale)
        return acc + recv, recv

    perm = [(i, (i + 1) % n) for i in range(n)]
    acc, _ = jax.lax.fori_loop(0, n - 1, body, (acc, send))
    return acc.astype(g.dtype)


def cna_grad_sync(
    g: jax.Array,
    *,
    intra_axes=("data",),
    pod_axis="pod",
    compress: bool = False,
):
    """The full CNA schedule: local reduce-scatter, (optionally compressed)
    pod crossing, local all-gather."""
    g = jax.lax.psum_scatter(g, intra_axes, scatter_dimension=0, tiled=True)
    if compress:
        g = compressed_pod_sum(g, pod_axis=pod_axis)
    else:
        g = jax.lax.psum(g, pod_axis)
    g = jax.lax.all_gather(g, intra_axes, axis=0, tiled=True)
    return g


def make_pod_average(mesh: Mesh, specs: Any):
    """Build a jitted ``params -> params`` that averages parameters over the
    pod axis — the deferred-sync "secondary queue flush".  Used by the
    local-updates trainer (optim/podlocal) every K steps; between flushes the
    pods run entirely on ICI, zero DCN traffic (the CNA analogue of keeping
    the lock on-socket between threshold events)."""
    if "pod" not in mesh.axis_names:
        raise ValueError("pod axis required for pod averaging")

    def avg_leaf(x):
        def f(x_shard):
            return jax.lax.pmean(x_shard, "pod")

        return f(x)

    def pod_average(params):
        flat, treedef = jax.tree.flatten(params)
        flat_specs, _ = jax.tree.flatten(specs)
        out = []
        for x, spec in zip(flat, flat_specs):
            fn = shard_map(
                avg_leaf,
                mesh=mesh,
                in_specs=(spec,),
                out_specs=spec,
                check_vma=False,
            )
            out.append(fn(x))
        return jax.tree.unflatten(treedef, out)

    return jax.jit(pod_average)


def wire_bytes_allreduce(nbytes: int, axis_size: int) -> float:
    """Ring all-reduce per-chip wire traffic: 2 * s * (n-1)/n."""
    return 2.0 * nbytes * (axis_size - 1) / axis_size


def wire_bytes_allgather(shard_bytes: int, axis_size: int) -> float:
    return float(shard_bytes) * (axis_size - 1)


def wire_bytes_reducescatter(nbytes: int, axis_size: int) -> float:
    return float(nbytes) * (axis_size - 1) / axis_size
