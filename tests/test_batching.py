"""Bucketed/packed/AOT-warmed prefill (repro.serving.batching).

Three contracts pinned here:

  * **planning** — buckets are powers of two, ``log2(cache_len)`` of them
    for a power-of-two cache, and ``plan_packs`` preserves admission order.
  * **bitwise** — a packed prefill row, and a continuation-prefill resume,
    are bit-for-bit what the per-request ``prefill`` returns for that
    prompt alone (logits, KV over the *whole* slot cache, and pos) —
    including bucket-boundary lengths, ``cache_len - 1``, and packs mixing
    buckets.  This is what lets the engine flip ``batching=True`` without
    changing a single emitted token.
  * **compile count** — a 40-prompt mixed-length workload leaves the trace
    counters exactly where AOT warm-up put them: packed-prefill traces
    <= log2(cache_len), one decode trace, zero per-request prefill traces.
"""

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback
    from _hypothesis_compat import given, settings, st

from repro.configs.base import get_reduced_config
from repro.models.registry import build_model
from repro.serving.batching import (
    PrefillBatcher,
    bucket_for,
    plan_packs,
    prompt_buckets,
)
from repro.serving.engine import DecodeEngine, Request
from repro.serving.kvcache import SlotCache

CACHE_LEN = 32
PACK = 4


@functools.lru_cache(maxsize=1)
def _setup():
    """Module-level (not a fixture: the hypothesis shim's runner takes no
    pytest arguments) — one reduced model + batcher + reference slot cache
    shared by every property test so jit caches amortise."""
    cfg = get_reduced_config("granite_3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batcher = PrefillBatcher(model, cache_len=CACHE_LEN, pack_width=PACK)
    slots = SlotCache.zeros(model, PACK, CACHE_LEN)
    ref_prefill = jax.jit(model.prefill)
    return cfg, model, params, batcher, slots, ref_prefill


def _prompts(lengths, seed):
    cfg = _setup()[0]
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, int(l)).astype(np.int32) for l in lengths]


def _single(prompt):
    """Per-request reference: prefill one prompt, refit to the slot shape."""
    _, model, params, _, slots, ref_prefill = _setup()
    logits, cache = ref_prefill(params, {"tokens": jnp.asarray(prompt)[None]})
    return logits[0], slots.fit_single(cache)


def _row(logits, cache, i):
    """Row ``i`` of a packed result in the same refitted slot shape."""
    _, _, _, batcher, slots, _ = _setup()
    return logits[i], slots.fit_single(batcher.extract_row(cache, i))


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        jnp.shape(x) == jnp.shape(y) and bool((jnp.asarray(x) == jnp.asarray(y)).all())
        for x, y in zip(la, lb)
    )


# ---------------------------------------------------------------------------
# planning core (pure python)
# ---------------------------------------------------------------------------

def test_buckets_power_of_two_budget():
    assert prompt_buckets(32) == [2, 4, 8, 16, 32]
    assert len(prompt_buckets(32)) == int(math.log2(32))
    assert prompt_buckets(2) == [2]
    with pytest.raises(ValueError):
        prompt_buckets(1)


@settings(max_examples=25, deadline=None)
@given(cache_len=st.integers(min_value=2, max_value=4096))
def test_buckets_cover_and_stay_logarithmic(cache_len):
    buckets = prompt_buckets(cache_len)
    assert buckets == sorted(set(buckets))
    assert all(b & (b - 1) == 0 for b in buckets)          # powers of two
    assert buckets[-1] >= cache_len - 1                     # longest admissible prompt fits
    assert len(buckets) <= math.log2(cache_len) + 1
    for l in (1, 2, cache_len - 1):
        b = bucket_for(l, buckets)
        assert l <= b and (b == buckets[0] or b // 2 < l)   # smallest covering bucket


@settings(max_examples=25, deadline=None)
@given(
    lengths=st.lists(st.integers(min_value=1, max_value=31), min_size=1, max_size=17),
    pack_width=st.integers(min_value=1, max_value=6),
)
def test_plan_packs_preserves_admission_order(lengths, pack_width):
    buckets = prompt_buckets(32)
    packs = plan_packs(lengths, pack_width=pack_width, buckets=buckets)
    flat = [i for _, rows in packs for i in rows]
    assert flat == list(range(len(lengths)))                # order is the fairness contract
    for bucket, rows in packs:
        assert len(rows) <= pack_width
        assert bucket == bucket_for(max(lengths[i] for i in rows), buckets)


# ---------------------------------------------------------------------------
# bitwise: packed prefill vs per-request reference
# ---------------------------------------------------------------------------

def test_packed_rows_bitwise_at_boundaries():
    """Bucket-boundary lengths, the longest admissible prompt, and a
    mixed-bucket pack — the explicit worst cases, always run."""
    _, _, params, batcher, _, _ = _setup()
    for lengths in ([2, 4, 8, 16], [CACHE_LEN - 1], [3, 16, 2, 31], [1, 5]):
        prompts = _prompts(lengths, seed=sum(lengths))
        logits, cache = batcher.prefill(params, prompts)
        for i, p in enumerate(prompts):
            ref_logits, ref_cache = _single(p)
            got_logits, got_cache = _row(logits, cache, i)
            assert bool((got_logits == ref_logits).all()), (lengths, i)
            assert _tree_equal(got_cache, ref_cache), (lengths, i)


@settings(max_examples=6, deadline=None)
@given(
    lengths=st.lists(st.integers(min_value=1, max_value=CACHE_LEN - 1), min_size=1, max_size=PACK),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_packed_rows_bitwise_property(lengths, seed):
    _, _, params, batcher, _, _ = _setup()
    prompts = _prompts(lengths, seed)
    logits, cache = batcher.prefill(params, prompts)
    for i, p in enumerate(prompts):
        ref_logits, ref_cache = _single(p)
        got_logits, got_cache = _row(logits, cache, i)
        assert bool((got_logits == ref_logits).all())
        assert _tree_equal(got_cache, ref_cache)


def test_dummy_rows_stay_empty():
    """Pack remainder rows (length 0) must read as vacant slots: pos 0 and
    all-zero KV, so inserting one over a free lane is indistinguishable
    from never touching it."""
    _, _, params, batcher, slots, _ = _setup()
    logits, cache = batcher.prefill(params, _prompts([5], seed=9))
    for i in range(1, PACK):
        row = slots.fit_single(batcher.extract_row(cache, i))
        assert int(row["pos"]) == 0
        assert all(
            bool((jnp.asarray(l) == 0).all())
            for k in row if k != "pos"
            for l in jax.tree.leaves(row[k])
        )


# ---------------------------------------------------------------------------
# bitwise: continuation prefill vs from-scratch reference
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(
    spec=st.lists(
        st.tuples(
            st.integers(min_value=2, max_value=CACHE_LEN - 1),  # full length
            st.integers(min_value=1, max_value=CACHE_LEN - 2),  # seeded prefix
        ),
        min_size=1,
        max_size=PACK,
    ),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_continuation_bitwise_property(spec, seed):
    """Seed each row with a per-request prefill of a proper prefix, extend
    by the suffix via ``continue_rows`` — bitwise the from-scratch prefill
    of the full prompt.  This is the contract that lets prefix-KV resumes
    ride the packed path without perturbing a single token."""
    _, _, params, batcher, _, _ = _setup()
    spec = [(l, min(m, l - 1)) for l, m in spec]             # 1 <= matched < len
    prompts = _prompts([l for l, _ in spec], seed)
    rows = [_single(p[:m])[1] for p, (_, m) in zip(prompts, spec)]
    suffixes = [p[m:] for p, (_, m) in zip(prompts, spec)]
    logits, cache = batcher.continue_rows(params, rows, suffixes)
    for i, p in enumerate(prompts):
        ref_logits, ref_cache = _single(p)
        got_logits, got_cache = _row(logits, cache, i)
        assert bool((got_logits == ref_logits).all()), spec[i]
        assert _tree_equal(got_cache, ref_cache), spec[i]


# ---------------------------------------------------------------------------
# compile-count regression (the trace-budget acceptance criterion)
# ---------------------------------------------------------------------------

def test_compile_count_bounded_on_mixed_workload():
    """40 prompts spanning every length the cache admits: packed-prefill
    traces stay <= log2(cache_len) (all paid at AOT warm-up, none in the
    serving loop), decode traces exactly 1, per-request prefill never runs."""
    _, model, params, _, _, _ = _setup()
    eng = DecodeEngine(model, params, n_slots=4, cache_len=CACHE_LEN, batching=True)
    warm = dict(eng.compile_counts)
    assert warm["packed_prefill"] <= math.log2(CACHE_LEN)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, 64, 2 + i % (CACHE_LEN - 2)).astype(np.int32),
                max_new=2, domain=i % 2)
        for i in range(40)
    ]
    eng.run(reqs)
    assert all(r.done for r in reqs)
    cc = eng.compile_counts
    assert cc["packed_prefill"] == warm["packed_prefill"]    # zero serving-loop traces
    assert cc["packed_prefill"] <= math.log2(CACHE_LEN)
    assert cc["decode"] == 1
    assert cc["prefill"] == 0


# ---------------------------------------------------------------------------
# engine equivalence: batching=True changes schedule shape, never tokens
# ---------------------------------------------------------------------------

def _mixed_requests(cfg, seed, n=8, max_new=3):
    rng = np.random.default_rng(seed)
    lens = rng.integers(2, CACHE_LEN - 1, n)
    rng2 = np.random.default_rng(seed + 1)
    return [
        Request(rid=i, prompt=rng2.integers(0, cfg.vocab, int(l)).astype(np.int32),
                max_new=max_new, domain=i % 2)
        for i, l in enumerate(lens)
    ]


def test_batched_engine_matches_legacy():
    cfg, model, params, _, _, _ = _setup()
    a = _mixed_requests(cfg, seed=3)
    b = _mixed_requests(cfg, seed=3)
    DecodeEngine(model, params, n_slots=4, cache_len=CACHE_LEN).run(a)
    eng = DecodeEngine(model, params, n_slots=4, cache_len=CACHE_LEN, batching=True)
    eng.run(b)
    assert [r.out for r in a] == [r.out for r in b]
    assert eng.compile_counts["prefill"] == 0


def test_batched_prefix_kv_matches_from_scratch():
    """Shared-prefix traffic over a live PrefixKVStore: full hits, partial
    hits (continuation pack) and boundary plants all active — outputs stay
    bitwise what a *from-scratch* engine (no store) emits.  Stronger than
    the per-request store path offers: its ``decode_step`` suffix replay
    agrees with from-scratch prefill only to cache-dtype resolution (see
    ``_greedy_reference_split`` in test_serving.py), so greedy argmax can
    legitimately flip there; ``prefill_cont`` replays the exact prefill op
    order and cannot."""
    cfg, model, params, _, _, _ = _setup()

    def mk(seed):
        rng = np.random.default_rng(seed)
        sys_p = np.random.default_rng(42).integers(0, cfg.vocab, 10).astype(np.int32)
        reqs = []
        for i in range(5):  # divergent suffixes off a shared system prompt
            sfx = rng.integers(0, cfg.vocab, 3 + i).astype(np.int32)
            reqs.append(Request(rid=i, prompt=np.concatenate([sys_p, sfx]),
                                max_new=3, domain=i % 2))
        for i in range(3):  # exact repeats -> full store hits
            reqs.append(Request(rid=5 + i, prompt=reqs[i].prompt.copy(),
                                max_new=3, domain=i % 2))
        for i in range(2):  # follow-ups extending prompt+output -> partial hits
            ext = np.concatenate([reqs[i].prompt,
                                  rng.integers(0, cfg.vocab, 3).astype(np.int32)])
            reqs.append(Request(rid=8 + i, prompt=ext, max_new=3, domain=i % 2))
        return reqs

    a, b, c = mk(5), mk(5), mk(5)
    scratch = DecodeEngine(model, params, n_slots=4, cache_len=2 * CACHE_LEN)
    scratch.run(a)
    legacy = DecodeEngine(model, params, n_slots=4, cache_len=2 * CACHE_LEN, prefix_kv=True)
    legacy.run(b)
    bat = DecodeEngine(model, params, n_slots=4, cache_len=2 * CACHE_LEN,
                       prefix_kv=True, batching=True)
    bat.run(c)
    assert [r.out for r in a] == [r.out for r in c]
    assert bat.reused_positions > 0                          # the store actually fired
    assert bat.compile_counts["cont_prefill"] <= math.log2(2 * CACHE_LEN)
    # reuse accounting is conserved against the per-request store path: the
    # same total positions flow through, though the computed/resumed split
    # may differ (a pack cannot resume from deposits made inside itself;
    # the serial path can)
    assert (legacy.prefill_positions + legacy.reused_positions
            == bat.prefill_positions + bat.reused_positions)


# ---------------------------------------------------------------------------
# the gate: archs where right-padding is not bitwise-invisible refuse
# ---------------------------------------------------------------------------

def test_gate_refuses_non_dense_arch():
    cfg = get_reduced_config("mamba2_130m")
    model = build_model(cfg)
    assert not model.supports_packed_prefill(CACHE_LEN)
    with pytest.raises(ValueError, match="batching off"):
        PrefillBatcher(model, cache_len=CACHE_LEN, pack_width=2)


def test_gate_checks_attn_dispatch_per_bucket():
    """Chunked attention streams above ``attn_chunk`` — a bucket past it
    would diverge from the per-request reference's dispatch, so the gate
    must refuse exactly then."""
    cfg = dataclasses.replace(get_reduced_config("granite_3_8b"), attn_chunk=8)
    model = build_model(cfg)
    assert model.supports_packed_prefill(8)
    assert not model.supports_packed_prefill(32)
    cfg_xla = dataclasses.replace(cfg, attn_impl="xla")
    assert build_model(cfg_xla).supports_packed_prefill(32)
