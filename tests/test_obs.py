"""repro.obs: causal spans, the unified registry, and the conservation law.

Three layers of guarantees:

  * unit — ``BoundedHistogram`` is list-compatible and exact under its cap
    (and stays bounded, with exact count/sum/min/max, beyond it);
    ``MetricsRegistry`` renders counters/gauges/views/histograms uniformly;
    ``Tracer`` nests spans causally under a deterministic clock and
    round-trips through the JSONL exporter;
  * property (hypothesis via tests/_hypothesis_compat.py) — on randomized
    fleet runs every opened span closes, every parent opens no later than its
    children, and the four ``phase.*`` spans sum *exactly* to that session's
    submit -> first-token stall (the attribution conservation law), per
    session and in aggregate;
  * zero-cost-off — a fleet run with a tracer attached yields a numerically
    identical ``FleetResult`` to the untraced run (the tracer takes no
    branch and draws no randomness the bare run doesn't).
"""

import json
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.obs import (
    NULL_TRACER,
    BoundedHistogram,
    MetricsRegistry,
    Tracer,
    flame,
    render_prometheus,
    to_jsonl,
    trace_key,
)
from repro.obs.export import from_jsonl
from repro.router import ShipCostModel, shared_prefix_sessions, simulate


# -- BoundedHistogram ---------------------------------------------------------


def _nearest_rank(samples, q):
    s = sorted(samples)
    return s[min(len(s) - 1, int(q / 100.0 * len(s)))]


def test_histogram_is_list_compatible():
    h = BoundedHistogram(cap=16)
    h.extend([5, 1, 3])
    h.append(2)
    assert len(h) == 4 and h[0] == 5 and list(h) == [5, 1, 3, 2]
    assert sorted(h) == [1, 2, 3, 5]
    assert bool(h) and not bool(BoundedHistogram())
    import numpy as np

    assert np.array(h).sum() == 11


def test_histogram_exact_under_cap():
    rng = random.Random(3)
    h = BoundedHistogram(cap=64)
    vals = [rng.randrange(1000) for _ in range(64)]
    h.extend(vals)
    assert h.n == 64 and h.total == sum(vals)
    for q in (0, 25, 50, 90, 99, 100):
        assert h.percentile(q) == _nearest_rank(vals, q)
    s = h.summary()
    assert s["count"] == 64 and s["min"] == min(vals) and s["max"] == max(vals)


def test_histogram_bounded_over_cap():
    h = BoundedHistogram(cap=8, seed=1)
    vals = list(range(1000))
    h.extend(vals)
    assert len(h) == 8          # retained stays bounded
    assert h.n == 1000          # true count exact
    assert h.total == sum(vals) and h.vmin == 0 and h.vmax == 999
    assert all(v in vals for v in h)
    assert h.summary()["retained"] == 8


def test_histogram_reservoir_is_deterministic_and_private():
    """Same seed -> same retained set, and filling one histogram never
    perturbs another (no shared RNG stream)."""
    a, b = BoundedHistogram(cap=4, seed=9), BoundedHistogram(cap=4, seed=9)
    for v in range(100):
        a.append(v)
        b.append(v)
    assert list(a) == list(b)
    state = random.getstate()
    BoundedHistogram(cap=2, seed=5).extend(range(50))
    assert random.getstate() == state  # module-level RNG untouched


@settings(max_examples=25)
@given(vals=st.lists(st.integers(min_value=0, max_value=10_000), min_size=1, max_size=200),
       q=st.integers(min_value=0, max_value=100))
def test_histogram_quantiles_exact_under_cap_property(vals, q):
    h = BoundedHistogram(cap=200)
    h.extend(vals)
    assert h.percentile(q) == _nearest_rank(vals, q)
    assert h.n == len(vals) and h.total == sum(vals)


# -- MetricsRegistry ----------------------------------------------------------


def test_registry_counter_gauge_histogram_roundtrip():
    reg = MetricsRegistry()
    reg.counter("grants").inc(3)
    reg.gauge("depth").set(7)
    reg.histogram("waits", cap=4).extend([1, 2, 3])
    snap = reg.collect()
    assert snap["grants"] == 3 and snap["depth"] == 7
    assert snap["waits"]["count"] == 3 and snap["waits"]["sum"] == 6
    assert "grants" in reg and reg["depth"].value == 7
    prom = reg.render_prometheus()
    assert "# TYPE grants counter" in prom and "grants 3" in prom
    assert 'waits{quantile="0.5"} 2' in prom and "waits_count 3" in prom


def test_registry_adopts_legacy_surface_as_live_views():
    from repro.serving.scheduler import CNAScheduler

    s = CNAScheduler(fairness_threshold=0xF)
    for i in range(6):
        s.submit(i, i % 2)
    reg = MetricsRegistry()
    s.metrics.register_into(reg)
    before = reg.collect()["sched_admitted"]
    while len(s):
        s.next_request()
    snap = reg.collect()
    assert before == 0 and snap["sched_admitted"] == 6  # view, not copy
    assert snap["sched_waits"]["count"] == 6
    assert 0.0 <= snap["sched_locality"] <= 1.0
    assert isinstance(snap["sched_per_domain"], dict)
    prom = reg.render_prometheus()
    assert 'sched_per_domain{key="0"}' in prom
    assert "sched_fairness_factor" in prom


def test_registry_sanitizes_metric_names():
    reg = MetricsRegistry()
    reg.counter("weird name/with:chars").inc()
    assert "weird_name_with:chars 1" in reg.render_prometheus()


# -- Tracer -------------------------------------------------------------------


def test_trace_key_prefers_rid_then_sid():
    class R:
        rid = 4

    class S:
        sid = "s9"

    assert trace_key(R()) == 4 and trace_key(S()) == "s9"
    assert trace_key(11) == 11 and trace_key("r3") == "r3"
    assert trace_key(3.5) == "3.5"  # non-id payloads stringify


def test_tracer_auto_parents_within_a_trace():
    tr = Tracer()
    root = tr.begin("session", 1, 0)
    child = tr.begin("request", 1, 2)
    other = tr.begin("session", 2, 1)  # different trace: no parent
    leaf = tr.span("queue_wait", 1, 2, 5)
    assert child.parent_id == root.span_id
    assert leaf.parent_id == child.span_id
    assert other.parent_id is None
    tr.end(child, 7)
    tr.end(root, 9)
    late = tr.span("attribution", 1, 0, 9)
    assert late.parent_id is None  # everything closed: no implicit parent
    assert [s.name for s in tr.for_trace(1)] == [
        "session", "request", "queue_wait", "attribution"
    ]
    assert tr.check() == [other]  # trace 2 still open


def test_tracer_end_clamps_and_events_attach():
    tr = Tracer()
    sp = tr.begin("decode", "r", 10)
    tr.event(sp, "token", 11, pos=0)
    tr.end(sp, 4)  # clock went backwards: clamp to start, never negative
    assert sp.end == 10 and sp.duration == 0
    assert sp.events == [("token", 11, {"pos": 0})]
    tr.end(sp, 99)  # double-end is a no-op
    assert sp.end == 10


def test_tracer_phase_cycles_sums_phase_spans():
    tr = Tracer()
    tr.span("phase.queue_wait", "s", 0, 4, cycles=4)
    tr.span("phase.prefill", "s", 4, 10, cycles=6)
    tr.span("phase.prefill", "s", 10, 11, cycles=1)
    tr.span("decode", "s", 11, 20)  # not a phase span
    assert tr.phase_cycles("s") == {"queue_wait": 4, "prefill": 7}


def test_null_tracer_is_falsy_and_inert():
    assert not NULL_TRACER and len(NULL_TRACER) == 0
    assert NULL_TRACER.begin("x", 1, 0) is None
    assert NULL_TRACER.span("x", 1, 0, 1) is None
    NULL_TRACER.end(None, 5)
    assert NULL_TRACER.check() == [] and NULL_TRACER.phase_cycles(1) == {}
    assert list(NULL_TRACER) == []


def test_jsonl_roundtrip_and_flame(tmp_path):
    tr = Tracer()
    root = tr.begin("session", 7, 0)
    tr.span("queue_wait", 7, 0, 3, kind="scan")
    tr.end(root, 10)
    path = tmp_path / "trace.jsonl"
    assert to_jsonl(tr, str(path)) == 2
    rows = from_jsonl(str(path))
    assert [r["name"] for r in rows] == ["session", "queue_wait"]
    assert rows[1]["parent_id"] == rows[0]["span_id"]
    assert json.loads(path.read_text().splitlines()[0])["trace"] == 7
    art = flame(tr, 7)
    assert "session" in art and "queue_wait" in art and "[scan]" in art


# -- fleet properties: well-formedness + the conservation law -----------------


def _run(arm, n_sessions, skew_seed, *, ship, tracer=None, registry=None):
    rng = random.Random(skew_seed)
    draws = [rng.randrange(10) for _ in range(n_sessions)]
    sessions = shared_prefix_sessions(draws, 64, 12, 16)
    return simulate(
        arm, sessions, n_replicas=3, inter_arrival=9, seed=skew_seed,
        kv_ship=ShipCostModel() if ship else None,
        tracer=tracer, registry=registry,
    )


@settings(max_examples=8)
@given(arm=st.sampled_from(["federated", "round_robin", "least_loaded"]),
       n_sessions=st.integers(min_value=5, max_value=60),
       skew_seed=st.integers(min_value=0, max_value=2**16))
def test_fleet_spans_well_formed_and_conservative(arm, n_sessions, skew_seed):
    tr = Tracer()
    r = _run(arm, n_sessions, skew_seed, ship=arm == "federated", tracer=tr)
    assert not tr.check()  # every opened span closed
    by_id = {s.span_id: s for s in tr.spans}
    for s in tr.spans:
        assert s.end >= s.start
        if s.parent_id is not None:
            p = by_id[s.parent_id]
            assert p.trace == s.trace
            assert p.start <= s.start  # parents open before children
    # conservation: per session and in aggregate, phases sum exactly to the
    # admission stall (submit -> first token)
    total = 0
    for trace in tr.traces():
        spans = {s.name: s for s in tr.for_trace(trace)}
        phases = tr.phase_cycles(trace)
        assert set(phases) == {"queue_wait", "dispatch", "ship_wait", "prefill"}
        stall = spans["phase.prefill"].end - spans["session"].start
        assert sum(phases.values()) == stall
        total += stall
    assert total == r.admission_stall_total
    assert sum(r.phase_cycles.values()) == r.admission_stall_total
    assert len(tr.traces()) == n_sessions == r.n_sessions


@pytest.mark.parametrize("arm", ["federated", "round_robin", "least_loaded"])
def test_tracer_off_vs_on_fleet_results_identical(arm):
    from dataclasses import asdict

    off = _run(arm, 40, 5, ship=arm == "federated")
    reg = MetricsRegistry()
    on = _run(arm, 40, 5, ship=arm == "federated", tracer=Tracer(), registry=reg)
    assert asdict(off) == asdict(on)
    # and the registry's adopted views agree with the result the run reported
    snap = reg.collect()
    assert snap[f"{arm}_router_sheds"] == on.sheds
    if arm == "federated":  # only the CNA-disciplined arm has a scheduler
        assert snap[f"{arm}_sched_waits"]["count"] >= 0


def test_fleet_registry_histograms_stay_bounded():
    reg = MetricsRegistry()
    _run("federated", 30, 2, ship=True, registry=reg)
    stalls = reg[f"federated_router_stalls"]
    assert isinstance(stalls, BoundedHistogram)
    assert len(stalls) <= stalls.cap and stalls.n == 30
    prom = render_prometheus(reg)
    assert "federated_router_stalls_count 30" in prom


# -- the bounded stat surfaces (satellite: waits/stalls no longer unbounded) --


def test_scheduler_waits_is_bounded_histogram():
    from repro.serving.scheduler import FIFOScheduler

    s = FIFOScheduler()
    s.metrics.waits = BoundedHistogram(cap=8)  # tiny cap to exercise bound
    for i in range(100):
        s.submit(i, 0)
    while len(s):
        s.next_request()
        s.tick()
    assert s.metrics.admitted == 100
    assert s.metrics.waits.n == 100 and len(s.metrics.waits) <= 8
    assert isinstance(FIFOScheduler().metrics.waits, BoundedHistogram)


def test_router_stats_stalls_is_bounded_histogram():
    from repro.router import RouterStats

    assert isinstance(RouterStats().stalls, BoundedHistogram)
