"""Continuous-batching decode engine.

One jit'd ``decode_step`` advances all active slots in one fused step
(per-slot positions); prefill runs per admitted request and its cache is
spliced into the claimed slot.  The admission order between waiting requests
is delegated to the scheduler (CNA or FIFO) — the engine reports its current
locality domain so the scheduler can apply the paper's same-socket
preference.

Greedy sampling (argmax) keeps the engine deterministic for tests; the
sampling hook is injectable.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .batching import CountingJit, PrefillBatcher
from .kvcache import SlotCache
from .prefixindex import PrefixIndex
from .prefixkv import PrefixKVStore
from .scheduler import CNAScheduler


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int
    # pod-locality domain of the prefix/KV home.  ``None`` asks the engine to
    # derive it from the prefix index at submit (production traffic has no
    # oracle); an explicit int remains an override.
    domain: int | None = 0
    out: list = field(default_factory=list)
    submit_t: int = 0
    admit_t: int = -1             # scheduler tick the request won a slot
    finish_t: int = -1
    # prompt tokens whose KV is already cached in the home domain (set by
    # prefix-index derivation); discounts the migration stall at admission —
    # only the uncached suffix of the KV moves.
    matched_len: int = 0

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class DecodeEngine:
    """Continuous-batching decode engine over a CNA-disciplined scheduler.

    Units, because three different quantities flow through here:

      * **ticks** — ``sim_time`` and every ``*_cost`` knob
        (``domain_switch_cost``, ``slot_migration_cost``) are simulated
        scheduler ticks; one ``step()`` is one tick plus any admission
        stalls charged that tick.  Wall-clock never enters the engine.
      * **tokens** — prompt/output lengths (``Request.prompt``,
        ``matched_len``) count tokens.
      * **positions** — ``prefill_positions`` / ``reused_positions`` count
        KV cache *positions* computed or resumed; for a given prompt these
        equal its token count, but the counters aggregate across requests
        and are the unit reuse claims are pinned in.

    Optional subsystems (all default off): ``placement`` makes the slot
    cache NUMA-homed over the scheduler's topology; ``prefix_index``
    derives ``domain=None`` homes from cached prefixes; ``prefix_kv``
    resumes prefill from stored caches, deposits retiring conversations
    back, and gives the router something to ship (``export_kv`` /
    ``import_kv``); ``batching`` routes admission through the bucketed /
    packed / AOT-warmed prefill layer (``repro.serving.batching``) — at
    most one packed prefill call per ``step()``, interleaved with running
    decode, with jit trace count bounded by the bucket count instead of
    growing with distinct prompt lengths."""

    def __init__(
        self,
        model,
        params,
        *,
        n_slots: int = 4,
        cache_len: int = 256,
        scheduler=None,
        eos: int | None = None,
        domain_switch_cost: int = 4,
        topology=None,
        placement=None,
        slot_migration_cost: int = 2,
        prefix_index=None,
        prefix_kv=None,
        batching: bool = False,
        pack_width: int | None = None,
        tracer=None,  # repro.obs.Tracer | None (None => zero-cost off)
        paging: bool = False,
        page_size: int = 16,
    ):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        # NB: schedulers define __len__, so `scheduler or default` would
        # silently replace an *empty* scheduler — compare to None explicitly.
        if scheduler is not None and topology is not None:
            raise ValueError(
                "pass topology via the scheduler (e.g. CNAScheduler(topology=...)); "
                "an explicit scheduler's topology would silently win otherwise"
            )
        self.scheduler = scheduler if scheduler is not None else CNAScheduler(topology=topology)
        # one tracer for engine + scheduler: an engine-level tracer is shared
        # down so queue_wait spans land in the same causal tree; with none
        # anywhere, both hold the falsy NULL_TRACER and every site below is
        # a single truthiness check (the zero-cost-off contract)
        if tracer is not None:
            self.scheduler.tracer = tracer
        self.tracer = self.scheduler.tracer
        self.eos = eos
        # placement: a repro.placement policy (name or instance) making the
        # slot cache NUMA-homed over the scheduler's topology — each request's
        # slot lands in (or nearest to) its KV/prefix home domain.
        if placement is not None and self.scheduler.topology is None:
            raise ValueError("placement needs a topology (e.g. CNAScheduler(topology=...))")
        # paging: the refcounted page table under the storage tier
        # (repro.serving.paging).  Gated exactly like packed prefill — paging
        # shares pages between sequences by token identity, which is
        # byte-identity only where prefill is bitwise batch-invariant (plain
        # dense attention); recurrent/SSM/sliding-window/VLM families have no
        # pageable kv_seq axis and keep the contiguous path.
        self._paged = bool(paging)
        if paging:
            gate = getattr(model, "supports_packed_prefill", None)
            if gate is None or not gate(cache_len):
                raise ValueError(
                    "paging=True needs a plain dense-attention stack (the "
                    "same gate as packed prefill): this model family has no "
                    "pageable kv_seq axis or is not bitwise batch-invariant "
                    "— run it with the contiguous path (paging=False)"
                )
            if not (prefix_kv is None or prefix_kv is True):
                raise ValueError(
                    "paging builds its own page-backed prefix store over the "
                    "slot cache's page table; pass prefix_kv=True or omit it"
                )
            from .paging import PagedPrefixKVStore
            from .paging_jax import PagedSlotCache

            store_capacity = 16  # the PrefixKVStore default; sizes the table
            self.slots = PagedSlotCache.zeros(
                model, n_slots, cache_len, page_size=page_size,
                store_slack=store_capacity,
                topology=self.scheduler.topology if placement is not None else None,
                policy=placement if placement is not None else "nearest_spill",
                page_topology=self.scheduler.topology if placement is not None else None,
            )
            prefix_kv = PagedPrefixKVStore(
                store_capacity, table=self.slots.table, pool=self.slots.pool,
            )
        else:
            self.slots = SlotCache.zeros(
                model, n_slots, cache_len,
                topology=self.scheduler.topology if placement is not None else None,
                policy=placement if placement is not None else "nearest_spill",
            )
        if self.slots.telemetry is not None:
            self.scheduler.metrics.placement = self.slots.telemetry
        # prefix_index: a repro.serving.PrefixIndex (or True for a default
        # one) deriving req.domain from the longest cached prefix when a
        # caller submits domain=None.  It learns from actual placements, so
        # it needs the placement-aware slot cache to feed it.
        if prefix_index is True:
            prefix_index = PrefixIndex()
        if prefix_index is not None and placement is None:
            raise ValueError(
                "a prefix index needs placement=... — derived homes are "
                "learned from where the slot cache actually puts each prefix"
            )
        self.prefix_index = prefix_index
        if prefix_index is not None:
            n_domains = self.scheduler.topology.n_domains
            if prefix_index.n_domains is None:
                prefix_index.n_domains = n_domains
            elif prefix_index.n_domains != n_domains:
                raise ValueError(
                    f"prefix index spans {prefix_index.n_domains} domains but "
                    f"the topology has {n_domains}"
                )
            # bind occupancy to THIS engine's live telemetry unconditionally:
            # a warm index handed over from a retired engine must not keep
            # reading (or keeping alive, via the closure) the old engine's
            # frozen counters
            telemetry = self.slots.telemetry
            prefix_index.occupancy = lambda: telemetry.per_domain_occupancy
        # prefix_kv: a repro.serving.PrefixKVStore (or True for a default one)
        # holding prefilled caches by prompt prefix, so a prompt extending a
        # stored prefix resumes decode from it instead of re-prefilling —
        # prefill_positions counts positions actually computed.
        if prefix_kv is True:
            prefix_kv = PrefixKVStore()
        self.prefix_kv = prefix_kv
        # positions actually computed vs resumed from stored caches (counts
        # of token positions, the unit reuse claims are pinned in); and
        # retirement-time deposits made back into the store
        self.prefill_positions = 0
        self.reused_positions = 0
        self.kv_deposits = 0
        # controller-coupled shedding: with both a placement-aware slot cache
        # and an adaptive controller, wire the controller's occupancy view so
        # a saturated home domain sheds new admissions to same-group siblings
        # before nearest_spill is forced to go cross-group (repro.placement).
        ctl = self.scheduler.controller
        if ctl is not None and self.slots.telemetry is not None:
            tel = self.slots.telemetry
            # rebind unconditionally, same rationale as the prefix index
            # above: a controller reused from a retired engine must not keep
            # shedding against the old engine's frozen occupancy counters or
            # a differently-shaped topology/capacity table
            ctl.occupancy = lambda: tel.per_domain_occupancy
            ctl.shed_topology = self.scheduler.topology
            ctl.domain_capacity = self.slots.pools.domain_capacity
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)
        self.active_req: dict[int, Request] = {}
        # simulated cost accounting: a domain switch stalls the pipe while the
        # prefix/KV home moves across DCN (the paper's remote cache miss);
        # under a hierarchical topology the stall scales with the inter-domain
        # distance (cross-pod moves cost double a same-pod move).  A slot
        # placed off its home domain additionally stalls per unit of distance
        # while the prefix/KV blocks migrate to the slot's pool.
        self.domain_switch_cost = domain_switch_cost
        self.slot_migration_cost = slot_migration_cost
        self.sim_time = 0
        # counting wrappers so compile-count tests and the serving bench can
        # pin trace budgets on either path
        self._prefill = CountingJit(model.prefill)
        self._step = CountingJit(model.decode_step)
        # batching: the bucketed/packed prefill layer.  Raises at
        # construction for archs where right-padding is not bitwise-invisible
        # (recurrent/SSM/MoE/sliding-window/VLM) — run those with it off.
        self.batcher = None
        if batching:
            self.batcher = PrefillBatcher(
                model, cache_len=cache_len, pack_width=pack_width or n_slots,
            )
            # AOT: every bucket trace compiles here, none in the serving loop
            self.batcher.warm(params, cont=self.prefix_kv is not None)

    @property
    def compile_counts(self) -> dict:
        """Jit trace counts per entry point: ``prefill``/``decode`` for the
        bare per-request paths, plus ``packed_prefill``/``cont_prefill``
        when batching is on.  The regression contract: decode traces once,
        and packed-prefill traces stay bounded by the bucket count no matter
        how many distinct prompt lengths the workload carries."""
        out = {"prefill": self._prefill.traces, "decode": self._step.traces}
        if self.batcher is not None:
            out["packed_prefill"] = self.batcher.packed.traces
            out["cont_prefill"] = self.batcher.cont.traces
        return out

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request):
        """Queue ``req`` for admission.  Prompts that cannot fit the cache are
        rejected here — prefill would return ``pos > cache_len``, ``_fit``
        would silently trim the KV, and the decode write would clamp onto the
        last cache entry, corrupting it.  ``domain=None`` derives the home
        from the prefix index (longest cached prefix; explicit domains remain
        an override)."""
        if len(req.prompt) >= self.cache_len:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens cannot fit cache_len="
                f"{self.cache_len} (need len(prompt) < cache_len to leave "
                "room for decode); truncate the prompt or grow the cache"
            )
        derived = req.domain is None
        if self.tracer:
            self.tracer.begin(
                "request", req.rid, self.scheduler.now, prompt_len=len(req.prompt)
            )
        if req.domain is None:
            if self.prefix_index is not None:
                domain, matched = self.prefix_index.home(req.prompt)
                req.matched_len = matched
                if self.slots.telemetry is not None:
                    self.slots.telemetry.record_derived_home(matched, len(req.prompt))
            else:
                domain = None
            # a cold index (or no index at all) has no opinion: domain 0 is
            # the engine's only defensible default, and it is explicit here
            # rather than coerced deep inside SlotCache.claim
            req.domain = 0 if domain is None else domain
        if self.tracer:
            now = self.scheduler.now
            self.tracer.span(
                "home_derivation", req.rid, now, now,
                domain=req.domain, matched=req.matched_len, derived=derived,
            )
        ctl = self.scheduler.controller
        if ctl is not None and self.slots.telemetry is not None:
            shed = ctl.shed_home(req.domain)
            if shed != req.domain:
                # home saturated, a same-group sibling has headroom: re-home
                # the admission there (shed) rather than letting placement
                # spill it — the matched-prefix discount no longer applies
                # at the new home, so the charge model stays honest
                if self.tracer:
                    now = self.scheduler.now
                    self.tracer.span(
                        "shed", req.rid, now, now, home=req.domain, to=shed
                    )
                req.domain = shed
                req.matched_len = 0
                self.slots.telemetry.record_shed()
        req.submit_t = self.scheduler.now
        self.scheduler.submit(req, req.domain)

    def _claim_and_charge(self, req: Request, switch_distance: int) -> int:
        """Claim a slot for a granted request and charge its admission
        stalls (domain switch + KV migration); returns the slot."""
        slot = self.slots.claim(req.rid, req.domain)
        if self._paged:
            # fresh pages for this admission's deposits land in (or nearest
            # to) the pool the slot actually got — page placement follows
            # slot placement instead of growing its own policy
            self.prefix_kv.alloc_domain = self.slots.last_domain
        migration = self.slot_migration_cost * self.slots.last_distance
        if req.matched_len and len(req.prompt):
            # only the uncached suffix of the KV is charged for an
            # off-home placement.  Modeling assumption (the index's
            # multi-holder records make it concrete): a prefix hot enough
            # to match is replicated into every pool that recently served
            # it, so the matched run is treated as already resident where
            # the slot lands and only the per-request suffix moves.
            uncached = max(0, len(req.prompt) - req.matched_len)
            migration = migration * uncached // len(req.prompt)
        stall = self.domain_switch_cost * switch_distance + migration
        self.sim_time += stall
        if self.tracer:
            now = self.scheduler.now
            sp = self.tracer.span(
                "admit", req.rid, now, now, slot=slot, domain=req.domain,
                switch_distance=switch_distance, stall_cycles=stall,
            )
            if self.slots.last_distance:
                self.tracer.span(
                    "migrate", req.rid, now, now, parent=sp,
                    distance=self.slots.last_distance, cycles=migration,
                )
        if self.prefix_index is not None and self.slots.last_domain is not None:
            # re-home: the prefix now lives wherever placement actually
            # put it, which is where the next match should send traffic
            self.prefix_index.record(req.prompt, self.slots.last_domain)
        # one handover sample per admission: the GCR feedback signal for
        # an adaptive max_active (no-op under a static/absent cap)
        self.scheduler.observe_handover(stall)
        req.admit_t = self.scheduler.now
        return slot

    def _admit(self):
        if self.batcher is not None:
            self._admit_packed()
            return
        while self.slots.n_free and len(self.scheduler):
            req = self.scheduler.next_request()
            if req is None:
                break
            slot = self._claim_and_charge(req, self.scheduler.last_admit_distance)
            p0, r0 = self.prefill_positions, self.reused_positions
            logits, cache = self._prefill_reuse(req.prompt, req.matched_len)
            if self.tracer:
                computed = self.prefill_positions - p0
                reused = self.reused_positions - r0
                kind = "reuse" if computed == 0 else ("cont" if reused else "fresh")
                now = self.scheduler.now
                self.tracer.span(
                    "prefill", req.rid, now, now,
                    kind=kind, computed=computed, reused=reused,
                )
                self.tracer.begin("decode", req.rid, now)
            self.slots.insert(slot, cache)
            if self._paged:
                # pin the live sequence to its pages: the deposit
                # _prefill_reuse just made holds the prompt's bundle, and
                # the slot keeps one reference per page until release
                self.slots.note_sequence(slot, self.prefix_kv.bundle(req.prompt))
            tok = int(jnp.argmax(logits[0]))
            req.out.append(tok)
            self.tokens = self.tokens.at[slot, 0].set(tok)
            self.active_req[slot] = req

    def _admit_packed(self):
        """Packed admission: at most one packed prefill call (plus at most
        one continuation call when a prefix-KV store is wired) per ``step``,
        so prefill *interleaves* with running decode instead of draining the
        queue synchronously.  Grants beyond ``pack_width`` stay queued for
        the next tick.  This subsumes ``_prefill_reuse`` for the batched
        path: store hits ride the continuation pack (whole suffixes at
        seeded positions, still bitwise the from-scratch result), boundary
        plants ride the fresh pack as extra rows, and accounting
        (``prefill_positions``/``reused_positions``) charges exactly what
        the per-request path would."""
        k = min(self.slots.n_free, self.batcher.pack_width)
        if k <= 0:
            return
        reqs = self.scheduler.next_batch(k)
        if not reqs:
            return
        store = self.prefix_kv
        admitted = [
            (req, self._claim_and_charge(req, dist))
            for req, dist in zip(reqs, self.scheduler.last_batch_distances)
        ]
        fresh = []   # (req, slot, boundary-plant hint)
        cont = []    # (req, slot, matched, stored cache)
        ready = []   # (req, slot, stored logits) — whole prompt cached
        if store is None:
            fresh = [(req, slot, 0) for req, slot in admitted]
        else:
            for req, slot in admitted:
                reuse = store.longest(req.prompt)
                if reuse is not None:
                    matched, cache, logits = reuse
                    self.reused_positions += matched
                    if matched == len(req.prompt):
                        self.slots.insert(slot, cache)
                        store.put([int(t) for t in req.prompt], cache, logits)
                        if self._paged:
                            self.slots.note_sequence(slot, store.bundle(req.prompt))
                        ready.append((req, slot, logits))
                    else:
                        cont.append((req, slot, matched, cache))
                else:
                    hint = max(int(req.matched_len), store.common_run(req.prompt))
                    if hint < store.min_plant or hint > len(req.prompt):
                        hint = 0
                    fresh.append((req, slot, hint))

        assign = []  # (req, slot, device first-token scalar)
        if fresh:
            rows = [req.prompt for req, _, _ in fresh]
            # boundary plants ride the same pack as extra rows when there is
            # room; their positions are a replica of the full row's prefix,
            # so they are not charged again
            plant = []
            for req, _, hint in fresh:
                if hint and len(rows) < self.batcher.pack_width:
                    plant.append((len(rows), [int(t) for t in req.prompt[:hint]]))
                    rows.append(req.prompt[:hint])
            logits, cache = self.batcher.prefill(self.params, rows)
            nxt = jnp.argmax(logits, axis=-1)
            for i, (req, slot, _hint) in enumerate(fresh):
                self.slots.insert_row(slot, cache, i)
                self.prefill_positions += len(req.prompt)
                if self.tracer:
                    now = self.scheduler.now
                    self.tracer.span(
                        "prefill", req.rid, now, now,
                        kind="fresh", computed=len(req.prompt), reused=0,
                    )
                assign.append((req, slot, nxt[i]))
                if store is not None:
                    single = self.slots.fit_single(self.batcher.extract_row(cache, i))
                    store.put([int(t) for t in req.prompt], single, logits[i : i + 1])
                    if self._paged:
                        self.slots.note_sequence(slot, store.bundle(req.prompt))
            for i, boundary in plant:
                single = self.slots.fit_single(self.batcher.extract_row(cache, i))
                store.put(boundary, single, logits[i : i + 1])
        if cont:
            rows = [c for _, _, _, c in cont]
            suffixes = [req.prompt[matched:] for req, _, matched, _ in cont]
            logits, cache = self.batcher.continue_rows(self.params, rows, suffixes)
            nxt = jnp.argmax(logits, axis=-1)
            for i, (req, slot, matched, _c) in enumerate(cont):
                self.slots.insert_row(slot, cache, i)
                self.prefill_positions += len(req.prompt) - matched
                if self.tracer:
                    now = self.scheduler.now
                    self.tracer.span(
                        "prefill", req.rid, now, now, kind="cont",
                        computed=len(req.prompt) - matched, reused=matched,
                    )
                assign.append((req, slot, nxt[i]))
                single = self.slots.fit_single(self.batcher.extract_row(cache, i))
                store.put([int(t) for t in req.prompt], single, logits[i : i + 1])
                if self._paged:
                    self.slots.note_sequence(slot, store.bundle(req.prompt))
        for req, slot, logits in ready:
            if self.tracer:
                now = self.scheduler.now
                self.tracer.span(
                    "prefill", req.rid, now, now,
                    kind="reuse", computed=0, reused=len(req.prompt),
                )
            assign.append((req, slot, jnp.argmax(logits[0])))

        # ONE host transfer for every admitted request's first token
        toks = jax.device_get([t for _, _, t in assign]) if assign else []
        for (req, slot, _), tok in zip(assign, toks):
            tok = int(tok)
            req.out.append(tok)
            self.tokens = self.tokens.at[slot, 0].set(tok)
            self.active_req[slot] = req
            if self.tracer:
                self.tracer.begin("decode", req.rid, self.scheduler.now)

    def _prefill_reuse(self, prompt, hint_len: int = 0):
        """Prefill ``prompt``, resuming from the longest stored prefix cache
        when a ``PrefixKVStore`` is wired.  A stored prefix seeds the KV
        write position past the cached run and only the uncached suffix is
        computed (one ``decode_step`` per suffix token — the incremental form
        of prefill, so results match the from-scratch path exactly);
        ``prefill_positions`` counts positions actually computed, which is
        what makes the reuse pinnable by tests and benchmarks.

        ``hint_len`` is the prefix index's ``matched_len``: when the store
        has no entry prefix-matching this prompt but the index says the run
        ``prompt[:hint_len]`` is hot, the prefill is split at that boundary
        and the boundary cache deposited, so the *next* prompt sharing the
        run resumes from it.  (Stored keys must be exact prefixes of the
        incoming prompt; shared-system-prompt traffic diverges after the
        common run, so without the boundary entry only whole-prompt
        extensions would ever hit.)"""
        store = self.prefix_kv
        if store is None:
            logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(prompt)[None]})
            cache["pos"] = jnp.asarray(cache["pos"], jnp.int32)
            self.prefill_positions += len(prompt)
            return logits, cache
        reuse = store.longest(prompt)
        # boundary hint: the index's matched_len (what the home pool holds)
        # or the store's own longest common run against a stored key —
        # whichever sees the longer shared run.  matched_len alone misses
        # batches submitted against a cold index (homes derive at submit,
        # before any placement taught the index).
        if reuse is None:
            hint_len = max(int(hint_len), store.common_run(prompt))
            if hint_len < store.min_plant:
                hint_len = 0
        if reuse is not None:
            matched, cache, logits = reuse
            self.reused_positions += matched
        elif 0 < hint_len <= len(prompt):
            boundary = [int(t) for t in prompt[:hint_len]]
            logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(boundary)[None]})
            # deposits go through fit_single so every stored entry — and
            # every suffix decode_step below — shares one (batch=1,
            # cache_len) shape and thus one jit trace; jax arrays are
            # immutable, so entries hold references, not copies
            cache = self.slots.fit_single(cache)
            store.put(boundary, cache, logits)
            matched = hint_len
            self.prefill_positions += hint_len
        else:
            matched = 0
        if matched == 0:
            logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(prompt)[None]})
            cache = self.slots.fit_single(cache)
            self.prefill_positions += len(prompt)
        else:
            for i in range(matched, len(prompt)):
                logits, cache = self._step(
                    self.params, cache, jnp.asarray([[int(prompt[i])]], jnp.int32)
                )
            self.prefill_positions += len(prompt) - matched
        store.put([int(t) for t in prompt], cache, logits)
        return logits, cache

    # -- KV shipping (repro.router.kvship) -------------------------------------
    def export_kv(self, prompt):
        """Export the longest stored prefix cache for ``prompt`` for a
        fabric transfer -> ``(tokens, (cache, logits))`` or None when no
        ``PrefixKVStore`` is wired or nothing prefixes the prompt.  The
        bundle is immutable jax arrays (references, not copies), so an
        export costs nothing until the fabric actually moves the bytes —
        pricing that move is the router's job, not this method's."""
        if self.prefix_kv is None:
            return None
        matched = self.prefix_kv.peek(prompt)
        if matched <= 0:
            return None
        key = tuple(int(t) for t in prompt)[:matched]
        entry = self.prefix_kv.get(key)
        if entry is None:
            return None
        return key, entry

    def import_kv(self, tokens, payload) -> bool:
        """Land a shipped prefix bundle in this engine's ``PrefixKVStore``
        so the next admission of a prompt extending ``tokens`` resumes from
        it (the ordinary ``_prefill_reuse`` path — shipped and locally
        prefilled caches are indistinguishable from there on).  Refuses
        (returns False) when no store is wired or the shipped cache cannot
        fit this engine's ``cache_len``; the caller then re-prefills."""
        if self.prefix_kv is None:
            return False
        cache, logits = payload
        if len(tokens) >= self.cache_len:
            return False
        self.prefix_kv.put(list(tokens), self.slots.fit_single(cache), logits)
        return True

    def peek_match(self, prompt) -> int:
        """Tokens of ``prompt`` resumable from the prefix-KV store (0
        without one) — side-effect-free, for the router's ship pricing."""
        return self.prefix_kv.peek(prompt) if self.prefix_kv is not None else 0

    # -- federation export -----------------------------------------------------
    def summary(self, top_k: int = 8) -> dict:
        """Compact replica-state export for a fleet/router tier
        (``repro.router``): live occupancy (decoding + queued) against slot
        capacity, plus the prefix index's hottest cached prefixes.  Plain
        dict so the serving layer stays import-independent of the router."""
        return {
            "occupancy": len(self.active_req) + len(self.scheduler),
            "capacity": self.n_slots,
            "prefixes": tuple(self.prefix_index.summary(top_k))
            if self.prefix_index is not None
            else (),
        }

    # -- observability ---------------------------------------------------------
    def register_metrics(self, registry, prefix: str = "engine") -> None:
        """Register this engine's live counters — and its scheduler's (and,
        transitively, placement telemetry's) surface — into a
        ``repro.obs.MetricsRegistry`` as thin views.  Reads through; nothing
        moves, no call-site changes anywhere."""
        self.scheduler.metrics.register_into(registry, prefix=f"{prefix}_sched")
        registry.gauge(f"{prefix}_prefill_positions", fn=lambda: self.prefill_positions)
        registry.gauge(f"{prefix}_reused_positions", fn=lambda: self.reused_positions)
        registry.gauge(f"{prefix}_kv_deposits", fn=lambda: self.kv_deposits)
        registry.gauge(f"{prefix}_sim_time", fn=lambda: self.sim_time)
        registry.gauge(f"{prefix}_active_slots", fn=lambda: len(self.active_req))
        registry.gauge(f"{prefix}_queued", fn=lambda: len(self.scheduler))
        if self._paged:
            # the memory-compaction claim as scrapeable numbers:
            # pages_total / pages_shared / pages_free / kv_bytes_held
            self.slots.register_into(registry, prefix=prefix)

    # -- decode ----------------------------------------------------------------
    def step(self):
        """One engine tick: admit, one fused decode step, retire finished."""
        self.scheduler.tick()
        self._admit()
        if not self.active_req:
            self.sim_time += 1
            return
        logits, new_cache = self._step(self.params, self.slots.cache, self.tokens)
        self.slots.cache = new_cache
        self.sim_time += 1
        # next-token feedback stays on device (the whole vector replaces
        # self.tokens — inactive lanes carry garbage, but claim->insert
        # overwrites a lane before it is ever decoded); the per-slot python
        # bookkeeping below then needs exactly ONE host transfer per tick
        # instead of two device syncs per active slot.
        nxt = jnp.argmax(logits, axis=-1)
        self.tokens = nxt[:, None].astype(jnp.int32)
        nxt_host, pos_host = jax.device_get((nxt, new_cache["pos"]))
        for slot, req in list(self.active_req.items()):
            tok = int(nxt_host[slot])
            req.out.append(tok)
            hit_eos = self.eos is not None and tok == self.eos
            past_len = int(pos_host[slot]) >= self.cache_len - 1
            if req.done or hit_eos or past_len:
                req.finish_t = self.scheduler.now
                deposits_before = self.kv_deposits
                if self.prefix_kv is not None:
                    # retirement-time deposit: the slot's cache now encodes
                    # prompt + out[:-1] (the final token was emitted, never
                    # fed), and this step's logits row predicts out[-1] —
                    # exactly the (tokens, cache, logits) contract the store
                    # keeps.  A conversation follow-up whose prompt extends
                    # prompt+output then resumes from here instead of
                    # re-prefilling the whole history.
                    seq = [int(t) for t in req.prompt] + [int(t) for t in req.out[:-1]]
                    pos = int(pos_host[slot])
                    if 0 < pos < self.cache_len and pos == len(seq):
                        if self._paged:
                            # the deposit shares the prompt entry's pages
                            # (the slot already pins them) and writes only
                            # the decoded suffix; home the fresh pages with
                            # the retiring slot's pool
                            self.prefix_kv.alloc_domain = self.slots.slot_domain(slot)
                        self.prefix_kv.put(
                            seq, self.slots.extract(slot), logits[slot : slot + 1]
                        )
                        self.kv_deposits += 1
                if self.prefix_index is not None:
                    # the retiring slot's pool now holds KV for the full
                    # sequence — index it before release so follow-ups that
                    # extend this conversation home to the same pool
                    dom = self.slots.slot_domain(slot)
                    if dom is not None:
                        self.prefix_index.record(
                            np.concatenate([np.asarray(req.prompt), np.asarray(req.out)]),
                            dom,
                        )
                if self.tracer:
                    now = self.scheduler.now
                    self.tracer.end(
                        self.tracer.open_span(req.rid, "decode"), now,
                        tokens=len(req.out),
                    )
                    root = self.tracer.open_span(req.rid, "request")
                    if self.kv_deposits > deposits_before:
                        self.tracer.event(root, "deposit", now)
                    self.tracer.event(root, "retire", now, slot=slot)
                    self.tracer.end(root, now)
                self.slots.release(slot)
                del self.active_req[slot]

    def run(self, requests: list[Request], max_ticks: int = 10_000) -> list[Request]:
        """Submit ``requests`` and step until all retire (or ``max_ticks``
        scheduler ticks elapse); returns the same list, outputs filled."""
        for r in requests:
            self.submit(r)
        ticks = 0
        while (len(self.scheduler) or self.active_req) and ticks < max_ticks:
            self.step()
            ticks += 1
        return requests
