"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback
    from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru_scan.ops import linear_scan
from repro.kernels.rglru_scan.ref import linear_scan_ref
from repro.kernels.ssd_scan.ops import ssd_intra
from repro.kernels.ssd_scan.ref import ssd_intra_ref


FA_CASES = [
    # b, sq, skv, h, hkv, hd, causal, window, dtype
    (2, 128, 128, 4, 2, 64, True, 0, jnp.float32),
    (1, 256, 256, 4, 4, 32, True, 64, jnp.float32),
    (1, 256, 256, 8, 1, 16, True, 0, jnp.float32),      # MQA
    (2, 64, 192, 2, 1, 16, False, 0, jnp.bfloat16),     # cross attention
    (1, 100, 100, 4, 2, 64, True, 0, jnp.float32),      # pad to block
    (1, 128, 128, 2, 2, 128, True, 32, jnp.bfloat16),   # narrow window
    (3, 96, 96, 6, 3, 48, True, 0, jnp.float32),
]


@pytest.mark.parametrize("case", FA_CASES, ids=[str(c) for c in FA_CASES])
def test_flash_attention_matches_ref(case):
    b, sq, skv, h, hkv, hd, causal, window, dt = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd), dt)
    k = jax.random.normal(ks[1], (b, skv, hkv, hd), dt)
    v = jax.random.normal(ks[2], (b, skv, hkv, hd), dt)
    got = flash_attention(q, k, v, causal=causal, window=window, block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=causal, window=window)
    tol = 3e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_block_shape_invariance():
    """Result must not depend on the BlockSpec tiling."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 256, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 256, 2, 32), jnp.float32)
    outs = [
        flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
        for bq, bk in [(64, 64), (128, 64), (64, 128), (128, 128), (256, 256)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=1e-5, rtol=1e-5)


@given(
    b=st.integers(1, 3),
    s=st.integers(2, 80),
    w=st.integers(1, 70),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_rglru_scan_property(b, s, w, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.random.uniform(ks[0], (b, s, w), jnp.float32, 0.2, 0.999)
    bb = jax.random.normal(ks[1], (b, s, w), jnp.float32)
    h0 = jax.random.normal(ks[2], (b, w), jnp.float32)
    got = linear_scan(a, bb, h0, block_s=32, block_w=32)
    want = linear_scan_ref(a, bb, h0)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("shape", [(2, 3, 16, 2, 8, 4), (1, 2, 32, 4, 16, 16), (1, 4, 64, 3, 32, 8)])
def test_ssd_intra_matches_ref(shape):
    b, nc, l, h, p, n = shape
    ks = jax.random.split(jax.random.PRNGKey(sum(shape)), 4)
    xc = jax.random.normal(ks[0], (b, nc, l, h, p), jnp.float32)
    dac = -jax.random.uniform(ks[1], (b, h, nc, l), jnp.float32, 0.01, 0.5)
    bc = jax.random.normal(ks[2], (b, nc, l, n), jnp.float32)
    cc = jax.random.normal(ks[3], (b, nc, l, n), jnp.float32)
    got = ssd_intra(xc, dac, bc, cc)
    want = ssd_intra_ref(xc, dac, bc, cc)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_flash_attention_grad_path_exists():
    """The kernel is used in the forward; ensure jax.grad flows through the
    interpret-mode kernel (needed by cfg.attn_impl='pallas' training smoke)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.float32)

    def f(q):
        return flash_attention(q, k, v, causal=True, block_q=32, block_k=32).sum()

    g = jax.grad(f)(q)
    assert jnp.isfinite(g).all()
