"""repro.serving.prefixindex: radix index over token prompts deriving
request homes from actual placements — plus the cross-layer contract that a
warm index drives the identical admission/placement trajectory an oracle
caller would produce."""

import numpy as np
import pytest

from repro.serving.prefixindex import PrefixIndex


# -- index structure -----------------------------------------------------------


def test_cold_index_misses_and_falls_back():
    ix = PrefixIndex(n_domains=4)
    assert ix.home([1, 2, 3]) == (0, 0)  # least-occupied fallback, no match
    ix.occupancy = lambda: {0: 3, 1: 1, 2: 2, 3: 5}
    assert ix.home([1, 2, 3]) == (1, 0)  # occupancy steers the cold start
    assert PrefixIndex().home([1, 2, 3]) == (None, 0)  # no n_domains: no opinion
    assert ix.lookups == 2 and ix.hits == 0


def test_longest_prefix_match_and_matched_len():
    ix = PrefixIndex(n_domains=4)
    ix.record([1, 2, 3, 4], 2)
    assert ix.home([1, 2, 3, 4]) == (2, 4)        # exact
    assert ix.home([1, 2, 9, 9]) == (2, 2)        # diverges mid-edge
    assert ix.home([1, 2, 3, 4, 5, 6]) == (2, 4)  # extends past the cache
    assert ix.home([7, 8]) == (0, 0)              # total miss -> fallback
    ix.record([1, 2, 3, 4, 5, 6], 3)              # deeper record wins the LPM
    assert ix.home([1, 2, 3, 4, 5, 6, 7]) == (3, 6)
    assert ix.home([1, 2, 3, 4])[1] == 4          # matched_len <= query length


def test_record_tags_every_prefix_and_splits_edges():
    ix = PrefixIndex(n_domains=4)
    ix.record([1, 2, 3, 4], 1)
    assert ix.n_nodes == 1                 # one compressed edge
    ix.record([1, 2, 8, 9], 2)             # split at [1,2]
    assert ix.n_nodes == 3
    dom, matched = ix.home([1, 2])
    assert matched == 2 and dom in (1, 2)  # both pools hold the shared run
    # domain 1 still owns the deep [1,2,3,4] branch it wrote
    assert ix.home([1, 2, 3, 4]) == (1, 4)
    assert ix.home([1, 2, 8, 9]) == (2, 4)


def test_ties_break_toward_least_occupied_domain():
    occ = {}
    ix = PrefixIndex(n_domains=4, occupancy=lambda: occ)
    ix.record([5, 6, 7], 1)
    ix.record([5, 6, 7], 2)  # same prefix now held by two pools
    occ.update({1: 4, 2: 0})
    assert ix.home([5, 6, 7]) == (2, 3)
    occ.update({1: 0, 2: 4})
    assert ix.home([5, 6, 7]) == (1, 3)
    occ.update({1: 2, 2: 2})
    assert ix.home([5, 6, 7]) == (2, 3)  # occupancy tie -> most recent holder


def test_rehoming_follows_the_latest_record():
    ix = PrefixIndex(n_domains=4)  # no occupancy signal: recency decides
    ix.record([5, 6, 7], 1)
    assert ix.home([5, 6, 7]) == (1, 3)
    ix.record([5, 6, 7], 3)  # placement spilled the prefix to domain 3
    assert ix.home([5, 6, 7]) == (3, 3)


def test_capacity_evicts_lru_leaves():
    ix = PrefixIndex(n_domains=2, capacity=16)
    ix.record([1, 2, 3], 0)
    for i in range(200):
        ix.record([1, 2, 3, 100 + i], 1)   # unique suffixes churn the leaves
        ix.home([1, 2, 3])                  # keep the shared prefix hot
    assert ix.n_nodes <= 16
    assert ix.home([1, 2, 3])[1] == 3       # the hot prefix survived eviction
    assert ix.records == 201


def test_record_validates_domain_and_ignores_empty():
    ix = PrefixIndex(n_domains=4)
    with pytest.raises(ValueError, match="out of range"):
        ix.record([1], 4)
    with pytest.raises(ValueError, match="out of range"):
        ix.record([1], -1)
    with pytest.raises(ValueError, match="out of range"):
        ix.record([1], None)
    ix.record([], 0)
    assert ix.n_nodes == 0 and ix.records == 0
    with pytest.raises(ValueError):
        PrefixIndex(capacity=0)


def test_numpy_prompts_and_python_lists_are_the_same_key():
    ix = PrefixIndex(n_domains=2)
    ix.record(np.array([4, 5, 6], dtype=np.int32), 1)
    assert ix.home([4, 5, 6]) == (1, 3)
    assert ix.home(np.array([4, 5, 6, 7], dtype=np.int64)) == (1, 3)


# -- engine wiring -------------------------------------------------------------


@pytest.fixture(scope="module")
def small_model():
    import jax

    from repro.configs.base import get_reduced_config
    from repro.models.registry import build_model

    cfg = get_reduced_config("granite_3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, *, index):
    from repro.core.topology import pod
    from repro.serving.engine import DecodeEngine
    from repro.serving.scheduler import CNAScheduler

    return DecodeEngine(
        model, params, n_slots=4, cache_len=64,
        scheduler=CNAScheduler(fairness_threshold=0xF, topology=pod(2, 2)),
        placement="nearest_spill", prefix_index=index,
    )


def _shared_prefix_requests(phase, n=6):
    """n requests over 3 shared 6-token prefixes with unique 2-token tails."""
    from repro.serving.engine import Request

    prefixes = [[10 + p, 11 + p, 12 + p, 13 + p, 14 + p, 15 + p] for p in (0, 20, 40)]
    return [
        Request(rid=100 * phase + i,
                prompt=np.array(prefixes[i % 3] + [70 + 10 * phase + i, 80 + i],
                                dtype=np.int32),
                max_new=3, domain=None)
        for i in range(n)
    ]


def _trace_claims(eng):
    trace = []
    orig = eng.slots.claim

    def claim(owner, domain=None):
        slot = orig(owner, domain)
        trace.append((owner, domain, slot))
        return slot

    eng.slots.claim = claim
    return trace


def test_prefix_index_requires_placement(small_model):
    cfg, model, params = small_model
    from repro.serving.engine import DecodeEngine

    with pytest.raises(ValueError, match="prefix index needs placement"):
        DecodeEngine(model, params, prefix_index=PrefixIndex())


def test_engine_auto_wires_index_to_topology_and_telemetry(small_model):
    cfg, model, params = small_model
    eng = _engine(model, params, index=True)
    assert eng.prefix_index.n_domains == 4
    assert eng.prefix_index.occupancy() == eng.slots.telemetry.per_domain_occupancy
    # a warm index handed to a NEW engine rebinds to the new engine's live
    # telemetry (it must not keep reading the retired engine's counters) and
    # rejects a topology of a different width
    eng2 = _engine(model, params, index=eng.prefix_index)
    assert eng2.prefix_index is eng.prefix_index
    assert eng2.prefix_index.occupancy() is eng2.slots.telemetry.per_domain_occupancy
    from repro.core.topology import pod
    from repro.serving.engine import DecodeEngine
    from repro.serving.scheduler import CNAScheduler

    with pytest.raises(ValueError, match="spans 4 domains"):
        DecodeEngine(model, params, n_slots=4, cache_len=64,
                     scheduler=CNAScheduler(topology=pod(1, 2)),
                     placement="nearest_spill", prefix_index=eng.prefix_index)


def test_engine_derives_homes_and_learns_from_placements(small_model):
    """domain=None requests get index-derived homes; after a warm phase the
    index answers with the full shared prefix matched, telemetry counts the
    derivations, and retirement records extend the cached sequences."""
    cfg, model, params = small_model
    eng = _engine(model, params, index=True)
    warm = _shared_prefix_requests(phase=0)
    eng.run(warm)
    assert all(r.done for r in warm)
    assert all(r.domain is not None for r in warm)  # resolved in place
    tel = eng.slots.telemetry
    assert tel.derived_homes == 6
    # retirement recorded prompt+output sequences, so the index holds more
    # tokens than the prompts alone
    probe = warm[0]
    dom, matched = eng.prefix_index.home(
        np.concatenate([probe.prompt, np.asarray(probe.out)]))
    assert matched == len(probe.prompt) + len(probe.out)
    test = _shared_prefix_requests(phase=1)
    eng.run(test)
    assert tel.derived_homes == 12
    for r in test:
        assert r.matched_len >= 6  # the shared prefix was cached and matched
    # warm-phase lookups all missed (6*8 tokens), test phase matched the
    # 6-token prefix of each 8-token prompt: 36/96
    assert tel.prefix_hit_rate == pytest.approx(0.375)


def test_contract_warm_index_matches_oracle_trajectory(small_model):
    """Cross-layer contract: the warm index's derived homes drive the
    IDENTICAL admission/placement trajectory that an oracle caller supplying
    those homes explicitly would produce — derivation changes labels, never
    the discipline — and the matched_len discount can only reduce the charged
    migration stall."""
    cfg, model, params = small_model
    from repro.serving.engine import Request

    # derived run: homes come from the index (warm after phase 0)
    eng_d = _engine(model, params, index=True)
    trace_d = _trace_claims(eng_d)
    warm_d = _shared_prefix_requests(phase=0)
    test_d = _shared_prefix_requests(phase=1)
    eng_d.run(warm_d)
    eng_d.run(test_d)
    resolved = {r.rid: r.domain for r in warm_d + test_d}

    # oracle run: a caller that already knows those homes submits them
    # explicitly (domain=..., matched_len untouched) over the same prompts
    eng_o = _engine(model, params, index=None)
    trace_o = _trace_claims(eng_o)
    warm_o = [Request(r.rid, r.prompt.copy(), r.max_new, domain=resolved[r.rid])
              for r in warm_d]
    test_o = [Request(r.rid, r.prompt.copy(), r.max_new, domain=resolved[r.rid])
              for r in test_d]
    eng_o.run(warm_o)
    eng_o.run(test_o)

    assert trace_d == trace_o  # identical (rid, home, slot) claim sequence
    md, mo = eng_d.scheduler.metrics, eng_o.scheduler.metrics
    assert (md.admitted, md.local_admits, md.domain_switches) == \
           (mo.admitted, mo.local_admits, mo.domain_switches)
    td, to = eng_d.slots.telemetry, eng_o.slots.telemetry
    assert td.per_domain_placements == to.per_domain_placements
    assert (td.locality, td.migration_cycles) == (to.locality, to.migration_cycles)
    # same decode output, and the uncached-suffix discount never charges MORE
    assert {r.rid: r.out for r in test_d} == {r.rid: r.out for r in test_o}
    assert eng_d.sim_time <= eng_o.sim_time


def test_summary_exports_distinct_maximal_runs():
    """summary(top_k) emits hottest-first distinct maximal runs: a path that
    is a prefix of another emitted path never spends a second slot — the
    shallower-but-hotter case deepens the chosen entry in place (recording
    the extension covers every prefix of it)."""
    ix = PrefixIndex(n_domains=2)
    ix.record([1, 2, 3, 4], 0)
    ix.record([1, 2], 0)        # hotter, but subsumed by the deeper run
    ix.record([9, 9, 9], 1)
    out = ix.summary(top_k=3)
    paths = [p for p, _ in out]
    assert (9, 9, 9) in paths and (1, 2, 3, 4) in paths
    assert len(paths) == 2      # no slot wasted on (1, 2)
    assert paths[0] == (9, 9, 9)  # hottest first
    # top_k bounds the emission; deepening still applies under the bound
    assert [p for p, _ in ix.summary(top_k=1)] == [(9, 9, 9)]
    assert ix.summary(top_k=0) == []
    assert PrefixIndex().summary() == []
