"""NUMA-aware slot placement + adaptive concurrency (repro.placement).

Two sections:

  * ``policy_level`` — a slot-allocator loop over a skewed (Zipf) domain mix
    on a hierarchical ``pod(2,2)`` fabric: requests with a KV/prefix home
    domain claim a decode slot, hold it for a service time, release it.
    ``home_domain`` / ``nearest_spill`` must beat the seed's ``lowest_free``
    rule on locality and total distance-priced migration cycles — the
    serving-side analog of the paper's remote-cache-miss avoidance.

  * ``adaptive_level`` — the GCR feedback loop in the lock simulator across
    load levels: sweep static ``max_active`` caps to find the collapse
    boundary (the largest cap that keeps ~plateau throughput), then let
    ``AdaptiveController`` find it online.  The settled cap must land within
    one slot of the static-optimal boundary at every oversubscribed load.

Both sections are pure python + the simulator (no jax), so the smoke lane
runs them in seconds.
"""

from __future__ import annotations

import random

from repro.core.locks_sim import ALL_LOCKS, AdaptiveRCNASim
from repro.core.numasim import TWO_SOCKET, Simulator, run_sweep
from repro.core.topology import pod
from repro.placement import AdaptiveController, DomainFreeLists, PlacementTelemetry, get_policy

from . import common
from .common import ascii_plot, claim, smoke, table

SEED = 7


# -- placement policies over a skewed domain mix ------------------------------


def _zipf_domains(n, n_domains, skew, rng):
    """Zipf-weighted home domains (shared ``common.zipf_draws`` sampler).
    Skew is what makes placement interesting — a hot domain's pool exhausts
    and the policy must decide where the overflow lands."""
    return common.zipf_draws(n, n_domains, skew, rng)


def _alloc_loop(policy_name, homes, *, topo, n_slots, seed):
    """Claim/hold/release over the domain-partitioned pools: one step admits
    at most one request (if a slot is free) and retires due holders."""
    pools = DomainFreeLists(n_slots, topo)
    policy = get_policy(policy_name)
    tel = PlacementTelemetry(n_domains=topo.n_domains)
    rng = random.Random(seed)
    active = []  # (retire_time, slot)
    t = 0
    i = 0
    while i < len(homes) or active:
        t += 1
        for due, slot in [a for a in active if a[0] <= t]:
            tel.record_release(pools.release(slot))
            active.remove((due, slot))
        if i < len(homes) and len(pools):
            p = policy.place(pools, homes[i], TWO_SOCKET)
            tel.record_placement(p)
            active.append((t + rng.randrange(4, 24), p.slot))
            i += 1
    return tel


def policy_level():
    topo = pod(2, 2)  # 4 domains, 2 pods: sibling spill is 2.5x cheaper than cross
    n_reqs = smoke(4000, 300)
    n_slots = 16
    results = {}
    rows = []
    for skew in (0.0, 1.1):
        rng = random.Random(SEED)
        homes = _zipf_domains(n_reqs, topo.n_domains, skew, rng)
        for name in ("lowest_free", "home_domain", "nearest_spill"):
            tel = _alloc_loop(name, homes, topo=topo, n_slots=n_slots, seed=SEED)
            results[(skew, name)] = tel
            rows.append([skew, name, tel.locality, tel.sibling_spills, tel.cross_spills,
                         tel.migration_cycles, tel.fairness_factor()])
    table(
        f"slot placement on pod(2,2), {n_reqs} reqs x {n_slots} slots (skew 0 = uniform, 1.1 = Zipf)",
        ["skew", "policy", "locality", "sib_spill", "cross_spill", "migr_cycles", "fairness"],
        rows,
    )
    if common.SMOKE:
        return results
    for skew in (0.0, 1.1):
        lf, hd, ns = (results[(skew, n)] for n in ("lowest_free", "home_domain", "nearest_spill"))
        claim(
            f"placement: home_domain/nearest_spill locality >= baseline (skew={skew})",
            hd.locality >= lf.locality and ns.locality >= lf.locality,
            f"lf={lf.locality:.2f} hd={hd.locality:.2f} ns={ns.locality:.2f}",
        )
        claim(
            f"placement: locality policies cut total migration cycles (skew={skew})",
            hd.migration_cycles < lf.migration_cycles
            and ns.migration_cycles < lf.migration_cycles,
            f"lf={lf.migration_cycles} hd={hd.migration_cycles} ns={ns.migration_cycles}",
        )
    ns0, ns1 = results[(1.1, "nearest_spill")], results[(1.1, "home_domain")]
    claim(
        "placement: nearest_spill prefers sibling over cross-pod overflow under skew",
        ns0.cross_spills <= ns1.cross_spills and ns0.migration_cycles <= ns1.migration_cycles,
        f"ns cross={ns0.cross_spills} cyc={ns0.migration_cycles} "
        f"vs hd cross={ns1.cross_spills} cyc={ns1.migration_cycles}",
    )
    return results


# -- adaptive max_active vs the static-optimal cap ----------------------------

N_CORES = 16


def _static_boundary(n_threads, dur):
    """Largest static cap keeping >=95% of the best static throughput — the
    collapse boundary a GCR controller is supposed to sit just under."""
    caps = [c for c in smoke(list(range(8, 21)), [10, 14, 18]) if c <= n_threads]
    tps = {}
    for cap in caps:
        r = run_sweep(
            ALL_LOCKS["cna_rcr"], [n_threads], 2, seed=42, duration_cycles=dur,
            noncs_cycles=0, lock_kwargs={"threshold": 0xFF, "max_active": cap},
            n_cores=N_CORES,
        )[0]
        tps[cap] = r.throughput_ops_per_us
    best = max(tps.values())
    return max(c for c, tp in tps.items() if tp >= 0.95 * best), tps


def adaptive_level():
    dur = smoke(8_000_000, 200_000)
    rows = []
    ok_all, detail = True, []
    trajs = {}
    for n_threads in smoke([32, 64, 96], [32]):
        boundary, tps = _static_boundary(n_threads, dur)
        ctrl = AdaptiveController(initial=n_threads, max_cap=n_threads, window=16)
        sim = Simulator(
            AdaptiveRCNASim, n_threads, 2, seed=42, duration_cycles=dur,
            noncs_cycles=0, lock_kwargs={"threshold": 0xFF, "controller": ctrl},
            n_cores=N_CORES,
        )
        r = sim.run()
        settled = ctrl.settled_cap()
        trajs[n_threads] = list(ctrl.trajectory)
        rows.append([n_threads, boundary, settled, tps[boundary], r.throughput_ops_per_us,
                     ctrl.stall_rate, max(tps.values())])
        ok_all &= abs(settled - boundary) <= 1
        detail.append(f"{n_threads}t: settled={settled} boundary={boundary}")
    table(
        f"adaptive max_active vs static-optimal cap ({N_CORES} cores)",
        ["threads", "static_boundary", "adaptive_settled", "tp_static", "tp_adaptive",
         "stall_rate", "tp_best_static"],
        rows,
    )
    longest = max(trajs.values(), key=len)
    ascii_plot(
        "figCR: adaptive cap trajectory (cap vs controller window) — AIMD descent "
        "from unrestricted to the collapse boundary",
        list(range(1, len(longest) + 1)),
        {f"{t}thr": trajs[t] + [None] * (len(longest) - len(trajs[t])) for t in sorted(trajs)},
    )
    if common.SMOKE:
        return rows
    claim(
        "adaptive: settled cap within one slot of the static-optimal boundary at every load",
        ok_all,
        "; ".join(detail),
    )
    claim(
        "adaptive: controller >= 4x unrestricted CNA at peak oversubscription",
        rows[-1][4] >= 4 * run_sweep(
            ALL_LOCKS["cna"], [rows[-1][0]], 2, seed=42, duration_cycles=dur,
            noncs_cycles=0, lock_kwargs={"threshold": 0xFF}, n_cores=N_CORES,
        )[0].throughput_ops_per_us,
        f"adaptive={rows[-1][4]:.2f}",
    )
    return rows


def run_all():
    policy_level()
    adaptive_level()
