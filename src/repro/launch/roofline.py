"""Roofline analysis from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), all in seconds-per-step:

    compute    = HLO_FLOPs_per_chip / PEAK_FLOPS_BF16
    memory     = HLO_bytes_per_chip / HBM_BW
    collective = max(ici_wire_bytes / ICI_BW,  dcn_wire_bytes / DCN_BW)

``compiled.cost_analysis()`` gives per-chip FLOPs/bytes (verified: an 8-way
sharded matmul reports 1/8 of global FLOPs).  Collective bytes are *not* in
cost_analysis — we parse the post-SPMD optimized HLO and sum wire traffic per
op with ring-collective cost models, classifying each op as intra-pod (ICI)
or cross-pod (DCN) by materialising its replica groups (512 ids) and checking
whether any group spans a pod boundary (id // 256).

MODEL_FLOPS uses the published 6*N*D (train) / 2*N*D (inference) approximation
with N = active params, D = tokens; the ratio MODEL_FLOPS / (HLO_FLOPs x chips)
exposes remat recompute, causal-masking waste and attention/routing overhead.
"""

from __future__ import annotations

import dataclasses
import json
import math
import re

import numpy as np

from .mesh import CHIPS_PER_POD, DCN_BW, HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# jax.named_scope markers for Pallas-kernel fusion regions (see
# hlo_analysis.analyze_hlo kernel_scopes)
KERNEL_SCOPES = ("fa_kernel_region", "ssd_kernel_region", "rglru_kernel_region")

_OP_RE = re.compile(
    r"=\s+(?P<ret>\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def _shape_bytes(ret: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(ret):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_groups(line: str):
    """-> (group_size, groups ndarray | None)."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        return s, ids.reshape(g, s)
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        groups = [
            [int(x) for x in grp.split(",") if x.strip()]
            for grp in re.findall(r"\{([^}]*)\}", m.group(1))
        ]
        if groups and groups[0]:
            return len(groups[0]), np.array(groups)
    return 1, None


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    cross_pod: bool
    wire_bytes: float  # per chip

    @staticmethod
    def wire(kind: str, nbytes: int, n: int) -> float:
        """Per-chip ring-collective wire bytes."""
        if n <= 1:
            return 0.0
        if kind == "all-reduce":
            return 2.0 * nbytes * (n - 1) / n
        if kind == "all-gather":
            return nbytes * (n - 1) / n          # nbytes = gathered (full) size
        if kind == "reduce-scatter":
            return nbytes * (n - 1)              # nbytes = shard (result) size
        if kind == "all-to-all":
            return nbytes * (n - 1) / n
        if kind == "collective-permute":
            return float(nbytes)
        return 0.0


def parse_collectives(hlo_text: str, chips_per_pod: int = CHIPS_PER_POD) -> list[CollectiveOp]:
    out = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None or "-done" in line[: m.start() + 20]:
            continue
        kind = m.group("op")
        nbytes = _shape_bytes(m.group("ret"))
        gsize, groups = _parse_groups(line)
        cross = False
        if groups is not None:
            cross = bool((groups // chips_per_pod != groups[:, :1] // chips_per_pod).any())
        out.append(
            CollectiveOp(
                kind=kind,
                result_bytes=nbytes,
                group_size=gsize,
                cross_pod=cross,
                wire_bytes=CollectiveOp.wire(kind, nbytes, gsize),
            )
        )
    return out


def model_flops(cfg, shape) -> float:
    """Published approximation: 6*N*D train, 2*N*D inference."""
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one new token per row
    return 2.0 * n_active * tokens


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    ici_bytes: float
    dcn_bytes: float
    n_collectives: int
    model_flops: float
    argument_bytes: int = 0
    output_bytes: int = 0
    temp_bytes: int = 0

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return max(self.ici_bytes / ICI_BW, self.dcn_bytes / DCN_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s, "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        hlo_global = self.flops_per_chip * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilisation at the roofline step time."""
        denom = self.step_s * self.chips * PEAK_FLOPS_BF16
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            compute_s=self.compute_s, memory_s=self.memory_s,
            collective_s=self.collective_s, dominant=self.dominant,
            step_s=self.step_s, useful_ratio=self.useful_ratio, mfu=self.mfu,
        )
        return d


def analyze(compiled, *, arch: str, shape, cfg, mesh_name: str, chips: int):
    """-> (Roofline, HLOCost).  FLOPs/bytes are *loop-corrected*:

    cost_analysis() counts while bodies once, so we re-derive FLOPs from the
    HLO dot/conv inventory with trip-count multiplicity (hlo_analysis), and
    scale cost_analysis' byte count by the (multiplicity-aware / body-once)
    ratio of our instruction-level byte model — calibrating our model's
    absolute conventions against XLA's while keeping the loop correction."""
    from repro.core.jax_compat import cost_analysis_dict

    from .hlo_analysis import analyze_hlo

    ca = cost_analysis_dict(compiled)
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    hc = analyze_hlo(text, kernel_scopes=KERNEL_SCOPES)
    # Byte calibration: our instruction-level model overcounts ~3-4x vs XLA's
    # HloCostAnalysis conventions (fusion-interior traffic).  Anchor the
    # absolute scale to cost_analysis() (body-once, unscoped) and apply our
    # model's *ratio* for the two corrections it adds: while-loop trip counts
    # and Pallas-kernel VMEM regions.
    hc_once = analyze_hlo(text, unroll_while=False)
    ratio = hc.bytes / hc_once.bytes if hc_once.bytes else 1.0
    bytes_corrected = float(ca.get("bytes accessed", 0.0)) * ratio
    r = Roofline(
        arch=arch,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        flops_per_chip=hc.flops,
        bytes_per_chip=bytes_corrected,
        ici_bytes=hc.ici_wire,
        dcn_bytes=hc.dcn_wire,
        n_collectives=int(sum(v["count"] for v in hc.collectives.values())),
        model_flops=model_flops(cfg, shape),
        argument_bytes=getattr(ma, "argument_size_in_bytes", 0),
        output_bytes=getattr(ma, "output_size_in_bytes", 0),
        temp_bytes=getattr(ma, "temp_size_in_bytes", 0),
    )
    return r, hc


def format_row(r: Roofline) -> str:
    return (
        f"{r.arch:<18} {r.shape:<12} {r.mesh:<9} "
        f"c={r.compute_s:9.4f}s m={r.memory_s:9.4f}s x={r.collective_s:9.4f}s "
        f"dom={r.dominant:<10} useful={r.useful_ratio:6.2f} mfu={r.mfu:6.3f}"
    )
