"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block: x -> [branch1: linear -> GeLU] * [branch2: linear -> causal depthwise
conv1d -> RG-LRU] -> out linear.

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a xc_t + b_a)          recurrence gate
    i_t = sigmoid(W_x xc_t + b_x)          input gate
    log a_t = c * r_t * log_sigmoid(Lambda)            (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * xc_t)

Training/prefill uses ``jax.lax.associative_scan`` over the sequence (O(S log S)
work, O(log S) depth — the TPU-friendly formulation); decode is the one-step
update.  A Pallas chunked-scan kernel (repro/kernels/rglru_scan) implements the
same recurrence with VMEM-resident state for the hot path.

Gate matrices are full (W x W) rather than Griffin's block-diagonal — noted in
DESIGN.md (slightly more params, same recurrence dynamics).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamBuilder
from .sharding import shard

RGLRU_C = 8.0


def declare_rglru(pb: ParamBuilder, prefix: str, d_model: int, width: int, conv_width: int, stack: int = 0):
    lead = (stack,) if stack else ()
    lax = ("layers",) if stack else ()
    pb.declare(f"{prefix}/wy", lead + (d_model, width), lax + ("fsdp", "mlp"))
    pb.declare(f"{prefix}/wx", lead + (d_model, width), lax + ("fsdp", "mlp"))
    pb.declare(f"{prefix}/conv_w", lead + (conv_width, width), lax + (None, "mlp"))
    pb.declare(f"{prefix}/conv_b", lead + (width,), lax + ("mlp",), init="zeros")
    pb.declare(f"{prefix}/wa", lead + (width, width), lax + ("fsdp", "mlp"), init="normal")
    pb.declare(f"{prefix}/ba", lead + (width,), lax + ("mlp",), init="zeros")
    pb.declare(f"{prefix}/wi", lead + (width, width), lax + ("fsdp", "mlp"), init="normal")
    pb.declare(f"{prefix}/bi", lead + (width,), lax + ("mlp",), init="zeros")
    pb.declare(f"{prefix}/lam", lead + (width,), lax + ("mlp",), init="rglru_a")
    pb.declare(f"{prefix}/wo", lead + (width, d_model), lax + ("mlp", "fsdp"))


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv.  x: (B, S, W); w: (K, W); b: (W,)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # K is tiny (4); unrolled adds beat a conv op here
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out + b


def conv1d_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array, b: jax.Array):
    """One decode step.  x_t: (B, W); conv_state: (B, K-1, W) past inputs."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, K, W)
    out = jnp.einsum("bkw,kw->bw", window, w) + b
    return out, window[:, 1:, :]


def _gates(params, xc):
    r = jax.nn.sigmoid(
        jnp.einsum("...w,wv->...v", xc.astype(jnp.float32), params["wa"].astype(jnp.float32))
        + params["ba"].astype(jnp.float32)
    )
    i = jax.nn.sigmoid(
        jnp.einsum("...w,wv->...v", xc.astype(jnp.float32), params["wi"].astype(jnp.float32))
        + params["bi"].astype(jnp.float32)
    )
    log_a = RGLRU_C * r * jax.nn.log_sigmoid(params["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xc.astype(jnp.float32))
    return a, gated_in


def rglru_scan(params: dict, xc: jax.Array, h0: jax.Array | None = None, *, impl: str = "assoc"):
    """xc: (B, S, W) conv output -> (y (B, S, W), h_last (B, W))."""
    a, gi = _gates(params, xc)
    if impl == "pallas":
        from repro.kernels.rglru_scan import ops as rg_ops

        h0_ = jnp.zeros(a[:, 0].shape, jnp.float32) if h0 is None else h0.astype(jnp.float32)
        y = rg_ops.linear_scan(a, gi, h0_)
        return y.astype(xc.dtype), y[:, -1].astype(jnp.float32)
    if h0 is not None:
        gi = gi.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    # rglru Pallas kernel region: the scan's intermediate tree levels stay in
    # VMEM on TPU (the kernel streams (a, gi) once and writes h once)
    with jax.named_scope("rglru_kernel_region"):
        _, h = jax.lax.associative_scan(combine, (a, gi), axis=1)
    return h.astype(xc.dtype), h[:, -1]


def rglru_step(params: dict, xc_t: jax.Array, h_prev: jax.Array):
    """One decode step.  xc_t: (B, W); h_prev: (B, W) fp32."""
    a, gi = _gates(params, xc_t)
    h = a * h_prev.astype(jnp.float32) + gi
    return h.astype(xc_t.dtype), h


def rglru_block(params: dict, x: jax.Array, *, scan_impl: str = "assoc"):
    """Full Griffin recurrent block, training/prefill mode.

    x: (B, S, D) -> (y: (B, S, D), state (h_last, conv_tail))."""
    y_branch = jnp.einsum("bsd,dw->bsw", x, params["wy"])
    y_branch = jax.nn.gelu(y_branch.astype(jnp.float32), approximate=True).astype(x.dtype)
    xb = jnp.einsum("bsd,dw->bsw", x, params["wx"])
    xb = shard(xb, "batch", None, "mlp")
    xc = causal_conv1d(xb, params["conv_w"], params["conv_b"])
    h, h_last = rglru_scan(params, xc, impl=scan_impl)
    out = jnp.einsum("bsw,wd->bsd", h * y_branch, params["wo"])
    k = params["conv_w"].shape[0]
    conv_tail = xb[:, -(k - 1) :, :] if xb.shape[1] >= k - 1 else jnp.pad(
        xb, ((0, 0), (k - 1 - xb.shape[1], 0), (0, 0))
    )
    return shard(out, "batch", "seq", "embed"), (h_last.astype(jnp.float32), conv_tail)


def rglru_block_step(params: dict, x_t: jax.Array, state):
    """Decode step.  x_t: (B, 1, D); state = (h (B,W) fp32, conv (B,K-1,W))."""
    h_prev, conv_state = state
    xt = x_t[:, 0, :]
    y_branch = jax.nn.gelu(
        (xt @ params["wy"]).astype(jnp.float32), approximate=True
    ).astype(x_t.dtype)
    xb = xt @ params["wx"]
    xc, conv_state = conv1d_step(xb, conv_state.astype(xb.dtype), params["conv_w"], params["conv_b"])
    h, h_new = rglru_step(params, xc, h_prev)
    out = (h * y_branch) @ params["wo"]
    return out[:, None, :], (h_new, conv_state)
