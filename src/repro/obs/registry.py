"""One metrics registry over four legacy counter surfaces.

``MetricsRegistry`` holds three metric kinds — ``Counter``, ``Gauge``
(optionally backed by a callable so legacy dataclass fields register as
*views* with zero call-site changes), and ``BoundedHistogram`` — and renders
them uniformly (``collect()`` dict, Prometheus-style text).

``BoundedHistogram`` is the fix for the unbounded sample lists
(``SchedulerMetrics.waits``, ``RouterStats.stalls``): list-compatible
(``append``/``len``/index/iterate, so ``np.array(m.waits)`` and
``sorted(stats.stalls)`` keep working), exact up to ``cap`` samples, then a
deterministic reservoir (private ``random.Random`` seed — never the shared
discipline RNG streams) keeps a uniform subsample while ``n``/``total``/
``vmin``/``vmax`` stay exact.  Default caps exceed every bench's sample
count, so swapping the lists changes no published number.
"""

from __future__ import annotations

import random
import re
from typing import Any, Callable, Iterator

_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")


def _sanitize(name: str) -> str:
    return _NAME_OK.sub("_", name)


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """A settable value, or a live view when constructed with ``fn``."""

    __slots__ = ("name", "_value", "fn")

    def __init__(self, name: str, fn: Callable[[], Any] | None = None) -> None:
        self.name = name
        self._value = 0
        self.fn = fn

    def set(self, v) -> None:
        self._value = v

    @property
    def value(self):
        return self.fn() if self.fn is not None else self._value


class BoundedHistogram:
    """Bounded sample reservoir with exact quantiles under the cap.

    Behaves like the list it replaces (append / len / index / iterate) but
    retains at most ``cap`` samples: Vitter's algorithm R over a private
    seeded RNG once full.  ``n`` (true count), ``total``, ``vmin``/``vmax``
    are always exact; ``percentile`` is exact while ``n <= cap`` and an
    unbiased estimate beyond.
    """

    __slots__ = ("cap", "n", "total", "vmin", "vmax", "_samples", "_rng")

    def __init__(self, cap: int = 8192, seed: int = 0x0B5E) -> None:
        if cap < 1:
            raise ValueError("cap must be >= 1")
        self.cap = cap
        self.n = 0
        self.total = 0
        self.vmin: float | None = None
        self.vmax: float | None = None
        self._samples: list = []
        self._rng = random.Random(seed)

    def append(self, v) -> None:
        self.n += 1
        self.total += v
        self.vmin = v if self.vmin is None else min(self.vmin, v)
        self.vmax = v if self.vmax is None else max(self.vmax, v)
        if len(self._samples) < self.cap:
            self._samples.append(v)
        else:
            j = self._rng.randrange(self.n)
            if j < self.cap:
                self._samples[j] = v

    observe = append

    def extend(self, vs) -> None:
        for v in vs:
            self.append(v)

    def __len__(self) -> int:
        return len(self._samples)

    def __getitem__(self, i):
        return self._samples[i]

    def __iter__(self) -> Iterator:
        return iter(self._samples)

    def __bool__(self) -> bool:
        return bool(self._samples)

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def percentile(self, q: float):
        """Nearest-rank percentile of the retained samples, ``q`` in [0, 100]."""
        if not self._samples:
            return 0
        s = sorted(self._samples)
        return s[min(len(s) - 1, int(q / 100.0 * len(s)))]

    def summary(self) -> dict:
        return {
            "count": self.n,
            "sum": self.total,
            "min": self.vmin if self.vmin is not None else 0,
            "max": self.vmax if self.vmax is not None else 0,
            "mean": self.mean,
            "p50": self.percentile(50),
            "p99": self.percentile(99),
            "retained": len(self._samples),
        }


class HistogramVector:
    """A labeled family of ``BoundedHistogram``s — one histogram per label
    value (e.g. per-tenant admission stalls in the region tier).

    Labels are created lazily on first ``observe``; each child keeps the
    usual bounded-reservoir guarantees.  Child seeds derive deterministically
    from the family seed and the label's creation order, so a run that
    observes the same labeled samples in the same order reproduces the same
    retained reservoirs bit-for-bit.  Renders as one Prometheus summary per
    label (``name{label="..."}``) and as a ``{label: summary}`` dict in
    ``MetricsRegistry.collect``.
    """

    __slots__ = ("label", "cap", "seed", "_hists")

    def __init__(self, label: str = "label", cap: int = 8192, seed: int = 0x0B5E) -> None:
        self.label = label
        self.cap = cap
        self.seed = seed
        self._hists: dict = {}

    def hist(self, key) -> BoundedHistogram:
        h = self._hists.get(key)
        if h is None:
            h = BoundedHistogram(self.cap, seed=self.seed + 0x9E37 * len(self._hists))
            self._hists[key] = h
        return h

    def observe(self, key, v) -> None:
        self.hist(key).append(v)

    def labels(self) -> list:
        return list(self._hists)

    def items(self):
        return self._hists.items()

    def __len__(self) -> int:
        return len(self._hists)

    def __contains__(self, key) -> bool:
        return key in self._hists

    def __getitem__(self, key) -> BoundedHistogram:
        return self._hists[key]

    def summary(self) -> dict:
        return {str(k): h.summary() for k, h in self._hists.items()}


class MetricsRegistry:
    """Named counters, gauges, histograms — one surface, many sources.

    Legacy stat objects register via ``adopt``: each numeric attribute (and
    any named property) becomes a live ``Gauge`` view, each
    ``BoundedHistogram`` attribute is attached under its own name, and dict
    attributes render as labeled gauges.  The legacy object stays the
    single source of truth; the registry reads through.
    """

    def __init__(self) -> None:
        self._metrics: dict[str, Any] = {}

    def _put(self, name: str, metric):
        self._metrics[name] = metric
        return metric

    def counter(self, name: str) -> Counter:
        m = self._metrics.get(name)
        if m is None:
            m = self._put(name, Counter(name))
        return m

    def gauge(self, name: str, fn: Callable[[], Any] | None = None) -> Gauge:
        m = self._metrics.get(name)
        if m is None or fn is not None:
            m = self._put(name, Gauge(name, fn))
        return m

    view = gauge

    def histogram(self, name: str, cap: int = 8192, seed: int = 0x0B5E) -> BoundedHistogram:
        m = self._metrics.get(name)
        if m is None:
            m = self._put(name, BoundedHistogram(cap, seed))
        return m

    def attach(self, name: str, hist):
        """Register an existing ``BoundedHistogram`` (e.g.
        ``SchedulerMetrics.waits``) or ``HistogramVector`` under ``name``."""
        return self._put(name, hist)

    def histogram_vector(self, name: str, label: str = "label",
                         cap: int = 8192, seed: int = 0x0B5E) -> HistogramVector:
        m = self._metrics.get(name)
        if m is None:
            m = self._put(name, HistogramVector(label, cap, seed))
        return m

    def adopt(self, prefix: str, obj: Any, fields=None, props=()) -> None:
        """Register a legacy stats object's numeric surface as live views.

        ``fields`` defaults to every public attribute holding an int/float,
        dict, or ``BoundedHistogram``; ``props`` names derived properties
        (``locality``, ``hit_rate``, …) to expose as gauges too.
        """
        names = fields if fields is not None else [
            a for a in vars(obj) if not a.startswith("_")
        ]
        for attr in names:
            v = getattr(obj, attr)
            name = f"{prefix}_{attr}"
            if isinstance(v, BoundedHistogram):
                self.attach(name, v)
            elif isinstance(v, dict):
                self.gauge(name, fn=(lambda o=obj, a=attr: dict(getattr(o, a))))
            elif isinstance(v, (int, float)):
                self.gauge(name, fn=(lambda o=obj, a=attr: getattr(o, a)))
        for prop in props:
            self.gauge(f"{prefix}_{prop}", fn=(lambda o=obj, p=prop: getattr(o, p)))

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str):
        return self._metrics[name]

    def names(self) -> list[str]:
        return list(self._metrics)

    def collect(self) -> dict:
        """Snapshot every metric as plain python values (JSON-safe)."""
        out: dict = {}
        for name, m in self._metrics.items():
            if isinstance(m, (BoundedHistogram, HistogramVector)):
                out[name] = m.summary()
            else:
                out[name] = m.value
        return out

    def render_prometheus(self) -> str:
        """Prometheus-style text exposition of the current snapshot."""
        lines: list[str] = []
        for name, m in self._metrics.items():
            pname = _sanitize(name)
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {m.value}")
            elif isinstance(m, BoundedHistogram):
                lines.append(f"# TYPE {pname} summary")
                lines.append(f'{pname}{{quantile="0.5"}} {m.percentile(50)}')
                lines.append(f'{pname}{{quantile="0.99"}} {m.percentile(99)}')
                lines.append(f"{pname}_count {m.n}")
                lines.append(f"{pname}_sum {m.total}")
            elif isinstance(m, HistogramVector):
                lines.append(f"# TYPE {pname} summary")
                lab = _sanitize(m.label)
                for key, h in sorted(m.items(), key=lambda e: str(e[0])):
                    sel = f'{lab}="{key}"'
                    lines.append(f'{pname}{{{sel},quantile="0.5"}} {h.percentile(50)}')
                    lines.append(f'{pname}{{{sel},quantile="0.99"}} {h.percentile(99)}')
                    lines.append(f'{pname}_count{{{sel}}} {h.n}')
                    lines.append(f'{pname}_sum{{{sel}}} {h.total}')
            else:
                v = m.value
                if isinstance(v, dict):
                    lines.append(f"# TYPE {pname} gauge")
                    for k, kv in sorted(v.items(), key=lambda e: str(e[0])):
                        lines.append(f'{pname}{{key="{k}"}} {kv}')
                else:
                    lines.append(f"# TYPE {pname} gauge")
                    lines.append(f"{pname} {v}")
        return "\n".join(lines) + "\n"
