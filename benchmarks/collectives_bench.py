"""CNA-inspired collective schedules: wire-byte accounting + numerics.

The multi-pod analogue of the paper's locality argument: per-step traffic on
the slow (DCN/"remote-socket") axis should carry 1/N-sized shards, compressed
payloads, or nothing at all (deferred sync) — measured here with the same
wire models the roofline uses, plus numeric validation on a subprocess mesh.
"""

from __future__ import annotations

import subprocess
import sys
import textwrap

import numpy as np

from repro.core.collectives import (
    dequantize_int8,
    quantize_int8,
    wire_bytes_allgather,
    wire_bytes_allreduce,
    wire_bytes_reducescatter,
)

from .common import REPO_ROOT, claim, subproc_env, table


def wire_accounting(grad_bytes=2 * 8_000_000_000, intra=16, pods=2):
    """Per-chip DCN traffic per step for an 8B-param bf16 gradient."""
    flat = wire_bytes_allreduce(grad_bytes, intra * pods)       # flat ring over all chips
    flat_dcn = flat  # worst-case: the ring crosses pods every hop / no locality
    hier_dcn = wire_bytes_allreduce(grad_bytes / intra, pods)   # after intra-pod RS
    comp_dcn = hier_dcn / 2                                      # int8 vs bf16
    defer_dcn = hier_dcn / 64                                    # sync every K=64 steps
    rows = [
        ["flat all-reduce (pod-oblivious)", flat_dcn / 2**30],
        ["hierarchical (CNA: RS-intra -> AR-pod -> AG-intra)", hier_dcn / 2**30],
        ["hierarchical + int8 compression", comp_dcn / 2**30],
        ["hierarchical + deferred K=64 (amortised)", defer_dcn / 2**30],
    ]
    table("gradient-sync DCN bytes per chip per step (8B params, GiB)",
          ["schedule", "dcn_GiB"], rows)
    claim("collectives: hierarchical cuts slow-axis traffic by ~intra x",
          flat_dcn / hier_dcn > intra * 0.9, f"ratio={flat_dcn / hier_dcn:.1f}")


def quantization_error():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (512, 512)).astype(np.float32)
    q, s = quantize_int8(x)
    err = np.abs(dequantize_int8(np.asarray(q), np.asarray(s)) - x).max()
    bound = float(np.asarray(s)) / 2 + 1e-7
    table("int8 compression error", ["max_err", "bound(scale/2)"], [[float(err), bound]])
    claim("collectives: int8 error <= scale/2", err <= bound, f"{err:.5f} <= {bound:.5f}")


_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.collectives import cna_grad_sync, hierarchical_grad_sync
    from repro.core.jax_compat import shard_map

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 8), jnp.float32)

    def flat(g):
        return jax.lax.psum(g, ("pod", "data"))

    spec = P(None, None)
    args = dict(mesh=mesh, in_specs=(spec,), out_specs=spec, check_vma=False)
    flat_fn = jax.jit(shard_map(flat, **args))
    hier_fn = jax.jit(shard_map(lambda g: hierarchical_grad_sync(g), **args))
    comp_fn = jax.jit(shard_map(lambda g: cna_grad_sync(g, compress=True), **args))

    want = np.asarray(flat_fn(x))
    got_h = np.asarray(hier_fn(x))
    got_c = np.asarray(comp_fn(x))
    np.testing.assert_allclose(got_h, want, rtol=1e-5)
    err = np.abs(got_c - want).max() / np.abs(want).max()
    assert err < 0.02, err
    print("MESH_OK hierarchical exact, compressed rel-err", float(err))
""")


def mesh_numerics():
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT], capture_output=True, text=True, timeout=300,
        env=subproc_env(), cwd=REPO_ROOT,
    )
    ok = proc.returncode == 0 and "MESH_OK" in proc.stdout
    claim("collectives: hierarchical == flat psum; compressed within 2% (8-dev mesh)",
          ok, proc.stdout.strip().splitlines()[-1] if ok else proc.stderr[-300:])


def run_all():
    wire_accounting()
    quantization_error()
    mesh_numerics()
