"""repro.placement: freelists, policies, adaptive controller, telemetry —
plus the cross-driver contract that ONE controller implementation drives both
the lock simulator and the serving scheduler."""

import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core.discipline import CNADiscipline, RestrictedDiscipline
from repro.core.locks_sim import AdaptiveRCNASim
from repro.core.numasim import TWO_SOCKET, Simulator
from repro.core.topology import flat, pod
from repro.placement import (
    AdaptiveController,
    DomainFreeLists,
    PlacementTelemetry,
    get_policy,
)


# -- freelists ----------------------------------------------------------------


def test_freelists_partition_follows_topology():
    topo = pod(2, 2)  # 4 domains, slots round-robin
    fl = DomainFreeLists(8, topo)
    assert fl.slot_domain == tuple(topo.domain_of(s) for s in range(8))
    assert [fl.free_count(d) for d in range(4)] == [2, 2, 2, 2]
    assert len(fl) == 8 and fl.free_slots() == list(range(8))


def test_freelists_claim_in_is_lowest_first_and_exhausts():
    fl = DomainFreeLists(8, flat(4))
    assert fl.claim_in(1) == 1
    assert fl.claim_in(1) == 5
    assert fl.claim_in(1) is None
    assert len(fl) == 6


def test_freelists_spill_order_distance_then_index():
    topo = pod(2, 2)  # domains {0,1} pod A, {2,3} pod B
    fl = DomainFreeLists(4, topo)
    assert fl.spill_order[0] == (0, 1, 2, 3)
    assert fl.spill_order[3] == (3, 2, 0, 1)
    # drain domain 1's pool; nearest claim for home=1 spills to sibling 0
    assert fl.claim_in(1) == 1
    assert fl.claim_nearest(1) == (0, 0)
    # both pod-A domains empty: next spill crosses the pod to domain 2
    assert fl.claim_nearest(1) == (2, 2)


def test_freelists_release_returns_home_and_validates():
    fl = DomainFreeLists(4, flat(2))
    slot = fl.claim_in(0)
    assert fl.release(slot) == 0
    with pytest.raises(ValueError, match="already free"):
        fl.release(slot)
    with pytest.raises(ValueError, match="out of range"):
        fl.release(99)


def test_freelists_conservation_under_random_churn():
    rng = random.Random(0)
    topo = pod(2, 2)
    fl = DomainFreeLists(12, topo)
    held = []
    for _ in range(500):
        if held and (len(fl) == 0 or rng.random() < 0.5):
            fl.release(held.pop(rng.randrange(len(held))))
        else:
            out = fl.claim_nearest(rng.randrange(4))
            assert out is not None
            held.append(out[0])
        assert len(fl) + len(held) == 12
    for s in held:
        fl.release(s)
    assert fl.free_slots() == list(range(12))


def test_freelists_explicit_slot_domain_map():
    fl = DomainFreeLists(4, flat(2), slot_domain=[0, 0, 0, 1])
    assert [fl.free_count(d) for d in range(2)] == [3, 1]
    with pytest.raises(ValueError, match="unknown domains"):
        DomainFreeLists(2, flat(2), slot_domain=[0, 5])
    with pytest.raises(ValueError, match="one entry per slot"):
        DomainFreeLists(3, flat(2), slot_domain=[0, 1])


def test_freelists_double_release_is_o1_against_free_set():
    """Regression for the release-path complexity fix: the double-free check
    now reads an O(1) set mirror of the pools (``_free_set`` — absent on the
    old code, which scanned the home pool's heap list), and double-release
    still raises after arbitrary churn."""
    topo = pod(2, 2)
    fl = DomainFreeLists(64, topo)
    held = [fl.claim_nearest(i % 4)[0] for i in range(40)]
    assert fl._free_set == set(fl.free_slots()) and len(fl) == 24
    s = held.pop()
    assert s not in fl._free_set
    fl.release(s)
    assert s in fl._free_set
    with pytest.raises(ValueError, match="already free"):
        fl.release(s)
    for s in held:
        fl.release(s)
    assert fl.free_slots() == list(range(64)) and fl._free_set == set(range(64))


# -- freelists property tests --------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(n_pods=st.integers(1, 3), spp=st.integers(1, 3),
       n_slots=st.integers(1, 24), seed=st.integers(0, 10_000))
def test_prop_freelists_invariants_under_churn(n_pods, spp, n_slots, seed):
    """claim/release round-trips preserve len, no slot ever appears in two
    pools (or twice in one), the free set mirrors the heaps exactly, and
    every pooled slot sits in its home domain's pool."""
    topo = pod(n_pods, spp)
    fl = DomainFreeLists(n_slots, topo)
    rng = random.Random(seed)
    held = []
    for _ in range(3 * n_slots):
        if held and (len(fl) == 0 or rng.random() < 0.5):
            fl.release(held.pop(rng.randrange(len(held))))
        else:
            held.append(fl.claim_nearest(rng.randrange(topo.n_domains))[0])
        pooled = [s for pool in fl._pools for s in pool]
        assert len(pooled) == len(set(pooled)) == len(fl)
        assert set(pooled) == fl._free_set
        assert len(fl) + len(held) == n_slots
        assert not fl._free_set & set(held)
        for dom, pool in enumerate(fl._pools):
            assert all(fl.slot_domain[s] == dom for s in pool)
    for s in held:
        fl.release(s)
    assert fl.free_slots() == list(range(n_slots))


@settings(max_examples=25, deadline=None)
@given(n_pods=st.integers(1, 4), spp=st.integers(1, 4))
def test_prop_spill_order_is_distance_sorted(n_pods, spp):
    """Every home's spill order is a permutation of the domains, starts at
    home, has non-decreasing distance, and breaks distance ties by index."""
    topo = pod(n_pods, spp)
    fl = DomainFreeLists(topo.n_domains, topo)
    for home, order in enumerate(fl.spill_order):
        assert sorted(order) == list(range(topo.n_domains))
        assert order[0] == home
        keys = [(topo.distance(home, d), d) for d in order]
        assert keys == sorted(keys)


# -- policies -----------------------------------------------------------------


def test_policy_home_hit_costs_nothing():
    fl = DomainFreeLists(8, pod(2, 2))
    p = get_policy("nearest_spill").place(fl, 2, TWO_SOCKET)
    assert p.slot_domain == 2 and p.local and p.distance == 0
    assert p.migration_cycles == 0


def test_policy_nearest_spill_prices_sibling_and_cross():
    topo = pod(2, 2)
    fl = DomainFreeLists(4, topo)
    pol = get_policy("nearest_spill")
    assert pol.place(fl, 1, TWO_SOCKET).slot == 1          # home hit
    sib = pol.place(fl, 1, TWO_SOCKET)                      # spill to sibling 0
    assert (sib.slot_domain, sib.distance) == (0, 1)
    assert sib.migration_cycles == TWO_SOCKET.c_remote_xfer
    cross = pol.place(fl, 1, TWO_SOCKET)                    # cross-pod spill
    assert (cross.slot_domain, cross.distance) == (2, 2)
    assert cross.migration_cycles == TWO_SOCKET.c_cross_xfer
    assert pol.place(fl, 1, TWO_SOCKET).slot_domain == 3
    assert pol.place(fl, 1, TWO_SOCKET) is None             # exhausted


def test_policy_lowest_free_matches_seed_rule():
    fl = DomainFreeLists(6, flat(3))
    pol = get_policy("lowest_free")
    order = [pol.place(fl, 2).slot for _ in range(6)]
    assert order == list(range(6))  # blind lowest-slot-first, like the seed


def test_policy_home_domain_falls_back_to_global_lowest():
    fl = DomainFreeLists(6, flat(3))
    pol = get_policy("home_domain")
    assert pol.place(fl, 1).slot == 1
    assert pol.place(fl, 1).slot == 4
    fallback = pol.place(fl, 1)
    assert fallback.slot == 0 and fallback.slot_domain == 0


def test_get_policy_coercions():
    from repro.placement import NearestSpill, PlacementPolicy

    assert isinstance(get_policy("home_domain"), PlacementPolicy)
    assert isinstance(get_policy(NearestSpill), NearestSpill)
    ns = NearestSpill()
    assert get_policy(ns) is ns
    with pytest.raises(KeyError, match="unknown placement policy"):
        get_policy("no_such_policy")
    with pytest.raises(TypeError):
        get_policy(3.14)


# -- telemetry ----------------------------------------------------------------


def test_telemetry_counters_and_locality():
    topo = pod(2, 2)
    fl = DomainFreeLists(4, topo)
    tel = PlacementTelemetry(n_domains=4)
    pol = get_policy("nearest_spill")
    for _ in range(3):  # home hit, sibling spill, cross spill for home=1
        tel.record_placement(pol.place(fl, 1, TWO_SOCKET))
    assert tel.placements == 3 and tel.local_placements == 1
    assert tel.sibling_spills == 1 and tel.cross_spills == 1 and tel.spills == 2
    assert tel.migration_cycles == TWO_SOCKET.c_remote_xfer + TWO_SOCKET.c_cross_xfer
    assert tel.locality == pytest.approx(1 / 3)
    assert tel.per_domain_occupancy == {1: 1, 0: 1, 2: 1}
    tel.record_release(0)
    assert tel.per_domain_occupancy[0] == 0 and tel.peak_occupancy[0] == 1


def test_telemetry_release_never_drives_occupancy_negative():
    """Regression: an unmatched release (double release, or one routed to a
    domain with no live placement) used to push ``per_domain_occupancy``
    negative — biasing every derived-home tie-break toward a domain that was
    never occupied.  It now counts as ``unmatched_releases`` and leaves the
    occupancy map untouched."""
    tel = PlacementTelemetry(n_domains=2)
    tel.record_release(0)  # nothing ever placed in domain 0
    assert tel.per_domain_occupancy.get(0, 0) == 0
    assert tel.releases == 1 and tel.unmatched_releases == 1

    fl = DomainFreeLists(2, pod(1, 2))
    tel.record_placement(get_policy("nearest_spill").place(fl, 1, TWO_SOCKET))
    tel.record_release(1)
    tel.record_release(1)  # double release of the same claim
    assert tel.per_domain_occupancy[1] == 0
    assert tel.unmatched_releases == 2
    assert min(tel.per_domain_occupancy.values()) >= 0


# -- adaptive controller ------------------------------------------------------


def test_controller_grows_on_cheap_handovers():
    c = AdaptiveController(initial=4, max_cap=10, window=8)
    for _ in range(24):
        c.observe(60)
    assert c.cap == 7 and c.trajectory == [5, 6, 7]


def test_controller_shrinks_on_stalls_and_respects_min():
    c = AdaptiveController(initial=3, min_active=2, window=4, tolerance=0)
    for _ in range(16):
        c.observe(60)
        c.observe(60)
        c.observe(60)
        c.observe(30_000)  # one preemption-stalled handover per window
    assert c.cap == 2  # shrank once per window, clamped at min_active
    assert c.stall_rate == pytest.approx(0.25)


def test_controller_collapse_shrinks_multiplicatively():
    c = AdaptiveController(initial=64, window=4)
    for _ in range(4):
        c.observe(100)
    for _ in range(4):  # majority-stalled window -> AIMD retreat
        c.observe(50_000)
    assert c.cap == 48  # 64 * 0.75, not 63


def test_controller_floor_tracks_cheapest_handover():
    c = AdaptiveController(initial=4)
    c.observe(500)
    assert c.floor == 500
    c.observe(60)
    assert c.floor == 60
    c.observe(30_000)  # floor only drifts up by floor_relax, never jumps
    assert c.floor == pytest.approx(60 * 1.001)


def test_controller_zero_latency_samples_are_cheap_not_stalls():
    """Regression: a zero-latency handover (home-domain admission, no switch
    — the engine's common case) must not pin the floor at 0 and turn every
    later positive sample into a 'stall' that ratchets the cap to min."""
    c = AdaptiveController(initial=8, max_cap=10, window=4)
    c.observe(0)
    for _ in range(11):  # mixed zero/cheap-switch samples, stall-free
        c.observe(0)
        c.observe(4)
        c.observe(8)
    assert c.stalls == 0
    assert c.cap > 8  # grew on stall-free windows instead of collapsing
    assert c.floor == pytest.approx(4, rel=0.05)  # cheapest *positive* sample
    c2 = AdaptiveController(initial=8, window=4)
    for _ in range(8):
        c2.observe(0)  # all-zero trace: no baseline, nothing stalls
    assert c2.stalls == 0 and c2.floor == 0.0


def test_controller_ewma_gates_growth_after_collapse():
    """A stall-free window alone is not enough to raise the cap while the
    smoothed latency still remembers a collapse episode."""
    c = AdaptiveController(initial=8, max_cap=16, window=4, alpha=1 / 64)
    for _ in range(4):
        c.observe(60)
    for _ in range(8):
        c.observe(30_000)  # collapse: ewma way above the stall threshold
    cap_after_collapse = c.cap
    for _ in range(4):  # one cheap window; ewma (slow alpha) still elevated
        c.observe(60)
    assert c.cap == cap_after_collapse  # growth held back by the ewma gate
    for _ in range(256):  # sustained cheap traffic drains the average
        c.observe(60)
    assert c.cap > cap_after_collapse


@settings(max_examples=25, deadline=None)
@given(initial=st.integers(1, 32), window=st.integers(1, 16),
       n=st.integers(1, 200))
def test_prop_controller_all_zero_stream_never_shrinks(initial, window, n):
    """Floor edge case: an all-zero-latency stream (every admission a
    home-domain hit) establishes no positive baseline, classifies nothing as
    a stall, and must never shrink the cap below its starting point."""
    c = AdaptiveController(initial=initial, window=window)
    for _ in range(n):
        c.observe(0)
        assert c.cap >= initial
    assert c.stalls == 0 and c.floor == 0.0


@settings(max_examples=25, deadline=None)
@given(f=st.floats(1e-3, 1e6), x=st.floats(0.0, 1e9))
def test_prop_floor_relaxation_cannot_cross_stall_threshold(f, x):
    """Floor edge case: one sample relaxes the floor by at most floor_relax
    (1.001x), which can never carry it across the stall threshold
    (stall_factor * floor) from below — so the classifier's baseline cannot
    jump past its own cutoff in a single step, whatever arrives."""
    c = AdaptiveController(initial=4)
    c.observe(f)
    assert c.floor == pytest.approx(f)
    threshold = c.stall_factor * c.floor + c.deadband
    c.observe(x)
    assert c.floor <= f * c.floor_relax * (1 + 1e-12)
    assert c.floor < threshold
    assert not c.is_stall(c.floor)


def test_controller_deterministic_and_validates():
    trace = [60, 70, 30_000, 65] * 32
    a, b = (AdaptiveController(initial=8, window=8) for _ in range(2))
    for x in trace:
        a.observe(x)
        b.observe(x)
    assert a.trajectory == b.trajectory and a.cap == b.cap
    assert a.settled_cap() == sorted(a.trajectory[-4:])[2]
    with pytest.raises(ValueError):
        AdaptiveController(initial=0)
    with pytest.raises(ValueError):
        AdaptiveController(initial=4, min_active=0)


def test_restricted_discipline_reads_controller_cap_live():
    ctrl = AdaptiveController(initial=2, window=4, tolerance=0)
    r = RestrictedDiscipline(CNADiscipline(rng=random.Random(1)), max_active=ctrl)
    for i in range(6):
        r.arrive(i, 0)
    assert len(r.inner) == 2 and r.n_passive == 4
    for _ in range(4):  # stall-free window -> controller raises the cap
        ctrl.observe(10)
    assert r.max_active == 3
    g = r.release(0)  # refill loop honours the new cap
    assert g is not None and len(r.inner) == 3
    with pytest.raises(AttributeError, match="controller-driven"):
        r.max_active = 5


def test_restricted_discipline_static_setter_still_works():
    r = RestrictedDiscipline(CNADiscipline(rng=random.Random(2)), max_active=4)
    r.max_active = 2
    assert r.max_active == 2
    with pytest.raises(ValueError):
        r.max_active = 0
    with pytest.raises(ValueError):
        RestrictedDiscipline(CNADiscipline(), max_active=0)


# -- cross-driver contract ----------------------------------------------------


def test_cap_trajectories_identical_across_sim_and_scheduler():
    """The acceptance contract: the SAME AdaptiveController type drives both
    the lock simulator (cna_rcr_adapt) and CNAScheduler, and an identical
    handover trace produces an identical cap trajectory through either
    driver's feed path."""
    from repro.serving.scheduler import CNAScheduler

    rng = random.Random(9)
    trace = [rng.choice([60, 60, 70, 400, 10_060]) for _ in range(512)]

    params = dict(initial=24, max_cap=32, window=16)
    sim = Simulator(
        AdaptiveRCNASim, n_threads=8, n_sockets=2,
        lock_kwargs={"controller": AdaptiveController(**params)},
    )
    caps_sim = [sim.lock.observe_handover(x) or sim.lock.controller.cap for x in trace]

    sched = CNAScheduler(max_active=AdaptiveController(**params))
    caps_sched = []
    for x in trace:
        sched.observe_handover(x)
        caps_sched.append(sched.controller.cap)

    assert caps_sim == caps_sched
    assert sim.lock.controller.trajectory == sched.controller.trajectory
    assert len(set(caps_sim)) > 1  # the trace actually moved the cap


def test_adaptive_sim_converges_under_oversubscription():
    """End-to-end in the event loop: starting unrestricted at 4x
    oversubscription, the controller walks the cap down to the collapse
    boundary (~n_cores) and the run stays deterministic."""
    kw = dict(
        n_threads=32, n_sockets=2, seed=42, duration_cycles=3_000_000,
        noncs_cycles=0, n_cores=8,
    )

    def run():
        ctrl = AdaptiveController(initial=32, max_cap=32, window=16)
        sim = Simulator(
            AdaptiveRCNASim, lock_kwargs={"threshold": 0xFF, "controller": ctrl}, **kw
        )
        return sim.run(), ctrl

    r1, c1 = run()
    r2, c2 = run()
    assert r1.ops == r2.ops and c1.trajectory == c2.trajectory  # deterministic
    assert c1.cap <= 10  # settled near the 8-core boundary, far below 32
    assert c1.trajectory[0] < 32  # it moved early, not at the end
    # restriction recovered throughput: ops far above the unrestricted run
    plain = Simulator(
        __import__("repro.core.locks_sim", fromlist=["CNASim"]).CNASim,
        lock_kwargs={"threshold": 0xFF}, **kw,
    ).run()
    assert r1.ops > 2 * plain.ops


# -- controller-coupled shedding (shed-before-spill) ---------------------------


def test_shed_home_unwired_is_identity():
    ctl = AdaptiveController(initial=4)
    assert ctl.shed_home(2) == 2  # no occupancy/capacity/topology: no-op


def test_shed_home_prefers_least_occupied_sibling_never_cross_group():
    occ = {}
    ctl = AdaptiveController(initial=4, occupancy=lambda: occ,
                             domain_capacity=(2, 2, 2, 2), shed_topology=pod(2, 2))
    # home has headroom: stay
    occ.update({0: 1, 1: 0, 2: 0, 3: 0})
    assert ctl.shed_home(0) == 0
    # home full, sibling (same pod) has room: shed sideways
    occ.update({0: 2})
    assert ctl.shed_home(0) == 1
    # whole pod full: do NOT shed cross-pod — spill pricing owns that move
    occ.update({1: 2})
    assert ctl.shed_home(0) == 0
    # flat topologies make every other domain a sibling
    ctl2 = AdaptiveController(initial=4, occupancy=lambda: occ,
                              domain_capacity=(2, 2, 2, 2), shed_topology=flat(4))
    occ.update({2: 1, 3: 0})
    assert ctl2.shed_home(0) == 3  # least occupied sibling wins


def test_freelists_domain_capacity():
    fl = DomainFreeLists(10, pod(2, 2))  # 10 slots round-robin over 4 domains
    assert fl.domain_capacity == (3, 3, 2, 2)
    assert sum(fl.domain_capacity) == 10


def test_shed_before_spill_ordering_over_freelists():
    """The ROADMAP unlock, at the placement layer: occupancy-coupled
    shedding re-homes admissions sideways while a sibling has headroom, so
    nearest_spill only crosses the pod once the whole pod is exhausted —
    and shed admissions cost no migration at all."""
    topo = pod(2, 2)
    fl = DomainFreeLists(8, topo)  # 2 slots per domain
    tel = PlacementTelemetry(n_domains=4)
    ctl = AdaptiveController(initial=8, occupancy=lambda: tel.per_domain_occupancy,
                             domain_capacity=fl.domain_capacity, shed_topology=topo)
    pol = get_policy("nearest_spill")
    placed = []
    for _ in range(5):  # five admissions all homed at domain 0
        home = ctl.shed_home(0)
        p = pol.place(fl, home, TWO_SOCKET)
        tel.record_placement(p)
        if home != 0:
            tel.record_shed()
        placed.append((home, p.slot_domain, p.migration_cycles))
    homes = [h for h, _, _ in placed]
    # order: 2 at home, then 2 shed to the sibling, then (pod full) spill
    assert homes == [0, 0, 1, 1, 0]
    assert tel.sheds == 2
    assert [d for _, d, _ in placed[:4]] == [0, 0, 1, 1]
    assert all(m == 0 for _, _, m in placed[:4])  # shed admissions are local
    assert placed[4][1] in (2, 3) and placed[4][2] > 0  # cross-pod spill, priced
    assert tel.cross_spills == 1 and tel.sibling_spills == 0
