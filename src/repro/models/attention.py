"""Attention: GQA/MQA, causal + sliding-window, three implementations.

Implementations (selected by ``cfg.attn_impl``):

  * ``xla``      — plain masked einsum.  O(S^2) score tensor; used by smoke
                   tests and short sequences.
  * ``chunked``  — block-streamed online-softmax over KV chunks via
                   ``lax.scan``; never materialises more than
                   (B, H, S_q, chunk) scores.  This is the dry-run/default
                   path for 32k prefill.  Computes full S_q x S_kv masked
                   (2x causal waste — see ``triangular`` for the fix).
  * ``triangular`` — block-causal pair scan: iterates only the
                   lower-triangular (q_chunk, kv_chunk) block pairs (plus the
                   sliding-window band when ``window`` is set), so HLO FLOPs
                   match causal-useful FLOPs.  This is perf-iteration #1 in
                   EXPERIMENTS.md §Perf.
  * ``pallas``   — the flash-attention Pallas kernel (TPU target; validated
                   with interpret=True on CPU).  See repro/kernels/flash_attention.

All entry points take q: (B, S_q, H, hd), k/v: (B, S_kv, Hkv, hd) and handle
GQA by repeating KV heads (keeps GSPMD head-sharding propagation trivial; the
Pallas kernel instead indexes KV heads directly, avoiding the materialised
repeat on the real hardware path).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .sharding import shard

NEG_INF = -1e30


def repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, Hkv, hd) -> (B, S, H, hd) by repeating each KV head H/Hkv times."""
    b, s, hkv, hd = k.shape
    if hkv == n_heads:
        return k
    rep = n_heads // hkv
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, rep, hd))
    return k.reshape(b, s, n_heads, hd)


def _band_mask(q_pos: jax.Array, k_pos: jax.Array, causal: bool, window: int):
    """Boolean mask (..., S_q, S_kv): True = attend."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    mask = jnp.ones(diff.shape, dtype=bool)
    if causal:
        mask &= diff >= 0
    if window > 0:
        mask &= diff < window
    return mask


# ---------------------------------------------------------------------------
# xla: plain masked attention (oracle + short-seq path)
# ---------------------------------------------------------------------------

def _group_q(q: jax.Array, hkv: int) -> jax.Array:
    """(B, S, H, hd) -> (B, S, Hkv, G, hd).  All attention math is grouped:
    K/V are never repeated to H heads — the repeat's broadcast forced GSPMD
    into 'involuntary full rematerialization' (replicate + repartition) of
    full (B,S,H,hd) fp32 tensors inside every KV chunk step (EXPERIMENTS.md
    §Perf, the single biggest train-memory bug)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, hkv, h // hkv, hd)


def attn_xla(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    """Reference attention.  q_offset shifts query positions (decode/chunks)."""
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    qg = _group_q(q * jnp.asarray(scale, q.dtype), hkv)
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg.astype(k.dtype), k, preferred_element_type=jnp.float32
    )
    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(skv)
    mask = _band_mask(q_pos, k_pos, causal, window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgqs,bskd->bqkgd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    return out.reshape(b, sq, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# chunked: online-softmax streamed over KV chunks (full-Q)
# ---------------------------------------------------------------------------

def attn_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Stream KV in chunks with a running (max, denom, acc) online softmax.

    Peak intermediate: (B, H, S_q, chunk) fp32 scores per scan step.
    """
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    chunk = min(chunk, skv)
    if skv % chunk != 0:  # pad KV to a chunk multiple with masked-out tail
        pad = chunk - skv % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_valid = skv
        skv = skv + pad
    else:
        kv_valid = skv
    n_chunks = skv // chunk
    scale = 1.0 / math.sqrt(hd)

    qg = _group_q(q * jnp.asarray(scale, q.dtype), hkv)      # (b, sq, kv, g, hd)
    kc = k.reshape(b, n_chunks, chunk, hkv, hd)
    vc = v.reshape(b, n_chunks, chunk, hkv, hd)
    q_pos = jnp.arange(sq) + q_offset

    def step(carry, inp):
        m, l, acc = carry
        kj, vj, j = inp                                       # (b, chunk, kv, hd)
        k_pos = j * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(kj.dtype), kj,
                       preferred_element_type=jnp.float32)
        mask = _band_mask(q_pos, k_pos, causal, window) & (k_pos < kv_valid)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows: exp(NEG_INF - NEG_INF) would be 1
        alpha = jnp.exp(jnp.where(m > NEG_INF / 2, m - m_new, NEG_INF))
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    # remat each KV-chunk step: without this the backward saves per-chunk
    # (B,H,Sq,chunk) fp32 score/probability residuals — O(S^2) bytes per layer
    # (measured: the dominant memory-roofline term across every train/prefill
    # cell, EXPERIMENTS.md §Perf iteration 1).  With it, only the O(S) carry
    # survives and scores are recomputed in the backward — the flash strategy.
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    (m, l, acc), _ = jax.lax.scan(
        step,
        (m0, l0, acc0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(n_chunks)),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]              # (b, kv, g, sq, hd)
    return jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b, sq, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# triangular: block-causal pair scan — HLO FLOPs == causal-useful FLOPs
# ---------------------------------------------------------------------------

def _block_pairs(n: int, window_blocks: int) -> tuple[list[int], list[int]]:
    """Static (i, j) pairs of (q_block, kv_block) with j <= i and, when a
    sliding window is set, i - j <= window_blocks.  Ordered by i then j so the
    running softmax stats for q-block i are contiguous."""
    qs, ks = [], []
    for i in range(n):
        j0 = 0 if window_blocks <= 0 else max(0, i - window_blocks)
        for j in range(j0, i + 1):
            qs.append(i)
            ks.append(j)
    return qs, ks


def attn_triangular(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Block-causal attention: scan over only the needed (q, kv) block pairs.

    Requires S_q == S_kv (self-attention prefill/train) and q_offset == 0;
    falls back to ``attn_chunked`` otherwise.  Compared to ``attn_chunked``
    this halves matmul FLOPs for causal full attention and cuts them to
    O(S * window) for sliding-window attention.
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    if (not causal) or sq != skv or q_offset != 0 or sq % min(chunk, sq) != 0:
        return attn_chunked(q, k, v, causal=causal, window=window, chunk=chunk, q_offset=q_offset)
    chunk = min(chunk, sq)
    n = sq // chunk
    wb = 0 if window <= 0 else (window + chunk - 1) // chunk
    qi, kj = _block_pairs(n, wb)
    hkv = k.shape[2]
    g = h // hkv

    scale = 1.0 / math.sqrt(hd)
    qc = _group_q(q * jnp.asarray(scale, q.dtype), hkv).reshape(b, n, chunk, hkv, g, hd)
    kc = k.reshape(b, n, chunk, hkv, hd)
    vc = v.reshape(b, n, chunk, hkv, hd)

    rel = jnp.arange(chunk)[:, None] - jnp.arange(chunk)[None, :]

    def step(carry, inp):
        m, l, acc, out = carry
        i, j, is_first, is_last = inp
        qb = jax.lax.dynamic_index_in_dim(qc, i, 1, keepdims=False)  # (b, chunk, kv, g, hd)
        kb = jax.lax.dynamic_index_in_dim(kc, j, 1, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vc, j, 1, keepdims=False)
        # reset stats at the first block of each q-row
        m = jnp.where(is_first, NEG_INF, m)
        l = jnp.where(is_first, 0.0, l)
        acc = jnp.where(is_first, 0.0, acc)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qb.astype(kb.dtype), kb,
                       preferred_element_type=jnp.float32)
        diff = (i - j) * chunk + rel
        mask = diff >= 0
        if window > 0:
            mask &= diff < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(jnp.where(m > NEG_INF / 2, m - m_new, NEG_INF))
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        blk = acc_new / jnp.maximum(l_new, 1e-30)[..., None]
        out = jax.lax.cond(
            is_last,
            lambda o: jax.lax.dynamic_update_index_in_dim(o, blk.astype(out.dtype), i, 1),
            lambda o: o,
            out,
        )
        return (m_new, l_new, acc_new, out), None

    m0 = jnp.full((b, hkv, g, chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, chunk), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, chunk, hd), jnp.float32)
    out0 = jnp.zeros((b, n, hkv, g, chunk, hd), jnp.float32)
    qi_a = jnp.array(qi, jnp.int32)
    kj_a = jnp.array(kj, jnp.int32)
    first = jnp.array([jj == (0 if wb <= 0 else max(0, ii - wb)) for ii, jj in zip(qi, kj)])
    last = jnp.array([ii == jj for ii, jj in zip(qi, kj)])
    # remat per block pair (see attn_chunked): O(chunk^2) recompute, O(chunk) saves
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    (_, _, _, out), _ = jax.lax.scan(step, (m0, l0, acc0, out0), (qi_a, kj_a, first, last))
    # (b, n, kv, g, chunk, hd) -> (b, n, chunk, kv, g, hd) -> (b, sq, h, hd)
    out = jnp.transpose(out, (0, 1, 4, 2, 3, 5)).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# decode: one new token against a (possibly ring-buffered) KV cache
# ---------------------------------------------------------------------------

def attn_decode(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_len: jax.Array,
    *,
    window: int = 0,
    ring: bool = False,
    extra_kv: tuple[jax.Array, jax.Array] | None = None,
) -> jax.Array:
    """q: (B, 1, H, hd); caches: (B, S_max, Hkv, hd); cur_len: () or (B,)
    number of valid positions *including* the token just written.

    ``ring`` marks a sliding-window ring buffer: all S_max slots are valid
    once cur_len >= S_max and the window test is carried by the buffer size
    itself (positions are not ordered, softmax is order-invariant).

    ``extra_kv``: (k_new, v_new) of shape (B, 1, Hkv, hd) — the *current*
    token's K/V, attended alongside the cache.  Passing it here (instead of
    writing it into the cache first) keeps the cache read-only inside the
    decode layer scan, so the single in-place cache update happens once per
    step outside the loop (EXPERIMENTS.md §Perf decode iteration 3).
    """
    b, sq, h, hd = q.shape
    s_max = k_cache.shape[1]
    hkv = k_cache.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(hd)
    with jax.named_scope("fa_kernel_region"):
        # grouped einsum — no materialised repeat of K/V to H heads (the
        # repeat forced an involuntary GSPMD reshard + an H/Hkv-times larger
        # KV stream), and no fp32 upcast of the cache: the QK/PV matmuls run
        # on the cache dtype with fp32 accumulation (MXU-native bf16xbf16
        # ->f32), which removed a per-layer fp32 KV copy worth ~3x the cache
        # (EXPERIMENTS.md §Perf decode iteration).
        qg = (q * jnp.asarray(scale, q.dtype)).reshape(b, sq, hkv, g, hd)
        s = jnp.einsum(
            "bqkgd,bskd->bkgqs", qg.astype(k_cache.dtype), k_cache,
            preferred_element_type=jnp.float32,
        )
        k_pos = jnp.arange(s_max)
        cur = jnp.asarray(cur_len)
        cur = cur[..., None, None, None, None] if cur.ndim else cur
        if ring:
            valid = k_pos < jnp.minimum(cur, s_max)
            if extra_kv is not None:
                # the slot the new token will occupy still holds the token
                # that just left the window — mask it out
                stale = (k_pos == jnp.mod(cur, s_max)) & (cur >= s_max)
                valid = valid & ~stale
        else:
            valid = k_pos < cur
            if window > 0:
                valid = valid & (k_pos >= (cur - window))
        valid = jnp.broadcast_to(valid, s.shape) if valid.ndim == s.ndim else valid[None, None, None, None, :]
        s = jnp.where(valid, s, NEG_INF)
        if extra_kv is not None:
            # merge the current token by a two-part online softmax rather than
            # concatenating a column: concat makes the score dim S+1, which
            # breaks the even kv_seq sharding and made GSPMD all-gather the
            # whole V cache per layer (40 GiB/token on granite decode).
            k_new, v_new = extra_kv
            s_self = jnp.einsum(
                "bqkgd,bskd->bkgqs", qg.astype(k_new.dtype), k_new,
                preferred_element_type=jnp.float32,
            )                                             # (b, kv, g, 1, 1)
            m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), s_self)
            p = jnp.exp(s - m)
            p_self = jnp.exp(s_self - m)
            denom = jnp.sum(p, axis=-1, keepdims=True) + p_self
            out = jnp.einsum(
                "bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache,
                preferred_element_type=jnp.float32,
            ) + jnp.einsum(
                "bkgqs,bskd->bqkgd", p_self.astype(v_new.dtype), v_new,
                preferred_element_type=jnp.float32,
            )
            # denom (b, kv, g, q, 1) -> (b, q, kv, g, 1) to divide out (b,q,kv,g,d)
            out = out / jnp.moveaxis(denom[..., 0], -1, 1)[..., None]
        else:
            p = jax.nn.softmax(s, axis=-1)
            out = jnp.einsum(
                "bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache,
                preferred_element_type=jnp.float32,
            )
    return out.reshape(b, sq, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    impl: str = "chunked",
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """The ``fa_kernel_region`` scope marks this computation as the body of
    the flash-attention Pallas kernel on the TPU target: the roofline's
    byte model treats everything inside as VMEM-resident (boundary tensors
    q/k/v/o are charged at the producing/consuming ops outside)."""
    q = shard(q, "batch", None, "heads", None)
    with jax.named_scope("fa_kernel_region"):
        if impl == "xla" or q.shape[1] <= chunk:
            out = attn_xla(q, k, v, causal=causal, window=window, q_offset=q_offset)
        elif impl == "triangular":
            out = attn_triangular(q, k, v, causal=causal, window=window, chunk=chunk, q_offset=q_offset)
        elif impl == "pallas":
            from repro.kernels.flash_attention import ops as fa_ops

            out = fa_ops.flash_attention(q, k, v, causal=causal, window=window)
        else:
            out = attn_chunked(q, k, v, causal=causal, window=window, chunk=chunk, q_offset=q_offset)
    return shard(out, "batch", None, "heads", None)
