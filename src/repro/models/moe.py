"""Mixture-of-Experts: top-k routing with capacity, sort-based dispatch.

Baseline ("tp") dispatch is *local*: every token is dispatched into an
(E, C, D) buffer within its own batch shard — no token ever crosses a data
shard — and expert FFN weights are sharded over ('expert'->data storage,
'mlp'->model compute), so GSPMD turns the expert matmul into an FSDP-style
all-gather + TP matmul.  An explicit expert-parallel (EP) all-to-all variant
lives in ``repro.models.moe_ep`` and is used in §Perf.

Routing is deterministic: per-sequence-row capacity C = ceil(S*k*cf/E);
positions inside each expert's buffer are ranks from a stable argsort of the
expert assignments (earlier tokens win slots; later ones drop — the standard
token-dropping discipline).  The backward of scatter/gather is gather/scatter,
so the whole thing is autodiff-clean.

CNA locality routing (beyond-paper, ``cfg.cna_routing``): the paper's
main-queue preference, applied to the router — each token gets a bounded
additive bias toward experts whose home shard matches the token's home shard
(main queue = local experts, secondary = remote).  The load-balancing aux loss
plays the role of the fairness threshold: remote experts keep receiving
tokens, so no expert starves.  Under EP this directly cuts all-to-all bytes;
measured in benchmarks/moe_locality.py.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ParamBuilder
from .mlp import declare_mlp, mlp_apply
from .sharding import shard


def moe_capacity(seq: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(math.ceil(seq * top_k * cf / n_experts))
    return max(4, (c + 3) // 4 * 4)  # pad to a multiple of 4 lanes


def declare_moe(pb: ParamBuilder, prefix: str, cfg, stack: int = 0):
    lead = (stack,) if stack else ()
    lax = ("layers",) if stack else ()
    d, e = cfg.d_model, cfg.n_experts
    eff = cfg.moe_d_ff or cfg.d_ff
    pb.declare(f"{prefix}/router", lead + (d, e), lax + (None, None), init="normal", scale=0.02)
    pb.declare(f"{prefix}/wi", lead + (e, d, eff), lax + ("expert", "fsdp", "mlp"))
    pb.declare(f"{prefix}/wg", lead + (e, d, eff), lax + ("expert", "fsdp", "mlp"))
    pb.declare(f"{prefix}/wo", lead + (e, eff, d), lax + ("expert", "mlp", "fsdp"))
    if cfg.n_shared_experts:
        declare_mlp(pb, f"{prefix}/shared", d, cfg.n_shared_experts * eff, "swiglu", stack)


def _positions(e_ids: jax.Array, n_experts: int, capacity: int):
    """Per-row buffer slots.  e_ids: (M,) int32 -> (pos, keep).

    Stable sort by expert; rank within expert = index - segment start; tokens
    with rank >= capacity are dropped (pos pinned to the overflow slot C)."""
    m = e_ids.shape[0]
    order = jnp.argsort(e_ids, stable=True)
    sorted_e = e_ids[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    rank_sorted = jnp.arange(m) - seg_start[sorted_e]
    rank = jnp.zeros((m,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < capacity
    pos = jnp.where(keep, rank, capacity)
    return pos, keep


def _route(params, x, cfg, n_domains: int):
    """Router logits -> (weights (B,S,k), experts (B,S,k), aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"].astype(jnp.float32))
    if cfg.cna_routing and n_domains > 1:
        # CNA main-queue bias: prefer experts homed on the token's domain.
        # Domains follow the contiguous GSPMD layout of the batch dim.
        tok_dom = (jnp.arange(b, dtype=jnp.int32) * n_domains) // b          # (B,)
        exp_dom = (jnp.arange(e, dtype=jnp.int32) * n_domains) // e          # (E,)
        local = (tok_dom[:, None] == exp_dom[None, :]).astype(jnp.float32)   # (B,E)
        logits = logits + cfg.cna_routing_bias * local[:, None, :]
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # load-balancing loss (Switch-style): E * sum_e f_e * P_e
    f = jnp.mean(jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=2), axis=(0, 1))
    p = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(f * p) * cfg.router_aux_coef
    return w.astype(x.dtype), idx.astype(jnp.int32), aux


def moe_apply(params: dict, x: jax.Array, cfg, *, n_domains: int = 1):
    """x: (B, S, D) -> (out, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = moe_capacity(s, k, e, cfg.capacity_factor)
    w, idx, aux = _route(params, x, cfg, n_domains)

    tok = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[:, None], (s, k)).reshape(-1)  # (M,)

    def dispatch_row(x_row, e_row, w_row):
        """x_row: (S, D); e_row/w_row: (S, k) -> (out_row (S, D))."""
        e_all = e_row.reshape(-1)                  # (M,) M = S*k
        w_all = w_row.reshape(-1)
        pos, keep = _positions(e_all, e, cap)
        x_tok = x_row[tok]                          # (M, D)
        buf = jnp.zeros((e, cap + 1, d), x_row.dtype)
        buf = buf.at[e_all, pos].add(jnp.where(keep[:, None], x_tok, 0))
        return buf[:, :cap], (e_all, pos, keep, w_all)

    buf, (e_all, pos, keep, w_all) = jax.vmap(dispatch_row)(x, idx, w)
    buf = shard(buf, "batch", "expert", None, None)  # (B, E, C, D)

    h = jnp.einsum("becd,edf->becf", buf, params["wi"])
    g = jnp.einsum("becd,edf->becf", buf, params["wg"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    h = shard(h, "batch", "expert", None, "mlp")
    out_buf = jnp.einsum("becf,efd->becd", h, params["wo"])
    out_buf = shard(out_buf, "batch", "expert", None, None)

    def combine_row(ob, e_all, pos, keep, w_all):
        y = ob[e_all, jnp.minimum(pos, cap - 1)]                      # (M, D)
        y = jnp.where(keep[:, None], y, 0) * w_all[:, None].astype(ob.dtype)
        return jnp.zeros((s, d), ob.dtype).at[tok].add(y)

    out = jax.vmap(combine_row)(out_buf, e_all, pos, keep, w_all)

    if cfg.n_shared_experts:
        out = out + mlp_apply(params["shared"], x, "swiglu")
    return shard(out, "batch", "seq", "embed"), aux
