"""The docs lane: executable documentation that cannot rot.

``docs/*.md``'s fenced ```python blocks are a narrative of the five layers
*and* a test suite: this module extracts them and executes them in order,
top to bottom, sharing one namespace per document (later blocks may use
names defined by earlier ones, exactly as a reader reads them).  Every
plain ```python block is jax-free by construction — the narrative runs
through the simulator-backed paths — so the CI ``docs`` lane runs this file
with numpy only, next to the bench smoke lane.  Blocks fenced as
```python jax (docs/models.md's reduced-config model walkthroughs) need the
real dependency: they execute in environments where jax imports (the tier-1
lane) and are skipped, not failed, in the numpy-only docs lane.

Cross-references are checked too: every relative markdown link in ``docs/``
and ``README.md`` must resolve to a real file, so a moved document breaks CI
instead of readers.
"""

import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = os.path.join(REPO, "docs")

_FENCE = re.compile(r"^```python( jax)?\s*$(.*?)^```\s*$", re.M | re.S)
_LINK = re.compile(r"\[[^\]]*\]\(([^)]+)\)")


def _have_jax():
    try:
        import jax  # noqa: F401
    except ImportError:
        return False
    return True


def _doc_files():
    return sorted(
        os.path.join(DOCS, f) for f in os.listdir(DOCS) if f.endswith(".md")
    )


def _blocks(path, *, jax_only=None):
    """Fenced blocks in document order.  ``jax_only=False`` keeps the plain
    ```python fences, ``True`` the ```python jax ones, ``None`` both."""
    with open(path) as f:
        found = _FENCE.findall(f.read())
    return [
        body
        for marker, body in found
        if jax_only is None or bool(marker) == jax_only
    ]


def test_docs_exist_and_have_examples():
    paths = _doc_files()
    names = {os.path.basename(p) for p in paths}
    assert {"architecture.md", "benchmarks.md", "models.md",
            "observability.md"} <= names
    arch = os.path.join(DOCS, "architecture.md")
    assert len(_blocks(arch)) >= 5, "the narrative lost its runnable examples"
    zoo = os.path.join(DOCS, "models.md")
    assert len(_blocks(zoo, jax_only=True)) >= 1, (
        "the model-zoo doc lost its runnable reduced-config example"
    )


@pytest.mark.parametrize(
    "path", _doc_files(), ids=[os.path.basename(p) for p in _doc_files()]
)
def test_doc_python_blocks_execute(path):
    """Run the document's python blocks in order in one shared namespace —
    the assertions inside them are the documentation's contract with the
    code.  A document without blocks passes trivially."""
    ns = {"__name__": f"docs:{os.path.basename(path)}"}
    for i, block in enumerate(_blocks(path, jax_only=False)):
        try:
            exec(compile(block, f"{path}#block{i}", "exec"), ns)
        except Exception as e:  # pragma: no cover - failure path
            pytest.fail(
                f"{os.path.basename(path)} block {i} failed: {e!r}\n{block}"
            )


@pytest.mark.parametrize(
    "path", _doc_files(), ids=[os.path.basename(p) for p in _doc_files()]
)
def test_doc_jax_blocks_execute(path):
    """Same contract for the ```python jax fences — executed where jax
    imports (the tier-1 lane), skipped in the numpy-only docs lane."""
    blocks = _blocks(path, jax_only=True)
    if not blocks:
        return
    if not _have_jax():
        pytest.skip("jax not installed: docs lane runs numpy-only")
    ns = {"__name__": f"docs:{os.path.basename(path)}"}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"{path}#jaxblock{i}", "exec"), ns)
        except Exception as e:  # pragma: no cover - failure path
            pytest.fail(
                f"{os.path.basename(path)} jax block {i} failed: {e!r}\n{block}"
            )


def _relative_links(path):
    with open(path) as f:
        text = f.read()
    for target in _LINK.findall(text):
        target = target.strip()
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        yield target.split("#", 1)[0]


@pytest.mark.parametrize(
    "path",
    _doc_files() + [os.path.join(REPO, "README.md")],
    ids=lambda p: os.path.relpath(p, REPO),
)
def test_doc_relative_links_resolve(path):
    base = os.path.dirname(path)
    missing = [
        t for t in _relative_links(path)
        if t and not os.path.exists(os.path.normpath(os.path.join(base, t)))
    ]
    assert not missing, f"dangling links in {os.path.basename(path)}: {missing}"
