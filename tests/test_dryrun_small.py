"""End-to-end dry-run machinery on a small faked mesh (fast CI-scale proof;
the full 512-device 80-cell run is the results/dryrun_opt/ artifact)."""

import subprocess
import sys
import textwrap

from _subproc import REPO_ROOT, run_env

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    try:
        from jax.sharding import AxisType
        mesh_kw = {"axis_types": (AxisType.Auto,) * 3}
    except ImportError:  # older jax: meshes are Auto-only
        mesh_kw = {}
    from repro.configs.base import SHAPES, get_reduced_config, ShapeConfig
    from repro.launch import roofline as rl
    from repro.models.registry import build_model, input_specs
    from repro.models.sharding import use_mesh
    from repro.training.step import (make_train_step, state_abstract,
                                     state_logical, tree_shardings)

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"), **mesh_kw)
    shapes = {
        "train": ShapeConfig("t", 64, 8, "train"),
        "prefill": ShapeConfig("p", 64, 8, "prefill"),
        "decode": ShapeConfig("d", 64, 8, "decode"),
    }
    for arch in ("granite_3_8b", "mixtral_8x22b", "mamba2_130m"):
        cfg = get_reduced_config(arch)
        model = build_model(cfg)
        for kind, shape in shapes.items():
            with use_mesh(mesh):
                specs, logical = input_specs(cfg, shape, model)
                in_sh = tree_shardings(specs, logical)
                p_abs = model.abstract_params()
                p_sh = tree_shardings(p_abs, model.logical_tree())
                if kind == "train":
                    step = make_train_step(model, cfg)
                    st = state_abstract(model, cfg)
                    st_sh = tree_shardings(st, state_logical(model))
                    lowered = jax.jit(step, in_shardings=(st_sh, in_sh)).lower(st, specs)
                elif kind == "prefill":
                    lowered = jax.jit(model.prefill, in_shardings=(p_sh, in_sh)).lower(p_abs, specs)
                else:
                    lowered = jax.jit(
                        model.decode_step,
                        in_shardings=(p_sh, in_sh["cache"], in_sh["tokens"]),
                    ).lower(p_abs, specs["cache"], specs["tokens"])
                compiled = lowered.compile()
            r, hc = rl.analyze(compiled, arch=arch, shape=shape, cfg=cfg,
                               mesh_name="2x2x2", chips=8)
            assert r.flops_per_chip > 0, (arch, kind)
            assert r.bytes_per_chip > 0, (arch, kind)
            assert r.dominant in ("compute", "memory", "collective")
            print("CELL_OK", arch, kind, r.dominant)
    print("DRYRUN_SMALL_OK")
""")


def test_dryrun_small_mesh_all_kinds():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True, timeout=900,
        env=run_env(), cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "DRYRUN_SMALL_OK" in proc.stdout
    assert proc.stdout.count("CELL_OK") == 9
