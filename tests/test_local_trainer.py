"""Pod-local deferred-sync training (the keep_lock_local optimizer analogue)."""

import subprocess
import sys
import textwrap

from _subproc import REPO_ROOT, run_env

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    try:
        from jax.sharding import AxisType
        mesh_kw = {"axis_types": (AxisType.Auto,) * 3}
    except ImportError:  # older jax: meshes are Auto-only
        mesh_kw = {}
    from repro.configs.base import get_reduced_config
    from repro.data.pipeline import BigramLMDataset
    from repro.models.registry import build_model
    from repro.models.sharding import use_mesh
    from repro.training.local import (make_local_train_step, pod_average,
                                      pod_drift, replicate_for_pods)
    from repro.training.step import init_state

    N_PODS, K = 2, 4
    cfg = get_reduced_config("granite_3_8b").replace(accum=1, vocab=64)
    model = build_model(cfg)
    ds = BigramLMDataset(cfg.vocab, seq_len=32, global_batch=8 * N_PODS, seed=0, branching=4)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"), **mesh_kw)

    with use_mesh(mesh):
        state = replicate_for_pods(init_state(model, jax.random.PRNGKey(0), cfg), N_PODS)
        step = jax.jit(make_local_train_step(model, cfg, sync_every=K,
                                             lr_fn=lambda s: 5e-3, weight_decay=0.0))
        losses, drifts, syncs = [], [], []
        for i in range(16):
            b = ds.batch(i)
            b = jax.tree.map(lambda x: x.reshape((N_PODS, -1) + x.shape[1:]), b)
            state, m = step(state, b)
            losses.append(float(m["loss"]))
            drifts.append(float(pod_drift(state)))
            syncs.append(bool(m["synced"]))

    assert losses[-1] < losses[0] - 0.2, losses
    # pods drift between syncs and re-converge exactly at sync steps
    assert any(d > 1e-6 for d in drifts), drifts
    for d, s in zip(drifts, syncs):
        if s:
            assert d < 1e-5, (d, "params must agree after a pod average")
    assert sum(syncs) == 4, syncs  # steps 4, 8, 12, 16
    print("LOCAL_TRAINER_OK", losses[0], losses[-1], max(drifts))
""")


def test_pod_local_deferred_sync():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True, timeout=900,
        env=run_env(), cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "LOCAL_TRAINER_OK" in proc.stdout
