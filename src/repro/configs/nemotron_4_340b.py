"""nemotron-4-340b [dense]: 96L d=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.
Squared-ReLU MLP per arXiv:2402.16819.  Optimizer moments in bf16: a 340B
train step on a single 256-chip v5e pod cannot hold fp32 Adam moments
(2.7 TB); see DESIGN.md and the dry-run memory analysis."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv=8, d_ff=73728, vocab=256000,
    mlp="relu2", accum=2, opt_state_dtype="bfloat16",
)

def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=96, n_heads=6, n_kv=2, d_ff=256,
                          vocab=512, accum=2, opt_state_dtype="float32", attn_chunk=64)
