"""SSD intra-chunk Pallas TPU kernel (Mamba-2 diagonal-block term).

Per (batch, chunk, head) grid cell, entirely in VMEM:

    cum    = cumsum(dA)                       (L,)
    L_mat  = tril(exp(cum_l - cum_s))         (L, L)
    scores = (C B^T) * L_mat                  (L, L)   — one MXU matmul
    Y      = scores @ X                       (L, P)   — one MXU matmul

With the default chunk L=128 and head dim P=64/128, all five tiles
(C: LxN, B: LxN, X: LxP, scores: LxL, Y: LxP) fit comfortably in VMEM
(< 512 KB at N=P=128 fp32) and both matmuls are 128-aligned for the MXU.
This is the compute-dense half of SSD; the inter-chunk recurrence stays in
XLA as a lax.scan (bandwidth-trivial: one (H,P,N) state per chunk).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, da_ref, b_ref, c_ref, o_ref):
    da = da_ref[0, 0, 0, :].astype(jnp.float32)             # (L,)
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)            # (L, P)
    bm = b_ref[0, 0].astype(jnp.float32)                    # (L, N)
    cm = c_ref[0, 0].astype(jnp.float32)                    # (L, N)
    l = da.shape[0]
    cum = jnp.cumsum(da)
    seg = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (l, l), 1
    )
    decay = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * decay
    y = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0, 0, :, 0, :] = y.astype(o_ref.dtype)


def ssd_intra_bchlpn(xc, dac, bc, cc, *, interpret: bool = True):
    """xc: (B,nc,L,H,P); dac: (B,H,nc,L); bc/cc: (B,nc,L,N) -> (B,nc,L,H,P)."""
    bsz, nc, l, h, p = xc.shape
    n = bc.shape[-1]
    return pl.pallas_call(
        _ssd_kernel,
        grid=(bsz, nc, h),
        in_specs=[
            pl.BlockSpec((1, 1, l, 1, p), lambda b, c, hh: (b, c, 0, hh, 0)),
            pl.BlockSpec((1, 1, 1, l), lambda b, c, hh: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, l, n), lambda b, c, hh: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, l, n), lambda b, c, hh: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, l, 1, p), lambda b, c, hh: (b, c, 0, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, nc, l, h, p), jnp.float32),
        interpret=interpret,
    )(xc, dac, bc, cc)
