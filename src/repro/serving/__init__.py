from .engine import DecodeEngine, Request  # noqa: F401
from .prefixindex import PrefixIndex  # noqa: F401
from .scheduler import CNAScheduler, FIFOScheduler, SchedulerMetrics  # noqa: F401
