"""Model factory + per-(arch, shape) abstract input specs for the dry-run.

``input_specs`` returns (abstract_inputs, logical_axes) pytrees of
``jax.ShapeDtypeStruct`` — the ShapeDtypeStruct stand-in pattern: weak-type
correct, shardable, zero device allocation.  ``decode`` shapes include the
full KV/recurrent cache as an input (one new token against a seq_len cache,
per the assignment).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from .encdec import EncDecLM
from .transformer import DecoderLM


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return DecoderLM(cfg)


def _tok(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, model=None):
    """-> (abstract inputs pytree, logical-axes pytree) for the given step.

    train:   {tokens, labels [, patches | frames]}
    prefill: {tokens [, patches | frames]}
    decode:  {cache, tokens (B, 1)}
    """
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    model = model or build_model(cfg)

    extra, extra_log = {}, {}
    if cfg.family == "encdec":
        extra["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model), dt)
        extra_log["frames"] = ("batch", None, None)
    if cfg.n_patches:
        extra["patches"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), dt)
        extra_log["patches"] = ("batch", None, None)

    if shape.kind == "train":
        specs = {"tokens": _tok(b, s), "labels": _tok(b, s), **extra}
        logical = {"tokens": ("batch", None), "labels": ("batch", None), **extra_log}
        return specs, logical
    if shape.kind == "prefill":
        specs = {"tokens": _tok(b, s), **extra}
        logical = {"tokens": ("batch", None), **extra_log}
        return specs, logical
    if shape.kind == "decode":
        cache = model.cache_abstract(b, s)
        specs = {"cache": cache, "tokens": _tok(b, 1)}
        logical = {"cache": model.cache_logical(cache), "tokens": ("batch", None)}
        return specs, logical
    raise ValueError(shape.kind)


def synthetic_batch(cfg: ModelConfig, shape_kind: str, batch: int, seq: int, seed: int = 0):
    """Concrete random inputs matching input_specs (for smoke tests/examples)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    out = {"tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab, jnp.int32)}
    if shape_kind == "train":
        out["labels"] = jax.random.randint(k2, (batch, seq), 0, cfg.vocab, jnp.int32)
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(k3, (batch, cfg.enc_seq, cfg.d_model), dt)
    if cfg.n_patches:
        out["patches"] = jax.random.normal(k3, (batch, cfg.n_patches, cfg.d_model), dt)
    return out
