"""codeqwen1.5-7b [dense]: 32L d=4096 32H (kv=32) d_ff=13440 vocab=92416.
Source: hf:Qwen/CodeQwen1.5-7B (qwen1.5 arch)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=32, d_ff=13440, vocab=92416,
    mlp="swiglu", rope_theta=1_000_000.0, accum=2,
)

def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128,
                          vocab=512, accum=1, attn_chunk=64)
