"""Config system: model architecture + input-shape + run configuration.

Every assigned architecture gets a module ``repro.configs.<id>`` exporting
``CONFIG: ModelConfig`` with the exact published dimensions, plus a
``reduced()`` variant for CPU smoke tests.  Shapes are the assignment's four
(seq_len, global_batch) cells; ``kind`` selects which step gets lowered
(train_step / prefill_step / decode_step).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "encdec", "vlm"]
BlockKind = Literal["attn", "rec"]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int

    head_dim: int = 0                  # 0 => d_model // n_heads
    mlp: str = "swiglu"                # swiglu | relu2 | geglu | gelu
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    pos: str = "rope"                  # rope | learned | none
    max_pos: int = 0                   # learned-pos table size (0 => max shape seq)
    window: int = 0                    # sliding-window attention size; 0 = full
    tie_embeddings: bool = False

    # -- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                  # per-expert hidden (deepseek fine-grained)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    first_k_dense: int = 0             # leading dense-FFN layers (deepseek: 1)
    moe_impl: str = "tp"               # tp (local dispatch) | ep (all-to-all)
    ep_remote_capacity_factor: float = 1.0  # CNA-EP: remote a2a provisioning
    cna_routing: bool = False          # locality-aware router bias (beyond-paper)
    cna_routing_bias: float = 0.5
    cna_domains: int = 1               # locality domains for cna_routing

    # -- hybrid (RG-LRU / Griffin) -------------------------------------------
    block_pattern: tuple[BlockKind, ...] = ()   # cycled over layers; () => all attn
    lru_width: int = 0
    conv_width: int = 4

    # -- SSM (Mamba-2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128

    # -- encoder-decoder (whisper) ----------------------------------------------
    enc_layers: int = 0
    enc_seq: int = 0                   # stub frontend: precomputed frame embeddings

    # -- VLM (pixtral) ------------------------------------------------------------
    n_patches: int = 0                 # stub frontend: precomputed patch embeddings

    # -- numerics / training -------------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    accum: int = 1                     # gradient-accumulation microbatches
    opt_state_dtype: str = "float32"   # adam m/v dtype (bf16 for 340B-class)
    attn_impl: str = "chunked"         # xla | chunked | triangular | pallas
    attn_chunk: int = 1024
    rec_impl: str = "assoc"            # assoc | pallas  (RG-LRU scan)
    ssd_impl: str = "jnp"              # jnp | pallas    (SSD intra-chunk)

    # ------------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab, 256)

    @property
    def is_subquadratic(self) -> bool:
        return self.window > 0 or self.family in ("ssm", "hybrid")

    @property
    def blocks(self) -> tuple[BlockKind, ...]:
        """Per-layer block kinds (cycled pattern, length n_layers)."""
        if not self.block_pattern:
            return ("attn",) * self.n_layers
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline's
        MODEL_FLOPS = 6*N*D."""
        d, ff, v = self.d_model, self.d_ff, self.padded_vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_attn = d * self.n_heads * self.hd + 2 * d * self.n_kv * self.hd + self.n_heads * self.hd * d
        if self.mlp in ("swiglu", "geglu"):
            per_mlp = 3 * d * ff
        else:
            per_mlp = 2 * d * ff
        total = emb
        for kind in self.blocks:
            if kind == "rec":
                w = self.lru_width or d
                total += 2 * d * w + w * d + w * self.conv_width + 2 * w  # rglru block
                total += per_mlp
                continue
            total += per_attn
            if self.family == "ssm":
                pass
            if self.n_experts:
                eff = self.moe_d_ff or ff
                total += self.n_experts * 3 * d * eff
                total += self.n_shared_experts * 3 * d * eff
                total += d * self.n_experts  # router
            else:
                total += per_mlp
        if self.family == "ssm":
            # mamba blocks instead of attn+mlp
            di, st, nh = self.d_inner, self.ssm_state, self.ssm_heads
            per = d * (2 * di + 2 * st + nh) + di * d + di  # in/out proj + conv/dt
            total = emb + self.n_layers * per
        if self.enc_layers:
            total += self.enc_layers * (per_attn + per_mlp)
            total += self.n_layers * per_attn  # cross-attention
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.n_experts:
            return self.n_params()
        eff = self.moe_d_ff or self.d_ff
        dense_moe = self.n_experts * 3 * self.d_model * eff
        active_moe = (self.top_k + self.n_shared_experts) * 3 * self.d_model * eff
        return int(self.n_params() - self.n_layers * (dense_moe - active_moe)
                   + self.n_layers * self.n_shared_experts * 0)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


# The assignment's four LM shape cells.
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full-attention arch: 500k context requires sub-quadratic attention (skip per assignment)"
    return True, ""


ARCH_IDS = [
    "granite_3_8b",
    "stablelm_3b",
    "codeqwen15_7b",
    "nemotron_4_340b",
    "recurrentgemma_2b",
    "whisper_large_v3",
    "mixtral_8x22b",
    "deepseek_moe_16b",
    "pixtral_12b",
    "mamba2_130m",
]

# CLI ids use dashes; module names use underscores.
def arch_module(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "")


def get_config(arch_id: str) -> ModelConfig:
    import importlib

    mod = importlib.import_module(f"repro.configs.{arch_module(arch_id)}")
    return mod.CONFIG


def get_reduced_config(arch_id: str) -> ModelConfig:
    import importlib

    mod = importlib.import_module(f"repro.configs.{arch_module(arch_id)}")
    return mod.reduced()
