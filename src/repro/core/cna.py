"""Compact NUMA-aware lock (CNA) — the threaded driver of the discipline core.

This module keeps the *medium-specific* half of the paper's Figures 2-5:
Python has no raw CAS/SWAP on object attributes, so the two atomic
instructions of the algorithm (SWAP on lock.tail in `lock`, CAS on lock.tail
in `unlock`) are emulated by a single internal mutex guarding *only* those two
operations — exactly the two touch points the paper identifies — plus the
local-spin thread parking and the linked-node pointer manipulation.  *Which*
waiter gets the lock (find_successor, keep_lock_local, the Section-6 shuffle
reduction) is decided by ``repro.core.discipline.decide`` — the same pure core
the discrete-event simulator and the serving admission queue drive, so all
three produce identical grant orders on a common schedule and seed.  The GIL
makes wall-clock throughput meaningless here; this implementation is for
*algorithmic correctness* (mutual exclusion, queue splicing, starvation
freedom); performance reproduction lives in ``repro.core.numasim`` /
``repro.core.locks_sim``.

The ``spin`` field carries, as in the paper, either 0 (wait), 1 (lock granted,
empty secondary queue) or a reference to the head node of the secondary queue
(lock granted, non-empty secondary queue).  In C this is pointer-stuffing into
one word; in Python the union is explicit.
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field

from .discipline import THRESHOLD, THRESHOLD2, DisciplineConfig, decide
from .topology import Topology, flat


class CNANode:
    """Queue node (paper Fig. 2).  One per (thread, nesting level)."""

    __slots__ = ("spin", "socket", "sec_tail", "next")

    def __init__(self) -> None:
        self.spin: object = 0          # 0 | 1 | CNANode (head of secondary queue)
        self.socket: int = -1
        self.sec_tail: CNANode | None = None
        self.next: CNANode | None = None


class _chain_domains:
    """Lazy domain view over a linked CNANode chain for ``decide`` — iterated
    only when the decision scans, never materialized."""

    __slots__ = ("head",)

    def __init__(self, head: CNANode | None) -> None:
        self.head = head

    def __bool__(self) -> bool:
        return self.head is not None

    def __iter__(self):
        node = self.head
        while node is not None:
            yield node.socket
            node = node.next


@dataclass
class CNAStats:
    """Optional bookkeeping used by tests/benchmarks (not part of the lock word)."""

    handovers: int = 0
    local_handovers: int = 0
    secondary_flushes: int = 0
    shuffles: int = 0
    # fissile fast path (fissile=True): acquisitions that never built a queue
    # node linkage, and the mode transitions around them
    fast_acquires: int = 0
    inflations: int = 0
    deflations: int = 0


class CNALock:
    """CNA lock.  The lock *state* is one word: ``tail``.

    ``numa_node_of`` maps a thread to its (virtual) NUMA node; on a real
    machine this is ``rdtscp``/``getcpu``; here it is injectable so tests can
    build arbitrary topologies on a single-core container.
    """

    def __init__(
        self,
        numa_node_of=None,
        threshold: int = THRESHOLD,
        shuffle_reduction: bool = False,
        threshold2: int = THRESHOLD2,
        seed: int = 0x5EED,
        fissile: bool = False,
    ) -> None:
        self.tail: CNANode | None = None          # <-- the single word of state
        self._atomic = threading.Lock()           # emulates SWAP/CAS only
        self._numa_node_of = numa_node_of or (lambda: 0)
        self._cfg = DisciplineConfig(threshold, shuffle_reduction, threshold2)
        self._rng = random.Random(seed)
        self._rng_lock = threading.Lock()
        self.stats = CNAStats()
        # fissile fast path (Dice & Kogan, arXiv 2003.05025): a TS-word analog
        # in front of the queue.  ``_fast_held`` is the TS bit; ``_fast_head``
        # is where a slow-path acquirer that found an empty queue registers so
        # the fast holder's release can adopt it as its successor chain.
        self._fissile = fissile
        self._fast_held = False
        self._fast_holder: CNANode | None = None
        self._fast_head: CNANode | None = None

    # -- emulated atomics ---------------------------------------------------
    def _swap_tail(self, new: CNANode | None) -> CNANode | None:
        with self._atomic:
            old, self.tail = self.tail, new
            return old

    def _cas_tail(self, expected: CNANode | None, new: CNANode | None) -> bool:
        with self._atomic:
            if self.tail is expected:
                self.tail = new
                return True
            return False

    # -- fissile fast path ----------------------------------------------------
    def _try_fast_takeover(self, me: CNANode) -> bool:
        """A slow-path acquirer whose SWAP found an empty queue: either the
        lock is genuinely free (take it, True) or a fast-path holder is in
        flight — register as the handover target its release will adopt and
        return False (caller spins on ``me.spin``)."""
        with self._atomic:
            if not self._fast_held:
                me.spin = 1
                return True
            self._fast_head = me
            return False

    # -- paper Fig. 3: cna_lock ---------------------------------------------
    def acquire(self, me: CNANode) -> None:
        me.next = None                             # L2
        me.socket = -1                             # L3
        me.spin = 0                                # L4
        if self._fissile:
            # the single CAS-analog decision: free *and* deflated -> no node
            # linkage, no SWAP on tail, no queue state touched at all
            with self._atomic:
                if self.tail is None and not self._fast_held:
                    self._fast_held = True
                    self._fast_holder = me
                    me.spin = 1
                    self.stats.fast_acquires += 1
                    return
        tail = self._swap_tail(me)                 # L6  (the one atomic)
        if tail is None:                           # L8: no one there?
            if self._fissile and not self._try_fast_takeover(me):
                while me.spin == 0:                # fast holder hands over
                    time.sleep(0)
                return
            me.spin = 1
            return
        me.socket = self._numa_node_of()           # L10
        tail.next = me                             # L11
        while me.spin == 0:                        # L13: local spinning
            time.sleep(0)                          # CPU_PAUSE under the GIL

    # -- paper Fig. 4: cna_unlock --------------------------------------------
    def release(self, me: CNANode) -> None:
        if me is self._fast_holder:                # fissile fast-path release
            with self._atomic:
                self._fast_holder = None
                if self.tail is None:              # nobody arrived: deflate —
                    self._fast_held = False        # TS bit clears in the same
                    self.stats.deflations += 1     # atomic step as the check
                    return
            # contended during our CS: inflate.  Adopt the queue head as our
            # successor chain and fall into the normal CNA release below, so
            # the very first contended handover already runs the full decide()
            # over every waiter — identical to a plain-CNA holder's release.
            while True:
                with self._atomic:
                    head = self._fast_head
                    if head is not None:           # L36-analog: wait for the
                        self._fast_head = None     # head to register itself
                        self._fast_held = False
                        break
                time.sleep(0)
            me.next = head
            self.stats.inflations += 1
        if me.next is None:                        # L18: successor in main queue?
            if me.spin == 1:                       # L20: secondary queue empty?
                if self._cas_tail(me, None):       # L23
                    return
            else:
                sec_head = me.spin                 # L27
                if self._cas_tail(me, sec_head.sec_tail):  # L28
                    sec_head.spin = 1              # L31: pass lock to sec. head
                    self.stats.handovers += 1
                    self.stats.secondary_flushes += 1
                    return
            while me.next is None:                 # L36: wait for successor link
                time.sleep(0)

        # L38-49 + Section 6: hand the shared core a *lazy* view of the main
        # chain (walked only if the decision actually scans — the fast path
        # and FIFO grants stay O(1), mirroring the deque drivers' _DomainView;
        # interior links are stable and the chain only grows past the walked
        # tail, so the live walk is one valid linearization, exactly like the
        # paper's find_successor), then replay the decision on the pointers.
        # n_secondary is only branched on for emptiness (its exact value feeds
        # event payloads this driver discards), so the O(1) spin-field test
        # stands in for counting the chain.
        my_socket = me.socket
        if my_socket == -1:                        # L54 (uncontended acquirer)
            my_socket = self._numa_node_of()
        with self._rng_lock:
            d = decide(
                _chain_domains(me.next),
                1 if isinstance(me.spin, CNANode) else 0,
                my_socket,
                self._rng,
                self._cfg,
            )

        self.stats.handovers += 1
        if d.kind == "scan":                       # find_successor hit (L51-69)
            prev, succ = None, me.next
            for _ in range(d.index):               # re-walk the skipped prefix
                prev, succ = succ, succ.next
            if d.index:                            # skipped prefix -> secondary
                sec_head, sec_tail = me.next, prev
                if isinstance(me.spin, CNANode):   # L64: secondary non-empty
                    me.spin.sec_tail.next = sec_head  # L65
                else:
                    me.spin = sec_head             # L66
                sec_tail.next = None               # L67
                me.spin.sec_tail = sec_tail        # L68
                self.stats.shuffles += 1
            succ.spin = me.spin                    # L42 (never 0: 1 or node)
            self.stats.local_handovers += 1
        elif d.kind == "flush":                    # L43-46: secondary head next
            succ = me.spin
            succ.sec_tail.next = me.next           # L45: splice sec. queue in front
            succ.spin = 1                          # L46
            self.stats.secondary_flushes += 1
        else:                                      # "fifo" (L48) / "fast_path" (§6)
            me.next.spin = 1


class MCSLock:
    """Classic MCS lock (Mellor-Crummey & Scott 1991) — the paper's baseline."""

    def __init__(self) -> None:
        self.tail: CNANode | None = None
        self._atomic = threading.Lock()

    def acquire(self, me: CNANode) -> None:
        me.next = None
        me.spin = 0
        with self._atomic:
            tail, self.tail = self.tail, me
        if tail is None:
            me.spin = 1
            return
        tail.next = me
        while me.spin == 0:
            time.sleep(0)

    def release(self, me: CNANode) -> None:
        if me.next is None:
            with self._atomic:
                if self.tail is me:
                    self.tail = None
                    return
            while me.next is None:
                time.sleep(0)
        me.next.spin = 1


@dataclass
class _Shared:
    counter: int = 0
    per_thread: dict = field(default_factory=dict)


def run_lock_stress(
    lock_factory,
    n_threads: int,
    n_sockets: int | None = None,
    iters: int = 100,
    *,
    cs_work: int = 0,
    topology: Topology | None = None,
) -> _Shared:
    """Drive ``n_threads`` through acquire/CS/release cycles; return the shared
    cell for invariant checking (counter == n_threads * iters proves mutual
    exclusion held for the increment sequence).  Thread -> virtual-socket
    placement comes from ``topology`` (default: ``flat(n_sockets)``)."""

    if topology is None:
        topology = flat(n_sockets if n_sockets is not None else 2)
    elif n_sockets is not None and n_sockets != topology.n_domains:
        raise ValueError(
            f"n_sockets={n_sockets} conflicts with topology "
            f"{topology.name!r} ({topology.n_domains} domains); pass one"
        )
    tls = threading.local()

    def socket_of() -> int:
        return tls.socket

    lock = lock_factory(socket_of)
    shared = _Shared()

    def body(tid: int) -> None:
        tls.socket = topology.domain_of(tid)
        node = CNANode()
        for _ in range(iters):
            lock.acquire(node)
            # critical section: racy read-modify-write, only safe under mutex
            v = shared.counter
            for _ in range(cs_work):
                pass
            shared.counter = v + 1
            shared.per_thread[tid] = shared.per_thread.get(tid, 0) + 1
            lock.release(node)

    threads = [threading.Thread(target=body, args=(i,)) for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return shared
