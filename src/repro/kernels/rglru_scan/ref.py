"""Pure-jnp oracle for the gated linear-recurrence scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t.  a/b: (B, S, W); h0: (B, W) -> (B, S, W)."""

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (jnp.moveaxis(a, 1, 0).astype(jnp.float32),
                          jnp.moveaxis(b, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(hs, 0, 1)
