"""Serving example (deliverable b): batched requests through the continuous-
batching engine under CNA vs FIFO admission.

    PYTHONPATH=src python examples/serve_cna.py
"""

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    raise SystemExit(serve_main([
        "--arch", "granite-3-8b", "--requests", "24", "--domains", "2",
        "--slots", "4", "--scheduler", "both",
    ]))
