"""Replica wrappers and the fleet-level admission controller.

A *replica* is anything the router can steer sessions to.  The protocol is
four members — ``capacity``, ``occupancy``, ``admit(session, now)`` and
``summary(top_k, now)`` — plus three KV-shipping hooks the router uses only
when shipping is enabled: ``peek_match(prompt)`` (tokens of the prompt the
replica's store holds, side-effect-free, for pricing),
``export_kv(prompt) -> (tokens, payload) | None`` and
``import_kv(tokens, payload)``.  Implemented here for a real ``DecodeEngine``
(``EngineReplica``) and in ``repro.router.sim`` for the jax-free fleet
simulator (``SimReplica``), so the router, federation, and benchmarks run
identically over either.

``FleetController`` is the GCR feedback loop at fleet granularity: one
``repro.placement.AdaptiveController`` per replica caps how many admissions
may be in flight there, fed from observed time-to-first-token.  A replica
whose TTFT collapses (queue buildup, cold cache storms) has its cap pulled
down, which makes the router shed new sessions to siblings — the fleet
analog of restricting the active set before scalability collapses.
"""

from __future__ import annotations

from repro.placement import AdaptiveController

from .federation import ReplicaSummary


class FleetController:
    """Per-replica in-flight admission caps driven by TTFT samples."""

    def __init__(
        self,
        n_replicas: int,
        *,
        initial: int = 8,
        min_active: int = 1,
        max_cap: int = 1 << 30,
        controllers=None,
        **controller_kwargs,
    ) -> None:
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        if controllers is not None:
            controllers = list(controllers)
            if len(controllers) != n_replicas:
                raise ValueError("need one controller per replica")
            self.controllers = controllers
        else:
            self.controllers = [
                AdaptiveController(
                    initial=initial,
                    min_active=min_active,
                    max_cap=max_cap,
                    **controller_kwargs,
                )
                for _ in range(n_replicas)
            ]
        self.inflight = [0] * n_replicas

    @property
    def n_replicas(self) -> int:
        return len(self.controllers)

    def cap(self, replica: int) -> int:
        return self.controllers[replica].cap

    @property
    def caps(self) -> list[int]:
        return [c.cap for c in self.controllers]

    def can_admit(self, replica: int) -> bool:
        return self.inflight[replica] < self.controllers[replica].cap

    def note_admit(self, replica: int) -> None:
        self.inflight[replica] += 1

    def note_finish(self, replica: int) -> None:
        if self.inflight[replica] <= 0:
            raise ValueError(f"replica {replica} has no admissions in flight")
        self.inflight[replica] -= 1

    def observe_ttft(self, replica: int, ttft) -> int:
        """Feed one time-to-first-token sample; returns the updated cap."""
        return self.controllers[replica].observe(ttft)


class EngineReplica:
    """A ``DecodeEngine`` behind the replica protocol.

    The engine must run a prefix index (that is what the summary advertises
    and what derives per-session homes inside the replica); sessions are
    submitted with ``domain=None`` so the engine's own index places them in
    its internal domains, while the router only chose the *replica*.
    """

    def __init__(self, rid: int, engine) -> None:
        if engine.prefix_index is None:
            raise ValueError(
                "EngineReplica needs an engine with a prefix index — the "
                "summary it exports to the federation comes from there"
            )
        self.rid = rid
        self.engine = engine
        self._live: dict[int, tuple] = {}  # sid -> (session, request)

    @property
    def capacity(self) -> int:
        return self.engine.n_slots

    @property
    def occupancy(self) -> int:
        return len(self.engine.active_req) + len(self.engine.scheduler)

    def has_capacity(self) -> bool:
        return self.occupancy < self.capacity

    def summary(self, top_k: int, now: int) -> ReplicaSummary:
        s = self.engine.summary(top_k)
        return ReplicaSummary(
            replica=self.rid,
            t=now,
            occupancy=s["occupancy"],
            capacity=s["capacity"],
            prefixes=s["prefixes"],
        )

    def admit(self, session, now: int) -> int:
        """Submit the steered session into the engine; returns the tokens of
        the prompt this replica already holds — what re-prefill accounting
        must count.  That is the *max* of the prefix index's matched_len
        (metadata: which pool is warm) and the prefix-KV store's resumable
        run (actual prefilled bytes, including just-shipped bundles): the
        index knows nothing of imported bundles and zeroes its match on an
        intra-engine shed, so counting it alone would book shipped tokens
        as re-prefilled while the router books them as avoided."""
        from repro.serving.engine import Request

        resumable = self.engine.peek_match(session.prompt)
        req = Request(
            rid=session.sid,
            prompt=list(session.prompt),
            max_new=session.decode_len,
            domain=None,
        )
        self.engine.submit(req)
        self._live[session.sid] = (session, req)
        return max(req.matched_len, resumable)

    # -- KV shipping hooks (repro.router.kvship) -------------------------------
    def peek_match(self, prompt, now: int = 0) -> int:
        """Tokens of ``prompt`` the engine's prefix-KV store could resume
        from (0 when the engine runs no store) — ship-pricing input.
        ``now`` is part of the protocol for the sim's in-flight-transfer
        embargo; a real engine's store has no router clock to consult."""
        return self.engine.peek_match(prompt)

    def export_kv(self, prompt):
        """Export the engine's longest stored prefix cache for ``prompt``
        (``(tokens, (cache, logits))`` of immutable jax arrays, or None).
        Replicas in one fleet serve the same model, so the bundle is
        shape-compatible with any sibling's ``import_kv``."""
        return self.engine.export_kv(prompt)

    def import_kv(self, tokens, payload, ready_t: int = 0) -> bool:
        """Deposit a shipped bundle into the engine's store; the steered
        session's admission then resumes from it via the ordinary
        prefill-reuse path (counted in ``reused_positions``).  ``ready_t``
        is the sim-side delivery embargo; an in-process engine receives the
        references immediately.  False means the bundle was refused (no
        store, or it cannot fit this engine's cache) — the caller must fall
        back to re-prefill and book nothing."""
        return self.engine.import_kv(tokens, payload)

    def step(self) -> list[tuple]:
        """One engine tick; returns ``(session, ttft)`` pairs for sessions
        that retired this tick.  TTFT is engine-clock ticks from submit to
        admission plus one (the admission's prefill emits the first token
        on that following tick), floored at 1 — the sample the fleet
        controller's GCR loop consumes."""
        self.engine.step()
        done = []
        for sid, (session, req) in list(self._live.items()):
            if req.finish_t >= 0:
                ttft = max(0, req.admit_t - req.submit_t) + 1
                done.append((session, ttft))
                del self._live[sid]
        return done
