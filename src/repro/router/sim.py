"""Deterministic discrete-event fleet simulator for routing-policy evaluation.

``repro.core.numasim`` reproduces the paper's lock dynamics with the smallest
cost model that exhibits them; this module is the same idiom one hierarchy
level up — seeded RNG, heapq event loop, integer tick costs — for a fleet of
decode replicas behind a router.  The ingredients mirror what the router tier
actually trades off:

  * per-token prefill cost for the *uncached* part of each prompt (the
    dominant term; re-prefilling a prefix that is warm elsewhere is the
    fleet-level remote miss),
  * per-token decode cost occupying a replica slot,
  * a serialized dispatch pipe whose steering cost scales with the replica-
    topology distance switched (why CNA-clustered dispatch order matters),
  * finite per-replica KV memory: a token-budget LRU prefix cache, so a
    replica that sees every prefix thrashes while a replica with a stable
    working set stays warm — the mechanism that separates federated routing
    from round-robin/least-loaded.

Everything is driven by one ``random.Random(seed)``: bit-for-bit
reproducible, no jax, so ``benchmarks/router_bench.py`` runs in the
dependency-light CI smoke lane.
"""

from __future__ import annotations

import heapq
import random
from collections import OrderedDict
from dataclasses import dataclass, field

from .federation import ReplicaSummary
from .router import ReplicaRouter, Session


@dataclass(frozen=True)
class FleetCostModel:
    """Tick costs (presets sized so prefill dominates, as it does in real
    prefix-heavy serving)."""

    c_prefill: int = 4      # per uncached prompt token
    c_decode: int = 2       # per generated token (slot residency)
    c_dispatch: int = 2     # router work per admission
    c_steer: int = 8        # extra router work per unit replica-distance switched
    # router work the *full* dispatch pipeline pays beyond the irreducible
    # admission (candidate scan, shed checks, ship pricing / federation
    # lookups) — a fissile fast-path dispatch skips it.  Default 0 keeps
    # every pre-existing bench and determinism pin bit-identical; the
    # fastpath bench sets it on both arms so only the bypass differs.
    c_pipeline: int = 0


class ReplicaCache:
    """Token-budget LRU prefix cache — finite KV memory for one replica.

    Entries are full token sequences; an insert is charged only for the
    tokens *not* shared with its best current match (the incremental cost of
    a radix KV store, so many suffixes of one hot prefix do not multiply the
    prefix's charge).  Evicting the least-recently-used entries frees their
    charge.  ``match`` returns the longest common run against any entry and
    refreshes the hit, so a steadily re-used prefix survives.

    ``page_size`` mirrors the engine's paged KV store jax-free: sharing is
    page-granular, so an insert reuses only *full* pages of its best match —
    the partial boundary page is copied (charged), exactly the engine's
    copy-on-write ingest.  The default (1) reproduces the token-granular
    charge bit-for-bit.  ``on_evict`` (settable after construction) fires
    with each evicted run — the router's fleet victim caching listens."""

    def __init__(self, budget_tokens: int, *, page_size: int = 1, on_evict=None) -> None:
        if budget_tokens < 1:
            raise ValueError("budget_tokens must be >= 1")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.budget = budget_tokens
        self.page_size = page_size
        self.on_evict = on_evict
        self._lru: "OrderedDict[tuple, int]" = OrderedDict()  # seq -> charged
        self._charged = 0
        self._stamp = 0
        self._stamps: dict[tuple, int] = {}

    def __len__(self) -> int:
        return len(self._lru)

    @property
    def charged_tokens(self) -> int:
        return self._charged

    @property
    def pages_held(self) -> int:
        """Charged tokens rounded up to pages — the sim-side analogue of
        the engine page table's ``pages_held``."""
        return -(-self._charged // self.page_size)

    @staticmethod
    def _common(a: tuple, b: tuple) -> int:
        n = min(len(a), len(b))
        k = 0
        while k < n and a[k] == b[k]:
            k += 1
        return k

    def match(self, tokens) -> int:
        """Longest common run between ``tokens`` and any cached sequence."""
        key = tuple(tokens)
        best, best_key = 0, None
        for seq in self._lru:
            k = self._common(seq, key)
            if k > best:
                best, best_key = k, seq
        if best_key is not None:
            self._touch(best_key)
        return best

    def peek(self, tokens) -> int:
        """``match`` without the LRU touch — the router's ship pricing reads
        this, and a price probe must not refresh an entry's recency."""
        key = tuple(tokens)
        return max((self._common(seq, key) for seq in self._lru), default=0)

    def _touch(self, key: tuple) -> None:
        self._lru.move_to_end(key)
        self._stamp += 1
        self._stamps[key] = self._stamp

    def insert(self, tokens) -> int:
        """Cache ``tokens``; returns the charged (uncached) token count."""
        key = tuple(tokens)
        if not key:
            return 0
        if key in self._lru:
            self._touch(key)
            return 0
        # page-granular sharing: only full pages of the best match are
        # reused; the partial boundary page is copied (COW) and charged.
        # page_size=1 -> held == match, the token-granular legacy charge.
        held = (self.match(key) // self.page_size) * self.page_size
        charge = len(key) - held
        self._lru[key] = charge
        self._charged += charge
        self._touch(key)
        while self._charged > self.budget and len(self._lru) > 1:
            old, freed = self._lru.popitem(last=False)
            del self._stamps[old]
            self._charged -= freed
            if self.on_evict is not None:
                self.on_evict(old)
        return charge

    def hottest(self, top_k: int) -> list[tuple[tuple, int]]:
        """Most-recently-used ``top_k`` sequences as (tokens, stamp) pairs,
        hottest first — the summary shape the federation ingests."""
        out = [(seq, self._stamps[seq]) for seq in reversed(self._lru)]
        return out[:top_k]


class SimReplica:
    """One simulated decode replica: slots + a finite prefix cache."""

    def __init__(
        self, rid: int, n_slots: int, *, cache_budget: int, page_size: int = 1
    ) -> None:
        self.rid = rid
        self.n_slots = n_slots
        self.cache = ReplicaCache(cache_budget, page_size=page_size)
        self.inflight = 0
        self.served = 0
        self.reprefill_tokens = 0
        # shipped prefixes in flight: (ready_t, tokens), invisible to match/
        # peek until the fabric delivers them (see import_kv)
        self._pending: list[tuple[int, tuple]] = []

    @property
    def capacity(self) -> int:
        return self.n_slots

    @property
    def occupancy(self) -> int:
        return self.inflight

    def has_capacity(self) -> bool:
        return self.inflight < self.n_slots

    def summary(self, top_k: int, now: int) -> ReplicaSummary:
        return ReplicaSummary(
            replica=self.rid,
            t=now,
            occupancy=self.inflight,
            capacity=self.n_slots,
            prefixes=tuple(self.cache.hottest(top_k)),
        )

    def admit(self, session: Session, now: int) -> int:
        """Occupy a slot; the prompt's cached run is reused, the uncached
        suffix is (re-)prefilled and enters this replica's cache."""
        if not self.has_capacity():
            raise ValueError(f"replica {self.rid} is full")
        ship = getattr(session, "ship", None)
        if ship is not None and ship.executed:
            # the shipping session's own prefill starts no earlier than its
            # transfer completes (the sim holds its first token until
            # fabric_end), so everything delivered by then is legitimately
            # reusable for this session.  NB: like every admit, the line
            # below then inserts the *whole prompt* optimistically — the
            # sim's uniform model (all arms, shipping or not) is that a
            # session's KV is visible from admission even though its
            # prefill finishes later, so the embargo protects imports that
            # are not immediately followed by the importer's admission
            # (e.g. a future prefetch path), not racers arriving after it.
            self._deliver(ship.fabric_end)
        else:
            self._deliver(now)
        self.inflight += 1
        matched = self.cache.match(session.prompt)
        self.cache.insert(session.prompt)
        self.served += 1
        self.reprefill_tokens += len(session.prompt) - matched
        return matched

    # -- KV shipping hooks (repro.router.kvship) -------------------------------
    def _deliver(self, now: int) -> None:
        """Land every in-flight shipped prefix whose transfer has completed
        by ``now`` — until then shipped KV is *not* reusable, so a second
        session racing the fabric cannot time-travel onto bytes that have
        not arrived."""
        if not self._pending:
            return
        still = []
        for ready_t, tokens in self._pending:
            if ready_t <= now:
                self.cache.insert(tokens)
            else:
                still.append((ready_t, tokens))
        self._pending = still

    def peek_match(self, prompt, now: int = 0) -> int:
        """Tokens of ``prompt`` this replica's cache holds at ``now``,
        without touching recency — what the router prices a ship decision
        against.  In-flight (undelivered) ships do not count."""
        self._deliver(now)
        return self.cache.peek(prompt)

    def export_kv(self, prompt):
        """Export the cached prefix of ``prompt`` for a fabric transfer ->
        ``(tokens, payload)`` or None when nothing matches.  In the sim the
        KV bytes are implied by the token run (payload None); the engine
        replica ships the actual cache bundle.  Export touches recency — a
        shipped prefix is hot, the LRU should keep it."""
        matched = self.cache.match(prompt)
        if matched <= 0:
            return None
        return tuple(prompt[:matched]), None

    def import_kv(self, tokens, payload, ready_t: int = 0) -> bool:
        """Accept a shipped prefix; it becomes visible once the fabric
        delivers it (``ready_t``, router ticks).  The eventual insert is
        charged against the KV budget exactly like a locally prefilled run
        (shipping moves bytes, it does not mint memory)."""
        self._pending.append((int(ready_t), tuple(tokens)))
        return True

    def set_victim_hook(self, cb) -> None:
        """Route this replica's cache evictions to ``cb(tokens)`` — the
        router's fleet victim caching subscribes here."""
        self.cache.on_evict = cb

    def finish(self, session: Session) -> None:
        if self.inflight <= 0:
            raise ValueError(f"replica {self.rid} has nothing in flight")
        self.inflight -= 1


class _BaselineRouter:
    """Round-robin / least-loaded control arms behind the router interface
    (FIFO dispatch, no federation, same capacity gating and completion
    accounting, so the comparison isolates the routing policy)."""

    def __init__(self, replicas, *, policy: str, topology=None, tracer=None) -> None:
        from collections import deque

        from repro.core.topology import flat, get_topology
        from repro.obs import NULL_TRACER

        from .router import RouterStats

        self.replicas = list(replicas)
        n = len(self.replicas)
        self.topology = (
            get_topology(topology) if topology is not None else flat(n, "replicas")
        )
        self.policy = policy
        self._q: "deque[Session]" = deque()
        self._clock = 0
        self._rr = 0
        self._prev = 0
        self.stats = RouterStats()
        self.tracer = tracer if tracer is not None else NULL_TRACER

    @property
    def now(self) -> int:
        return self._clock

    def tick(self) -> None:
        self._clock += 1

    def advance(self, now: int) -> None:
        while self._clock < now:
            self.tick()

    def sync(self) -> None:  # baselines have no federation
        pass

    def __len__(self) -> int:
        return len(self._q)

    def submit(self, session: Session) -> int:
        session.submit_t = self.now
        session.home = 0
        if self.tracer:
            self.tracer.begin(
                "session", session.sid, self.now, prompt_len=len(session.prompt)
            )
        self._q.append(session)
        return 0

    def _pick(self) -> int | None:
        n = len(self.replicas)
        free = [r for r in range(n) if self.replicas[r].has_capacity()]
        if not free:
            return None
        if self.policy == "round_robin":
            for off in range(n):
                r = (self._rr + off) % n
                if r in free:
                    self._rr = r + 1
                    return r
        return min(free, key=lambda r: (self.replicas[r].occupancy, r))

    def dispatch_one(self):
        if not self._q:
            return None
        target = self._pick()
        if target is None:
            return None
        session = self._q.popleft()
        session.replica = target
        session.home = target
        session.dispatch_t = self.now
        dist = 0 if target == self._prev else self.topology.distance(self._prev, target)
        self._prev = target
        if self.tracer:
            self.tracer.span(
                "queue_wait", session.sid, session.submit_t, self.now,
                domain=target, kind=self.policy,
            )
            self.tracer.span(
                "dispatch", session.sid, self.now, self.now,
                replica=target, steer_distance=dist,
            )
        session.local_matched = self.replicas[target].admit(session, self.now)
        self.stats.dispatched += 1
        self.stats.routed_tokens += len(session.prompt)
        self.stats.reprefill_tokens += len(session.prompt) - session.local_matched
        if session.local_matched:
            self.stats.local_hits += 1
        self.stats.stalls.append(session.stall)
        return session, target, dist

    def complete(self, session: Session, *, ttft=None) -> None:
        session.finish_t = self.now
        if self.tracer:
            self.tracer.end(self.tracer.open_span(session.sid, "session"), self.now)


@dataclass
class FleetResult:
    """One simulated run's aggregates.  ``stall_*`` are queueing only
    (submit -> dispatch, router ticks); ``admission_stall_*`` include the
    service the admission still owes before a first token — ship wait +
    transfer + prefill of the uncached suffix (submit -> first token) —
    which is the quantity KV shipping trades against re-prefill."""

    name: str
    n_sessions: int = 0
    ticks: int = 0
    reprefill_tokens: int = 0
    routed_tokens: int = 0
    hit_rate: float = 0.0
    reuse_fraction: float = 0.0
    stall_mean: float = 0.0
    stall_p99: float = 0.0
    sheds: int = 0
    dispatch_locality: float = 0.0   # discipline-side: no-switch dispatches
    per_replica_served: list = field(default_factory=list)
    ttfts: list = field(default_factory=list)
    # admission stall (submit -> first token), the ship/re-prefill currency
    admission_stall_total: int = 0
    admission_stall_p50: float = 0.0
    admission_stall_p99: float = 0.0
    # fissile fast path (router_kwargs={"fissile": True}): dispatches that
    # bypassed the full pipeline (0 everywhere when fissile is off)
    fast_dispatches: int = 0
    # KV shipping (0 everywhere when shipping is off)
    ships: int = 0
    shipped_tokens: int = 0
    ship_cycles: int = 0
    reprefill_avoided: int = 0
    ship_segments: int = 0
    prefetch_ships: int = 0
    prefetch_tokens: int = 0
    victim_ships: int = 0
    victim_tokens: int = 0
    # latency attribution: admission stall decomposed per phase, summed over
    # sessions.  Conservation law (property-tested): queue_wait + dispatch +
    # ship_wait + prefill == admission_stall_total, exactly — the same
    # identity each session's phase.* trace spans satisfy individually.
    phase_cycles: dict = field(default_factory=dict)

    @property
    def fairness_factor(self) -> float:
        counts = sorted(self.per_replica_served, reverse=True)
        tot = sum(counts)
        if not counts or tot == 0:
            return 1.0
        half = max(1, len(counts) // 2)
        return sum(counts[:half]) / tot


def shared_prefix_sessions(
    draws, prefix_len: int, suffix_len: int, decode_len: int
) -> list[Session]:
    """Sessions over shared system-prompt prefixes + unique suffixes — the
    same workload shape ``benchmarks.serving_bench.shared_prefix`` uses, at
    session granularity.  ``draws`` is the prefix id per session (callers
    sample it, e.g. with ``benchmarks.common.zipf_draws``, so every bench
    workload skews identically)."""
    return [
        Session(
            sid=i,
            prompt=tuple(1_000 * pid + j for j in range(prefix_len))
            + tuple(900_000 + i * suffix_len + j for j in range(suffix_len)),
            decode_len=decode_len,
        )
        for i, pid in enumerate(draws)
    ]


def make_router(
    arm: str, replicas, *, topology=None, seed: int = 0xF1EE7, tracer=None, **kw
):
    """Build the routing arm: ``federated`` (the tier under test) or the
    ``round_robin`` / ``least_loaded`` controls.  ``tracer`` threads a
    ``repro.obs.Tracer`` through either arm (None => zero-cost off)."""
    if arm == "federated":
        return ReplicaRouter(replicas, topology=topology, seed=seed, tracer=tracer, **kw)
    if arm in ("round_robin", "least_loaded"):
        return _BaselineRouter(replicas, policy=arm, topology=topology, tracer=tracer)
    raise KeyError(f"unknown routing arm {arm!r}")


def simulate(
    arm: str,
    sessions: list[Session],
    *,
    n_replicas: int = 4,
    n_slots: int = 4,
    cache_budget: int = 600,
    topology=None,
    cm: FleetCostModel | None = None,
    inter_arrival: int = 16,
    seed: int = 42,
    arrivals=None,
    rng: random.Random | None = None,
    kv_ship=None,
    page_size: int | None = None,
    router_kwargs: dict | None = None,
    tracer=None,
    registry=None,
) -> FleetResult:
    """Run ``sessions`` through a fleet under one routing arm; returns the
    aggregate ``FleetResult``.  Event loop: arrivals are scheduled up front
    with ~uniform jitter around ``inter_arrival``; dispatches drain whenever
    the serialized dispatch pipe is free; a dispatched session occupies its
    replica for prefill(uncached) + decode ticks, then frees the slot and
    reports TTFT to the router.

    ``kv_ship`` (federated arm only): a ``repro.router.kvship.ShipCostModel``
    or True.  The router then prices min(re-prefill, ship) per dispatch; a
    chosen ship queues on the serialized fabric pipe and the session's first
    token waits for max(dispatch, transfer) before prefilling only the
    unshipped suffix.  The ship model's ``c_prefill`` is re-pinned to this
    run's ``cm.c_prefill`` so the argmin prices the machine that executes.

    Randomness is seedable end-to-end: the *only* RNG in this module is the
    run-scoped ``random.Random(seed)`` built here (audited — no module-level
    random state anywhere in ``repro.router``), and callers may inject their
    own via ``rng`` or bypass sampling entirely with ``arrivals`` — an
    explicit per-session list of arrival ticks (e.g. a ``repro.workload``
    trace schedule), so paired arms replay bit-identical schedules.

    ``tracer`` (a ``repro.obs.Tracer``, any arm): per-session causal spans
    plus the attribution layer — ``phase.queue_wait`` / ``phase.dispatch`` /
    ``phase.ship_wait`` / ``phase.prefill`` spans whose cycles sum *exactly*
    to that session's admission stall (submit -> first token).  ``registry``
    (a ``repro.obs.MetricsRegistry``): the run's stat surfaces register into
    it as live views.  Both default off and never perturb the run."""
    cm = cm or FleetCostModel()
    rng = rng if rng is not None else random.Random(seed)
    router_kwargs = dict(router_kwargs or {})
    scm = None
    if kv_ship:
        if arm != "federated":
            raise ValueError(
                "kv_ship requires the federated arm — the baselines have no "
                "federation to discover remote holders with"
            )
        from dataclasses import replace

        from .kvship import ShipCostModel

        scm = ShipCostModel() if kv_ship is True else kv_ship
        scm = replace(scm, c_prefill=cm.c_prefill)
        router_kwargs["kv_ship"] = scm
    # page-granular accounting: the replicas' caches mirror the ship model's
    # page size so the bytes the router prices are the bytes the caches hold
    # (explicit page_size overrides; 0/None -> token-granular legacy)
    ps = page_size or getattr(scm, "page_size", 0) or 1
    replicas = [
        SimReplica(r, n_slots, cache_budget=cache_budget, page_size=ps)
        for r in range(n_replicas)
    ]
    router = make_router(arm, replicas, topology=topology, seed=seed,
                         tracer=tracer, **router_kwargs)

    events: list[tuple[int, int, str, object]] = []
    seq = 0

    def push(t: int, kind: str, payload) -> None:
        nonlocal seq
        seq += 1
        heapq.heappush(events, (t, seq, kind, payload))

    if arrivals is not None:
        if len(arrivals) != len(sessions):
            raise ValueError(
                f"arrivals gives {len(arrivals)} ticks for {len(sessions)} sessions"
            )
        for at, s in zip(arrivals, sessions):
            push(int(at), "arrive", s)
    else:
        t = 0
        for s in sessions:
            t += max(1, int(inter_arrival * rng.uniform(0.5, 1.5)))
            push(t, "arrive", s)

    busy_until = 0
    finished = 0
    ttfts: list[int] = []
    admission_stalls: list[int] = []
    # attribution totals (always kept — four int adds per dispatch); the
    # conservation law is sum(phases) == admission_stall_total, exactly
    phases = {"queue_wait": 0, "dispatch": 0, "ship_wait": 0, "prefill": 0}
    last_t = 0
    while events:
        t, _, kind, payload = heapq.heappop(events)
        last_t = t
        router.advance(t)
        if kind == "arrive":
            router.submit(payload)
        elif kind == "finish":
            session, ttft = payload
            replicas[session.replica].finish(session)
            router.complete(session, ttft=ttft)
            ttfts.append(ttft)
            finished += 1
        # drain the dispatch pipe
        while busy_until <= t:
            d = router.dispatch_one()
            if d is None:
                break
            session, target, dist = d
            cost = cm.c_dispatch + cm.c_steer * dist
            if not getattr(session, "fast", False):
                cost += cm.c_pipeline  # full pipeline; the fast path skips it
            start = t + cost
            busy_until = start
            uncached = len(session.prompt) - session.local_matched
            prefill = cm.c_prefill * uncached
            # a chosen ship already reserved the fabric at dispatch time:
            # the first token additionally waits for the transfer to land
            # (pipe and fabric overlap — max, not sum)
            ready = start
            ship = session.ship
            if ship is not None and ship.executed:
                ready = max(start, ship.fabric_end)
            first_tok = ready + prefill
            # TTFT for the fleet controller runs from *dispatch*, not submit:
            # the GCR loop throttles a replica whose admissions take long to
            # produce a first token (cold-cache storms, internal queueing) —
            # router-side queueing is the signal's *output*, and feeding it
            # back would read congestion as collapse and choke the fleet
            ttft = first_tok - session.dispatch_t
            admission_stalls.append(first_tok - session.submit_t)
            # exact decomposition of this session's admission stall:
            #   (t - submit) + cost + (ready - start) + prefill
            # == first_tok - submit  (telescoping: start = t + cost,
            # first_tok = ready + prefill) — integers, no rounding
            phases["queue_wait"] += t - session.submit_t
            phases["dispatch"] += cost
            phases["ship_wait"] += ready - start
            phases["prefill"] += prefill
            if tracer:
                root = tracer.open_span(session.sid, "session")
                sid = session.sid
                tracer.span("phase.queue_wait", sid, session.submit_t, t,
                            parent=root, cycles=t - session.submit_t)
                tracer.span("phase.dispatch", sid, t, start,
                            parent=root, cycles=cost)
                tracer.span("phase.ship_wait", sid, start, ready,
                            parent=root, cycles=ready - start)
                tracer.span("phase.prefill", sid, ready, first_tok,
                            parent=root, cycles=prefill, uncached=uncached)
            finish_t = first_tok + cm.c_decode * session.decode_len
            push(finish_t, "finish", (session, ttft))
        if busy_until > t and len(router):
            push(busy_until, "drain", None)

    assert finished == len(sessions), f"{finished}/{len(sessions)} finished"
    stats = router.stats
    if registry is not None:
        stats.register_into(registry, prefix=f"{arm}_router")
        m = getattr(router, "metrics", None)
        if m is not None:
            m.register_into(registry, prefix=f"{arm}_sched")
        fabric = getattr(router, "fabric", None)
        if fabric is not None:
            fabric.stats.register_into(registry, prefix=f"{arm}_ship")
    stalls = sorted(stats.stalls)
    p99 = stalls[min(len(stalls) - 1, int(0.99 * len(stalls)))] if stalls else 0
    adm = sorted(admission_stalls)
    adm_p50 = adm[min(len(adm) - 1, int(0.50 * len(adm)))] if adm else 0
    adm_p99 = adm[min(len(adm) - 1, int(0.99 * len(adm)))] if adm else 0
    m = getattr(router, "metrics", None)
    return FleetResult(
        name=arm,
        n_sessions=len(sessions),
        ticks=last_t,
        reprefill_tokens=stats.reprefill_tokens,
        routed_tokens=stats.routed_tokens,
        hit_rate=stats.hit_rate,
        reuse_fraction=stats.reuse_fraction,
        stall_mean=sum(stalls) / max(1, len(stalls)),
        stall_p99=float(p99),
        sheds=getattr(stats, "sheds", 0),
        dispatch_locality=m.locality if m is not None else 0.0,
        per_replica_served=[r.served for r in replicas],
        ttfts=ttfts,
        admission_stall_total=sum(adm),
        admission_stall_p50=float(adm_p50),
        admission_stall_p99=float(adm_p99),
        fast_dispatches=getattr(stats, "fast_dispatches", 0),
        ships=getattr(stats, "ships", 0),
        shipped_tokens=getattr(stats, "shipped_tokens", 0),
        ship_cycles=getattr(stats, "ship_cycles", 0),
        reprefill_avoided=getattr(stats, "reprefill_avoided", 0),
        ship_segments=getattr(stats, "ship_segments", 0),
        prefetch_ships=getattr(stats, "prefetch_ships", 0),
        prefetch_tokens=getattr(stats, "prefetch_tokens", 0),
        victim_ships=getattr(stats, "victim_ships", 0),
        victim_tokens=getattr(stats, "victim_tokens", 0),
        phase_cycles=phases,
    )
