"""Training driver with checkpoint/restart, heartbeats and straggler hooks.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
        --preset reduced --steps 100 --ckpt-dir /tmp/ckpt --resume

On the CPU container this drives reduced configs end-to-end (the full configs
are exercised by the dry-run); on a real pod the same driver runs per host
with ``--mesh production``.  Fault handling: the loop checkpoints every
``--ckpt-every`` steps, reports heartbeats, and on (injected) worker failure
restores the latest checkpoint onto the surviving mesh via ElasticTrainer —
the restart path is exercised in tests/test_fault_elastic.py.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_config, get_reduced_config
from repro.data.pipeline import BigramLMDataset, ShardedLoader
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.registry import build_model
from repro.models.sharding import use_mesh
from repro.optim.schedule import warmup_cosine
from repro.runtime.fault import HeartbeatMonitor, StragglerDetector
from repro.training.step import (
    init_state,
    make_train_step,
    state_abstract,
    state_logical,
    tree_shardings,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--preset", default="reduced", choices=["reduced", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default="host", choices=["host", "production", "production-multipod"])
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = args.arch.replace("-", "_").replace(".", "")
    cfg = get_reduced_config(arch) if args.preset == "reduced" else get_config(arch)
    cfg = cfg.replace(accum=max(1, cfg.accum if args.batch % max(1, cfg.accum) == 0 else 1))
    model = build_model(cfg)
    if args.mesh == "host":
        mesh = make_host_mesh(args.model_parallel)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh.endswith("multipod"))

    ds = BigramLMDataset(cfg.vocab, args.seq, args.batch, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    hb = HeartbeatMonitor(n_workers=1, timeout=600.0)
    straggle = StragglerDetector(n_workers=1)

    lr_fn = lambda s: warmup_cosine(s, peak_lr=args.lr, warmup=max(1, args.steps // 20), total=args.steps)
    step_fn = make_train_step(model, cfg, lr_fn=lr_fn, weight_decay=0.0)

    with use_mesh(mesh):
        sh = tree_shardings(state_abstract(model, cfg), state_logical(model))
        start = 0
        if args.resume and ckpt and ckpt.latest_step() is not None:
            state, extra = ckpt.restore(ckpt.latest_step(), state_abstract(model, cfg),
                                        shardings=sh, extra=True)
            start = extra.get("data_step", int(state["step"]))
            print(f"resumed at step {start}")
        else:
            state = init_state(model, jax.random.PRNGKey(args.seed), cfg)
            if sh is not None:
                state = jax.device_put(state, sh)
        loader = ShardedLoader(ds, start_step=start)
        jstep = jax.jit(step_fn, in_shardings=(sh, None), out_shardings=(sh, None), donate_argnums=0)

        losses = []
        for i in range(start, args.steps):
            t0 = time.time()
            batch = next(loader)
            state, metrics = jstep(state, batch)
            dt = time.time() - t0
            hb.beat(0)
            straggle.record(0, dt)
            losses.append(float(metrics["loss"]))
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss {losses[-1]:.4f} lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} {dt*1000:.0f}ms")
            if ckpt and (i + 1) % args.ckpt_every == 0:
                ckpt.save(int(state["step"]), state, extra={"data_step": loader.step}, blocking=False)
        if ckpt:
            ckpt.save(int(state["step"]), state, extra={"data_step": loader.step})
            ckpt.wait()
    floor = ds.entropy_floor
    print(f"final loss {losses[-1]:.4f} (bigram entropy floor {floor:.4f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
