"""All attention implementations agree; decode path matches full recompute."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback
    from _hypothesis_compat import given, settings, st

from repro.models.attention import (
    attn_chunked,
    attn_decode,
    attn_triangular,
    attn_xla,
)


def _qkv(key, b, sq, skv, h, hkv, hd, dt=jnp.float32):
    ks = jax.random.split(key, 3)
    return (
        jax.random.normal(ks[0], (b, sq, h, hd), dt),
        jax.random.normal(ks[1], (b, skv, hkv, hd), dt),
        jax.random.normal(ks[2], (b, skv, hkv, hd), dt),
    )


@pytest.mark.parametrize("window", [0, 32])
@pytest.mark.parametrize("chunk", [16, 64, 128])
def test_chunked_matches_xla(window, chunk):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 128, 128, 4, 2, 32)
    want = attn_xla(q, k, v, causal=True, window=window)
    got = attn_chunked(q, k, v, causal=True, window=window, chunk=chunk)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [0, 48])
@pytest.mark.parametrize("chunk", [32, 64])
def test_triangular_matches_xla(window, chunk):
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 128, 128, 4, 4, 32)
    want = attn_xla(q, k, v, causal=True, window=window)
    got = attn_triangular(q, k, v, causal=True, window=window, chunk=chunk)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_chunked_cross_attention_unpadded_kv():
    """Non-causal, S_kv not a multiple of chunk (whisper cross-attn path)."""
    q, k, v = _qkv(jax.random.PRNGKey(2), 2, 64, 100, 4, 4, 32)
    want = attn_xla(q, k, v, causal=False, window=0)
    got = attn_chunked(q, k, v, causal=False, window=0, chunk=32)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@given(
    sq=st.integers(1, 40),
    h=st.sampled_from([1, 2, 4]),
    hkv=st.sampled_from([1, 2]),
    window=st.sampled_from([0, 8]),
    seed=st.integers(0, 1000),
)
@settings(max_examples=20, deadline=None)
def test_chunked_equals_xla_property(sq, h, hkv, window, seed):
    if h % hkv:
        hkv = 1
    q, k, v = _qkv(jax.random.PRNGKey(seed), 1, sq, sq, h, hkv, 16)
    want = attn_xla(q, k, v, causal=True, window=window)
    got = attn_chunked(q, k, v, causal=True, window=window, chunk=8)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


def test_decode_matches_full_last_row():
    """attn_decode on a cache of n valid entries == row n-1 of full attention."""
    b, s, h, hkv, hd = 2, 32, 4, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(3), b, s, s, h, hkv, hd)
    full = attn_xla(q, k, v, causal=True)
    for n in (1, 7, 32):
        out = attn_decode(q[:, n - 1 : n], k, v, jnp.asarray(n))
        np.testing.assert_allclose(out[:, 0], full[:, n - 1], atol=2e-5, rtol=2e-5)


def test_decode_window_matches_full():
    b, s, h, hkv, hd, w = 1, 64, 2, 1, 16, 16
    q, k, v = _qkv(jax.random.PRNGKey(4), b, s, s, h, hkv, hd)
    full = attn_xla(q, k, v, causal=True, window=w)
    n = 50
    out = attn_decode(q[:, n - 1 : n], k, v, jnp.asarray(n), window=w)
    np.testing.assert_allclose(out[:, 0], full[:, n - 1], atol=2e-5, rtol=2e-5)


def test_decode_ring_buffer_equivalence():
    """A ring cache of size w holding the last w tokens == windowed decode."""
    b, s, h, hkv, hd, w = 1, 48, 2, 2, 16, 16
    q, k, v = _qkv(jax.random.PRNGKey(5), b, s, s, h, hkv, hd)
    n = 40  # current length; ring holds tokens n-w..n-1 in rotated order
    ring_idx = [(i % w) for i in range(n - w, n)]
    k_ring = jnp.zeros((b, w, hkv, hd), k.dtype)
    v_ring = jnp.zeros((b, w, hkv, hd), v.dtype)
    for pos, slot in zip(range(n - w, n), ring_idx):
        k_ring = k_ring.at[:, slot].set(k[:, pos])
        v_ring = v_ring.at[:, slot].set(v[:, pos])
    got = attn_decode(q[:, n - 1 : n], k_ring, v_ring, jnp.asarray(n), window=w, ring=True)
    want = attn_xla(q, k, v, causal=True, window=w)[:, n - 1]
    np.testing.assert_allclose(got[:, 0], want, atol=2e-5, rtol=2e-5)


def test_per_row_positions_decode():
    """attn_decode with per-row cur_len matches per-row scalar calls."""
    b, s, h, hkv, hd = 3, 24, 2, 1, 16
    q, k, v = _qkv(jax.random.PRNGKey(6), b, s, s, h, hkv, hd)
    lens = jnp.asarray([5, 13, 24])
    got = attn_decode(q[:, :1], k, v, lens)
    for i, n in enumerate([5, 13, 24]):
        want = attn_decode(q[i : i + 1, :1], k[i : i + 1], v[i : i + 1], jnp.asarray(n))
        np.testing.assert_allclose(got[i : i + 1], want, atol=2e-5, rtol=2e-5)


def test_gradients_flow_and_match():
    """d(loss)/dq identical between xla and chunked implementations."""
    q, k, v = _qkv(jax.random.PRNGKey(7), 1, 64, 64, 2, 2, 16)

    g1 = jax.grad(lambda q: attn_xla(q, k, v, causal=True).sum())(q)
    g2 = jax.grad(lambda q: attn_chunked(q, k, v, causal=True, chunk=16).sum())(q)
    g3 = jax.grad(lambda q: attn_triangular(q, k, v, causal=True, chunk=16).sum())(q)
    np.testing.assert_allclose(g1, g2, atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(g1, g3, atol=3e-5, rtol=3e-5)
