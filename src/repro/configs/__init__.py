from .base import (  # noqa: F401
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    get_reduced_config,
    shape_applicable,
)
