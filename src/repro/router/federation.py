"""Federated prefix homes: one index over many replicas' summaries.

The paper's discipline keeps lock ownership where the cache is warm; one
level up, a fleet of decode-engine replicas is itself a NUMA machine — a
prefix hot on replica A should not be re-prefilled on replica B.  Replicas
cannot share a live radix tree (they are separate processes in production),
so each periodically emits a *compact summary* — its top-K hottest cached
prefixes plus occupancy (``DecodeEngine.summary`` / ``PrefixIndex.summary``)
— and this module aggregates them into one ``FederatedPrefixIndex`` that
answers ``route(prompt) -> (replica, matched_len)`` by longest federated
match with a least-loaded tie-break.

The merged view is *rebuilt from the live summaries* whenever they change
(summaries are tiny — K prefixes per replica — so a rebuild is cheap).
Rebuilding, rather than patching, gives the federation its two safety
properties by construction, both pinned by property tests:

  * it never routes a matched prompt to a replica whose current summary did
    not contain the matched run (a replica that stopped advertising a prefix
    stops receiving its traffic at the next rebuild);
  * staleness degrades, never errors: summaries older than ``max_age`` drop
    out of the merged view, and a prompt matching nothing routes to the
    least-loaded replica — the same cold-start rule ``PrefixIndex`` uses.

The merged structure *is* a ``PrefixIndex`` whose "domains" are replica ids:
the radix machinery, longest-prefix match, occupancy tie-break, and fallback
are reused verbatim at the second hierarchy level.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.serving.prefixindex import PrefixIndex


@dataclass(frozen=True)
class ReplicaSummary:
    """One replica's compact state export.

    ``prefixes`` is hottest-first ``(tokens, stamp)`` pairs — the shape
    ``PrefixIndex.summary`` emits; ``t`` is the router-clock emission time
    used for staleness; ``occupancy``/``capacity`` are live admissions vs
    slots, the load half of the route decision."""

    replica: int
    t: int
    occupancy: int
    capacity: int
    prefixes: tuple = ()


@dataclass
class FederationStats:
    routes: int = 0
    hits: int = 0              # routes that matched >= 1 federated token
    matched_tokens: int = 0
    routed_tokens: int = 0
    rebuilds: int = 0
    applied: int = 0
    expired: int = 0           # summaries dropped for staleness (per rebuild)
    withdrawn: int = 0         # summaries removed by elastic departure

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.routes)

    @property
    def matched_fraction(self) -> float:
        return self.matched_tokens / max(1, self.routed_tokens)


class FederatedPrefixIndex:
    """Aggregate per-replica prefix summaries; route by longest match.

    ``occupancy`` is a zero-arg callable returning a live ``{replica: load}``
    map (the router wires it to replica telemetry); without one, the last
    summaries' occupancy plus a steered-since-summary delta is used, so the
    tie-break never reads stale load without correction.  ``max_age`` (in
    router-clock ticks, the unit of every ``now``/``t`` here) bounds how
    long a silent replica's summary keeps attracting traffic; ``None``
    trusts summaries forever.  All ``matched*`` quantities are token
    counts over the prompt's token sequence.
    """

    def __init__(
        self,
        n_replicas: int,
        *,
        occupancy=None,
        max_age: int | None = None,
        capacity: int = 1 << 14,
    ) -> None:
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        if max_age is not None and max_age < 0:
            raise ValueError("max_age must be >= 0 (or None)")
        self.n_replicas = n_replicas
        self.occupancy = occupancy
        self.max_age = max_age
        self.capacity = capacity
        self.stats = FederationStats()
        self._summaries: dict[int, ReplicaSummary] = {}
        self._steered: dict[int, int] = {}
        self._version = 0
        self._merged: PrefixIndex | None = None
        self._built: tuple | None = None  # (version, frozenset of live replicas)

    # -- load view -------------------------------------------------------------
    def load(self, replica: int) -> int:
        """Best-known live load of ``replica`` for tie-breaks/fallback."""
        if self.occupancy is not None:
            return int(self.occupancy().get(replica, 0))
        s = self._summaries.get(replica)
        base = s.occupancy if s is not None else 0
        return base + self._steered.get(replica, 0)

    def _load_view(self) -> dict[int, int]:
        return {r: self.load(r) for r in range(self.n_replicas)}

    def note_steered(self, replica: int) -> None:
        """Record a route decision so the summary-based load view tracks
        in-flight steering between syncs (no-op effect under a live
        ``occupancy`` callable, which already sees it)."""
        self._steered[replica] = self._steered.get(replica, 0) + 1

    # -- summary ingestion -----------------------------------------------------
    def apply(self, summary: ReplicaSummary) -> None:
        """Ingest one replica summary, superseding that replica's previous
        one entirely (a prefix absent from the new summary is no longer
        advertised by the replica — it must stop attracting routes)."""
        if not 0 <= summary.replica < self.n_replicas:
            raise ValueError(
                f"summary for replica {summary.replica} out of range "
                f"({self.n_replicas} replicas)"
            )
        self._summaries[summary.replica] = summary
        self._steered[summary.replica] = 0
        self._version += 1
        self.stats.applied += 1

    def withdraw(self, replica: int) -> bool:
        """Remove ``replica``'s summary entirely — the elastic-departure
        path.  Unlike staleness (which lets a silent replica age out after
        ``max_age``), a withdrawal is immediate: the next rebuild excludes
        the replica, so routes issued mid-departure degrade to the
        least-loaded live replica instead of erroring.  Idempotent; returns
        whether a summary was actually on file."""
        if replica not in self._summaries:
            return False
        del self._summaries[replica]
        self._steered.pop(replica, None)
        self._version += 1
        self.stats.withdrawn += 1
        return True

    def _live_summaries(self, now: int) -> list[ReplicaSummary]:
        if self.max_age is None:
            return list(self._summaries.values())
        return [s for s in self._summaries.values() if now - s.t <= self.max_age]

    def _ensure(self, now: int) -> PrefixIndex:
        live = self._live_summaries(now)
        key = (self._version, frozenset(s.replica for s in live))
        if self._merged is not None and self._built == key:
            return self._merged
        merged = PrefixIndex(
            n_domains=self.n_replicas,
            occupancy=self._load_view,
            capacity=self.capacity,
        )
        # deterministic rebuild: replicas in id order; within a summary,
        # coldest first so the hottest prefix carries the freshest merged
        # stamp (PrefixIndex breaks occupancy ties toward recency)
        for s in sorted(live, key=lambda s: s.replica):
            for tokens, _stamp in reversed(s.prefixes):
                merged.record(tokens, s.replica)
        self.stats.rebuilds += 1
        self.stats.expired += len(self._summaries) - len(live)
        self._merged, self._built = merged, key
        return merged

    # -- routing ---------------------------------------------------------------
    def route(self, prompt, now: int = 0) -> tuple[int, int]:
        """Longest federated prefix match for ``prompt`` ->
        ``(replica, matched_len)``; ties break toward the least-loaded
        holder, and a total miss (or an entirely stale/empty federation)
        falls back to the least-loaded replica with ``matched_len`` 0."""
        merged = self._ensure(now)
        replica, matched = merged.home(prompt)
        self.stats.routes += 1
        self.stats.routed_tokens += len(prompt)
        if matched:
            self.stats.hits += 1
            self.stats.matched_tokens += matched
        assert replica is not None  # n_domains is set: fallback always answers
        return replica, matched

    def holders(self, prompt, now: int = 0) -> dict[int, int]:
        """Per-replica longest advertised prefix of ``prompt`` (lengths in
        tokens) from the live merged summaries — the discovery view behind
        ship-source selection.  A summary's token runs *are* the
        advertisement of what the replica could ship, so this prices remote
        holdings without touching any replica; advertised lengths may trail
        a replica's live store (staleness) — callers re-confirm with the
        source before reserving the fabric.  Read-only."""
        return self._ensure(now).holders(prompt)

    def shippable(
        self, prompt, now: int = 0, exclude: int | None = None
    ) -> tuple[int | None, int]:
        """Best ship *source* for ``prompt`` by advertised length alone: the
        replica (never ``exclude``, normally the dispatch target itself)
        whose summaries cover the longest run -> ``(replica, matched_len)``,
        equal lengths tie toward the least-loaded holder; ``(None, 0)`` when
        no other replica advertises a single matching token.  NB the router
        itself selects over ``holders()`` with a *fabric-distance* tie-break
        instead — source load is irrelevant to a ship (an export copies
        references), while source->target distance multiplies the priced
        bytes; this load-based form remains for callers with no topology."""
        best_r, best_m = None, 0
        for r, m in self.holders(prompt, now).items():
            if r == exclude or m <= 0:
                continue
            if m > best_m or (
                m == best_m and best_r is not None and self.load(r) < self.load(best_r)
            ):
                best_r, best_m = r, m
        return best_r, best_m

    def holder_summary(self, replica: int) -> ReplicaSummary | None:
        """The summary currently on file for ``replica`` (tests/telemetry)."""
        return self._summaries.get(replica)
