"""NUMA-aware slot placement, migration accounting, and adaptive concurrency.

The serving stack's allocation layer, built on ``repro.core.topology``:

  ``freelists``   domain-partitioned slot pools with distance-ordered spill
                  (per-socket NUMA allocator free lists);
  ``policy``      pluggable placement: ``lowest_free`` | ``home_domain`` |
                  ``nearest_spill``, pricing misses via ``xfer_cycles``;
  ``controller``  GCR-style ``AdaptiveController`` driving
                  ``RestrictedDiscipline.max_active`` from observed handover
                  latency — shared by the lock simulator and the scheduler;
  ``telemetry``   per-domain occupancy/migration/handover counters surfaced
                  through ``SchedulerMetrics.placement``.
"""

from .controller import AdaptiveController
from .freelists import DomainFreeLists
from .policy import (
    POLICIES,
    HomeDomain,
    LowestFree,
    NearestSpill,
    Placement,
    PlacementPolicy,
    get_policy,
)
from .telemetry import PlacementTelemetry

__all__ = [
    "AdaptiveController",
    "DomainFreeLists",
    "POLICIES",
    "HomeDomain",
    "LowestFree",
    "NearestSpill",
    "Placement",
    "PlacementPolicy",
    "PlacementTelemetry",
    "get_policy",
]
