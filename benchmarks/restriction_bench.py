"""Concurrency restriction vs scalability collapse (GCR over CNA).

"Avoiding Scalability Collapse by Restricting Concurrency" (Dice & Kogan
2019) observes that once runnable threads exceed cores, queue locks collapse:
the next-in-line waiter is frequently descheduled, so every handover eats a
scheduling quantum.  The simulator models exactly that with ``n_cores`` +
``c_preempt`` (``Simulator.preempt_penalty``), and ``cna_rcr`` wraps the CNA
discipline in ``RestrictedDiscipline``: at most ``max_active`` waiters spin,
the excess park (non-runnable), and a grant-count timeout rotates them in.

The sweep shows the collapse-avoidance curve the wrapper buys:

  * plain MCS/CNA throughput falls off a cliff past ``n_cores`` threads;
  * restricted CNA stays near its peak while *preserving* CNA's locality
    (remote-transfer rate stays far below MCS);
  * everything is seeded and deterministic.
"""

from __future__ import annotations

from repro.core.locks_sim import ALL_LOCKS
from repro.core.numasim import run_sweep

from .common import claim, table

THREADS = [4, 8, 16, 32, 64, 96]
N_CORES = 16
DUR = 4_000_000
SEED = 42
KW = {
    "cna": {"threshold": 0xFF},
    "cna_rcr": {"threshold": 0xFF, "max_active": N_CORES - 2},
}


def _sweep(names, *, seed=SEED):
    return {
        name: run_sweep(
            ALL_LOCKS[name],
            THREADS,
            2,
            seed=seed,
            duration_cycles=DUR,
            noncs_cycles=0,
            lock_kwargs=KW.get(name),
            n_cores=N_CORES,
        )
        for name in names
    }


def run_all():
    names = ["mcs", "cna", "cna_rcr"]
    res = _sweep(names)
    rows = [
        [t]
        + [res[n][i].throughput_ops_per_us for n in names]
        + [res[n][i].preemptions for n in names]
        + [res[n][i].remote_rate for n in names]
        for i, t in enumerate(THREADS)
    ]
    table(
        f"concurrency restriction ({N_CORES} cores, preemption quantum on handover)",
        ["threads"]
        + [f"tp_{n}" for n in names]
        + [f"preempt_{n}" for n in names]
        + [f"remote_{n}" for n in names],
        rows,
    )

    tp = {n: [r.throughput_ops_per_us for r in res[n]] for n in names}
    i_fit = THREADS.index(N_CORES)  # last thread count that fits in cores
    claim(
        "restriction: plain CNA collapses once threads exceed cores (>=3x drop)",
        tp["cna"][-1] < tp["cna"][i_fit] / 3,
        f"{tp['cna'][i_fit]:.2f} -> {tp['cna'][-1]:.2f} ops/us",
    )
    claim(
        "restriction: cna_rcr holds >=70% of its in-cores throughput at 6x oversubscription",
        tp["cna_rcr"][-1] >= 0.7 * tp["cna_rcr"][i_fit],
        f"{tp['cna_rcr'][i_fit]:.2f} -> {tp['cna_rcr'][-1]:.2f} ops/us",
    )
    claim(
        "restriction: cna_rcr >= 2x plain CNA when oversubscribed",
        tp["cna_rcr"][-1] >= 2 * tp["cna"][-1],
        f"ratio={tp['cna_rcr'][-1] / max(tp['cna'][-1], 1e-9):.2f}",
    )
    claim(
        "restriction: parked waiters mean almost no preemptions for cna_rcr",
        res["cna_rcr"][-1].preemptions < 0.05 * max(1, res["cna"][-1].preemptions),
        f"{res['cna_rcr'][-1].preemptions} vs {res['cna'][-1].preemptions}",
    )
    claim(
        "restriction: CNA locality preserved under the cap (remote rate << MCS)",
        res["cna_rcr"][-1].remote_rate < 0.5 * res["mcs"][-1].remote_rate,
        f"{res['cna_rcr'][-1].remote_rate:.2f} vs {res['mcs'][-1].remote_rate:.2f}",
    )
    res2 = _sweep(["cna_rcr"])
    claim(
        "restriction: sweep is deterministic (same seed, same ops)",
        [r.ops for r in res2["cna_rcr"]] == [r.ops for r in res["cna_rcr"]],
        "",
    )
    return res
