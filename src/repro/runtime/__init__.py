from .fault import HeartbeatMonitor, StragglerDetector, WorkerFailure  # noqa: F401
from .elastic import plan_mesh, ElasticTrainer  # noqa: F401
