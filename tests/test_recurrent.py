"""SSD (Mamba-2) and RG-LRU numerics: chunked == sequential, step == scan."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.rglru import rglru_scan, rglru_step, _gates
from repro.models.ssm import ssd_chunked, ssd_step
from repro.models.common import ParamBuilder
from repro.models.rglru import declare_rglru


def _ssd_inputs(key, b, s, h, p, n):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.random.uniform(ks[1], (b, s, h), jnp.float32, 0.001, 0.1)
    a = -jax.random.uniform(ks[2], (h,), jnp.float32, 0.5, 4.0)
    bb = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    cc = jax.random.normal(jax.random.fold_in(key, 9), (b, s, n), jnp.float32)
    return x, dt, a, bb, cc


def _ssd_sequential(x, dt, a, b, c):
    """Token-by-token oracle for the SSD recurrence."""
    bs, s, h, p = x.shape
    n = b.shape[-1]
    st = jnp.zeros((bs, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y, st = ssd_step(x[:, t], dt[:, t], a, b[:, t], c[:, t], st)
        ys.append(y)
    return jnp.stack(ys, axis=1), st


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_ssd_chunked_matches_sequential(chunk):
    x, dt, a, b, c = _ssd_inputs(jax.random.PRNGKey(0), 2, 64, 3, 8, 16)
    y_ref, s_ref = _ssd_sequential(x, dt, a, b, c)
    y, s_last = ssd_chunked(x, dt, a, b, c, chunk=chunk)
    np.testing.assert_allclose(y, y_ref, atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(s_last, s_ref, atol=2e-4, rtol=2e-3)


def test_ssd_chunk_size_invariance():
    x, dt, a, b, c = _ssd_inputs(jax.random.PRNGKey(1), 1, 96, 2, 8, 8)
    y16, _ = ssd_chunked(x, dt, a, b, c, chunk=16)
    y32, _ = ssd_chunked(x, dt, a, b, c, chunk=32)
    y96, _ = ssd_chunked(x, dt, a, b, c, chunk=96)
    np.testing.assert_allclose(y16, y32, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(y16, y96, atol=1e-4, rtol=1e-3)


def test_ssd_nonmultiple_seq_pads():
    x, dt, a, b, c = _ssd_inputs(jax.random.PRNGKey(2), 1, 50, 2, 8, 8)
    y_ref, _ = _ssd_sequential(x, dt, a, b, c)
    y, _ = ssd_chunked(x, dt, a, b, c, chunk=16)
    np.testing.assert_allclose(y, y_ref, atol=2e-4, rtol=2e-3)


def _rglru_params(key, w):
    pb = ParamBuilder(dtype=jnp.float32)
    declare_rglru(pb, "rec", 16, w, 4)
    return pb.init(key)["rec"]


def test_rglru_scan_matches_steps():
    w, b, s = 24, 2, 40
    params = _rglru_params(jax.random.PRNGKey(0), w)
    xc = jax.random.normal(jax.random.PRNGKey(1), (b, s, w), jnp.float32)
    ys, h_last = rglru_scan(params, xc)
    h = jnp.zeros((b, w), jnp.float32)
    for t in range(s):
        y_t, h = rglru_step(params, xc[:, t], h)
        np.testing.assert_allclose(ys[:, t], y_t, atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(h_last, h, atol=2e-5, rtol=2e-4)


def test_rglru_pallas_impl_matches():
    w, b, s = 32, 2, 64
    params = _rglru_params(jax.random.PRNGKey(3), w)
    xc = jax.random.normal(jax.random.PRNGKey(4), (b, s, w), jnp.float32)
    y1, h1 = rglru_scan(params, xc, impl="assoc")
    y2, h2 = rglru_scan(params, xc, impl="pallas")
    np.testing.assert_allclose(y1, y2, atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(h1, h2, atol=1e-5, rtol=1e-4)


def test_rglru_decay_bounded():
    """a_t in (0, 1): the recurrence is contractive (no state blow-up)."""
    w = 16
    params = _rglru_params(jax.random.PRNGKey(5), w)
    xc = 10.0 * jax.random.normal(jax.random.PRNGKey(6), (1, 8, w), jnp.float32)
    a, gi = _gates(params, xc)
    assert float(a.min()) > 0.0 and float(a.max()) <= 1.0 + 1e-6
    ys, _ = rglru_scan(params, xc)
    assert jnp.isfinite(ys).all()
