"""pixtral-12b [vlm]: 40L d=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
Backbone only (mistral-nemo-style decoder); the pixtral ViT frontend is a
STUB per the assignment: input_specs() provides precomputed patch embeddings
(batch, n_patches, d_model) consumed as a prefix."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv=8, head_dim=160, d_ff=14336,
    vocab=131072, mlp="swiglu", rope_theta=1_000_000.0, n_patches=256, accum=4,
)

def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
                          d_ff=128, vocab=512, n_patches=8, accum=1, attn_chunk=64)
