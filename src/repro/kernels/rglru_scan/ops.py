"""jit wrapper for the RG-LRU linear-scan kernel (padding + backend select)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import linear_scan_bsw


@functools.partial(jax.jit, static_argnames=("block_s", "block_w", "interpret"))
def linear_scan(
    a: jax.Array,
    b: jax.Array,
    h0: jax.Array,
    *,
    block_s: int = 256,
    block_w: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t.  a/b: (B, S, W); h0: (B, W) -> (B, S, W) f32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bsz, s, w = a.shape
    bs = min(block_s, s)
    bw = min(block_w, w)
    pad_s = (-s) % bs
    pad_w = (-w) % bw
    if pad_s or pad_w:
        # a=1, b=0 padding keeps the recurrence identity on padded steps
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_w)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_w)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_w)))
    out = linear_scan_bsw(
        a.astype(jnp.float32), b.astype(jnp.float32), h0.astype(jnp.float32),
        block_s=bs, block_w=bw, interpret=interpret,
    )
    return out[:, :s, :w]
