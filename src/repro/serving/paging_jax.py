"""The jax side of paged KV: a physical page pool and the paged slot view.

``PagedKVPool`` owns one pytree of page-major arrays — per cache leaf, the
slot cache's ``(batch, kv_seq, ...)`` pair becomes ``(n_pages, page_size,
...)`` — and moves bytes page-at-a-time between dense (batch=1) caches and
the pool.  ``PagedSlotCache`` is the ``SlotCache`` the engine drives when
paging is on: same ``claim``/``insert``/``insert_row``/``extract``/
``release`` signatures over the same dense decode working set (``decode_step``
still advances all slots in one fused call — a paged attention kernel that
reads KV through the page map *in* the kernel is the roadmap's next step),
but the *storage* tier behind it is the page table: every live slot pins its
sequence's pages, every deposit lands as pages, and release drops references
instead of bytes.

Import through ``repro.serving.paging`` — this module is jax-only by
construction and resolves lazily from there.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kvcache import SlotCache
from .paging import PageBundle, PageTable

_LOGICAL_LEAF = lambda x: isinstance(x, tuple) and all(  # noqa: E731
    isinstance(i, (str, type(None))) for i in x
)


class PagedKVPool:
    """Page-major physical storage for one model's attention KV.

    Built from the model's own cache spec: every leaf must carry both a
    "batch" and a "kv_seq" logical axis (plain dense attention — the same
    families ``supports_packed_prefill`` admits).  Recurrent/SSM state has
    no sequence axis to page; those families keep the contiguous path and
    this constructor refuses them.
    """

    def __init__(self, model, cache_len: int, n_pages: int, page_size: int):
        if cache_len % page_size:
            raise ValueError(
                f"page_size {page_size} must divide cache_len {cache_len}"
            )
        self.cache_len = cache_len
        self.n_pages = n_pages
        self.page_size = page_size
        abs1 = model.cache_abstract(1, cache_len)
        logical = model.cache_logical(abs1)

        def ax_of(name):
            def index(l):
                if not ("batch" in l and "kv_seq" in l):
                    raise ValueError(
                        "paged KV needs attention KV leaves (batch + kv_seq "
                        f"axes); got logical axes {l} — this model family "
                        "keeps the contiguous path"
                    )
                return l.index(name)

            return index

        # two parallel int-leaved trees (a tuple leaf would itself be a
        # pytree and break the zipped tree.maps below)
        self.batch_ax = {
            k: jax.tree.map(ax_of("batch"), logical[k], is_leaf=_LOGICAL_LEAF)
            for k in abs1
            if k != "pos"
        }
        self.seq_ax = {
            k: jax.tree.map(ax_of("kv_seq"), logical[k], is_leaf=_LOGICAL_LEAF)
            for k in abs1
            if k != "pos"
        }
        self.template = {k: abs1[k] for k in abs1 if k != "pos"}

        def page_leaf(spec, bax, sax):
            shape = list(spec.shape)
            shape[bax] = n_pages
            shape[sax] = page_size
            return jnp.zeros(tuple(shape), spec.dtype)

        self.pool = {
            k: jax.tree.map(page_leaf, abs1[k], self.batch_ax[k], self.seq_ax[k])
            for k in abs1
            if k != "pos"
        }

    @property
    def bytes_per_page(self) -> int:
        total = 0
        for leaves in jax.tree.leaves(self.pool):
            total += leaves.size // self.n_pages * leaves.dtype.itemsize
        return total

    def write(self, cache, start: int, end: int, pages) -> None:
        """Copy token positions ``[start, end)`` of a dense (batch=1,
        ``fit_single``-shaped) cache into ``pages`` (page-aligned ``start``;
        the final page takes the source bytes through its page boundary, so
        the in-page tail beyond ``end`` round-trips exactly)."""
        if start % self.page_size:
            raise ValueError(f"unaligned page write at token {start}")
        ps = self.page_size

        def put_page(dst, src, bax, sax, page, p0):
            lane = jax.lax.dynamic_slice_in_dim(src, p0, ps, axis=sax)
            idx = [0] * dst.ndim
            idx[bax] = page
            return jax.lax.dynamic_update_slice(
                dst, lane.astype(dst.dtype), tuple(idx)
            )

        for j, page in enumerate(pages):
            p0 = start + j * ps
            if p0 >= end:
                raise ValueError(f"more pages than tokens: {pages} for [{start},{end})")
            self.pool = {
                k: jax.tree.map(
                    lambda d, s, b, x: put_page(d, jnp.asarray(s), b, x, page, p0),
                    self.pool[k], cache[k], self.batch_ax[k], self.seq_ax[k],
                )
                for k in self.pool
            }

    def read(self, bundle: PageBundle):
        """Materialize a dense (batch=1, ``fit_single``-shaped) cache from
        ``bundle`` — byte-identical to the cache its pages were written
        from, ``pos`` seeded at the bundle's length so the engine's resume
        path consumes it exactly like a locally prefilled deposit."""
        ps = self.page_size

        def fetch(dst, src, bax, sax, page, p0):
            lane = jax.lax.dynamic_slice_in_dim(src, page, 1, axis=bax)
            idx = [0] * dst.ndim
            idx[sax] = p0
            return jax.lax.dynamic_update_slice(dst, lane, tuple(idx))

        dense = {
            k: jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), self.template[k])
            for k in self.template
        }
        for j, page in enumerate(bundle.pages):
            dense = {
                k: jax.tree.map(
                    lambda d, s, b, x: fetch(d, s, b, x, page, j * ps),
                    dense[k], self.pool[k], self.batch_ax[k], self.seq_ax[k],
                )
                for k in dense
            }
        dense["pos"] = jnp.asarray(bundle.length, jnp.int32)
        return dense


class PagedSlotCache(SlotCache):
    """``SlotCache`` whose storage tier is a refcounted page table.

    The dense decode working set (one lane per slot) behaves exactly as the
    base class — that is what keeps decode bitwise-identical — while
    ``seq_pages`` pins each live slot's sequence to its physical pages:
    claimed at admission from the deposit the engine just made, released
    (reference drop, not byte drop) at retirement.  Gauges delegate to the
    table; ``register_into`` makes them scrapeable.
    """

    @classmethod
    def zeros(
        cls, model, n_slots: int, cache_len: int, *, page_size: int = 16,
        n_pages: int | None = None, store_slack: int = 16, topology=None,
        policy="nearest_spill", cost_model=None, page_topology=None,
    ):
        self = super().zeros(
            model, n_slots, cache_len,
            topology=topology, policy=policy, cost_model=cost_model,
        )
        if n_pages is None:
            # room for every slot's live sequence plus a full prefix store
            # of ``store_slack`` worst-case entries; sharing keeps most of
            # it free, which is the point of the gauges
            n_pages = (n_slots + store_slack) * (cache_len // page_size)
        self.pool = PagedKVPool(model, cache_len, n_pages, page_size)
        self.table = PageTable(
            n_pages, page_size, topology=page_topology,
            bytes_per_page=self.pool.bytes_per_page,
        )
        self.seq_pages = {}
        return self

    def note_sequence(self, slot: int, bundle: PageBundle | None) -> None:
        """Pin ``slot``'s sequence to ``bundle``'s pages (one reference per
        page, dropped at release) — how a live sequence *is* a list of page
        indices even while the store's LRU churns underneath it."""
        if slot not in self.owner:
            raise ValueError(f"note_sequence on unowned slot {slot}")
        prev = self.seq_pages.pop(slot, None)
        if prev:
            self.table.release(prev)
        if bundle is not None:
            self.table.retain(bundle.pages)
            self.seq_pages[slot] = bundle.pages

    def release(self, slot: int):
        prev = self.seq_pages.pop(slot, None)
        if prev:
            self.table.release(prev)
        super().release(slot)

    def register_into(self, registry, prefix: str = "kv") -> None:
        self.table.register_into(registry, prefix=prefix)
