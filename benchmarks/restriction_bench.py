"""Concurrency restriction vs scalability collapse (GCR over CNA).

"Avoiding Scalability Collapse by Restricting Concurrency" (Dice & Kogan
2019) observes that once runnable threads exceed cores, queue locks collapse:
the next-in-line waiter is frequently descheduled, so every handover eats a
scheduling quantum.  The simulator models exactly that with ``n_cores`` +
``c_preempt`` (``Simulator.preempt_penalty``), and ``cna_rcr`` wraps the CNA
discipline in ``RestrictedDiscipline``: at most ``max_active`` waiters spin,
the excess park (non-runnable), and a grant-count timeout rotates them in.
``cna_rcr_adapt`` replaces the static cap with the shared
``repro.placement.AdaptiveController`` — the cap walks to the collapse
boundary online from the observed handover latencies.

Three sections:

  * ``run_all``      the collapse-avoidance sweep (static + adaptive caps);
  * ``calibrate``    the ``c_preempt`` grid fit against the published GCR
                     collapse depths — asserts the shipped ``CostModel``
                     default is the grid argmin (ROADMAP "Calibrate the
                     preemption model");
  * ``fig_collapse`` a paper-style (ASCII) figure of normalized throughput
                     vs offered threads, the GCR Fig. 1/2 shape.
"""

from __future__ import annotations

from dataclasses import replace

from repro.core.locks_sim import ALL_LOCKS
from repro.core.numasim import TWO_SOCKET, CostModel, run_sweep

from . import common
from .common import ascii_plot, claim, smoke, table

THREADS = [4, 8, 16, 32, 64, 96]
N_CORES = 16
SEED = 42
KW = {
    "cna": {"threshold": 0xFF},
    "cna_rcr": {"threshold": 0xFF, "max_active": N_CORES - 2},
    "cna_rcr_adapt": {"threshold": 0xFF},
}

# Published collapse depths read off the GCR paper's motivating curves
# (Figs. 1-2: AVL tree / LevelDB under MCS): throughput falls roughly an
# order of magnitude once threads exceed cores, with a further slow decay
# as oversubscription deepens.  The fit below chooses ``c_preempt`` so the
# simulator reproduces these retention ratios.
GCR_TARGET_RETAIN = {2: 0.12, 6: 0.08}  # threads/cores -> tp fraction of in-cores peak


def _dur() -> int:
    return smoke(4_000_000, 150_000)


def _sweep(names, *, seed=SEED, cm=None, threads=None):
    return {
        name: run_sweep(
            ALL_LOCKS[name],
            threads or THREADS,
            2,
            cm,
            seed=seed,
            duration_cycles=_dur(),
            noncs_cycles=0,
            lock_kwargs=KW.get(name),
            n_cores=N_CORES,
        )
        for name in names
    }


def calibrate():
    """Grid-fit ``c_preempt`` to the published GCR collapse retention ratios.

    ``n_cores`` is a benchmark knob (the paper's machines are 16-80 hardware
    threads; we sweep offered threads against a fixed 16), so the one free
    parameter of the preemption model is the effective per-handover penalty.
    The error is the summed |log(sim/target)| over the 2x and 6x
    oversubscription points — log space because the published curves are
    read off log-scaled throughput axes."""
    import math

    grid = smoke([5_000, 10_000, 20_000, 30_000], [5_000, 10_000, 20_000])
    in_cores, over2, over6 = N_CORES, 2 * N_CORES, 6 * N_CORES
    rows, errs = [], {}
    for cp in grid:
        cm = replace(TWO_SOCKET, c_preempt=cp)
        res = _sweep(["cna"], cm=cm, threads=[in_cores, over2, over6])["cna"]
        tp = {r.n_threads: r.throughput_ops_per_us for r in res}
        r2, r6 = tp[over2] / tp[in_cores], tp[over6] / tp[in_cores]
        errs[cp] = abs(math.log(r2 / GCR_TARGET_RETAIN[2])) + abs(
            math.log(r6 / GCR_TARGET_RETAIN[6])
        )
        rows.append([cp, r2, r6, errs[cp]])
    table(
        f"c_preempt calibration vs GCR collapse targets "
        f"(retain@2x={GCR_TARGET_RETAIN[2]}, retain@6x={GCR_TARGET_RETAIN[6]})",
        ["c_preempt", "retain_2x", "retain_6x", "log_err"],
        rows,
    )
    fit = min(errs, key=errs.get)
    shipped = CostModel().c_preempt
    claim(
        "calibration: shipped c_preempt default is the grid-fit argmin",
        fit == shipped,
        f"fit={fit} shipped={shipped}",
    )
    return fit


def fig_collapse(res=None):
    """Paper-style figure: normalized throughput vs offered threads (the GCR
    Fig. 1/2 collapse shape, plus the restricted/adaptive recovery)."""
    names = ["mcs", "cna", "cna_rcr", "cna_rcr_adapt"]
    res = res or _sweep(names)
    i_fit = THREADS.index(N_CORES)
    norm = {
        n: [r.throughput_ops_per_us / max(res[n][i_fit].throughput_ops_per_us, 1e-9)
            for r in res[n]]
        for n in names
    }
    ascii_plot(
        f"figGCR: throughput normalized to the in-cores ({N_CORES}-thread) point, "
        f"log scale — collapse past {N_CORES} threads, restriction holds the line",
        THREADS,
        norm,
        logy=True,
    )
    return res


def run_all():
    names = ["mcs", "cna", "cna_rcr", "cna_rcr_adapt"]
    res = _sweep(names)
    rows = [
        [t]
        + [res[n][i].throughput_ops_per_us for n in names]
        + [res[n][i].preemptions for n in names]
        + [res[n][i].remote_rate for n in names]
        for i, t in enumerate(THREADS)
    ]
    table(
        f"concurrency restriction ({N_CORES} cores, preemption quantum on handover)",
        ["threads"]
        + [f"tp_{n}" for n in names]
        + [f"preempt_{n}" for n in names]
        + [f"remote_{n}" for n in names],
        rows,
    )
    fig_collapse(res)
    calibrate()
    if common.SMOKE:
        # smoke mode only exercises the code paths; the claims below need
        # full durations for the curves to separate.
        return res

    tp = {n: [r.throughput_ops_per_us for r in res[n]] for n in names}
    i_fit = THREADS.index(N_CORES)  # last thread count that fits in cores
    claim(
        "restriction: plain CNA collapses once threads exceed cores (>=3x drop)",
        tp["cna"][-1] < tp["cna"][i_fit] / 3,
        f"{tp['cna'][i_fit]:.2f} -> {tp['cna'][-1]:.2f} ops/us",
    )
    claim(
        "restriction: cna_rcr holds >=70% of its in-cores throughput at 6x oversubscription",
        tp["cna_rcr"][-1] >= 0.7 * tp["cna_rcr"][i_fit],
        f"{tp['cna_rcr'][i_fit]:.2f} -> {tp['cna_rcr'][-1]:.2f} ops/us",
    )
    claim(
        "restriction: cna_rcr >= 2x plain CNA when oversubscribed",
        tp["cna_rcr"][-1] >= 2 * tp["cna"][-1],
        f"ratio={tp['cna_rcr'][-1] / max(tp['cna'][-1], 1e-9):.2f}",
    )
    claim(
        "restriction: adaptive cap recovers most of the static-cap win (>=2x plain CNA)",
        tp["cna_rcr_adapt"][-1] >= 2 * tp["cna"][-1],
        f"ratio={tp['cna_rcr_adapt'][-1] / max(tp['cna'][-1], 1e-9):.2f}",
    )
    claim(
        "restriction: parked waiters mean almost no preemptions for cna_rcr",
        res["cna_rcr"][-1].preemptions < 0.05 * max(1, res["cna"][-1].preemptions),
        f"{res['cna_rcr'][-1].preemptions} vs {res['cna'][-1].preemptions}",
    )
    claim(
        "restriction: CNA locality preserved under the cap (remote rate << MCS)",
        res["cna_rcr"][-1].remote_rate < 0.5 * res["mcs"][-1].remote_rate,
        f"{res['cna_rcr'][-1].remote_rate:.2f} vs {res['mcs'][-1].remote_rate:.2f}",
    )
    res2 = _sweep(["cna_rcr", "cna_rcr_adapt"])
    claim(
        "restriction: sweep is deterministic (same seed, same ops; adaptive included)",
        [r.ops for r in res2["cna_rcr"]] == [r.ops for r in res["cna_rcr"]]
        and [r.ops for r in res2["cna_rcr_adapt"]] == [r.ops for r in res["cna_rcr_adapt"]],
        "",
    )
    return res
