"""Schema validation for the ``BENCH_<section>.json`` record set.

    PYTHONPATH=src python -m benchmarks.check_bench [--dir D] \
        [--require section ...] [paths ...]

Every ``benchmarks/run.py`` section writes one record through
``benchmarks.common.bench_section`` — this checker pins that contract from
the consumer side, so a section that drifts (renamed key, stringly-typed
claim, missing pass/fail) fails CI instead of silently producing records the
trajectory tooling cannot read.  The schema is the *shared* one: the
required keys and claim shape here must match what ``bench_section`` emits,
and ``schema`` must equal ``benchmarks.common.BENCH_SCHEMA`` exactly —
bumping the writer without bumping the checker (or vice versa) is the error
this catches first.

``--require`` additionally asserts that specific sections produced a record
at all (a lane that stops *running* a bench emits nothing — absence is the
failure mode validation alone cannot see).
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from .common import BENCH_SCHEMA

# the record contract bench_section writes: key -> required type
_RECORD_KEYS = {
    "bench": str,
    "schema": int,
    "smoke": bool,
    "claims": list,
    "metrics": dict,
    "passed": bool,
}
_CLAIM_KEYS = {"name": str, "ok": bool, "detail": str}


def check_record(path: str) -> list[str]:
    """Validate one record file; returns a list of violations (empty = ok)."""
    errs: list[str] = []
    try:
        with open(path) as f:
            rec = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable record: {e}"]
    if not isinstance(rec, dict):
        return [f"{path}: record is {type(rec).__name__}, expected object"]
    for key, typ in _RECORD_KEYS.items():
        if key not in rec:
            errs.append(f"{path}: missing key {key!r}")
        elif not isinstance(rec[key], typ):
            errs.append(
                f"{path}: {key!r} is {type(rec[key]).__name__}, "
                f"expected {typ.__name__}"
            )
    if errs:
        return errs
    if rec["schema"] != BENCH_SCHEMA:
        errs.append(
            f"{path}: schema {rec['schema']} != writer schema {BENCH_SCHEMA} "
            "(stale record, or checker/writer bumped out of lockstep)"
        )
    expect = f"BENCH_{rec['bench']}.json"
    if os.path.basename(path) != expect:
        errs.append(f"{path}: bench {rec['bench']!r} belongs in {expect}")
    for i, c in enumerate(rec["claims"]):
        if not isinstance(c, dict):
            errs.append(f"{path}: claims[{i}] is not an object")
            continue
        for key, typ in _CLAIM_KEYS.items():
            if key not in c:
                errs.append(f"{path}: claims[{i}] missing {key!r}")
            elif not isinstance(c[key], typ):
                errs.append(
                    f"{path}: claims[{i}].{key} is "
                    f"{type(c[key]).__name__}, expected {typ.__name__}"
                )
    if all(isinstance(c, dict) and "ok" in c for c in rec["claims"]):
        derived = all(c["ok"] for c in rec["claims"])
        if rec["passed"] != derived:
            errs.append(
                f"{path}: passed={rec['passed']} but claims say {derived}"
            )
    for k in rec["metrics"]:
        if not isinstance(k, str):
            errs.append(f"{path}: non-string metric key {k!r}")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="*",
                    help="record files to check (default: --dir glob)")
    ap.add_argument("--dir", default=".",
                    help="directory to glob BENCH_*.json from when no "
                         "explicit paths are given")
    ap.add_argument("--require", nargs="*", default=[], metavar="SECTION",
                    help="section names that must have produced a record")
    args = ap.parse_args(argv)

    paths = args.paths or sorted(glob.glob(os.path.join(args.dir, "BENCH_*.json")))
    errs: list[str] = []
    seen: set[str] = set()
    for path in paths:
        file_errs = check_record(path)
        errs.extend(file_errs)
        if not file_errs:
            with open(path) as f:
                seen.add(json.load(f)["bench"])
        status = "ok" if not file_errs else "INVALID"
        print(f"[check_bench] {path}: {status}")
    for section in args.require:
        if section not in seen:
            errs.append(f"required section {section!r} produced no valid record")
    if errs:
        print(f"{len(errs)} schema violation(s):")
        for e in errs:
            print(f"  - {e}")
        return 1
    print(f"[check_bench] {len(paths)} record(s) valid "
          f"({len(seen)} section(s): {', '.join(sorted(seen))})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
