"""repro.router.kvship: the priced ship/re-prefill boundary.

Three layers of pinning, matching the ISSUE's acceptance criteria:

  * property — ``decide()``'s choice equals the argmin of the two priced
    costs at ANY bandwidth/distance/backlog (hypothesis, or the seeded
    fallback sweep in containers without it);
  * sim — every decision a live fleet run records is the argmin of its own
    recorded costs, the fabric serializes in-flight ships, and shipping
    never loses to the shed-before-stall baseline;
  * contract (jax) — a shipped session's decode output bitwise-matches the
    re-prefilled one, and retirement-time deposits let conversation
    follow-ups resume from prompt *plus* generated output.
"""

import random
import sys

import pytest

sys.path.insert(0, __file__.rsplit("/", 1)[0])
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core.topology import flat, pod
from repro.router import (
    Fabric,
    ReplicaRouter,
    Session,
    ShipCostModel,
    SimReplica,
    decide,
    shared_prefix_sessions,
    simulate,
)

# -- decide(): the priced argmin, as a property --------------------------------


@settings(max_examples=200, deadline=None)
@given(
    prompt_len=st.integers(min_value=0, max_value=512),
    local=st.integers(min_value=0, max_value=512),
    src_m=st.integers(min_value=0, max_value=512),
    distance=st.integers(min_value=1, max_value=2),
    backlog=st.integers(min_value=0, max_value=10_000),
    bw=st.integers(min_value=1, max_value=1024),
    bpt=st.integers(min_value=1, max_value=256),
    c_prefill=st.integers(min_value=1, max_value=64),
)
def test_decide_choice_is_the_priced_argmin(
    prompt_len, local, src_m, distance, backlog, bw, bpt, c_prefill
):
    local = min(local, prompt_len)
    src_m = min(src_m, prompt_len)
    cm = ShipCostModel(
        kv_bytes_per_token=bpt, fabric_bytes_per_cycle=bw, c_prefill=c_prefill
    )
    d = decide(
        prompt_len=prompt_len, local_matched=local, src_matched=src_m,
        src=0, dst=1, distance=distance, backlog=backlog, cm=cm,
    )
    # the two priced costs, recomputed from the model's published formula
    xfer = cm.xfer_cycles(src_m, distance)
    ship_total = backlog + xfer + c_prefill * (prompt_len - src_m)
    reprefill = c_prefill * (prompt_len - local)
    assert d.ship_cycles == xfer
    assert d.ship_total == ship_total
    assert d.reprefill_cycles == reprefill
    eligible = src_m > local and src_m >= cm.min_ship_tokens
    assert d.choice == ("ship" if eligible and ship_total < reprefill else "reprefill")


def test_decide_validates_matched_ranges():
    with pytest.raises(ValueError):
        decide(prompt_len=4, local_matched=5, src_matched=2, src=0, dst=1, distance=1)
    with pytest.raises(ValueError):
        decide(prompt_len=4, local_matched=0, src_matched=9, src=0, dst=1, distance=1)


def test_decide_ties_and_tiny_prefixes_reprefill():
    # a zero-gain ship (equal cost) must not buy fabric traffic
    cm = ShipCostModel(kv_bytes_per_token=4, fabric_bytes_per_cycle=1,
                       c_ship_setup=0, c_prefill=4, min_ship_tokens=1)
    d = decide(prompt_len=8, local_matched=0, src_matched=8, src=0, dst=1,
               distance=1, cm=cm)  # ship 8*4/1 = 32 == reprefill 8*4
    assert d.choice == "reprefill"
    # below min_ship_tokens never ships, however cheap
    d = decide(prompt_len=8, local_matched=0, src_matched=2, src=0, dst=1,
               distance=1, cm=ShipCostModel(min_ship_tokens=4))
    assert d.choice == "reprefill"


# -- Fabric: serialized in-flight ships ----------------------------------------


def test_fabric_serializes_ships_and_prices_backlog():
    fab = Fabric(flat(2), ShipCostModel(fabric_bytes_per_cycle=64))
    d1 = fab.price(prompt_len=96, local_matched=0, src_matched=96,
                   src=0, dst=1, now=100)
    assert d1.choice == "ship" and d1.wait_cycles == 0
    end1 = fab.reserve(100, d1)
    assert end1 == 100 + d1.ship_cycles == d1.fabric_end
    # second ship at the same tick queues behind the first — and its PRICE
    # already includes that wait
    d2 = fab.price(prompt_len=96, local_matched=0, src_matched=96,
                   src=1, dst=0, now=100)
    assert d2.wait_cycles == d1.ship_cycles
    if d2.choice == "ship":
        assert fab.reserve(100, d2) == end1 + d2.ship_cycles
    assert fab.stats.ships >= 1
    with pytest.raises(ValueError):
        fab.reserve(0, decide(prompt_len=4, local_matched=0, src_matched=0,
                              src=0, dst=1, distance=1))


def test_fabric_distance_scales_ship_cost():
    cm = ShipCostModel()
    near = cm.xfer_cycles(64, 1)
    far = cm.xfer_cycles(64, 2)
    assert far > near
    assert cm.xfer_cycles(0, 2) == 0


# -- router: ship moves the prefix before admit --------------------------------


def _warm_router(**kw):
    reps = [SimReplica(r, 1, cache_budget=600) for r in range(4)]
    router = ReplicaRouter(reps, topology=pod(2, 2), sync_every=0,
                           kv_ship=True, **kw)
    reps[0].cache.insert(tuple(range(50)))   # only replica 0 is warm
    router.sync()
    return router, reps


def test_router_ships_warm_prefix_on_shed():
    router, reps = _warm_router()
    reps[0].inflight = 1                     # home full -> shed
    s = Session(sid=0, prompt=tuple(range(50)) + (99,), decode_len=1)
    assert router.submit(s) == 0
    sess, target, _ = router.dispatch_one()
    assert target != 0 and router.stats.sheds == 1
    assert s.ship is not None and s.ship.choice == "ship" and s.ship.executed
    assert s.ship.src == 0 and s.ship.dst == target
    # the shipped prefix landed before admit: the target reused all 50 tokens
    assert s.local_matched == 50
    assert router.stats.ships == 1
    assert router.stats.shipped_tokens == 50
    assert router.stats.reprefill_avoided == 50
    assert router.stats.reprefill_tokens == 1     # only the suffix token


def test_router_records_declined_decision_on_slow_fabric():
    # fabric priced at 16 ticks/token vs c_prefill 4: re-prefill must win,
    # but the priced decision is still recorded on the session for audit
    router, reps = _warm_router()
    router.fabric.cm = ShipCostModel(fabric_bytes_per_cycle=4)
    reps[0].inflight = 1
    s = Session(sid=0, prompt=tuple(range(50)) + (99,), decode_len=1)
    router.submit(s)
    sess, target, _ = router.dispatch_one()
    assert s.ship is not None and s.ship.choice == "reprefill"
    assert s.ship.ship_total >= s.ship.reprefill_cycles
    assert router.stats.ships == 0 and router.stats.ship_declined == 1
    assert s.local_matched == 0                   # nothing moved


def test_router_does_not_price_when_target_already_holds_best():
    router, reps = _warm_router()
    s = Session(sid=0, prompt=tuple(range(50)) + (99,), decode_len=1)
    router.submit(s)
    sess, target, _ = router.dispatch_one()
    assert target == 0                            # home had capacity
    assert s.ship is None                         # nothing beyond its own holding


# -- sim: recorded decisions are argmins; ship never loses ---------------------


def _workload(n=240, n_prefixes=6, seed=3):
    rng = random.Random(seed)
    draws = [rng.randrange(n_prefixes) for _ in range(n)]
    return lambda: shared_prefix_sessions(draws, prefix_len=64, suffix_len=8,
                                          decode_len=16)


@pytest.mark.parametrize("bw", [512, 64, 8])
def test_sim_recorded_choices_match_priced_argmin(bw):
    mk = _workload()
    sessions = mk()
    simulate("federated", sessions, n_replicas=3, n_slots=2, cache_budget=400,
             inter_arrival=10, seed=5,
             kv_ship=ShipCostModel(fabric_bytes_per_cycle=bw))
    priced = [s.ship for s in sessions if s.ship is not None]
    assert priced, "workload produced no priced decisions"
    for d in priced:
        should_ship = (
            d.src_matched > d.local_matched
            and d.src_matched >= ShipCostModel().min_ship_tokens
            and d.ship_total < d.reprefill_cycles
        )
        assert d.choice == ("ship" if should_ship else "reprefill"), vars(d)


def test_sim_ship_never_loses_and_degrades_to_baseline():
    mk = _workload(n=200, seed=9)
    kw = dict(n_replicas=3, n_slots=3, cache_budget=400, inter_arrival=12, seed=7)
    base = simulate("federated", mk(), **kw)
    results = {
        bw: simulate("federated", mk(),
                     kv_ship=ShipCostModel(fabric_bytes_per_cycle=bw), **kw)
        for bw in (512, 64, 8)
    }
    for bw, r in results.items():
        assert r.admission_stall_total <= base.admission_stall_total, bw
    assert results[512].ships > 0
    assert results[512].admission_stall_total < base.admission_stall_total
    # a fabric slower than prefill ships nothing and coincides with baseline
    assert results[8].ships == 0
    assert results[8].admission_stall_total == base.admission_stall_total
    assert results[8].reprefill_tokens == base.reprefill_tokens


def test_sim_deterministic_with_shipping():
    mk = _workload(n=100, seed=13)
    kw = dict(n_replicas=3, n_slots=2, cache_budget=300, inter_arrival=10,
              seed=5, kv_ship=True)
    a = simulate("federated", mk(), **kw)
    b = simulate("federated", mk(), **kw)
    assert (a.ships, a.shipped_tokens, a.admission_stall_total, a.ticks) == (
        b.ships, b.shipped_tokens, b.admission_stall_total, b.ticks
    )


def test_replica_cache_peek_has_no_side_effects():
    from repro.router import ReplicaCache

    c = ReplicaCache(16)
    c.insert((1, 1, 1, 1))
    c.insert((2, 2, 2, 2))
    assert c.peek((1, 1, 1, 9)) == 3
    # peek must NOT have refreshed (1,1,1,1): inserting a large entry now
    # evicts it first (oldest), unlike after a match()
    c.insert((3, 3, 3, 3, 3, 3, 3, 3, 3, 3))
    assert c.peek((1, 1)) == 0


def test_sim_replica_embargoes_inflight_ships():
    """A shipped prefix is invisible until the fabric delivers it: a second
    session racing the transfer cannot reuse bytes that have not arrived,
    while the shipping session itself (whose prefill waits for fabric_end)
    does see its own bundle."""
    rep = SimReplica(0, 4, cache_budget=600)
    assert rep.import_kv((1, 2, 3, 4, 5), None, ready_t=100)
    assert rep.peek_match((1, 2, 3, 4, 5), now=50) == 0    # in flight
    racer = Session(sid=1, prompt=(1, 2, 3, 4, 5), decode_len=1)
    assert rep.admit(racer, now=50) == 0                   # no time travel
    assert rep.peek_match((1, 2, 3, 4, 5), now=100) == 5   # delivered


def test_router_books_nothing_when_import_refused():
    """A target that refuses the bundle (here: no store behind import_kv)
    must leave no fabric reservation and no ship counters; the recorded
    decision keeps its argmin (`choice` stays "ship") with `executed`
    False, and the refusal counts as ship_failed, not ship_declined."""
    router, reps = _warm_router()
    reps[0].inflight = 1
    target_rep = reps[1]
    target_rep.import_kv = lambda tokens, payload, ready_t=0: False
    s = Session(sid=0, prompt=tuple(range(50)) + (99,), decode_len=1)
    router.submit(s)
    _, target, _ = router.dispatch_one()
    assert s.ship is not None and s.ship.choice == "ship"
    assert not s.ship.executed
    assert router.stats.ships == 0
    assert router.stats.ship_failed == 1 and router.stats.ship_declined == 0
    assert router.fabric.busy_until == 0                   # nothing reserved
    assert router.fabric.stats.ships == 0
    assert s.local_matched == 0                            # it re-prefilled


# -- federation: shippable holders ---------------------------------------------


def test_router_picks_nearest_source_among_equal_holders():
    """Equal advertised lengths tie toward the holder nearest the target:
    distance multiplies the priced bytes, so the far source could flip the
    argmin and lose a profitable ship."""
    reps = [SimReplica(r, 1, cache_budget=600) for r in range(4)]
    router = ReplicaRouter(reps, topology=pod(2, 2), sync_every=0, kv_ship=True)
    seq = tuple(range(40))
    reps[0].cache.insert(seq)     # cross-pod holder relative to the target
    reps[3].cache.insert(seq)     # same-pod holder (recorded later -> fresher
    router.sync()                 # stamp -> the federation homes here)
    s = Session(sid=0, prompt=seq + (99,), decode_len=1)
    assert router.submit(s) == 3  # equal-occupancy tie -> fresher stamp
    reps[3].inflight = 1          # home full -> shed to 2, its pod sibling
    _, target, _ = router.dispatch_one()
    assert target == 2
    assert s.ship is not None and s.ship.executed
    assert s.ship.src == 3 and s.ship.distance == 1   # not the distance-2 holder


def test_federation_shippable_reports_longest_remote_holder():
    reps = [SimReplica(r, 2, cache_budget=400) for r in range(3)]
    router = ReplicaRouter(reps, sync_every=0)
    reps[0].cache.insert((1, 2, 3, 4, 5, 6))
    reps[1].cache.insert((1, 2, 3))
    router.sync()
    probe = (1, 2, 3, 4, 5, 6, 7)
    assert router.federation.shippable(probe, now=0) == (0, 6)
    # excluding the best holder falls to the next-longest
    assert router.federation.shippable(probe, now=0, exclude=0) == (1, 3)
    assert router.federation.shippable((9, 9), now=0) == (None, 0)


def test_prefix_index_holders_is_read_only():
    from repro.serving.prefixindex import PrefixIndex

    idx = PrefixIndex(n_domains=3)
    idx.record((1, 2, 3, 4), 0)
    idx.record((1, 2), 1)
    lookups_before = idx.lookups
    h = idx.holders((1, 2, 3, 4, 5))
    assert h == {0: 4, 1: 2}
    assert idx.lookups == lookups_before     # pricing probes are not traffic
    assert idx.holders((7,)) == {}


# -- engine contract (jax): shipped == re-prefilled, bit for bit ---------------


@pytest.fixture(scope="module")
def small_model():
    jax = pytest.importorskip("jax")
    import numpy as np  # noqa: F401  (fixture consumers use it)

    from repro.configs.base import get_reduced_config
    from repro.models.registry import build_model

    cfg = get_reduced_config("granite_3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, **kw):
    from repro.serving.engine import DecodeEngine

    return DecodeEngine(model, params, n_slots=1, cache_len=64, prefix_kv=True, **kw)


def test_shipped_decode_bitwise_matches_reprefilled(small_model):
    """The acceptance contract: run the same prompt (a) from scratch and
    (b) resuming from a KV bundle shipped out of another engine — the decode
    outputs must be identical token for token."""
    import numpy as np

    from repro.serving.engine import Request

    cfg, model, params = small_model
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab, 12).astype(np.int32)
    prompt = np.concatenate([shared, rng.integers(0, cfg.vocab, 5).astype(np.int32)])

    src = _engine(model, params)
    src.run([Request(rid=0, prompt=shared, max_new=1)])  # warms src's store
    exported = src.export_kv(prompt)
    assert exported is not None and len(exported[0]) >= len(shared)

    dst = _engine(model, params)
    assert dst.import_kv(*exported)
    shipped = Request(rid=1, prompt=prompt, max_new=5)
    dst.run([shipped])
    assert dst.reused_positions >= len(shared)   # the ship actually resumed

    fresh = _engine(model, params)
    reprefilled = Request(rid=2, prompt=prompt, max_new=5)
    fresh.run([reprefilled])
    assert fresh.reused_positions == 0

    assert shipped.out == reprefilled.out        # bitwise contract


def test_retirement_deposit_resumes_follow_ups(small_model):
    """ROADMAP "retirement-time prefix-KV deposits": after a request
    retires, its prompt *plus generated output* is resumable — a follow-up
    extending the whole conversation computes only its new tokens (plus the
    final emitted token the cache never encoded)."""
    import numpy as np

    from repro.serving.engine import Request

    cfg, model, params = small_model
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, 10).astype(np.int32)
    eng = _engine(model, params)
    r1 = Request(rid=0, prompt=prompt, max_new=4)
    eng.run([r1])
    assert eng.kv_deposits == 1
    convo = np.concatenate([prompt, np.asarray(r1.out, np.int32)])
    # the store holds prompt + out[:-1]: everything the model ever encoded
    assert eng.peek_match(convo) == len(prompt) + len(r1.out) - 1

    follow = np.concatenate([convo, rng.integers(0, cfg.vocab, 3).astype(np.int32)])
    before = eng.prefill_positions
    r2 = Request(rid=1, prompt=follow, max_new=3)
    eng.run([r2])
    # computed: 3 new tokens + the one emitted-but-never-fed token
    assert eng.prefill_positions - before == 4

    ref = _engine(model, params)
    r3 = Request(rid=2, prompt=follow, max_new=3)
    ref.run([r3])
    assert r2.out == r3.out                      # deposits change cost, not output


def test_engine_replica_admit_counts_shipped_bundles(small_model):
    """RouterStats consistency over live engines: admit() must report the
    replica's *actual* resumable holding — including a just-imported
    (shipped) bundle the prefix index knows nothing about — so the router
    does not book the same tokens as both re-prefilled and avoided."""
    import numpy as np

    from repro.core.topology import pod
    from repro.router import EngineReplica, Session as RSession
    from repro.serving.engine import DecodeEngine

    cfg, model, params = small_model
    rng = np.random.default_rng(5)
    shared = rng.integers(0, cfg.vocab, 10).astype(np.int32)
    prompt = tuple(int(t) for t in np.concatenate(
        [shared, rng.integers(0, cfg.vocab, 3).astype(np.int32)]))

    src = _engine(model, params)
    src.run([__import__("repro.serving.engine", fromlist=["Request"])
             .Request(rid=0, prompt=shared, max_new=1)])
    exported = src.export_kv(prompt)
    assert exported is not None

    dst = EngineReplica(1, DecodeEngine(
        model, params, n_slots=1, cache_len=64,
        scheduler=None, topology=pod(1, 2),
        placement="nearest_spill", prefix_index=True, prefix_kv=True))
    assert dst.import_kv(*exported)              # the ship lands
    got = dst.admit(RSession(sid=7, prompt=prompt, decode_len=1), now=0)
    assert got >= len(shared)                    # shipped tokens count as held


def test_import_kv_refuses_overlength_bundle(small_model):
    import numpy as np

    from repro.serving.engine import Request

    cfg, model, params = small_model
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 12).astype(np.int32)
    src = _engine(model, params)
    src.run([Request(rid=0, prompt=prompt, max_new=1)])
    exported = src.export_kv(prompt)
    assert exported is not None

    from repro.serving.engine import DecodeEngine

    tiny = DecodeEngine(model, params, n_slots=1, cache_len=8, prefix_kv=True)
    assert not tiny.import_kv(*exported)         # cannot fit cache_len=8
    assert len(tiny.prefix_kv) == 0
