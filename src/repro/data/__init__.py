from .pipeline import BigramLMDataset, UniformLMDataset, ShardedLoader  # noqa: F401
