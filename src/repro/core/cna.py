"""Compact NUMA-aware lock (CNA) — faithful executable transcription of the paper.

This module transcribes Figures 2-5 of Dice & Kogan, "Compact NUMA-aware Locks"
(EuroSys 2019) into Python, line-for-line where possible.  Python has no raw
CAS/SWAP on object attributes, so the two atomic instructions of the algorithm
(SWAP on lock.tail in `lock`, CAS on lock.tail in `unlock`) are emulated by a
single internal mutex guarding *only* those two operations — exactly the two
touch points the paper identifies.  All other fields follow the paper's
publication order.  The GIL makes wall-clock throughput meaningless here, so
this implementation is for *algorithmic correctness* (mutual exclusion, queue
splicing, starvation freedom); performance reproduction lives in
``repro.core.numasim`` / ``repro.core.locks_sim``.

The ``spin`` field carries, as in the paper, either 0 (wait), 1 (lock granted,
empty secondary queue) or a reference to the head node of the secondary queue
(lock granted, non-empty secondary queue).  In C this is pointer-stuffing into
one word; in Python the union is explicit.
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field

# Long-term fairness threshold (paper Fig. 5: 0xffff).  Tests shrink it to
# exercise the secondary-queue flush path quickly.
THRESHOLD = 0xFFFF
# Shuffle-reduction threshold (paper Section 6: 0xff).
THRESHOLD2 = 0xFF


class CNANode:
    """Queue node (paper Fig. 2).  One per (thread, nesting level)."""

    __slots__ = ("spin", "socket", "sec_tail", "next")

    def __init__(self) -> None:
        self.spin: object = 0          # 0 | 1 | CNANode (head of secondary queue)
        self.socket: int = -1
        self.sec_tail: CNANode | None = None
        self.next: CNANode | None = None


@dataclass
class CNAStats:
    """Optional bookkeeping used by tests/benchmarks (not part of the lock word)."""

    handovers: int = 0
    local_handovers: int = 0
    secondary_flushes: int = 0
    shuffles: int = 0


class CNALock:
    """CNA lock.  The lock *state* is one word: ``tail``.

    ``numa_node_of`` maps a thread to its (virtual) NUMA node; on a real
    machine this is ``rdtscp``/``getcpu``; here it is injectable so tests can
    build arbitrary topologies on a single-core container.
    """

    def __init__(
        self,
        numa_node_of=None,
        threshold: int = THRESHOLD,
        shuffle_reduction: bool = False,
        threshold2: int = THRESHOLD2,
        seed: int = 0x5EED,
    ) -> None:
        self.tail: CNANode | None = None          # <-- the single word of state
        self._atomic = threading.Lock()           # emulates SWAP/CAS only
        self._numa_node_of = numa_node_of or (lambda: 0)
        self._threshold = threshold
        self._shuffle_reduction = shuffle_reduction
        self._threshold2 = threshold2
        self._rng = random.Random(seed)
        self._rng_lock = threading.Lock()
        self.stats = CNAStats()

    # -- emulated atomics ---------------------------------------------------
    def _swap_tail(self, new: CNANode | None) -> CNANode | None:
        with self._atomic:
            old, self.tail = self.tail, new
            return old

    def _cas_tail(self, expected: CNANode | None, new: CNANode | None) -> bool:
        with self._atomic:
            if self.tail is expected:
                self.tail = new
                return True
            return False

    def _pseudo_rand(self) -> int:
        with self._rng_lock:
            return self._rng.getrandbits(30)

    # -- paper Fig. 3: cna_lock ---------------------------------------------
    def acquire(self, me: CNANode) -> None:
        me.next = None                             # L2
        me.socket = -1                             # L3
        me.spin = 0                                # L4
        tail = self._swap_tail(me)                 # L6  (the one atomic)
        if tail is None:                           # L8: no one there?
            me.spin = 1
            return
        me.socket = self._numa_node_of()           # L10
        tail.next = me                             # L11
        while me.spin == 0:                        # L13: local spinning
            time.sleep(0)                          # CPU_PAUSE under the GIL

    # -- paper Fig. 5 auxiliaries --------------------------------------------
    def _keep_lock_local(self) -> bool:            # L77
        return bool(self._pseudo_rand() & self._threshold)

    def _find_successor(self, me: CNANode) -> CNANode | None:  # L51-74
        nxt = me.next
        my_socket = me.socket
        if my_socket == -1:                        # L54
            my_socket = self._numa_node_of()
        if nxt.socket == my_socket:                # L56: immediate successor local
            return nxt
        sec_head = nxt                             # L57
        sec_tail = nxt                             # L58
        cur = nxt.next                             # L59
        while cur is not None:                     # L61: traverse main queue
            if cur.socket == my_socket:            # L63
                if isinstance(me.spin, CNANode):   # L64: secondary queue non-empty
                    me.spin.sec_tail.next = sec_head  # L65
                else:
                    me.spin = sec_head             # L66
                sec_tail.next = None               # L67
                me.spin.sec_tail = sec_tail        # L68
                self.stats.shuffles += 1
                return cur                         # L69
            sec_tail = cur                         # L71
            cur = cur.next                         # L72
        return None                                # L74

    # -- paper Fig. 4: cna_unlock --------------------------------------------
    def release(self, me: CNANode) -> None:
        if me.next is None:                        # L18: successor in main queue?
            if me.spin == 1:                       # L20: secondary queue empty?
                if self._cas_tail(me, None):       # L23
                    return
            else:
                sec_head = me.spin                 # L27
                if self._cas_tail(me, sec_head.sec_tail):  # L28
                    sec_head.spin = 1              # L31: pass lock to sec. head
                    self.stats.handovers += 1
                    self.stats.secondary_flushes += 1
                    return
            while me.next is None:                 # L36: wait for successor link
                time.sleep(0)

        # Section 6 shuffle-reduction optimization (between L37 and L38).
        if (
            self._shuffle_reduction
            and me.spin == 1
            and (self._pseudo_rand() & self._threshold2)
        ):
            me.next.spin = 1
            self.stats.handovers += 1
            return

        # L40-49: determine next lock holder.
        succ = None
        if self._keep_lock_local():
            succ = self._find_successor(me)        # L41
        if succ is not None:
            succ.spin = me.spin                    # L42 (never 0: me.spin is 1 or node)
            self.stats.handovers += 1
            self.stats.local_handovers += 1
        elif isinstance(me.spin, CNANode):         # L43: secondary queue non-empty
            succ = me.spin                         # L44
            succ.sec_tail.next = me.next           # L45: splice sec. queue in front
            succ.spin = 1                          # L46
            self.stats.handovers += 1
            self.stats.secondary_flushes += 1
        else:
            me.next.spin = 1                       # L48
            self.stats.handovers += 1


class MCSLock:
    """Classic MCS lock (Mellor-Crummey & Scott 1991) — the paper's baseline."""

    def __init__(self) -> None:
        self.tail: CNANode | None = None
        self._atomic = threading.Lock()

    def acquire(self, me: CNANode) -> None:
        me.next = None
        me.spin = 0
        with self._atomic:
            tail, self.tail = self.tail, me
        if tail is None:
            me.spin = 1
            return
        tail.next = me
        while me.spin == 0:
            time.sleep(0)

    def release(self, me: CNANode) -> None:
        if me.next is None:
            with self._atomic:
                if self.tail is me:
                    self.tail = None
                    return
            while me.next is None:
                time.sleep(0)
        me.next.spin = 1


@dataclass
class _Shared:
    counter: int = 0
    per_thread: dict = field(default_factory=dict)


def run_lock_stress(
    lock_factory,
    n_threads: int,
    n_sockets: int,
    iters: int,
    *,
    cs_work: int = 0,
) -> _Shared:
    """Drive ``n_threads`` through acquire/CS/release cycles; return the shared
    cell for invariant checking (counter == n_threads * iters proves mutual
    exclusion held for the increment sequence)."""

    tls = threading.local()

    def socket_of() -> int:
        return tls.socket

    lock = lock_factory(socket_of)
    shared = _Shared()

    def body(tid: int) -> None:
        tls.socket = tid % n_sockets
        node = CNANode()
        for _ in range(iters):
            lock.acquire(node)
            # critical section: racy read-modify-write, only safe under mutex
            v = shared.counter
            for _ in range(cs_work):
                pass
            shared.counter = v + 1
            shared.per_thread[tid] = shared.per_thread.get(tid, 0) + 1
            lock.release(node)

    threads = [threading.Thread(target=body, args=(i,)) for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return shared
