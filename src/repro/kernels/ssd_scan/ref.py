"""Pure-jnp oracle for the SSD intra-chunk kernel."""

from __future__ import annotations

import jax.numpy as jnp


def _segsum(x):
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_intra_ref(xc, dac, bc, cc):
    """Intra-chunk SSD term.

    xc:  (B, nc, L, H, P)  dt-weighted inputs
    dac: (B, H, nc, L)     dt * A
    bc:  (B, nc, L, N)
    cc:  (B, nc, L, N)
    ->   (B, nc, L, H, P)
    """
    lmat = jnp.exp(_segsum(dac.astype(jnp.float32)))
    return jnp.einsum(
        "bcln,bcsn,bhcls,bcshp->bclhp",
        cc.astype(jnp.float32), bc.astype(jnp.float32), lmat, xc.astype(jnp.float32),
    )
