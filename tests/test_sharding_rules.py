"""Logical-axis sharding rules: divisibility fallback, axis dedup, pod axis."""

import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.sharding import DEFAULT_RULES, spec_for, use_mesh

from _subproc import REPO_ROOT, run_env


def test_no_mesh_is_noop():
    assert spec_for((4, 8), ("batch", "embed")) == P()


_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.models.sharding import spec_for, use_mesh

    mesh = jax.make_mesh((2, 2, 4), ("pod", "data", "model"))
    with use_mesh(mesh):
        # batch maps to (pod, data); divisible
        assert spec_for((8, 128), ("batch", None)) == P(("pod", "data"), None), spec_for((8,128),("batch",None))
        # batch not divisible by 4 -> replicated
        assert spec_for((3, 128), ("batch", None)) == P(None, None)
        # heads / mlp to model
        assert spec_for((16, 8, 64), ("fsdp", "heads", None)) == P("data", "model", None)
        # dedup: expert wants data, fsdp also wants data -> second gets None
        assert spec_for((8, 64, 32), ("expert", "fsdp", "mlp")) == P("data", None, "model")
        # vocab to model
        assert spec_for((1024, 64), ("vocab", "fsdp")) == P("model", "data")

    mesh1 = jax.make_mesh((4, 4), ("data", "model"))
    with use_mesh(mesh1):
        # no pod axis: batch falls back to data alone
        assert spec_for((8, 128), ("batch", None)) == P("data", None)
        # kv heads=2 not divisible by model=4 -> replicated
        assert spec_for((16, 2, 64), ("fsdp", "kv_heads", None)) == P("data", None, None)
    print("SHARDING_OK")
""")


def test_rules_on_multi_axis_mesh():
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT], capture_output=True, text=True, timeout=300,
        env=run_env(), cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARDING_OK" in proc.stdout


def test_default_rules_cover_model_axes():
    for ax in ("batch", "heads", "kv_heads", "mlp", "vocab", "fsdp", "expert", "seq"):
        assert ax in DEFAULT_RULES
