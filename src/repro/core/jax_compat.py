"""Version shims for jax APIs the repo uses that moved between releases.

The container pins an older jax than some call sites were written against;
everything funnels through here so the rest of the codebase can use the
modern spelling unconditionally.

  * ``shard_map``: new jax exposes ``jax.shard_map(f, mesh=..., in_specs=...,
    out_specs=..., axis_names=..., check_vma=...)``; old jax has
    ``jax.experimental.shard_map.shard_map`` where ``check_vma`` is spelled
    ``check_rep`` and "manual only over ``axis_names``" is spelled as the
    complementary ``auto=`` axis set.
  * ``axis_size``: ``jax.lax.axis_size`` is new; ``psum(1, axis)`` is the
    portable spelling (constant-folded at trace time).
  * ``cost_analysis_dict``: ``compiled.cost_analysis()`` returns a dict on
    new jax and a one-element list of dicts on old jax.
  * ``axis_types_kw``: ``jax.make_mesh(..., axis_types=...)`` /
    ``jax.sharding.AxisType`` only exist on newer jax; older meshes are
    Auto-only, so omitting the kwarg is equivalent.
"""

from __future__ import annotations

import jax


def axis_size(axis_name) -> int:
    """Size of a mapped mesh axis, usable inside shard_map/pmap bodies."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


try:
    from jax.sharding import AxisType as _AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    _AxisType = None


def axis_types_kw(n_axes: int) -> dict:
    """kwargs making every mesh axis Auto on jax versions that type axes."""
    if _AxisType is None:
        return {}
    return {"axis_types": (_AxisType.Auto,) * n_axes}


def cost_analysis_dict(compiled) -> dict:
    """Normalised ``compiled.cost_analysis()``: always a (possibly empty) dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}

try:  # jax >= 0.6-ish
    from jax import shard_map as _shard_map_new

    _NEW = True
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map_old

    _NEW = False


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=None):
    if _NEW:
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return _shard_map_new(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    kw = {}
    if check_vma is not None:
        kw["check_rep"] = check_vma
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kw["auto"] = auto
    return _shard_map_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
