"""Fault tolerance: heartbeats, stragglers, checkpoint-restart, elastic re-mesh."""

import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.runtime.fault import HeartbeatMonitor, StragglerDetector, WorkerFailure

from _subproc import REPO_ROOT, run_env


def test_heartbeat_detects_dead_worker():
    t = [0.0]
    mon = HeartbeatMonitor(n_workers=4, timeout=10.0, clock=lambda: t[0])
    t[0] = 5.0
    for w in (0, 1, 3):
        mon.beat(w)
    t[0] = 12.0
    assert mon.dead_workers() == [2]
    with pytest.raises(WorkerFailure):
        mon.check()


def test_straggler_detection_and_recovery():
    det = StragglerDetector(n_workers=4, factor=2.0, min_samples=3)
    for step in range(5):
        for w in range(4):
            det.record(w, 1.0 if w != 2 else 5.0)
    assert det.stragglers() == [2]
    # worker 2 recovers -> EWMA decays below threshold -> readmitted
    for _ in range(20):
        det.record(2, 1.0)
    assert det.stragglers() == []


def test_straggler_reassignment_prefers_pod_peers():
    det = StragglerDetector(n_workers=8, factor=2.0, min_samples=3)
    for _ in range(3):
        for w in range(8):
            det.record(w, 4.0 if w == 1 else 1.0)
    plan = det.reassignment(n_hosts=8)
    assert sum(len(v) for v in plan.values()) == 1
    donor = next(iter(plan))
    # worker 1 is in pod 0 (hosts 0-3); the donor must be a pod-0 peer
    assert donor in (0, 2, 3)


_ELASTIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_reduced_config
    from repro.models.registry import build_model, synthetic_batch
    from repro.checkpoint.manager import CheckpointManager
    from repro.runtime.elastic import ElasticTrainer, plan_mesh, make_mesh_from_plan
    from repro.models.sharding import use_mesh
    from repro.training.step import init_state, make_train_step, state_abstract, state_logical, tree_shardings

    cfg = get_reduced_config("granite_3_8b").replace(accum=1)
    model = build_model(cfg)
    ckpt = CheckpointManager("{root}")

    # phase 1: train on an 8-device mesh (2 pods x 2 data x 2 model)
    mesh8 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    step_fn = make_train_step(model, cfg, lr_fn=lambda s: 1e-3)
    with use_mesh(mesh8):
        state = init_state(model, jax.random.PRNGKey(0), cfg)
        sh = tree_shardings(state_abstract(model, cfg), state_logical(model))
        state = jax.device_put(state, sh)
        batch = synthetic_batch(cfg, "train", 8, 16)
        state, m = jax.jit(step_fn, in_shardings=(sh, None), out_shardings=(sh, None))(state, batch)
    loss8 = float(m["loss"])
    ckpt.save(int(state["step"]), state, extra={"loss": loss8})

    # phase 2: "pod failure" -> only 4 devices -> restore elastically
    trainer = ElasticTrainer(model, cfg, ckpt, model_parallel=2)
    mesh4, state4, extra = trainer.restore_on(jax.devices()[:4], want_pods=1)
    assert tuple(mesh4.shape.values()) == (2, 2), mesh4.shape
    with use_mesh(mesh4):
        sh4 = tree_shardings(state_abstract(model, cfg), state_logical(model))
        batch = synthetic_batch(cfg, "train", 8, 16)
        state4b, m4 = jax.jit(step_fn, in_shardings=(sh4, None), out_shardings=(sh4, None))(state4, batch)

    # the restored step must continue from the checkpoint
    assert int(state4b["step"]) == int(state["step"]) + 1

    # determinism: same batch, same params => same loss on both meshes
    with use_mesh(mesh8):
        state8r, m8 = jax.jit(step_fn, in_shardings=(sh, None), out_shardings=(sh, None))(
            jax.device_put(ckpt.restore(ckpt.latest_step(), state_abstract(model, cfg)), sh), batch)
    np.testing.assert_allclose(float(m4["loss"]), float(m8["loss"]), rtol=1e-4)
    print("ELASTIC_OK", loss8, float(m4["loss"]))
""")


def test_elastic_restart_across_meshes(tmp_path):
    """Full scenario: train on 8 devices (2 pods), checkpoint, lose a pod,
    restore on 4 devices with re-sharding, continue training with identical
    numerics.  Runs in a subprocess so XLA_FLAGS can fake 8 CPU devices."""
    script = _ELASTIC_SCRIPT.replace("{root}", str(tmp_path / "ckpt"))
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=600,
        env=run_env(), cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ELASTIC_OK" in proc.stdout


def test_plan_mesh_shapes():
    from repro.runtime.elastic import plan_mesh

    assert plan_mesh(512, model_parallel=16, want_pods=2) == ((2, 16, 16), ("pod", "data", "model"))
    assert plan_mesh(256, model_parallel=16) == ((16, 16), ("data", "model"))
    assert plan_mesh(4, model_parallel=2) == ((2, 2), ("data", "model"))
    with pytest.raises(ValueError):
        plan_mesh(10, model_parallel=4)
