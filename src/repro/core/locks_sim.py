"""Lock disciplines implemented against the discrete-event NUMA simulator.

Implemented locks (paper Section 7 evaluates this exact menagerie):

  * ``TASSim``        — test-and-set, global spinning (related work §2)
  * ``TicketSim``     — FIFO ticket lock, global spinning
  * ``HBOSim``        — hierarchical backoff lock (Radovic & Hagersten)
  * ``MCSSim``        — MCS queue lock: the paper's baseline
  * ``CNASim``        — the paper's contribution (two queues + fairness threshold)
  * ``CNAOptSim``     — CNA + Section-6 shuffle-reduction optimization
  * ``CohortSim``     — C-BO-MCS: per-socket MCS under a global backoff-TAS
  * ``HMCSSim``       — hierarchical MCS (Chabbi et al.)

Each lock charges handover latencies through ``sim.charge_xfer`` (which also
feeds the remote-transfer counters behind the paper's LLC-miss-rate figure).
The CNA/CNAOpt disciplines are behaviourally identical to ``repro.core.cna``
(same queue splicing, same threshold semantics); a property test cross-checks
admission orders between the two on a common schedule.
"""

from __future__ import annotations

from collections import deque

from .numasim import LockSim

# Defaults mirror the paper: keep_lock_local ~ 1/(THRESHOLD+1) flush chance per
# handover; benchmarks pass scaled-down thresholds so that (flushes per run) in
# a ~10-50M-cycle simulation matches the paper's (flushes per 10s run) regime.
THRESHOLD = 0xFFFF
THRESHOLD2 = 0xFF


class MCSSim(LockSim):
    name = "mcs"

    def __init__(self, sim) -> None:
        super().__init__(sim)
        self.queue: deque[int] = deque()
        self.holder: int | None = None

    def arrive(self, tid: int):
        if self.holder is None and not self.queue:
            self.holder = tid
            return self.cm.c_atomic
        self.queue.append(tid)
        return None

    def release(self, tid: int):
        if not self.queue:
            self.holder = None
            return None
        nxt = self.queue.popleft()
        self.holder = nxt
        cost = self.sim.charge_xfer(self.socket(tid), self.socket(nxt))
        return nxt, cost


class CNASim(LockSim):
    """The paper's algorithm over the simulator's queue abstraction.

    ``main``/``secondary`` mirror the two queues; scan costs model
    find_successor touching each skipped node's cache line.
    """

    name = "cna"
    shuffle_reduction = False

    def __init__(self, sim, threshold: int = THRESHOLD, threshold2: int = THRESHOLD2) -> None:
        super().__init__(sim)
        self.main: deque[int] = deque()
        self.secondary: deque[int] = deque()
        self.holder: int | None = None
        self.threshold = threshold
        self.threshold2 = threshold2

    def arrive(self, tid: int):
        if self.holder is None and not self.main:
            # Lock word free: single SWAP, exactly MCS's uncontended path.
            # (CNA's extra fields are touched only under contention — L10.)
            self.holder = tid
            return self.cm.c_atomic
        self.main.append(tid)
        return None

    def _keep_lock_local(self) -> bool:
        return bool(self.rng.getrandbits(30) & self.threshold)

    def _grant(self, tid: int, from_tid: int, extra: int = 0):
        self.holder = tid
        return tid, extra + self.sim.charge_xfer(self.socket(from_tid), self.socket(tid))

    def release(self, tid: int):
        if not self.main:
            if not self.secondary:
                self.holder = None
                return None
            # L28: whole secondary queue becomes the main queue.
            self.main = self.secondary
            self.secondary = deque()
            nxt = self.main.popleft()
            self.sim.result.shuffles += 1
            return self._grant(nxt, tid)

        # Section 6 shuffle reduction: secondary empty -> skip find_successor
        # with high probability and hand to the immediate successor.
        if (
            self.shuffle_reduction
            and not self.secondary
            and (self.rng.getrandbits(30) & self.threshold2)
        ):
            return self._grant(self.main.popleft(), tid)

        scan_cost = 0
        if self._keep_lock_local():
            # find_successor: walk the main queue for a same-socket thread,
            # paying a per-node inspection cost; on success move the skipped
            # prefix to the secondary queue (L64-68).
            me_socket = self.socket(tid)
            for i, cand in enumerate(self.main):
                if self.socket(cand) == me_socket:
                    scan_cost += self.cm.c_scan_local
                else:
                    scan_cost += self.cm.c_scan_remote
                    self.sim.result.remote_transfers += 1
                if self.socket(cand) == me_socket:
                    for _ in range(i):
                        self.secondary.append(self.main.popleft())
                    if i:
                        self.sim.result.shuffles += 1
                    nxt = self.main.popleft()
                    return self._grant(nxt, tid, extra=scan_cost)
            # No local successor found: find_successor returned NULL (L74).

        if self.secondary:
            # L43-46: hand to secondary head; splice the rest of the secondary
            # queue in front of the remaining main queue.
            nxt = self.secondary.popleft()
            self.secondary.extend(self.main)
            self.main = self.secondary
            self.secondary = deque()
            self.sim.result.shuffles += 1
            return self._grant(nxt, tid, extra=scan_cost)
        return self._grant(self.main.popleft(), tid, extra=scan_cost)


class CNAOptSim(CNASim):
    name = "cna_opt"
    shuffle_reduction = True


class TASSim(LockSim):
    """Global-spinning test-and-set.  Handover suffers a coherence storm that
    grows with the spinner count; the winner is biased to the releaser's
    socket (the line lands in that LLC first) => unfair."""

    name = "tas"
    local_bias = 4.0
    storm_scale = 1.0

    def __init__(self, sim) -> None:
        super().__init__(sim)
        self.spinners: list[int] = []
        self.holder: int | None = None

    def arrive(self, tid: int):
        if self.holder is None and not self.spinners:
            self.holder = tid
            return self.cm.c_atomic
        self.spinners.append(tid)
        return None

    def _pick(self, releaser_socket: int) -> int:
        weights = [
            self.local_bias if self.socket(t) == releaser_socket else 1.0
            for t in self.spinners
        ]
        total = sum(weights)
        r = self.rng.random() * total
        acc = 0.0
        for i, w in enumerate(weights):
            acc += w
            if r <= acc:
                return i
        return len(self.spinners) - 1

    def release(self, tid: int):
        if not self.spinners:
            self.holder = None
            return None
        s = self.socket(tid)
        idx = self._pick(s)
        nxt = self.spinners.pop(idx)
        self.holder = nxt
        n = len(self.spinners)
        # every spinner re-fetches the line => storm; remote spinners miss.
        remote_spin = sum(1 for t in self.spinners if self.socket(t) != s)
        self.sim.result.remote_transfers += remote_spin
        self.sim.result.local_transfers += n - remote_spin
        cost = self.sim.charge_xfer(s, self.socket(nxt)) + int(
            self.cm.c_storm * self.storm_scale * n
        )
        return nxt, cost


class TicketSim(TASSim):
    """FIFO grant order, but still global spinning => storms without bias."""

    name = "ticket"

    def release(self, tid: int):
        if not self.spinners:
            self.holder = None
            return None
        s = self.socket(tid)
        nxt = self.spinners.pop(0)
        self.holder = nxt
        n = len(self.spinners)
        remote_spin = sum(1 for t in self.spinners if self.socket(t) != s)
        self.sim.result.remote_transfers += remote_spin
        self.sim.result.local_transfers += n - remote_spin
        cost = self.sim.charge_xfer(s, self.socket(nxt)) + int(self.cm.c_storm * n)
        return nxt, cost


class HBOSim(TASSim):
    """Hierarchical backoff (Radovic & Hagersten): remote spinners back off to
    long waits => strong same-socket bias, reduced storm, poor fairness, and a
    polling-latency penalty when the lock does cross sockets."""

    name = "hbo"
    storm_scale = 0.35

    def _pick(self, releaser_socket: int) -> int:
        # Exponential backoff on remote spinners => a remote thread wins only
        # when no same-socket spinner exists at release time.  This is the
        # starvation behaviour the paper (and HBO's authors) report.
        local = [i for i, t in enumerate(self.spinners) if self.socket(t) == releaser_socket]
        if local:
            return self.rng.choice(local)
        return self.rng.randrange(len(self.spinners))

    def release(self, tid: int):
        out = super().release(tid)
        if out is None:
            return None
        nxt, cost = out
        if self.socket(nxt) != self.socket(tid):
            cost += 2 * self.cm.c_remote_xfer  # missed backoff polling window
        return nxt, cost


class CohortSim(LockSim):
    """C-BO-MCS cohort lock: per-socket MCS queues under a global backoff-TAS.

    The uncontended path takes two atomics (local MCS swap + global TAS), which
    is exactly why the paper's Fig. 6 shows hierarchical locks losing to
    MCS/CNA at one thread."""

    name = "c-bo-mcs"
    batch_limit = 64

    def __init__(self, sim, batch_limit: int | None = None) -> None:
        super().__init__(sim)
        self.local: dict[int, deque[int]] = {s: deque() for s in range(sim.n_sockets)}
        self.owner_socket: int | None = None
        self.holder: int | None = None
        self.batch = 0
        if batch_limit is not None:
            self.batch_limit = batch_limit

    def arrive(self, tid: int):
        if self.holder is None and all(not q for q in self.local.values()):
            self.holder = tid
            self.owner_socket = self.socket(tid)
            self.batch = 1
            return 2 * self.cm.c_atomic + self.cm.c_l1
        self.local[self.socket(tid)].append(tid)
        return None

    def _pick_next_socket(self, releaser_socket: int) -> int | None:
        # The global lock is a *backoff* test-and-set: when the batch limit
        # forces a global release, a waiter on the releaser's own socket
        # re-acquires it before remote sockets finish their backoff window —
        # this is exactly the starvation behaviour the paper observes for
        # C-BO-MCS (fairness factor near 1, Fig. 8).
        sockets = [s for s, q in self.local.items() if q]
        if not sockets:
            return None
        if releaser_socket in sockets:
            return releaser_socket
        return self.rng.choice(sockets)

    def release(self, tid: int):
        s = self.socket(tid)
        q = self.local[s]
        if q and self.batch < self.batch_limit:
            nxt = q.popleft()
            self.holder = nxt
            self.batch += 1
            return nxt, self.sim.charge_xfer(s, s)
        nxt_socket = self._pick_next_socket(s)
        if nxt_socket is None:
            self.holder = None
            self.owner_socket = None
            return None
        nxt = self.local[nxt_socket].popleft()
        self.holder = nxt
        self.owner_socket = nxt_socket
        self.batch = 1
        cost = self.sim.charge_xfer(s, nxt_socket) + self.cm.c_remote_xfer  # backoff window
        return nxt, cost


class HMCSSim(CohortSim):
    """HMCS: per-socket MCS queues under a global MCS of sockets (FIFO across
    sockets) => cohort-like throughput with near-MCS fairness."""

    name = "hmcs"

    def __init__(self, sim, batch_limit: int | None = None) -> None:
        super().__init__(sim, batch_limit)
        self.socket_fifo: deque[int] = deque()

    def arrive(self, tid: int):
        out = super().arrive(tid)
        s = self.socket(tid)
        if out is None and s not in self.socket_fifo and self.owner_socket != s:
            self.socket_fifo.append(s)
        return out

    def release(self, tid: int):
        s = self.socket(tid)
        q = self.local[s]
        if q and self.batch < self.batch_limit:
            nxt = q.popleft()
            self.holder = nxt
            self.batch += 1
            return nxt, self.sim.charge_xfer(s, s)
        # pass the global MCS to the next socket in FIFO order
        while self.socket_fifo:
            nxt_socket = self.socket_fifo.popleft()
            if self.local[nxt_socket]:
                nxt = self.local[nxt_socket].popleft()
                self.holder = nxt
                self.owner_socket = nxt_socket
                self.batch = 1
                if q:  # our socket still has waiters: requeue it
                    self.socket_fifo.append(s)
                # two-level handover: global MCS link + local grant
                cost = self.sim.charge_xfer(s, nxt_socket) + self.cm.c_local_xfer
                return nxt, cost
        if q:
            nxt = q.popleft()
            self.holder = nxt
            self.batch = 1
            return nxt, self.sim.charge_xfer(s, s)
        self.holder = None
        self.owner_socket = None
        return None


ALL_LOCKS = {
    cls.name: cls
    for cls in [TASSim, TicketSim, HBOSim, MCSSim, CNASim, CNAOptSim, CohortSim, HMCSSim]
}
