"""Deterministic-clock causal spans.

A ``Span`` is an interval ``[start, end]`` on whatever clock the caller
already keeps (scheduler ticks, fleet-sim event time, engine stall cycles)
— the tracer never reads a wall clock and never draws randomness, so a
traced run replays bit-for-bit.  Causality is structural: a span begun
while another span of the same trace is open becomes its child, which is
exactly the shape of the serving stack (``session`` ⊃ ``request`` ⊃
``queue_wait`` / ``prefill`` / ``decode``).

``NULL_TRACER`` is the off switch: falsy, method-compatible, allocation
free.  Instrumentation sites guard with ``if self.tracer:`` so the disabled
path is one truthiness check — the zero-cost-off contract the cross-driver
grant-order tests pin.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Iterator


def trace_key(item: Any) -> Any:
    """Stable per-request trace id: ``rid`` (engine requests), ``sid``
    (router sessions), or the item itself for plain str/int payloads."""
    for attr in ("rid", "sid"):
        v = getattr(item, attr, None)
        if v is not None:
            return v
    if isinstance(item, (str, int)):
        return item
    return str(item)


@dataclass
class Span:
    """One named interval of one trace; ``end == -1`` while still open."""

    name: str
    trace: Any
    span_id: int
    parent_id: int | None
    start: int
    end: int = -1
    attrs: dict = field(default_factory=dict)
    events: list = field(default_factory=list)

    @property
    def open(self) -> bool:
        return self.end < 0

    @property
    def duration(self) -> int:
        return 0 if self.open else self.end - self.start

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "trace": self.trace,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "start": self.start,
            "end": self.end,
            "attrs": dict(self.attrs),
            "events": [
                {"name": n, "t": t, "attrs": dict(a)} for n, t, a in self.events
            ],
        }


def _event_attrs(ev: Any) -> dict:
    """Flatten a discipline event (``Scan``/``Shuffle``/``SecondaryFlush``/
    ``Park``/``Unpark``) into JSON-safe attrs — payload items are reduced to
    their trace key so spans never pin request objects alive."""
    if dataclasses.is_dataclass(ev) and not isinstance(ev, type):
        out = {}
        for f in dataclasses.fields(ev):
            v = getattr(ev, f.name)
            out[f.name] = v if isinstance(v, (int, float, str, bool, type(None))) else trace_key(v)
        return out
    return {"value": str(ev)}


class Tracer:
    """Collects spans under a caller-supplied deterministic clock.

    Every mutation takes an explicit time ``t`` — the tracer has no clock of
    its own.  ``begin`` with no explicit parent nests under the innermost
    open span of the same trace, which makes causal linking automatic when
    the layers share one tracer (router opens ``session``, engine opens
    ``request`` inside it, scheduler emits ``queue_wait`` inside that).
    """

    enabled = True

    def __init__(self) -> None:
        self.spans: list[Span] = []
        self._open: dict[Any, list[Span]] = {}
        self._next_id = 0

    def __bool__(self) -> bool:
        return True

    def __len__(self) -> int:
        return len(self.spans)

    # -- span lifecycle ---------------------------------------------------
    def begin(self, name: str, trace: Any, t: int, parent: Span | None = None, **attrs) -> Span:
        stack = self._open.setdefault(trace, [])
        if parent is None and stack:
            parent = stack[-1]
        sp = Span(name, trace, self._next_id, parent.span_id if parent else None, t, attrs=attrs)
        self._next_id += 1
        self.spans.append(sp)
        stack.append(sp)
        return sp

    def end(self, span: Span | None, t: int, **attrs) -> None:
        if span is None or not span.open:
            return
        span.end = max(t, span.start)
        if attrs:
            span.attrs.update(attrs)
        stack = self._open.get(span.trace)
        if stack and span in stack:
            stack.remove(span)

    def span(self, name: str, trace: Any, start: int, end: int, parent: Span | None = None, **attrs) -> Span:
        """Emit an already-closed span (attribution intervals, instant
        events with duration zero).  Auto-parents like ``begin``."""
        stack = self._open.get(trace)
        if parent is None and stack:
            parent = stack[-1]
        sp = Span(
            name, trace, self._next_id, parent.span_id if parent else None,
            start, max(end, start), attrs,
        )
        self._next_id += 1
        self.spans.append(sp)
        return sp

    def event(self, span: Span | None, name: str, t: int, **attrs) -> None:
        if span is not None:
            span.events.append((name, t, attrs))

    def discipline_events(self, span: Span | None, events, t: int) -> None:
        """Attach a grant's discipline-level events (``Shuffle``,
        ``SecondaryFlush``, …) to a span as child events."""
        if span is None:
            return
        for ev in events:
            span.events.append((type(ev).__name__.lower(), t, _event_attrs(ev)))

    # -- queries ----------------------------------------------------------
    def open_span(self, trace: Any, name: str | None = None) -> Span | None:
        """Innermost open span of ``trace`` (optionally by name)."""
        for sp in reversed(self._open.get(trace, ())):
            if name is None or sp.name == name:
                return sp
        return None

    def for_trace(self, trace: Any) -> list[Span]:
        return [sp for sp in self.spans if sp.trace == trace]

    def traces(self) -> list:
        seen: dict = {}
        for sp in self.spans:
            seen.setdefault(sp.trace, None)
        return list(seen)

    def check(self) -> list[Span]:
        """Spans still open — empty after a fully-drained run."""
        return [sp for stack in self._open.values() for sp in stack]

    def phase_cycles(self, trace: Any) -> dict:
        """Per-phase attribution for one trace: sums the ``cycles`` attr of
        its ``phase.*`` spans — the quantity the conservation law pins."""
        out: dict = {}
        for sp in self.spans:
            if sp.trace == trace and sp.name.startswith("phase."):
                key = sp.name[len("phase."):]
                out[key] = out.get(key, 0) + sp.attrs.get("cycles", sp.duration)
        return out

    def __iter__(self) -> Iterator[Span]:
        return iter(self.spans)


class NullTracer:
    """Falsy no-op stand-in: the disabled path costs one truthiness check."""

    enabled = False
    spans: tuple = ()

    def __bool__(self) -> bool:
        return False

    def __len__(self) -> int:
        return 0

    def __iter__(self):
        return iter(())

    def begin(self, *a, **k):
        return None

    def end(self, *a, **k):
        return None

    def span(self, *a, **k):
        return None

    def event(self, *a, **k):
        return None

    def discipline_events(self, *a, **k):
        return None

    def open_span(self, *a, **k):
        return None

    def for_trace(self, trace):
        return []

    def traces(self):
        return []

    def check(self):
        return []

    def phase_cycles(self, trace):
        return {}


NULL_TRACER = NullTracer()
