"""Domain-partitioned free lists over a ``Topology``.

A NUMA allocator does not keep one global free list: each socket owns a pool
of local pages, and an allocation that cannot be satisfied locally spills to
the *nearest* socket (Linux's zonelist fallback order).  This module is that
structure for decode-cache slots: every slot has a fixed home domain (the
topology's placement rule — round-robin or block, exactly how the simulator
places threads on sockets), each domain keeps its free slots in a min-heap,
and ``claim_nearest`` walks domains in precomputed (distance, index) order.

The heaps keep claims O(log n_slots); release is a heap push plus an O(1)
double-free check against a free-slot *set* kept alongside the heaps (an
earlier version scanned the home pool's heap list for membership, an O(n)
walk that contradicted this bound).  Lowest-slot-first within a domain keeps
placement deterministic for tests.
"""

from __future__ import annotations

import heapq

from repro.core.topology import Topology, get_topology


class DomainFreeLists:
    """Per-domain slot pools with distance-ordered spill."""

    def __init__(self, n_slots: int, topology: Topology, slot_domain=None) -> None:
        self.topology = get_topology(topology)
        self.n_slots = n_slots
        if slot_domain is None:
            slot_domain = [self.topology.domain_of(s) for s in range(n_slots)]
        else:
            slot_domain = list(slot_domain)
            if len(slot_domain) != n_slots:
                raise ValueError("slot_domain must have one entry per slot")
            bad = [d for d in slot_domain if not 0 <= d < self.topology.n_domains]
            if bad:
                raise ValueError(f"slot_domain references unknown domains: {bad}")
        self.slot_domain = tuple(slot_domain)
        self._pools: list[list[int]] = [[] for _ in range(self.topology.n_domains)]
        for slot in range(n_slots):
            heapq.heappush(self._pools[self.slot_domain[slot]], slot)
        # mirror of the heaps' contents: O(1) membership for the release-path
        # double-free check (and the free count)
        self._free_set: set[int] = set(range(n_slots))
        # Linux-zonelist-style fallback order: for each home domain, every
        # domain sorted by (distance from home, domain index).
        n = self.topology.n_domains
        self.spill_order = tuple(
            tuple(sorted(range(n), key=lambda d: (self.topology.distance(home, d), d)))
            for home in range(n)
        )

    def __len__(self) -> int:
        return len(self._free_set)

    @property
    def domain_capacity(self) -> tuple[int, ...]:
        """Total slots homed in each domain (free or claimed) — the capacity
        the shed coupling compares occupancy against."""
        caps = [0] * self.topology.n_domains
        for d in self.slot_domain:
            caps[d] += 1
        return tuple(caps)

    def free_count(self, domain: int) -> int:
        return len(self._pools[domain])

    def free_slots(self) -> list[int]:
        """All free slots, ascending (introspection/tests; not the hot path)."""
        return sorted(self._free_set)

    def _pop(self, domain: int) -> int:
        slot = heapq.heappop(self._pools[domain])
        self._free_set.discard(slot)
        return slot

    def claim_in(self, domain: int) -> int | None:
        """Pop the lowest free slot homed in ``domain`` (None if exhausted)."""
        if not self._pools[domain]:
            return None
        return self._pop(domain)

    def claim_nearest(self, home: int) -> tuple[int, int] | None:
        """Pop a free slot from the nearest non-empty domain to ``home``;
        returns ``(slot, slot_domain)`` or None when everything is claimed."""
        for dom in self.spill_order[home]:
            if self._pools[dom]:
                return self._pop(dom), dom
        return None

    def claim_lowest(self) -> tuple[int, int] | None:
        """Pop the globally lowest free slot (the seed baseline's rule),
        regardless of domain; returns ``(slot, slot_domain)``."""
        best = None
        for dom, pool in enumerate(self._pools):
            if pool and (best is None or pool[0] < self._pools[best][0]):
                best = dom
        if best is None:
            return None
        return self._pop(best), best

    def release(self, slot: int) -> int:
        """Return ``slot`` to its home pool; returns that domain.  The
        double-free check is O(1) against the free set, not a pool scan."""
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range")
        if slot in self._free_set:
            raise ValueError(f"slot {slot} is already free")
        dom = self.slot_domain[slot]
        heapq.heappush(self._pools[dom], slot)
        self._free_set.add(slot)
        return dom
