"""deepseek-moe-16b [moe]: 28L d=2048 16H (kv=16) vocab=102400,
fine-grained MoE: 2 shared + 64 routed experts top-6, expert d_ff=1408
(arXiv:2401.06066).  64 % 16 == 0 => true expert parallelism over the data
axis.  This arch is the CNA-routing flagship (locality-aware expert bias)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv=16, d_ff=1408, vocab=102400,
    mlp="swiglu", n_experts=64, top_k=6, n_shared_experts=2, moe_d_ff=1408,
    capacity_factor=1.25, first_k_dense=1, accum=2,
)

def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=3, d_model=64, n_heads=4, n_kv=4, d_ff=96,
                          vocab=512, n_experts=8, top_k=2, n_shared_experts=1,
                          moe_d_ff=96, first_k_dense=1, accum=1, attn_chunk=64)
