"""The fissile fast path's differential harness.

``FissileDiscipline`` (repro.core.discipline) morphs between two modes —
a single-slot fast path and the full CNA two-queue core — and the morphing
boundary is exactly the kind of concurrent protocol that needs invariants
encoded as state-machine tests, not example runs.  The load-bearing property
here is the *shadow construction*: a plain ``CNADiscipline`` runs side by
side through every interleaving the state machine generates, and must grant
the same item at every release.  A fissile fast grant is forced (its waiter
is the only one), so the only divergence it can introduce is the RNG draw
the shadow spent deciding among one — which the machine resynchronizes,
turning "bitwise-identical at saturation" into the stronger "never reorders
under any interleaving".

Also here: the mode invariants (fast mode <=> empty inner core; deflation
only when both queues drain), inflate/deflate conservation, the fissile
``CNALock`` (threaded driver) under scripted and threaded stress, and the
router-level regressions — a headroom-home fast dispatch books zero
fabric/ship/federation counters, and the phase-attribution conservation law
survives the bypass.
"""

import random
import threading

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core.cna import CNALock, CNANode, run_lock_stress
from repro.core.discipline import (
    CNADiscipline,
    Deflate,
    DisciplineStats,
    FissileDiscipline,
    Inflate,
    RestrictedDiscipline,
)


# -- the state machine ---------------------------------------------------------

# an op is (arrive?, domain): True -> arrive(fresh item, domain),
# False -> release(current holder domain).  Domains span two "sockets plus
# overflow" so schedules exercise local, remote and mixed interleavings.
OPS = st.lists(
    st.tuples(st.booleans(), st.integers(min_value=0, max_value=3)),
    min_size=1,
    max_size=60,
)


@settings(max_examples=120)
@given(
    ops=OPS,
    seed=st.integers(min_value=0, max_value=2**16),
    threshold=st.sampled_from([0x0, 0x1, 0xF, 0xFFFF]),
    shuffle=st.booleans(),
)
def test_state_machine_fissile_never_reorders_vs_plain_cna(ops, seed, threshold, shuffle):
    """Shadow construction over arbitrary interleavings of arrive/release:

    * mode invariant — fast mode implies an empty inner core (so a fast
      grant can never barge past an inflated waiter), inflated mode implies
      an empty fast slot;
    * inflation moves exactly the slot occupant plus the contended arrival;
    * deflation fires only when both inner queues have drained;
    * the shadow plain CNA grants the *same item* at every release.  After a
      fast grant the shadow's RNG is resynced to the fissile inner core's
      (the fast path draws zero; the shadow spent draws choosing among one),
      so lockstep extends through any number of inflate/deflate cycles.
    """
    fiss = FissileDiscipline(
        CNADiscipline(threshold=threshold, shuffle_reduction=shuffle,
                      rng=random.Random(seed))
    )
    shadow = CNADiscipline(threshold=threshold, shuffle_reduction=shuffle,
                           rng=random.Random(seed))
    stats = DisciplineStats()
    holder_dom = 0
    n_arrived = 0
    granted = []
    for is_arrive, dom in ops:
        if is_arrive:
            evs = fiss.arrive(n_arrived, dom)
            shadow.arrive(n_arrived, dom)
            stats.consume(None, evs)
            n_arrived += 1
            inflates = [e for e in evs if isinstance(e, Inflate)]
            if inflates:
                assert len(inflates) == 1 and inflates[0].n_moved == 2
                assert fiss.mode == "inflated"
        else:
            g = fiss.release(holder_dom)
            g_shadow = shadow.release(holder_dom)
            stats.consume(g)
            assert (g is None) == (g_shadow is None)
            if g is None:
                continue
            # the shadow grants the same item under ANY interleaving
            assert g.item == g_shadow.item and g.domain == g_shadow.domain
            assert g.local == g_shadow.local
            if g.kind == "fast":
                # no barging: the fast path only fires over an empty core,
                # and it costs zero RNG draws — resync the shadow's
                assert len(fiss.inner) == 0
                shadow.rng.setstate(fiss.inner.rng.getstate())
            if any(isinstance(e, Deflate) for e in g.events):
                assert fiss.mode == "fast" and len(fiss.inner) == 0
            granted.append(g.item)
            holder_dom = g.domain
        # mode invariants hold after every transition
        if fiss.mode == "fast":
            assert len(fiss.inner) == 0
        else:
            assert fiss.fast_peek() is None and not fiss.fast_ready()
        assert len(fiss) == len(shadow)  # conservation, op by op

    # nothing lost, nothing duplicated, and the wrapper's own counters agree
    # with the event-folded stats
    assert len(granted) == len(set(granted))
    assert len(granted) + len(fiss) == n_arrived
    assert sorted(granted + [item for item, _ in fiss]) == list(range(n_arrived))
    assert stats.fast_grants == fiss.fast_grants
    assert stats.inflations == fiss.inflations
    assert stats.deflations == fiss.deflations
    # transitions pair up: deflations can trail inflations by at most the one
    # inflation currently open
    assert fiss.inflations - fiss.deflations == (1 if fiss.mode == "inflated" else 0)


@settings(max_examples=40)
@given(
    ops=OPS,
    seed=st.integers(min_value=0, max_value=2**16),
    max_active=st.integers(min_value=1, max_value=4),
)
def test_fissile_composes_over_restriction(ops, seed, max_active):
    """Fissile outside GCR restriction: a lone waiter bypasses both layers
    (one item trivially satisfies any cap >= 1), the inflated core honours
    the cap, and items are conserved through every transition."""
    fiss = FissileDiscipline(
        RestrictedDiscipline(
            CNADiscipline(threshold=0xF, rng=random.Random(seed)),
            max_active=max_active, rotate_after=8,
        )
    )
    assert fiss.max_active == max_active
    holder_dom = 0
    n_arrived = 0
    granted = []
    for is_arrive, dom in ops:
        if is_arrive:
            fiss.arrive(n_arrived, dom)
            n_arrived += 1
        else:
            g = fiss.release(holder_dom)
            if g is None:
                continue
            granted.append(g.item)
            holder_dom = g.domain
        if fiss.mode == "inflated":
            # the restriction's active set stays within its cap (+1
            # transiently inside release, re-absorbed before it returns)
            assert len(fiss.inner.inner) <= max_active
    assert sorted(granted + [item for item, _ in fiss]) == list(range(n_arrived))


def test_fissile_drain_resets_to_fast_mode():
    f = FissileDiscipline(CNADiscipline(rng=random.Random(0)))
    f.arrive("a", 0)
    f.arrive("b", 1)  # inflates
    f.arrive("c", 0)
    assert f.mode == "inflated"
    assert sorted(x for x, _ in f.drain()) == ["a", "b", "c"]
    assert f.mode == "fast" and len(f) == 0 and not f.fast_ready()
    f.arrive("d", 2)
    assert f.fast_ready() and f.fast_peek() == ("d", 2)
    g = f.release(0)
    assert g.kind == "fast" and g.item == "d" and not g.local


# -- the threaded lock driver --------------------------------------------------


def test_fissile_lock_uncontended_cycle_deflates():
    """Uncontended acquire/release cycles ride the fast path every time and
    never touch the queue word."""
    lock = CNALock(fissile=True)
    node = CNANode()
    for _ in range(7):
        lock.acquire(node)
        assert lock._fast_held and lock.tail is None
        lock.release(node)
        assert not lock._fast_held
    assert lock.stats.fast_acquires == 7
    assert lock.stats.deflations == 7
    assert lock.stats.inflations == 0
    assert lock.stats.handovers == 0  # no queue handover ever happened


def test_fissile_lock_inflates_to_full_decide_over_the_whole_chain():
    """The fast holder's contended release adopts the registered queue head
    as its successor chain and runs the full CNA decide() — the first
    contended handover already sees every waiter, which is what makes the
    lock bitwise-identical to plain CNA at saturation (the contract test in
    test_discipline.py drives both through shared schedules)."""
    cell = {"d": 0}
    lock = CNALock(numa_node_of=lambda: cell["d"], threshold=(1 << 29) - 1,
                   fissile=True)
    holder = CNANode()
    lock.acquire(holder)  # fast
    nodes = []
    for d in [1, 1, 0]:  # two remote waiters ahead of a local one
        n = CNANode()
        n.next, n.spin, n.socket = None, 0, d
        tail = lock._swap_tail(n)
        if tail is None:
            assert not lock._try_fast_takeover(n)  # holder still in its CS
        else:
            tail.next = n
        nodes.append(n)
    lock.release(holder)
    # keep_lock_local ~ always under this threshold: the grant scanned past
    # both remote waiters to the local one — impossible unless the release
    # decided over the whole chain rather than handing to the head
    assert nodes[2].spin != 0
    assert lock.stats.inflations == 1 and lock.stats.shuffles == 1
    # the skipped remote prefix moved to the secondary queue of the grantee
    assert nodes[2].spin is nodes[0]


def test_fissile_lock_threaded_stress_mutual_exclusion():
    for threads, sockets in [(8, 2), (6, 3)]:
        shared = run_lock_stress(
            lambda sock: CNALock(numa_node_of=sock, threshold=0xF, fissile=True),
            n_threads=threads, n_sockets=sockets, iters=40,
        )
        assert shared.counter == threads * 40


def test_fissile_lock_fast_path_races_takeover():
    """Two threads hammer an empty fissile lock: every acquisition is either
    a fast acquire or a takeover/handover, and mutual exclusion holds (the
    TS bit and the tail CAS are checked in one atomic step)."""
    lock = CNALock(fissile=True)
    counter = {"v": 0}
    iters = 300

    def body():
        node = CNANode()
        for _ in range(iters):
            lock.acquire(node)
            v = counter["v"]
            counter["v"] = v + 1
            lock.release(node)

    ts = [threading.Thread(target=body) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert counter["v"] == 2 * iters
    assert lock.tail is None and not lock._fast_held
    assert lock.stats.fast_acquires >= 1
    # every fast acquire's release either deflated (no one arrived) or
    # inflated (adopted the queue head) — never both, never neither
    assert lock.stats.fast_acquires == lock.stats.inflations + lock.stats.deflations


# -- router-level regressions ---------------------------------------------------


def _fleet(fissile: bool, *, kv_ship=True):
    from repro.router.router import ReplicaRouter
    from repro.router.sim import SimReplica

    replicas = [SimReplica(r, 4, cache_budget=4_000) for r in range(2)]
    # replica 1 holds the hot prefix; sessions are homed on replica 0, so a
    # full-pipeline dispatch would price shipping 1 -> 0
    replicas[1].cache.insert(tuple(range(64)))
    router = ReplicaRouter(
        replicas, seed=3, sync_every=0, kv_ship=kv_ship, fissile=fissile
    )
    router.sync()  # federation learns replica 1's holding
    return router, replicas


def test_router_fast_dispatch_books_zero_phantom_pricing():
    """A headroom-home fissile dispatch skips ship pricing, fabric
    accounting and federation discovery entirely — no phantom counters —
    while the identically-configured plain arm prices the very same ship."""
    from repro.router.router import Session

    for fissile in (False, True):
        router, replicas = _fleet(fissile)
        routes_before = router.federation.stats.routes
        s = Session(sid=0, prompt=tuple(range(64)), decode_len=2)
        router.submit(s, home=0)  # pinned home: no route lookup either
        out = router.dispatch_one()
        assert out is not None and out[1] == 0
        if fissile:
            assert s.fast and s.ship is None
            assert router.stats.fast_dispatches == 1
            # zero fabric/ship/federation side effects
            assert router.fabric.stats.priced == 0
            assert router.stats.ships == 0
            assert router.stats.ship_declined == 0
            assert router.stats.ship_failed == 0
            assert router.federation.stats.routes == routes_before
        else:
            # the control: the full pipeline did price this dispatch
            assert not s.fast and s.ship is not None
            assert router.stats.fast_dispatches == 0
            assert router.fabric.stats.priced == 1
        # real accounting is booked either way
        assert router.stats.dispatched == 1
        assert router.fleet.inflight[0] == 1
        assert len(router.stats.stalls) == 1


def test_router_fast_path_defers_to_pipeline_without_home_headroom():
    """fast_ready alone is not enough: when the lone session's home is full,
    the dispatch takes the full pipeline (and sheds) instead of admitting
    past capacity."""
    from repro.router.router import Session

    router, replicas = _fleet(True, kv_ship=None)
    # saturate replica 0 (the home)
    for i in range(replicas[0].capacity):
        filler = Session(sid=100 + i, prompt=(100 + i,), decode_len=2)
        router.submit(filler, home=0)
        router.dispatch_one()
    assert not replicas[0].has_capacity()
    s = Session(sid=0, prompt=(1, 2, 3), decode_len=2)
    router.submit(s, home=0)
    out = router.dispatch_one()
    assert out is not None
    assert not s.fast and s.replica == 1  # shed, not fast-dispatched
    assert router.stats.sheds == 1


def test_phase_conservation_survives_the_fissile_bypass():
    """The exact attribution identity — queue_wait + dispatch + ship_wait +
    prefill == admission_stall_total — holds on a fissile arm whose run
    mixes fast-path and inflated dispatches (and prices the pipeline skip
    via c_pipeline)."""
    from benchmarks.common import zipf_draws
    from repro.router.sim import FleetCostModel, shared_prefix_sessions, simulate

    draws = zipf_draws(120, n_items=6, skew=1.0, rng=random.Random(5))
    sessions = shared_prefix_sessions(draws, prefix_len=24, suffix_len=6, decode_len=6)
    # bursty arrivals: long idle gaps (fast path) + pileups (inflation)
    rng = random.Random(11)
    t, arrivals = 0, []
    for i in range(len(sessions)):
        t += rng.choice([0, 0, 1, 2, 90])
        arrivals.append(t)
    res = simulate(
        "federated", sessions, n_replicas=3, n_slots=2, cache_budget=500,
        cm=FleetCostModel(c_pipeline=6), arrivals=arrivals, seed=7,
        router_kwargs={"fissile": True},
    )
    assert 0 < res.fast_dispatches < res.n_sessions  # both modes exercised
    assert sum(res.phase_cycles.values()) == res.admission_stall_total


def test_fissile_sim_registered_in_lock_menagerie():
    from repro.core.locks_sim import ALL_LOCKS, FissileCNASim

    assert ALL_LOCKS["cna_fissile"] is FissileCNASim
