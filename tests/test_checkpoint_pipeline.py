"""Checkpoint manager (atomic/async/elastic) + data pipeline (deterministic,
resumable, shard-partitioned)."""

import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback
    from _hypothesis_compat import given, settings, st

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import BigramLMDataset, ShardedLoader, UniformLMDataset


def _tree(key):
    ks = jax.random.split(key, 3)
    return {
        "params": {"w": jax.random.normal(ks[0], (8, 4)), "b": jnp.zeros((4,), jnp.bfloat16)},
        "opt": {"m": {"w": jax.random.normal(ks[1], (8, 4)), "b": jnp.zeros((4,))}},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(jax.random.PRNGKey(0))
    mgr.save(7, tree, extra={"data_step": 7})
    assert mgr.latest_step() == 7
    restored, extra = mgr.restore(7, tree, extra=True)
    assert extra == {"data_step": 7}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_async_save_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(jax.random.PRNGKey(1))
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=False)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]  # keep=2 retention


def test_atomicity_tmp_dirs_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(jax.random.PRNGKey(2))
    mgr.save(5, tree)
    # simulate a crashed write
    os.makedirs(tmp_path / "step_00000009.tmp")
    (tmp_path / "step_00000009.tmp" / "000000.npy").write_bytes(b"garbage")
    assert mgr.latest_step() == 5
    # and a committed dir missing its manifest is also ignored
    os.makedirs(tmp_path / "step_00000010")
    assert mgr.latest_step() == 5


def test_missing_leaf_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.ones((2,))})
    with pytest.raises(KeyError):
        mgr.restore(1, {"a": jnp.ones((2,)), "b": jnp.ones((3,))})


# -- pipeline -----------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    ds = BigramLMDataset(vocab=64, seq_len=16, global_batch=4, seed=9)
    b1 = ds.batch(5)
    b2 = ds.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    loader = ShardedLoader(ds)
    for _ in range(3):
        next(loader)
    state = loader.state()
    b_next = next(loader)
    resumed = ShardedLoader.resume(ds, state)
    np.testing.assert_array_equal(next(resumed)["tokens"], b_next["tokens"])


@given(n_hosts=st.sampled_from([1, 2, 4, 8]), step=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_pipeline_host_partition_property(n_hosts, step):
    """Concatenating host slices reproduces the global batch exactly —
    elastic rescale sees the same global stream."""
    ds = UniformLMDataset(vocab=97, seq_len=8, global_batch=8, seed=3)
    full = ds.batch(step)["tokens"]
    parts = [ds.batch(step, host=h, n_hosts=n_hosts)["tokens"] for h in range(n_hosts)]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), full)


def test_bigram_labels_follow_table():
    ds = BigramLMDataset(vocab=32, seq_len=16, global_batch=2, seed=1, branching=4)
    b = ds.batch(0)
    # every (token, label) pair must be a valid bigram-table transition
    for row_t, row_l in zip(b["tokens"], b["labels"]):
        for t, l in zip(row_t[1:], row_l[:-1]):
            assert t == l  # labels are next-tokens
        for t, l in zip(row_t, row_l):
            assert l in ds.table[t]
