"""Shared harness for tests that spawn subprocesses with faked device meshes.

Subprocesses must not inherit hardcoded machine paths (the suite also runs on
CI runners), and must pin ``JAX_PLATFORMS=cpu`` — with libtpu installed but no
TPU attached, an unpinned jax spends minutes probing TPU metadata endpoints.
"""

from __future__ import annotations

import os
from pathlib import Path

REPO_ROOT = str(Path(__file__).resolve().parents[1])


def run_env() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    return env
