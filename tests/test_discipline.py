"""One discipline, three drivers: grant-order equivalence + core unit tests.

The refactor's contract is that ``CNALock`` (threaded), ``CNASim``
(discrete-event) and ``CNAAdmissionQueue`` (serving admission) are thin
drivers of ``repro.core.discipline`` — so on a shared arrival schedule and
RNG seed all three must produce *identical* grant orders, including the
shuffle-reduction fast path and the fairness-flush path under a tiny
threshold.  Each driver is driven single-threaded through the same script:
one holder plus N waiters enqueued upfront, then released one grant at a
time.
"""

import random

import pytest

from repro.core.cna import CNALock, CNANode
from repro.core.discipline import (
    CNADiscipline,
    DisciplineConfig,
    DisciplineStats,
    Park,
    RestrictedDiscipline,
    Scan,
    SecondaryFlush,
    Shuffle,
    Unpark,
    decide,
)
from repro.core.locks_sim import CNAOptSim, CNASim
from repro.core.numasim import Simulator
from repro.core.policy import CNAAdmissionQueue
from repro.core.topology import flat, get_topology, pod, table


# -- scripted drivers ---------------------------------------------------------


def drive_lock(domains, holder_domain, seed, threshold, shuffle, threshold2):
    """Single-threaded scripted drive of the threaded lock: waiters are linked
    in exactly as Fig. 3 would (SWAP + next-link), minus the parking."""
    cell = {"d": holder_domain}
    lock = CNALock(
        numa_node_of=lambda: cell["d"],
        threshold=threshold,
        shuffle_reduction=shuffle,
        threshold2=threshold2,
        seed=seed,
    )
    holder = CNANode()
    lock.acquire(holder)  # uncontended fast path
    nodes = []
    for d in domains:
        n = CNANode()
        n.next, n.spin, n.socket = None, 0, d
        tail = lock._swap_tail(n)
        tail.next = n
        nodes.append(n)
    index_of = {id(n): i for i, n in enumerate(nodes)}
    waiting = list(nodes)
    order = []
    cur = holder
    while True:
        lock.release(cur)
        nxt = next((n for n in waiting if n.spin != 0), None)
        if nxt is None:
            break
        order.append(index_of[id(nxt)])
        waiting.remove(nxt)
        cur = nxt
    assert lock.tail is None
    return order


def drive_sim(domains, holder_domain, seed, threshold, shuffle, threshold2):
    """Drive the simulator's lock object directly (no event loop): tid 0 is
    the holder, tids 1..N the schedule."""
    topo = table((holder_domain, *domains))
    sim = Simulator(
        CNAOptSim if shuffle else CNASim,
        n_threads=len(domains) + 1,
        topology=topo,
        seed=seed,
        lock_kwargs={"threshold": threshold, "threshold2": threshold2},
    )
    assert sim.lock.arrive(0) is not None  # uncontended: tid 0 holds
    for tid in range(1, len(domains) + 1):
        assert sim.lock.arrive(tid) is None
    order = []
    cur = 0
    while True:
        out = sim.lock.release(cur)
        if out is None:
            break
        cur = out[0]
        order.append(cur - 1)
    return order


def drive_queue(domains, holder_domain, seed, threshold, shuffle, threshold2, fissile=False):
    q = CNAAdmissionQueue(
        threshold=threshold, shuffle_reduction=shuffle, threshold2=threshold2,
        seed=seed, fissile=fissile,
    )
    for i, d in enumerate(domains):
        q.push(i, d)
    order = []
    dom = holder_domain
    while len(q):
        v, dom = q.pop(dom)
        order.append(v)
    return order


# -- the fissile fourth column: every driver wrapped in the fast path ----------


def drive_lock_fissile(domains, holder_domain, seed, threshold, shuffle, threshold2):
    """The scripted lock drive with ``fissile=True``: the holder's acquire
    takes the fast path (no tail SWAP), so the first scripted waiter's SWAP
    finds an empty queue and registers as the fast head the holder's release
    adopts.  Everything after that is the plain script."""
    cell = {"d": holder_domain}
    lock = CNALock(
        numa_node_of=lambda: cell["d"],
        threshold=threshold,
        shuffle_reduction=shuffle,
        threshold2=threshold2,
        seed=seed,
        fissile=True,
    )
    holder = CNANode()
    lock.acquire(holder)  # fissile fast path: tail stays None
    assert lock.stats.fast_acquires == 1
    nodes = []
    for d in domains:
        n = CNANode()
        n.next, n.spin, n.socket = None, 0, d
        tail = lock._swap_tail(n)
        if tail is None:
            assert not lock._try_fast_takeover(n)  # holder still holds
        else:
            tail.next = n
        nodes.append(n)
    index_of = {id(n): i for i, n in enumerate(nodes)}
    waiting = list(nodes)
    order = []
    cur = holder
    while True:
        lock.release(cur)
        nxt = next((n for n in waiting if n.spin != 0), None)
        if nxt is None:
            break
        order.append(index_of[id(nxt)])
        waiting.remove(nxt)
        cur = nxt
    assert lock.tail is None and not lock._fast_held
    assert lock.stats.inflations == 1  # saturation: one inflation, no deflation mid-run
    return order


def drive_sim_fissile(domains, holder_domain, seed, threshold, shuffle, threshold2):
    from repro.core.locks_sim import FissileCNASim

    class _FissileOptSim(FissileCNASim):
        name = "cna_fissile_opt"
        shuffle_reduction = True

    topo = table((holder_domain, *domains))
    sim = Simulator(
        _FissileOptSim if shuffle else FissileCNASim,
        n_threads=len(domains) + 1,
        topology=topo,
        seed=seed,
        lock_kwargs={"threshold": threshold, "threshold2": threshold2},
    )
    assert sim.lock.arrive(0) is not None  # uncontended: tid 0 holds
    for tid in range(1, len(domains) + 1):
        assert sim.lock.arrive(tid) is None
    order = []
    cur = 0
    while True:
        out = sim.lock.release(cur)
        if out is None:
            break
        cur = out[0]
        order.append(cur - 1)
    return order


def drive_router(domains, holder_domain, seed, threshold, shuffle, fissile):
    """ReplicaRouter as a grant-order driver: one ample-capacity replica per
    domain, homes pinned at submit (no federation routing), all sessions
    queued before any dispatch — saturation, the regime where the fissile
    wrapper must be bitwise-invisible."""
    from repro.router.router import ReplicaRouter, Session
    from repro.router.sim import SimReplica
    from repro.serving.scheduler import CNAScheduler

    n_dom = max([holder_domain, *domains]) + 1
    replicas = [
        SimReplica(r, len(domains) + 1, cache_budget=10_000) for r in range(n_dom)
    ]
    router = ReplicaRouter(
        replicas, fairness_threshold=threshold, seed=seed, sync_every=0,
        fissile=fissile,
    )
    # the router does not expose shuffle_reduction (deliberately — see
    # CNAAdmissionQueue's adaptation note); the contract drive swaps in an
    # identically-seeded scheduler carrying it so all five parameter columns
    # cover the same grid
    router.scheduler = CNAScheduler(
        fairness_threshold=threshold, shuffle_reduction=shuffle, seed=seed,
        topology=router.topology, fissile=fissile,
    )
    router.tracer = router.scheduler.tracer
    router.scheduler.current_domain = holder_domain
    sessions = [Session(sid=i, prompt=(i,), decode_len=1) for i in range(len(domains))]
    for s, d in zip(sessions, domains):
        router.submit(s, home=d)
    order = []
    while (out := router.dispatch_one()) is not None:
        order.append(out[0].sid)
    assert router.stats.sheds == 0  # ample capacity: pure discipline order
    return order


SCHEDULES = {
    "flat2_rr": [flat(2).domain_of(t) for t in range(12)],
    "flat4_rr": [flat(4).domain_of(t) for t in range(17)],
    "pod2x2": [pod(2, 2).domain_of(t) for t in range(15)],
    "pod2x2_block": [pod(2, 2, cores_per_socket=3).domain_of(t) for t in range(18)],
    "random3": [random.Random(9).randrange(3) for _ in range(25)],
    "burst": [0] * 6 + [2] * 5 + [1] * 4,
}


@pytest.mark.parametrize("sched", sorted(SCHEDULES))
@pytest.mark.parametrize(
    "threshold,shuffle,threshold2",
    [
        (0xFFFF, False, 0xFF),  # paper defaults: locality-dominant
        (0x1, False, 0xFF),     # tiny fairness threshold: constant flushes
        (0x0, False, 0xFF),     # keep_lock_local always false: FIFO+flush
        (0xF, True, 0x3),       # shuffle reduction with a leaky fast path
        (0xFFFF, True, 0xFF),   # shuffle reduction, fast path dominant
    ],
)
@pytest.mark.parametrize("seed", [7, 0xBEEF])
def test_three_drivers_identical_grant_order(sched, threshold, shuffle, threshold2, seed):
    domains = SCHEDULES[sched]
    holder = domains[0]
    args = (domains, holder, seed, threshold, shuffle, threshold2)
    lock_order = drive_lock(*args)
    sim_order = drive_sim(*args)
    queue_order = drive_queue(*args)
    assert lock_order == sim_order == queue_order
    assert sorted(lock_order) == list(range(len(domains)))  # nobody lost
    # the fissile fourth column: at saturation (every waiter queued before
    # the first grant) the fast-path wrapper is bitwise-invisible, so the
    # fissile-wrapped lock / sim / queue agree with plain CNA exactly
    assert drive_lock_fissile(*args) == lock_order
    assert drive_sim_fissile(*args) == lock_order
    assert drive_queue(*args, fissile=True) == lock_order


@pytest.mark.parametrize("sched", sorted(SCHEDULES))
@pytest.mark.parametrize("threshold,shuffle", [(0xFFFF, False), (0x1, False), (0xF, True)])
@pytest.mark.parametrize("seed", [7, 0xBEEF])
def test_router_driver_keeps_the_grant_order_contract(sched, threshold, shuffle, seed):
    """The fleet router as a further driver column: at saturation with ample
    capacity its dispatch order equals the bare admission queue's grant
    order — and the fissile router equals both (the fast path never fires
    while inflated waiters exist)."""
    domains = SCHEDULES[sched]
    holder = domains[0]
    queue_order = drive_queue(domains, holder, seed, threshold, shuffle, 0xFF)
    plain = drive_router(domains, holder, seed, threshold, shuffle, fissile=False)
    fissile = drive_router(domains, holder, seed, threshold, shuffle, fissile=True)
    assert plain == fissile == queue_order


def test_equivalence_holds_for_hierarchical_topology_mapping():
    """pod() placement produces different schedules than flat round-robin, and
    the equivalence still holds on them (the discipline only compares domains
    for equality; the hierarchy matters to cost charging, not ordering)."""
    topo = pod(2, 2, cores_per_socket=3)  # block placement, not round-robin
    domains = [topo.domain_of(t) for t in range(20)]
    assert domains != [flat(4).domain_of(t) for t in range(20)]
    args = (domains, domains[0], 3, 0xF, False, 0xFF)
    assert drive_lock(*args) == drive_sim(*args) == drive_queue(*args)


# -- pure core ----------------------------------------------------------------


def test_decide_promote_and_empty():
    rng = random.Random(0)
    cfg = DisciplineConfig()
    assert decide([], 0, 0, rng, cfg).kind == "none"
    d = decide([], 3, 0, rng, cfg)
    assert d.kind == "promote" and d.events == (SecondaryFlush(3),)


def test_decide_scan_moves_remote_prefix():
    rng = random.Random(0)
    cfg = DisciplineConfig(threshold=(1 << 29) - 1)  # keep_lock_local ~ always
    d = decide([1, 1, 0, 0], 0, 0, rng, cfg)
    assert d.kind == "scan" and d.index == 2
    assert d.events == (Scan(1, 2), Shuffle(2))


def test_decide_failed_scan_flushes_secondary():
    rng = random.Random(0)
    cfg = DisciplineConfig(threshold=(1 << 29) - 1)
    d = decide([1, 2], 2, 0, rng, cfg)
    assert d.kind == "flush"
    assert d.events == (Scan(0, 2), SecondaryFlush(2))


def test_discipline_events_fold_into_stats():
    core = CNADiscipline(threshold=(1 << 29) - 1, rng=random.Random(1))
    stats = DisciplineStats()
    for item, dom in [("a", 1), ("b", 1), ("c", 0), ("d", 1)]:
        stats.consume(None, core.arrive(item, dom))
    g = core.release(0)
    stats.consume(g)
    assert g.item == "c" and g.local and g.kind == "scan"
    assert stats.grants == 1 and stats.local_grants == 1
    assert stats.shuffles == 1 and stats.scanned == 3
    # the two skipped remote items sit in the secondary queue
    assert core.n_secondary == 2 and len(core) == 3


def test_restricted_caps_active_set_and_conserves_items():
    inner = CNADiscipline(threshold=0xF, rng=random.Random(2))
    r = RestrictedDiscipline(inner, max_active=4, rotate_after=8)
    for i in range(20):
        r.arrive(i, i % 3)
    assert len(inner) == 4 and r.n_passive == 16 and len(r) == 20
    granted = []
    dom = 0
    while True:
        g = r.release(dom)
        if g is None:
            break
        # the active set never exceeds the cap (+1 transiently via rotation
        # is re-absorbed before release returns)
        assert len(inner) <= r.max_active
        granted.append(g.item)
        dom = g.domain
    assert sorted(granted) == list(range(20))


def test_restricted_rotation_bounds_passive_wait():
    """A parked waiter re-enters the active set within bounded grants even
    when hot waiters recirculate lock-style (restriction must not starve;
    threshold=0 makes the inner discipline FIFO-with-flushes so the unparked
    item is then granted promptly too)."""
    inner = CNADiscipline(threshold=0, rng=random.Random(3))
    r = RestrictedDiscipline(inner, max_active=2, rotate_after=4)
    r.arrive("h1", 0)
    r.arrive("h2", 0)
    r.arrive("cold", 1)  # parked
    assert r.n_passive == 1
    seen = set()
    unparked = set()
    dom = 0
    for _ in range(6):
        g = r.release(dom)
        seen.add(g.item)
        unparked |= {e.item for e in g.events if isinstance(e, Unpark)}
        dom = g.domain
        r.arrive(g.item, 0 if g.item != "cold" else 1)  # lock-style recirculation
    assert "cold" in unparked
    assert "cold" in seen


def test_restricted_emits_park_unpark():
    r = RestrictedDiscipline(CNADiscipline(rng=random.Random(4)), max_active=1)
    assert r.arrive("a", 0) == ()
    evs = r.arrive("b", 1)
    assert evs == (Park("b", 1),)
    g = r.release(0)
    assert g.item == "a"
    assert any(isinstance(e, Unpark) and e.item == "b" for e in g.events)


# -- topology -----------------------------------------------------------------


def test_flat_topology_matches_seed_mapping():
    topo = flat(4)
    assert [topo.domain_of(t) for t in range(8)] == [t % 4 for t in range(8)]
    assert topo.distance(1, 1) == 0
    assert topo.distance(0, 3) == 1  # all sockets mutually remote, never 2


def test_pod_topology_distances_and_block_placement():
    topo = pod(2, 2, cores_per_socket=2)
    # 4 sockets in 2 pods; consecutive ids fill a socket before spilling
    assert [topo.domain_of(t) for t in range(8)] == [0, 0, 1, 1, 2, 2, 3, 3]
    assert topo.distance(0, 1) == 1  # same pod
    assert topo.distance(0, 2) == 2  # cross pod
    cm = __import__("repro.core.numasim", fromlist=["TWO_SOCKET"]).TWO_SOCKET
    assert topo.xfer_cycles(cm, 0, 0) == cm.c_local_xfer
    assert topo.xfer_cycles(cm, 0, 1) == cm.c_remote_xfer
    assert topo.xfer_cycles(cm, 0, 2) == cm.c_cross_xfer


def test_simulator_rejects_conflicting_n_sockets_and_topology():
    with pytest.raises(ValueError, match="n_sockets=4 conflicts"):
        Simulator(CNASim, n_threads=4, n_sockets=4, topology=pod(2, 4))
    # consistent redundancy is allowed
    Simulator(CNASim, n_threads=4, n_sockets=8, topology=pod(2, 4))


def test_get_topology_coercions():
    assert get_topology("two_socket").n_domains == 2
    assert get_topology(3).n_domains == 3
    t = table([0, 2, 1, 2])
    assert get_topology(t) is t
    assert [t.domain_of(i) for i in range(6)] == [0, 2, 1, 2, 0, 2]
    with pytest.raises(KeyError):
        get_topology("no_such_fabric")


def test_hierarchical_sim_charges_cross_pod_premium():
    """Under pod(2,2) the same thread count pays more for cross-pod handovers
    than under flat(4), and CNA keeps most handovers socket-local either way."""
    from repro.core.locks_sim import MCSSim
    from repro.core.numasim import run_sweep

    kw = dict(seed=11, duration_cycles=2_000_000, noncs_cycles=0)
    flat_r = run_sweep(MCSSim, [16], topology=flat(4), **kw)[0]
    pod_r = run_sweep(MCSSim, [16], topology=pod(2, 2), **kw)[0]
    assert pod_r.ops < flat_r.ops  # cross-pod transfers cost more
    cna_pod = run_sweep(CNASim, [16], topology=pod(2, 2), lock_kwargs={"threshold": 0xFF}, **kw)[0]
    assert cna_pod.ops > pod_r.ops  # locality pays off even more on a fabric


# -- tracing is a fourth observer, never a fourth driver ----------------------


def drive_scheduler(domains, holder_domain, seed, threshold, shuffle, tracer=None):
    """CNAScheduler as a grant-order driver (the serving wrapper over
    CNAAdmissionQueue), optionally observed by a repro.obs.Tracer."""
    from repro.serving.scheduler import CNAScheduler

    s = CNAScheduler(
        fairness_threshold=threshold, shuffle_reduction=shuffle, seed=seed,
        tracer=tracer,
    )
    s.current_domain = holder_domain
    for i, d in enumerate(domains):
        s.submit(i, d)
    order = []
    while len(s):
        order.append(s.next_request())
    return order


@pytest.mark.parametrize("sched", sorted(SCHEDULES))
@pytest.mark.parametrize("threshold,shuffle", [(0xFFFF, False), (0x1, False), (0xF, True)])
def test_traced_scheduler_keeps_the_grant_order_contract(sched, threshold, shuffle):
    """The cross-driver contract extended through the tracer: a CNAScheduler
    with a live Tracer attached admits in exactly the order the bare
    CNAAdmissionQueue grants (zero-cost-off means zero-effect-on, too), and
    every grant's queue_wait span carries the discipline events."""
    from repro.obs import Tracer

    domains = SCHEDULES[sched]
    holder = domains[0]
    seed = 7
    queue_order = drive_queue(domains, holder, seed, threshold, shuffle, 0xFF)
    untraced = drive_scheduler(domains, holder, seed, threshold, shuffle)
    tr = Tracer()
    traced = drive_scheduler(domains, holder, seed, threshold, shuffle, tracer=tr)
    assert untraced == traced == queue_order
    spans = [s for s in tr.spans if s.name == "queue_wait"]
    assert [s.trace for s in spans] == queue_order  # one span per grant, in order
    assert not tr.check()  # all closed
    assert all(s.attrs.get("kind") for s in spans)  # every grant labelled
    if not shuffle:  # the shuffle-reduction fast path grants without events
        assert any(s.events for s in spans)  # discipline events ride along
