"""CNA-EP benchmark: locality-biased routing vs the all-to-all wire budget.

The paper's trade-off (Fig. 6 throughput vs Fig. 8 fairness), restaged for
expert parallelism: sweep the router bias (main-queue preference strength)
and the remote-exchange provisioning r = C_rem / C_uniform, and measure

  * locality  — fraction of (token, expert) assignments served on-shard
                (no collective — the same-socket handover);
  * drop rate — remote assignments that miss the provisioned capacity
                (the cost of under-provisioning the secondary queue);
  * a2a bytes — the per-layer all-to-all payload (both directions).

The CNA claim: with the bias on, r can shrink ~4x at <2% drops; unbiased
routing at the same r drops ~40% of remote traffic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamBuilder
from repro.models.moe import declare_moe
from repro.models.moe_ep import ep_routing_stats

from .common import claim, table


def _cfg(**kw):
    base = dict(
        name="dsk", family="moe", n_layers=1, d_model=64, n_heads=4, n_kv=4,
        d_ff=96, vocab=128, n_experts=64, top_k=6, moe_d_ff=96,
        capacity_factor=1.25,
    )
    base.update(kw)
    return ModelConfig(**base)


def run_all(n_ep: int = 16, batch: int = 32, seq: int = 64):
    pb = ParamBuilder(dtype=jnp.float32)
    declare_moe(pb, "moe", _cfg())
    params = pb.init(jax.random.PRNGKey(0))["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, seq, 64), jnp.float32)

    rows = []
    results = {}
    for bias in (0.0, 1.0, 2.0):
        for r in (1.0, 0.5, 0.25):
            cfg = _cfg(cna_routing=bias > 0, cna_routing_bias=bias,
                       ep_remote_capacity_factor=r)
            s = ep_routing_stats(params, x, cfg, n_ep=n_ep)
            rows.append([bias, r, s["locality"], s["drop_rate"], s["a2a_bytes"] / 2**20])
            results[(bias, r)] = s
    table(
        f"CNA-EP routing (deepseek-like 64e top-6, {n_ep} shards)",
        ["bias", "remote_cap_r", "locality", "remote_drop_rate", "a2a_MiB_per_layer"],
        rows,
    )
    base = results[(0.0, 1.0)]
    cna = results[(2.0, 0.25)]
    claim("moe-ep: unbiased locality ~ 1/n_ep",
          base["locality"] < 2.5 / n_ep + 0.1, f"{base['locality']:.3f}")
    claim("moe-ep: CNA bias locality > 0.5",
          cna["locality"] > 0.5, f"{cna['locality']:.3f}")
    claim("moe-ep: CNA @ r=0.25 drops less than unbiased @ r=0.5 (4x less wire than r=1)",
          cna["drop_rate"] <= results[(0.0, 0.5)]["drop_rate"] + 1e-9,
          f"cna={cna['drop_rate']:.3f} unbiased={results[(0.0, 0.5)]['drop_rate']:.3f}")
    claim("moe-ep: a2a bytes scale with r (wire saved = 4x at r=0.25)",
          abs(cna["a2a_bytes"] / base["a2a_bytes"] - 0.25) < 0.1,
          f"ratio={cna['a2a_bytes'] / base['a2a_bytes']:.3f}")
    return results
