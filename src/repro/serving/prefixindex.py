"""Prefix-cache-aware request homes: a radix index over token prompts.

The serving mapping (scheduler.py header) defines a request's locality domain
as "the pod holding its prefix/KV-cache home" — but production traffic does
not arrive with that label.  This module derives it the way RadixAttention
derives prefix reuse: a radix tree over token sequences records, per cached
prefix, which domains' slot pools last held it, and answers

    home(prompt) -> (domain, matched_len)

by longest-prefix match.  When several domains hold the same longest prefix,
the tie breaks toward the least-occupied one (live per-domain claims from
``PlacementTelemetry.per_domain_occupancy``), so a hot prefix replicated
across pods drains onto the pod with headroom.  A prompt matching nothing
falls back to the least-occupied domain outright — the cold-start rule.

The index is *descriptive*, not prescriptive: it is fed from actual
placements (``DecodeEngine`` records where the slot cache really put each
sequence, at admission and again at retirement), so hot prefixes re-home to
wherever placement spilled them instead of pinning to a stale oracle.  The
``matched_len`` half of the answer is the engine's migration discount: only
the uncached suffix of the KV moves when a slot lands off-home.

Structure: a path-compressed radix tree (token runs live on edges, one split
per divergence point — the sglang/RadixAttention shape), with monotonic
stamps for recency and a capacity bound enforced by pruning the
least-recently-touched leaves.  Pure python, no jax — the smoke benchmark
lane exercises build/lookup/re-home without an accelerator.
"""

from __future__ import annotations

import heapq


def _common_len(edge, tokens, start: int) -> int:
    """Length of the common run between ``edge`` and ``tokens[start:]``."""
    n = min(len(edge), len(tokens) - start)
    k = 0
    while k < n and edge[k] == tokens[start + k]:
        k += 1
    return k


class _Node:
    """One radix node: the token run on its incoming edge, children keyed by
    their edge's first token, and the domains whose pools last held the
    prefix this node spells (domain -> last-touch stamp)."""

    __slots__ = ("edge", "children", "domains", "stamp")

    def __init__(self, edge=()):
        self.edge = tuple(edge)
        self.children: dict[int, _Node] = {}
        self.domains: dict[int, int] = {}
        self.stamp = 0


class PrefixIndex:
    """Radix index mapping token prefixes to their KV-cache home domains.

    ``n_domains`` bounds valid domains and enables the cold-start fallback;
    ``occupancy`` is a zero-arg callable returning a live ``{domain: claims}``
    map (wire it to ``PlacementTelemetry.per_domain_occupancy``); ``capacity``
    caps the node count — LRU leaves are pruned when inserts exceed it.
    """

    def __init__(self, *, n_domains: int | None = None, occupancy=None,
                 capacity: int = 1 << 16):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.n_domains = n_domains
        self.occupancy = occupancy
        self.capacity = capacity
        self.root = _Node()
        self.n_nodes = 0          # excludes the root
        self.records = 0
        self.lookups = 0
        self.hits = 0             # lookups that matched >= 1 token
        self._stamp = 0

    def __len__(self) -> int:
        return self.n_nodes

    @staticmethod
    def _key(tokens) -> tuple[int, ...]:
        return tuple(int(t) for t in tokens)

    def _check_domain(self, domain: int) -> None:
        limit = self.n_domains
        if domain is None or domain < 0 or (limit is not None and domain >= limit):
            raise ValueError(
                f"domain {domain!r} out of range for prefix index "
                f"({'unbounded' if limit is None else f'{limit} domains'})"
            )

    # -- write path ------------------------------------------------------------
    def record(self, tokens, domain: int) -> None:
        """Record that ``domain``'s slot pool now holds (a KV cache covering)
        ``tokens``; every prefix of the sequence is held along with it."""
        self._check_domain(domain)
        tokens = self._key(tokens)
        if not tokens:
            return
        self.records += 1
        self._stamp += 1
        stamp = self._stamp
        node, i = self.root, 0
        while i < len(tokens):
            head = tokens[i]
            child = node.children.get(head)
            if child is None:
                child = _Node(tokens[i:])
                node.children[head] = child
                self.n_nodes += 1
            else:
                k = _common_len(child.edge, tokens, i)
                if k < len(child.edge):
                    # diverged (or ran out) mid-edge: split so the shared run
                    # gets its own node, which inherits the deep side's
                    # holders — a holder of a sequence holds all its prefixes
                    mid = _Node(child.edge[:k])
                    mid.children[child.edge[k]] = child
                    mid.domains = dict(child.domains)
                    mid.stamp = child.stamp
                    child.edge = child.edge[k:]
                    node.children[head] = mid
                    self.n_nodes += 1
                    child = mid
            # the child's edge is now fully consumed (new leaf, full match,
            # or the freshly split shared run), so the path node it spells is
            # a prefix of ``tokens`` — tag it as held by ``domain``
            i += len(child.edge)
            child.domains[domain] = stamp
            child.stamp = stamp
            node = child
        if self.n_nodes > self.capacity:
            self._evict()

    # -- read path -------------------------------------------------------------
    def home(self, tokens) -> tuple[int | None, int]:
        """Longest-prefix match: the domain whose pool holds the longest
        cached prefix of ``tokens`` (ties -> least occupied), plus the number
        of matched tokens.  (fallback domain, 0) on a total miss — the least
        occupied domain when ``n_domains`` is known, else ``None``."""
        tokens = self._key(tokens)
        self.lookups += 1
        node, i = self.root, 0
        best, best_len = None, 0
        path = []
        while i < len(tokens):
            child = node.children.get(tokens[i])
            if child is None:
                break
            k = _common_len(child.edge, tokens, i)
            if k == 0:
                break
            path.append(child)
            if child.domains:
                # a partial edge match still matches: the node's sequence
                # extends the query's matched prefix, so its holders hold it
                best, best_len = child, i + k
            i += k
            if k < len(child.edge):
                break
            node = child
        if best is None:
            return self._fallback(), 0
        self.hits += 1
        self._stamp += 1
        for n in path:  # touch the matched path so hot prefixes survive LRU
            n.stamp = self._stamp
        occ = self.occupancy() if self.occupancy is not None else {}
        domain = min(
            best.domains.items(),
            key=lambda kv: (occ.get(kv[0], 0), -kv[1], kv[0]),
        )[0]
        return domain, min(best_len, len(tokens))

    def holders(self, tokens) -> dict[int, int]:
        """Every domain holding a cached prefix of ``tokens``, with the
        longest held length: ``{domain: matched_len}`` (lengths in tokens,
        domains absent when they hold nothing).  This is the per-holder view
        behind ``home()``'s single answer — the federation reads it to price
        *shipping* a remote holding against re-prefilling (a summary already
        advertises full token runs, so the shippable length per replica is
        exactly the matched run here).  Read-only: no stamps touched, no
        lookup counted — pricing probes must not look like traffic."""
        tokens = self._key(tokens)
        out: dict[int, int] = {}
        node, i = self.root, 0
        while i < len(tokens):
            child = node.children.get(tokens[i])
            if child is None:
                break
            k = _common_len(child.edge, tokens, i)
            if k == 0:
                break
            # as in home(): a partial edge match still matches — the node's
            # sequence extends the query's prefix, so its holders hold it
            for d in child.domains:
                if i + k > out.get(d, 0):
                    out[d] = i + k
            i += k
            if k < len(child.edge):
                break
            node = child
        return out

    def _fallback(self) -> int | None:
        if self.n_domains is None:
            return None
        occ = self.occupancy() if self.occupancy is not None else {}
        return min(range(self.n_domains), key=lambda d: (occ.get(d, 0), d))

    # -- federation export -----------------------------------------------------
    def summary(self, top_k: int = 8) -> list[tuple[tuple[int, ...], int]]:
        """The ``top_k`` hottest cached prefixes as ``(tokens, stamp)`` pairs,
        hottest first — the compact state a fleet/router tier aggregates
        (``repro.router.federation``).  Hotness is last-touch recency; among
        nodes of equal stamp the deeper path wins and subsumed prefixes (a
        path that is a prefix of an already-chosen one) are skipped, so the K
        slots carry K distinct maximal runs rather than one run K times."""
        scored = []
        stack = [(self.root, ())]
        while stack:
            node, path = stack.pop()
            for child in node.children.values():
                cpath = path + child.edge
                scored.append((-child.stamp, -len(cpath), cpath))
                stack.append((child, cpath))
        scored.sort()
        out: list[tuple[tuple[int, ...], int]] = []
        for neg_stamp, _, cpath in scored:
            if any(chosen[: len(cpath)] == cpath for chosen, _ in out):
                continue  # subsumed: a deeper, at-least-as-hot path is in
            ext = next(
                (i for i, (chosen, _) in enumerate(out)
                 if cpath[: len(chosen)] == chosen),
                None,
            )
            if ext is not None:
                # a colder extension of a chosen run: deepen that entry in
                # place (recording the extension covers every prefix of it)
                # rather than spending a second slot on the same run
                out[ext] = (cpath, out[ext][1])
            elif len(out) < top_k:
                out.append((cpath, -neg_stamp))
        return out

    # -- capacity --------------------------------------------------------------
    def _evict(self) -> None:
        """Prune least-recently-touched leaves until 3/4 of capacity.  Rounds
        repeat because pruning exposes new leaves; interior nodes left with a
        single child are not re-merged (the next split is cheap and rare)."""
        target = max(1, self.capacity * 3 // 4)
        while self.n_nodes > target:
            leaves = []
            stack = [self.root]
            while stack:
                node = stack.pop()
                for head, child in node.children.items():
                    if child.children:
                        stack.append(child)
                    else:
                        leaves.append((child.stamp, head, node))
            if not leaves:
                break
            for _, head, parent in heapq.nsmallest(
                self.n_nodes - target, leaves
            ):
                del parent.children[head]
                self.n_nodes -= 1

    def clear(self) -> None:
        self.root = _Node()
        self.n_nodes = 0
