"""GCR-style adaptive concurrency controller.

"Avoiding Scalability Collapse by Restricting Concurrency" (Dice & Kogan
2019) does not pick the active-set size offline: it watches the lock's own
handover latency and shrinks the active set when handovers start eating
scheduling quanta (the grantee was descheduled), growing it back when they
run at cache-transfer speed.  ``AdaptiveController`` is that feedback loop as
a standalone object so *one implementation* drives both

  * the lock simulator (``repro.core.locks_sim.AdaptiveRCNASim`` — samples
    are handover cycles incl. any preemption penalty), and
  * the serving scheduler (``CNAScheduler(max_active=controller)`` — samples
    are admission-stall ticks: domain-switch + slot-migration cost).

``RestrictedDiscipline`` reads ``controller.cap`` as its live ``max_active``;
drivers feed ``controller.observe(latency)`` after every handover.

Mechanism (deterministic, no wall clock):

  * ``floor`` tracks the cheapest *positive* handover seen, with a slow
    multiplicative relaxation so a one-off lucky sample cannot pin it
    forever — this is the "uncontested handover" baseline, and makes the
    controller scale-free (cycles in the simulator, ticks in the scheduler:
    same code).  Zero-latency samples (a home-domain admission with no
    switch) are trivially cheap: they never count as stalls and never touch
    the floor — a zero floor would otherwise classify *every* positive
    sample as a stall and ratchet the cap to ``min_active``.
  * a handover is a *stall* when it exceeds ``stall_factor * floor +
    deadband`` — in the simulator a preemption adds ``c_preempt`` (~500x a
    local transfer), so the classifier has a wide margin.  ``ewma`` smooths
    the raw latencies (gain ``alpha``) and gates *growth*: a stall-free
    window only raises the cap while the smoothed latency itself sits below
    the stall threshold, so the cap does not creep up while a collapse
    episode is still draining out of the average.
  * every ``window`` samples: shrink the cap by one when stalls exceeded
    ``tolerance``, grow it by one when the window was stall-free.  One slot
    per window is GCR's gentle ramp; it converges from either side and then
    oscillates within one slot of the boundary.  A *majority*-stalled window
    means outright collapse (deep oversubscription: nearly every grantee was
    descheduled), and waiting for -1 steps would take longer than the run —
    the cap shrinks multiplicatively (``collapse_factor``) instead, the AIMD
    shape: gentle probing near the boundary, decisive retreat far above it.

The cap trajectory (one entry per window decision) is recorded for
telemetry, benchmarks, and the cross-driver equivalence test.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class AdaptiveController:
    """Adaptive ``max_active`` from observed handover latencies.

    Scale-free by design: samples are whatever unit the driver charges in
    (cycles in the lock simulator, scheduler ticks in the serving engine,
    router ticks in the fleet controller) — only *ratios* against the
    tracked floor matter.  The shrink decision is **windowed stall counts**
    (``window`` samples, ``tolerance`` forgiven); the EWMA does not shrink
    anything — it only *gates growth*, so a stall-free window cannot raise
    the cap while a collapse episode still dominates the smoothed average.
    ``cap`` is a count of concurrently active waiters/admissions."""

    initial: int = 8
    min_active: int = 1
    max_cap: int = 1 << 30
    # EWMA gain for the smoothed-latency growth gate; the shrink decision is
    # windowed stall counts so one outlier cannot flap the cap.
    alpha: float = 1 / 16
    window: int = 32
    stall_factor: float = 8.0
    deadband: float = 0.0
    tolerance: int = 1          # stalls per window forgiven before shrinking
    collapse_factor: float = 0.75  # multiplicative shrink on majority-stalled windows
    floor_relax: float = 1.001  # per-sample upward drift of the floor
    # -- controller-coupled shedding (ROADMAP "controller-coupled placement"):
    # when wired with a live per-domain occupancy view, per-domain slot
    # capacities, and a topology, ``shed_home`` re-homes an admission whose
    # home domain is saturated onto the least-occupied same-group sibling
    # with headroom — load sheds sideways *before* the placement policy's
    # nearest_spill is forced to go cross-group.  All three default to None
    # (shedding off); ``DecodeEngine`` auto-wires them when it runs both a
    # placement-aware slot cache and an adaptive controller.
    occupancy: "object | None" = None      # zero-arg callable -> {domain: claims}
    domain_capacity: "tuple | None" = None  # slots homed per domain
    shed_topology: "object | None" = None   # repro.core.topology.Topology

    cap: int = field(init=False)
    samples: int = field(init=False, default=0)
    stalls: int = field(init=False, default=0)
    ewma: float = field(init=False, default=0.0)
    floor: float = field(init=False, default=0.0)  # 0 = no positive baseline yet
    trajectory: list = field(init=False, default_factory=list)
    _window_stalls: int = field(init=False, default=0)

    def __post_init__(self) -> None:
        if self.min_active < 1:
            raise ValueError("min_active must be >= 1")
        if not self.min_active <= self.initial <= self.max_cap:
            raise ValueError("need min_active <= initial <= max_cap")
        self.cap = self.initial

    def is_stall(self, latency: float) -> bool:
        # no positive baseline yet, or a zero-latency sample: trivially cheap
        if self.floor <= 0.0 or latency <= 0.0:
            return False
        return latency > self.stall_factor * self.floor + self.deadband

    def observe(self, latency: float) -> int:
        """Feed one handover latency sample; returns the (possibly updated)
        cap so call sites can use it inline."""
        if self.samples == 0:
            self.ewma = float(latency)
        else:
            self.ewma += self.alpha * (latency - self.ewma)
        if latency > 0.0:
            if self.floor <= 0.0:
                self.floor = float(latency)
            else:
                self.floor = min(self.floor * self.floor_relax, float(latency))
        self.samples += 1
        if self.is_stall(latency):
            self.stalls += 1
            self._window_stalls += 1
        if self.samples % self.window == 0:
            if 2 * self._window_stalls > self.window:
                self.cap = max(self.min_active, min(self.cap - 1, int(self.cap * self.collapse_factor)))
            elif self._window_stalls > self.tolerance:
                self.cap = max(self.min_active, self.cap - 1)
            elif self._window_stalls == 0 and not self.is_stall(self.ewma):
                self.cap = min(self.max_cap, self.cap + 1)
            self.trajectory.append(self.cap)
            self._window_stalls = 0
        return self.cap

    # -- controller-coupled shedding ------------------------------------------
    def shed_home(self, home: int) -> int:
        """Where a new admission homed at ``home`` should actually go: ``home``
        while it has free capacity, else the least-occupied *same-group*
        sibling with headroom (ties toward the lower domain index).  When the
        whole group is saturated the home is returned unchanged — cross-group
        traffic is the spill policy's decision, priced as a migration, not a
        silent re-home.  No-op (returns ``home``) until occupancy, capacities,
        and a topology are wired."""
        topo = self.shed_topology
        if self.occupancy is None or self.domain_capacity is None or topo is None:
            return home
        occ = self.occupancy()
        if occ.get(home, 0) < self.domain_capacity[home]:
            return home
        siblings = [
            d
            for d in range(topo.n_domains)
            if topo.distance(home, d) == 1 and occ.get(d, 0) < self.domain_capacity[d]
        ]
        if not siblings:
            return home
        return min(siblings, key=lambda d: (occ.get(d, 0), d))

    @property
    def stall_rate(self) -> float:
        return self.stalls / max(1, self.samples)

    def settled_cap(self, tail: float = 0.25) -> int:
        """Median cap over the last ``tail`` fraction of window decisions —
        the "converged" value benchmarks compare to the best static cap."""
        if not self.trajectory:
            return self.cap
        n = max(1, int(len(self.trajectory) * tail))
        last = sorted(self.trajectory[-n:])
        return last[len(last) // 2]
