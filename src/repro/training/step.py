"""Train-step assembly: grad accumulation, AdamW, logical-axis shardings.

The train step is one jit-able function ``(state, batch) -> (state, metrics)``:

  * gradient accumulation over ``cfg.accum`` microbatches via ``lax.scan``
    (compiles once; accumulator dtype configurable — fp32 default, bf16 for
    the 340B config where a second fp32 param-sized tree does not fit);
  * gradients arrive *sharded like the parameters* (fsdp x model): GSPMD
    turns the batch-axis reduction into reduce-scatters against the FSDP
    sharding — the hierarchical "intra-pod first" schedule the CNA adaptation
    wants falls out of the sharding rules;
  * AdamW with decoupled weight decay, global-norm clipping, warmup-cosine.

``state_abstract``/``state_logical`` give ShapeDtypeStruct + logical-axis
trees for the dry-run and the checkpoint manager.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.sharding import shard, spec_for, current_ctx
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine

TrainState = dict  # {"params": ..., "opt": {"m","v"}, "step": int32}


def init_state(model, key, cfg) -> TrainState:
    params = model.init(key)
    opt_dt = jnp.bfloat16 if cfg.opt_state_dtype == "bfloat16" else jnp.float32
    return {
        "params": params,
        "opt": adamw_init(params, opt_dt),
        "step": jnp.zeros((), jnp.int32),
    }


def state_abstract(model, cfg) -> TrainState:
    params = model.abstract_params()
    opt_dt = jnp.bfloat16 if cfg.opt_state_dtype == "bfloat16" else jnp.float32
    mom = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, opt_dt), params)
    return {
        "params": params,
        "opt": {"m": mom, "v": mom},
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def state_logical(model) -> TrainState:
    log = model.logical_tree()
    return {"params": log, "opt": {"m": log, "v": log}, "step": ()}


def _shard_batch_leaf(x, extra_lead: int = 0):
    axes = [None] * extra_lead + ["batch"] + [None] * (x.ndim - 1 - extra_lead)
    return shard(x, *axes)


def make_train_step(
    model,
    cfg,
    *,
    lr_fn: Callable[[jax.Array], jax.Array] | None = None,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
    b2: float = 0.95,
) -> Callable[[TrainState, Any], tuple[TrainState, dict]]:
    accum = max(1, cfg.accum)
    acc_dt = jnp.bfloat16 if cfg.opt_state_dtype == "bfloat16" else jnp.float32
    if lr_fn is None:
        lr_fn = lambda s: warmup_cosine(s, peak_lr=3e-4, warmup=100, total=10_000)
    logical = model.logical_tree()

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def _constrain_grads(grads):
        """Pin each grad leaf to its parameter's sharding so the partitioner
        reduces batch-partial grads with reduce-scatter into the FSDP layout
        instead of all-reduce + slice (nemotron train_4k: the dominant
        collective; EXPERIMENTS.md §Perf)."""
        return jax.tree.map(
            lambda g, l: shard(g, *l),
            grads,
            logical,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(i, (str, type(None))) for i in x),
        )

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        params = state["params"]
        batch = jax.tree.map(_shard_batch_leaf, batch)

        if accum > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]), batch
            )
            mbs = jax.tree.map(lambda x: _shard_batch_leaf(x, 1), mbs)

            def micro(carry, mb):
                g_acc, loss_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                g = _constrain_grads(g)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, loss_acc + loss), None

            # the accumulator init must carry the FSDP sharding explicitly:
            # an unconstrained zeros() accumulator was resolved *replicated*
            # by the partitioner (a 51.5 GiB loop carry on nemotron-340b)
            g0 = _constrain_grads(jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params))
            (grads, loss_sum), _ = jax.lax.scan(micro, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = _constrain_grads(grads)

        lr = lr_fn(state["step"])
        new_params, new_opt, om = adamw_update(
            params, grads, state["opt"], state["step"],
            lr=lr, weight_decay=weight_decay, clip_norm=clip_norm, b2=b2,
        )
        metrics = {"loss": loss, "lr": lr, **om}
        return {"params": new_params, "opt": new_opt, "step": state["step"] + 1}, metrics

    return train_step


def tree_shardings(abstract_tree, logical_tree):
    """NamedSharding tree under the active mesh context (None without one)."""
    ctx = current_ctx()
    if ctx is None:
        return None
    from jax.sharding import NamedSharding

    def leaf(a, l):
        return NamedSharding(ctx.mesh, spec_for(a.shape, tuple(l)))

    return jax.tree.map(
        leaf, abstract_tree, logical_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(i, (str, type(None))) for i in x),
    )
