"""Training loop: loss decreases, grad-accum equivalence, schedules, AdamW."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_reduced_config
from repro.data.pipeline import BigramLMDataset
from repro.models.registry import build_model
from repro.optim.adamw import adamw_init, adamw_update, global_norm
from repro.optim.schedule import warmup_cosine
from repro.training.step import init_state, make_train_step


def test_loss_decreases_on_bigram_data():
    cfg = get_reduced_config("granite_3_8b").replace(accum=1, vocab=64)
    model = build_model(cfg)
    ds = BigramLMDataset(cfg.vocab, seq_len=32, global_batch=16, seed=0, branching=4)
    step_fn = jax.jit(make_train_step(model, cfg, lr_fn=lambda s: 1e-2, weight_decay=0.0))
    state = init_state(model, jax.random.PRNGKey(0), cfg)
    losses = []
    for i in range(60):
        state, m = step_fn(state, ds.batch(i))
        losses.append(float(m["loss"]))
    # learns most of the bigram structure: from ~ln(64) toward ln(branching)
    assert losses[-1] < losses[0] - 1.5, (losses[:3], losses[-3:])
    assert losses[-1] < ds.entropy_floor + 1.2
    assert np.isfinite(losses).all()


def test_grad_accum_equivalence():
    """accum=2 over a 2x batch == accum=1: same loss metric, ~same update."""
    cfg1 = get_reduced_config("stablelm_3b").replace(accum=1, dtype="float32")
    cfg2 = cfg1.replace(accum=2)
    model = build_model(cfg1)
    state = init_state(model, jax.random.PRNGKey(1), cfg1)
    ds = BigramLMDataset(cfg1.vocab, seq_len=16, global_batch=4, seed=3)
    batch = ds.batch(0)
    s1, m1 = jax.jit(make_train_step(model, cfg1, lr_fn=lambda s: 1e-3))(state, batch)
    s2, m2 = jax.jit(make_train_step(model, cfg2, lr_fn=lambda s: 1e-3))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    d1 = jax.tree.leaves(s1["params"])
    d2 = jax.tree.leaves(s2["params"])
    for a, b in zip(d1, d2):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-5, rtol=2e-3
        )


def test_adamw_clip_and_decay():
    params = {"w": jnp.ones((4, 4)) * 2.0}
    grads = {"w": jnp.full((4, 4), 100.0)}  # huge -> clipped
    opt = adamw_init(params)
    p2, opt2, m = adamw_update(params, grads, opt, jnp.zeros((), jnp.int32),
                               lr=0.1, clip_norm=1.0, weight_decay=0.1)
    assert float(m["grad_norm"]) == pytest.approx(400.0)
    assert float(m["clip_scale"]) == pytest.approx(1 / 400.0, rel=1e-5)
    assert jnp.all(p2["w"] < params["w"])  # moved against grad + decay
    # moments updated
    assert float(jnp.abs(opt2["m"]["w"]).sum()) > 0


def test_adamw_bf16_moments_close_to_fp32():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (32, 32))}
    grads = {"w": jax.random.normal(jax.random.fold_in(key, 1), (32, 32)) * 0.1}
    o32 = adamw_init(params, jnp.float32)
    o16 = adamw_init(params, jnp.bfloat16)
    p32, _, _ = adamw_update(params, grads, o32, jnp.zeros((), jnp.int32), lr=1e-2)
    p16, _, _ = adamw_update(params, grads, o16, jnp.zeros((), jnp.int32), lr=1e-2)
    np.testing.assert_allclose(p32["w"], p16["w"], atol=1e-3, rtol=1e-2)


def test_warmup_cosine_shape():
    lr = [float(warmup_cosine(s, peak_lr=1.0, warmup=10, total=100)) for s in range(100)]
    assert lr[0] == 0.0
    assert lr[10] == pytest.approx(1.0, abs=0.01)
    assert lr[99] < 0.2  # decayed toward the floor
    assert all(a <= b + 1e-6 for a, b in zip(lr[:10], lr[1:11]))  # warmup monotone


def test_global_norm():
    t = {"a": jnp.ones((3,)) * 3.0, "b": jnp.ones((4,)) * 2.0}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(9 * 3 + 4 * 4))
