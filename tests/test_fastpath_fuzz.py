"""Differential fuzz lane: plain vs fissile arms on seed-swept schedules.

Each seed generates a random fleet (replica count, slots, session mix,
shared-prefix pool) and a random interleaving of dispatch / clock-advance /
completion ops.  Both arms replay the identical schedule; at saturation
(every session submitted before the first dispatch) the fissile wrapper must
be *bitwise* identical to plain CNA — same grant order, same stall totals,
same tracer span tree — because the fast path never fires while inflated
waiters exist and an inflated core delegates verbatim (same RNG stream).

The tracer-off bitwise guarantee is extended to the fast path here too: a
fissile run at low occupancy (fast path firing on most dispatches) produces
the same dispatches, stalls and counters with a live tracer as with none.
"""

import random

import pytest

from repro.obs import Tracer
from repro.router.router import ReplicaRouter, Session
from repro.router.sim import SimReplica


def _make_sessions(rng: random.Random, n: int, n_prefixes: int) -> list[Session]:
    out = []
    for i in range(n):
        pid = rng.randrange(n_prefixes)
        plen = rng.randint(8, 24)
        slen = rng.randint(2, 6)
        prompt = tuple(1_000 * pid + j for j in range(plen)) + tuple(
            900_000 + i * 8 + j for j in range(slen)
        )
        out.append(Session(sid=i, prompt=prompt, decode_len=rng.randint(1, 6)))
    return out


def _run_arm(seed: int, *, fissile: bool, tracer=None, saturated: bool = True):
    """One fuzz run: returns (dispatch order, stalls, sheds, fast_dispatches,
    tracer).  All randomness comes from ``seed`` so paired arms replay the
    identical schedule and op interleaving."""
    rng = random.Random(seed)
    n_replicas = rng.randint(2, 4)
    n_slots = rng.randint(2, 3)
    n_sessions = rng.randint(14, 26)
    sessions = _make_sessions(rng, n_sessions, n_prefixes=rng.randint(2, 4))
    replicas = [SimReplica(r, n_slots, cache_budget=2_000) for r in range(n_replicas)]
    router = ReplicaRouter(
        replicas, seed=seed, sync_every=8, fissile=fissile, tracer=tracer
    )
    order: list[int] = []
    stalls: list[int] = []
    inflight: list[Session] = []

    def dispatch():
        out = router.dispatch_one()
        if out is None:
            return False
        session, _target, _dist = out
        order.append(session.sid)
        stalls.append(session.stall)
        inflight.append(session)
        return True

    pending = list(sessions)
    if saturated:
        for s in pending:
            router.submit(s)
        pending = []
    # random op interleaving; op choices depend only on (rng, queue sizes,
    # inflight count), which evolve identically across paired arms.  The
    # unsaturated flavour is dispatch-heavy so the queue keeps draining to
    # empty and arrivals land uncontended (low occupancy).
    p_submit, p_dispatch = (0.35, 0.65) if saturated else (0.22, 0.72)
    while pending or len(router) or inflight:
        op = rng.random()
        if pending and op < p_submit:
            router.submit(pending.pop(0))
        elif op < p_dispatch:
            if not dispatch() and not pending and inflight:
                # pipe blocked on capacity: retire someone
                s = inflight.pop(rng.randrange(len(inflight)))
                replicas[s.replica].finish(s)
                router.complete(s, ttft=rng.randint(1, 9))
        elif inflight and op < 0.88:
            s = inflight.pop(rng.randrange(len(inflight)))
            replicas[s.replica].finish(s)
            router.complete(s, ttft=rng.randint(1, 9))
        else:
            for _ in range(rng.randint(1, 5)):
                router.tick()
    return order, stalls, router.stats.sheds, router.stats.fast_dispatches, router


_TRANSITIONS = ("inflate", "deflate")


def _span_tree(tracer: Tracer) -> tuple[list[dict], list[str]]:
    """Canonical span-tree view in emission order (span_ids are assigned
    sequentially, so equal lists mean equal trees).  Mode-transition markers
    (inflate/deflate) are the fissile arm's one legitimate trace footprint;
    they are split out so the caller can assert they are the *only* delta."""
    tree, markers = [], []
    for sp in tracer.spans:
        d = sp.to_dict()
        kept = [ev for ev in d["events"] if ev["name"] not in _TRANSITIONS]
        markers.extend(ev["name"] for ev in d["events"] if ev["name"] in _TRANSITIONS)
        d["events"] = kept
        tree.append(d)
    return tree, markers


@pytest.mark.parametrize("seed", range(12))
def test_fuzz_saturated_fissile_is_bitwise_plain(seed):
    p_order, p_stalls, p_sheds, p_fast, _ = _run_arm(seed, fissile=False)
    f_order, f_stalls, f_sheds, f_fast, _ = _run_arm(seed, fissile=True)
    assert f_fast == 0  # saturation: the fast path never fired
    assert f_order == p_order
    assert f_stalls == p_stalls
    assert f_sheds == p_sheds
    assert sorted(f_order) == list(range(len(f_order)))  # nobody lost


@pytest.mark.parametrize("seed", [3, 7, 11])
def test_fuzz_saturated_span_trees_match(seed):
    tp, tf = Tracer(), Tracer()
    _run_arm(seed, fissile=False, tracer=tp)
    _run_arm(seed, fissile=True, tracer=tf)
    p_tree, p_markers = _span_tree(tp)
    f_tree, f_markers = _span_tree(tf)
    assert f_tree == p_tree
    assert p_markers == []
    # at saturation the core inflates at submit time (before any span is
    # open) and deflates on the emptying grant — so the single deflate
    # marker is the only trace delta the fissile arm may leave
    assert f_markers == ["deflate"]
    assert not tf.check()  # every span closed


@pytest.mark.parametrize("seed", range(8))
def test_fuzz_tracer_off_bitwise_extends_to_the_fast_path(seed):
    """Low occupancy — sessions trickle in, so most dispatches ride the
    fast path — and a live tracer changes nothing the run can observe."""
    off = _run_arm(seed, fissile=True, saturated=False)
    tr = Tracer()
    on = _run_arm(seed, fissile=True, tracer=tr, saturated=False)
    assert on[0] == off[0]    # dispatch order
    assert on[1] == off[1]    # stalls
    assert on[2] == off[2]    # sheds
    assert on[3] == off[3]    # fast dispatches
    assert on[3] > 0          # the fast path actually fired
    # the traced run recorded the fast dispatches it bypassed nothing for
    fast_spans = [
        sp for sp in tr.spans if sp.name == "dispatch" and sp.attrs.get("fast")
    ]
    assert len(fast_spans) == on[3]


@pytest.mark.parametrize("seed", [0, 5])
def test_fuzz_unsaturated_fissile_conserves_sessions(seed):
    """Off saturation the arms may legitimately diverge (that is the win);
    what must still hold: every session dispatches exactly once and the
    wrapper's transitions pair up."""
    order, _stalls, _sheds, fast, router = _run_arm(seed, fissile=True, saturated=False)
    assert sorted(order) == list(range(len(order)))
    q = router.scheduler._q
    # every router fast dispatch popped the fast slot; the queue may count
    # more (a fast-slot grant routed through the full pipeline when the
    # home domain lacked headroom)
    assert q.stats.fast_grants >= fast
    assert q.stats.inflations - q.stats.deflations in (0, 1)
