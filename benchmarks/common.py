"""Shared helpers for the benchmark suite: CSV tables, claim checks, and the
shared ``BENCH_<section>.json`` emitter every ``benchmarks/run.py`` section
writes through (one schema: claims, headline metrics, pass/fail)."""

from __future__ import annotations

import json
import sys
import time
from contextlib import contextmanager


def table(title: str, header: list[str], rows: list[list]):
    print(f"\n## {title}")
    print(",".join(str(h) for h in header))
    for r in rows:
        print(",".join(f"{x:.4g}" if isinstance(x, float) else str(x) for x in r))
    sys.stdout.flush()


# --smoke: tiny iteration counts so CI can exercise every benchmark's code
# path in seconds.  Claims still print but are not load-bearing at smoke
# scale (the curves need full durations); run.py only gates on them in a
# full run.
SMOKE = False

FAILED_CLAIMS: list[str] = []


def smoke(full, tiny):
    """Pick the full-scale or smoke-scale value for an iteration knob."""
    return tiny if SMOKE else full


def zipf_draws(n: int, n_items: int, skew: float, rng) -> list[int]:
    """n inverse-CDF draws over items weighted 1/(k+1)^skew (skew 0 =
    uniform).  The one Zipf sampler for every bench workload — domain mixes
    and shared-prefix pools must skew identically to be comparable."""
    weights = [1.0 / (k + 1) ** skew for k in range(n_items)]
    tot = sum(weights)
    out = []
    for _ in range(n):
        r = rng.random() * tot
        acc = 0.0
        for k, w in enumerate(weights):
            acc += w
            if r <= acc:
                out.append(k)
                break
        else:
            out.append(n_items - 1)
    return out


def claim(name: str, ok: bool, detail: str = ""):
    status = "PASS" if ok else "FAIL"
    if not ok:
        FAILED_CLAIMS.append(name)
    if _SECTION is not None:
        _SECTION["claims"].append({"name": name, "ok": bool(ok), "detail": detail})
    print(f"CLAIM [{status}] {name}  {detail}")
    return ok


# -- shared BENCH_<section>.json schema ---------------------------------------
# One record per run.py section: {"bench", "schema", "smoke", "claims":
# [{name, ok, detail}], "metrics": {...}, "passed"}.  Claims land via claim()
# while the section is active; headline numbers via headline() /
# headline_registry() (the latter snapshots a repro.obs.MetricsRegistry, which
# is how sections source their numbers from the unified registry).
BENCH_SCHEMA = 1

_SECTION: dict | None = None


@contextmanager
def bench_section(name: str, json_dir: str = "."):
    """Collect claims + headline metrics for one bench section and write
    ``BENCH_<name>.json`` on exit (even when the section raises — a partial
    record with its failed claims beats no record)."""
    global _SECTION
    prev = _SECTION
    _SECTION = {
        "bench": name,
        "schema": BENCH_SCHEMA,
        "smoke": SMOKE,
        "claims": [],
        "metrics": {},
    }
    try:
        yield _SECTION
    finally:
        rec, _SECTION = _SECTION, prev
        rec["passed"] = all(c["ok"] for c in rec["claims"])
        path = _os.path.join(json_dir, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=2, default=str)
        print(
            f"[wrote {path}: {len(rec['claims'])} claims, "
            f"{'pass' if rec['passed'] else 'FAIL'}]"
        )


def headline(**metrics) -> None:
    """Merge headline numbers into the active section's record (no-op when
    no section is active, so benches stay runnable standalone)."""
    if _SECTION is not None:
        _SECTION["metrics"].update(metrics)


def headline_registry(registry, prefix: str = "") -> None:
    """Snapshot a ``repro.obs.MetricsRegistry`` into the active section's
    metrics — the registry-sourced path for BENCH records."""
    if _SECTION is not None:
        snap = registry.collect()
        if prefix:
            snap = {f"{prefix}{k}": v for k, v in snap.items()}
        _SECTION["metrics"].update(snap)


def emit_json(payload: dict, json_path: str | None = None) -> None:
    """Route a bench's own JSON payload: merged into the active section's
    metrics when one is active (the section file carries it), else written
    directly to ``json_path`` (standalone invocations, tests)."""
    if _SECTION is not None:
        _SECTION["metrics"].update(payload)
    elif json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, default=str)


def ascii_plot(title: str, xs, series: dict, *, width: int = 64, height: int = 16,
               logy: bool = False):
    """Paper-style ASCII line chart: one mark per series, shared y scale.

    ``series`` maps name -> list of y values (same length as ``xs``).  Keeps
    benchmark output self-contained (no matplotlib in the container)."""
    import math

    marks = "ox+*#@%&"
    ys_all = [y for ys in series.values() for y in ys if y is not None]
    if not ys_all:
        return
    f = (lambda v: math.log10(max(v, 1e-12))) if logy else (lambda v: v)
    lo, hi = min(f(y) for y in ys_all), max(f(y) for y in ys_all)
    span = (hi - lo) or 1.0
    grid = [[" "] * width for _ in range(height)]
    for si, (name, ys) in enumerate(series.items()):
        for i, y in enumerate(ys):
            if y is None:
                continue
            col = round(i * (width - 1) / max(1, len(xs) - 1))
            row = height - 1 - round((f(y) - lo) / span * (height - 1))
            grid[row][col] = marks[si % len(marks)]
    print(f"\n## {title}")
    ylab = "log10 " if logy else ""
    print(f"  y: {ylab}[{lo:.3g} .. {hi:.3g}]   x: {xs[0]} .. {xs[-1]}")
    for row in grid:
        print("  |" + "".join(row))
    print("  +" + "-" * width)
    legend = "   ".join(f"{marks[i % len(marks)]}={name}" for i, name in enumerate(series))
    print(f"   {legend}")
    sys.stdout.flush()


@contextmanager
def timed(name: str):
    t0 = time.time()
    yield
    print(f"({name}: {time.time() - t0:.1f}s)")


THREADS_2S = [1, 2, 4, 8, 16, 24, 36, 48, 70]
THREADS_4S = [1, 2, 4, 8, 16, 36, 72, 108, 142]
LOCK_SET = ["mcs", "cna", "cna_opt", "c-bo-mcs", "hmcs", "tas", "ticket", "hbo"]
MAIN_LOCKS = ["mcs", "cna", "cna_opt", "c-bo-mcs", "hmcs"]


# -- subprocess harness (mirrors tests/_subproc.py — keep the two in sync) ----
# Subprocesses must not inherit hardcoded machine paths, and must pin
# JAX_PLATFORMS=cpu: with libtpu installed but no TPU attached, an unpinned
# jax spends minutes probing TPU metadata endpoints.
import os as _os

REPO_ROOT = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))


def subproc_env() -> dict:
    env = dict(_os.environ)
    env["PYTHONPATH"] = _os.path.join(REPO_ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    return env
