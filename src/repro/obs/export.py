"""Exporters: JSONL traces, Prometheus text, ASCII flame summaries.

Self-contained (no dependency on the ``benchmarks`` package, whose import
resolution depends on cwd) — the bench sections layer ``ascii_plot`` over
these for aggregate views, while ``flame`` here renders the per-request
causal picture a trace exists to answer: *where did this session's TTFT
go?*
"""

from __future__ import annotations

import json
from typing import Any, IO

from .registry import MetricsRegistry
from .trace import Span, Tracer


def to_jsonl(tracer: Tracer, path_or_file: str | IO) -> int:
    """Dump every span as one JSON object per line; returns the span count."""
    own = isinstance(path_or_file, str)
    f = open(path_or_file, "w") if own else path_or_file
    try:
        n = 0
        for sp in tracer:
            f.write(json.dumps(sp.to_dict(), default=str) + "\n")
            n += 1
        return n
    finally:
        if own:
            f.close()


def from_jsonl(path_or_file: str | IO) -> list[dict]:
    """Read a JSONL trace dump back as a list of span dicts."""
    own = isinstance(path_or_file, str)
    f = open(path_or_file) if own else path_or_file
    try:
        return [json.loads(line) for line in f if line.strip()]
    finally:
        if own:
            f.close()


def render_prometheus(registry: MetricsRegistry) -> str:
    return registry.render_prometheus()


def _children(spans: list[Span]) -> dict:
    kids: dict = {None: []}
    by_id = {sp.span_id: sp for sp in spans}
    for sp in spans:
        parent = sp.parent_id if sp.parent_id in by_id else None
        kids.setdefault(parent, []).append(sp)
    for v in kids.values():
        v.sort(key=lambda s: (s.start, s.span_id))
    return kids


def flame(tracer: Tracer, trace: Any, width: int = 64) -> str:
    """ASCII flame summary of one trace: each span a bar positioned and
    scaled on the trace's own clock, children indented under parents.

        session #s3                              [0, 1220]
        ├─ ████████░░░░░░░░  queue_wait      180 cy
        ...
    """
    spans = tracer.for_trace(trace)
    if not spans:
        return f"(no spans for trace {trace!r})"
    t0 = min(sp.start for sp in spans)
    t1 = max(max(sp.end, sp.start) for sp in spans)
    extent = max(1, t1 - t0)
    kids = _children(spans)
    lines = [f"trace {trace!r}  [{t0}, {t1}]  ({len(spans)} spans)"]

    def emit(sp: Span, depth: int) -> None:
        lo = int((sp.start - t0) / extent * width)
        hi = max(lo + 1, int((max(sp.end, sp.start) - t0) / extent * width))
        bar = "." * lo + "#" * (hi - lo) + "." * (width - hi)
        dur = "open" if sp.open else f"{sp.duration} cy"
        extra = ""
        if "kind" in sp.attrs:
            extra = f" [{sp.attrs['kind']}]"
        lines.append(f"  {'  ' * depth}{bar}  {sp.name}{extra}  {dur}")
        for child in kids.get(sp.span_id, ()):
            emit(child, depth + 1)

    for root in kids[None]:
        emit(root, 0)
    return "\n".join(lines)
