"""Simulator tests: determinism, paper-claim reproduction, invariants."""

import pytest

from repro.core.locks_sim import ALL_LOCKS, CNASim, MCSSim
from repro.core.numasim import FOUR_SOCKET, TWO_SOCKET, Simulator, run_sweep

DUR = 8_000_000


def run_one(name, n_threads, n_sockets, cm=TWO_SOCKET, seed=42, noncs=0, **kw):
    return Simulator(
        ALL_LOCKS[name],
        n_threads,
        n_sockets,
        cm,
        seed=seed,
        duration_cycles=DUR,
        noncs_cycles=noncs,
        lock_kwargs=kw,
    ).run()


def test_deterministic():
    a = run_one("cna", 16, 2, seed=7)
    b = run_one("cna", 16, 2, seed=7)
    assert a.ops == b.ops
    assert a.per_thread_ops == b.per_thread_ops
    assert a.remote_transfers == b.remote_transfers


def test_all_ops_accounted():
    for name in ALL_LOCKS:
        r = run_one(name, 12, 2)
        assert r.ops == sum(r.per_thread_ops)
        assert r.ops > 0


def test_cna_matches_mcs_single_thread():
    """Paper claim: CNA has the single-thread performance of MCS."""
    mcs = run_one("mcs", 1, 2)
    cna = run_one("cna", 1, 2)
    assert cna.ops == pytest.approx(mcs.ops, rel=0.02)


def test_hierarchical_locks_slower_single_thread():
    """Paper Section 1: hierarchical locks pay multiple atomics uncontended."""
    mcs = run_one("mcs", 1, 2)
    for name in ("c-bo-mcs", "hmcs"):
        r = run_one(name, 1, 2)
        assert r.ops < mcs.ops


def test_cna_beats_mcs_under_contention_two_socket():
    """Paper: ~40%+ speedup on 2 sockets under contention."""
    mcs = run_one("mcs", 36, 2)
    cna = run_one("cna", 36, 2)
    assert cna.ops > 1.25 * mcs.ops


def test_four_socket_gap_larger_than_two_socket():
    """Paper: ~100%+ on 4 sockets vs ~40% on 2 (costlier remote miss)."""
    m2, c2 = run_one("mcs", 32, 2), run_one("cna", 32, 2)
    m4, c4 = (
        run_one("mcs", 32, 4, cm=FOUR_SOCKET),
        run_one("cna", 32, 4, cm=FOUR_SOCKET),
    )
    assert c4.ops / m4.ops > c2.ops / m2.ops


def test_mcs_fairness_strictly_fifo():
    r = run_one("mcs", 16, 2)
    assert r.fairness_factor == pytest.approx(0.5, abs=0.02)


def test_cna_longterm_fairness_preserved():
    """Paper Fig. 8: CNA fairness factor stays well below unfair locks when
    the run is long relative to the flush period."""
    r = run_one("cna", 16, 2, threshold=0xFF)
    assert r.fairness_factor < 0.65
    hbo = run_one("hbo", 16, 2)
    assert r.fairness_factor < hbo.fairness_factor


def test_cna_remote_rate_far_below_mcs():
    """Paper Fig. 7: LLC-miss-rate proxy separation under contention."""
    mcs = run_one("mcs", 36, 2)
    cna = run_one("cna", 36, 2)
    assert cna.remote_rate < 0.3 * mcs.remote_rate


def test_global_spinning_storms():
    """TAS/ticket remote traffic scales with spinners (Section 2)."""
    tas = run_one("tas", 36, 2)
    mcs = run_one("mcs", 36, 2)
    assert tas.remote_rate > 3 * mcs.remote_rate


def test_shuffle_reduction_reduces_shuffles_light_contention():
    """Paper Section 6/7: at light contention CNA(opt) restructures the queue
    ~10x less while keeping throughput within noise of plain CNA."""
    base = run_one("cna", 4, 2, noncs=800, threshold=0xFF)
    opt = run_one("cna_opt", 4, 2, noncs=800, threshold=0xFF)
    assert base.shuffles > 0
    assert opt.shuffles < base.shuffles
    # paper Fig. 9: the optimization closes CNA's low-contention gap
    assert opt.ops >= base.ops


def test_sweep_shapes():
    rs = run_sweep(ALL_LOCKS["cna"], [1, 2, 4], 2, duration_cycles=1_000_000)
    assert [r.n_threads for r in rs] == [1, 2, 4]


def test_cna_queue_conservation():
    """No waiter is ever lost: total grants + still-queued == total arrivals.
    (Indirectly: every op completes; ops per thread are contiguous cycles.)"""
    r = run_one("cna", 24, 4, cm=FOUR_SOCKET, threshold=0x1F)
    assert all(c > 0 for c in r.per_thread_ops)
