"""Sharded AdamW.

Moments are plain pytrees mirroring the parameter tree, so they inherit the
parameters' GSPMD sharding (fsdp x model) — ZeRO-style optimizer-state
sharding falls out of the logical-axis rules with no extra machinery.
``dtype`` selects the moment precision: fp32 default, bf16 for 340B-class
models where fp32 moments alone would exceed HBM (nemotron-4-340b config).

Update math runs in fp32 regardless of storage dtype (cast up, update, cast
down) — bf16 moments lose ~3 bits of mantissa on the EMA, an accepted
trade-off recorded in DESIGN.md.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params, dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    params,
    grads,
    opt_state,
    step: jax.Array,
    *,
    lr: jax.Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.where(gnorm > clip_norm, clip_norm / jnp.maximum(gnorm, 1e-12), 1.0)

    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - jnp.power(b1, t)
    bc2 = 1.0 - jnp.power(b2, t)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + g * (1.0 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g) * (1.0 - b2)
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "clip_scale": scale}
    return new_p, {"m": new_m, "v": new_v}, metrics
