"""Batched KV/recurrent cache slots for continuous batching.

The engine owns one cache pytree with a slot (decode-batch) axis.  Each slot
is independently claimable; inserting a prefilled (B=1) cache into slot ``i``
is a per-leaf ``dynamic_update_slice`` on that leaf's batch axis.  The batch
axis per leaf comes from the model's ``cache_logical`` tree (the position of
the "batch" logical axis), so attention KV (B,S,kv,hd), stacked KV
(L,B,S,kv,hd), RG-LRU state (B,W), SSD state (B,H,P,N) and encdec cross-KV
are all handled uniformly.

Slot *selection* is pluggable.  The baseline keeps one heap of free slots
(lowest-first, O(log n) claim/release).  With a ``topology``, slots become
NUMA-homed: ``repro.placement`` partitions them into per-domain pools, and
``claim(owner, domain)`` places each request in (or nearest to) its KV/prefix
home domain under the configured policy, charging distance-aware migration on
misses and recording per-domain telemetry.
"""

from __future__ import annotations

import heapq

import jax
import jax.numpy as jnp


class SlotCache:
    """cache pytree + slot bookkeeping."""

    def __init__(
        self, cache, axes, n_slots: int, *, topology=None, policy="nearest_spill",
        cost_model=None,
    ):
        self.cache = cache
        self.axes = axes  # per-leaf batch-axis index (or None for pos)
        self.n_slots = n_slots
        self.owner: dict[int, object] = {}
        # distance/migration cost of the most recent claim (0 for a home hit
        # or the baseline path); the engine charges stall time from these.
        # ``last_domain`` is where the slot actually landed (None on the
        # baseline path) — the prefix index re-homes hot prefixes from it.
        self.last_distance = 0
        self.last_migration_cycles = 0
        self.last_domain = None
        # CostModel pricing telemetry's migration_cycles (None -> the
        # placement layer's TWO_SOCKET default); keep it consistent with
        # whatever model benchmarks compare those cycles against.
        self.cost_model = cost_model
        if topology is None:
            self.pools = None
            self.policy = None
            self.telemetry = None
            self._free = list(range(n_slots))  # a fresh range is a valid heap
        else:
            from repro.placement import DomainFreeLists, PlacementTelemetry, get_policy

            self.pools = DomainFreeLists(n_slots, topology)
            self.policy = get_policy(policy)
            self.telemetry = PlacementTelemetry(n_domains=self.pools.topology.n_domains)
            self._free = None

    @property
    def n_free(self) -> int:
        """Free-slot count — the O(1) check for the engine's admit loop."""
        if self.pools is not None:
            return len(self.pools)
        return len(self._free)

    @property
    def free(self) -> list[int]:
        """Free slots, ascending.  NB: a *copy* under placement; treat as
        read-only and use claim/release to mutate."""
        if self.pools is not None:
            return self.pools.free_slots()
        return sorted(self._free)

    @classmethod
    def zeros(
        cls, model, n_slots: int, cache_len: int, *, topology=None, policy="nearest_spill",
        cost_model=None,
    ):
        abs_cache = model.cache_abstract(n_slots, cache_len)
        logical = model.cache_logical(abs_cache)
        axes = jax.tree.map(
            lambda l: l.index("batch") if "batch" in l else None,
            logical,
            is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(i, (str, type(None))) for i in x),
        )
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), abs_cache)
        cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
        axes["pos"] = None
        return cls(cache, axes, n_slots, topology=topology, policy=policy, cost_model=cost_model)

    def claim(self, owner, domain: int | None = None) -> int:
        """Claim a free slot for ``owner``.  ``domain`` is the request's
        KV/prefix home; the baseline path ignores it (lowest free slot).
        Under placement the domain is required and range-checked up front —
        the same validation ``_BaseScheduler.submit`` applies — so a bad home
        cannot masquerade as domain-0 traffic in the telemetry or surface as
        an opaque IndexError inside the pools."""
        if self.pools is not None:
            topo = self.pools.topology
            if domain is None:
                raise ValueError(
                    "claim under placement needs the request's KV/prefix home "
                    "domain (got domain=None); derive one (PrefixIndex.home) "
                    "or pass it explicitly"
                )
            if not 0 <= domain < topo.n_domains:
                raise ValueError(
                    f"domain {domain} out of range for topology "
                    f"{topo.name!r} ({topo.n_domains} domains)"
                )
            p = self.policy.place(self.pools, domain, self.cost_model)
            if p is None:
                raise IndexError("claim from an exhausted SlotCache")
            self.telemetry.record_placement(p)
            self.last_distance = p.distance
            self.last_migration_cycles = p.migration_cycles
            self.last_domain = p.slot_domain
            slot = p.slot
        else:
            if not self._free:
                raise IndexError("claim from an exhausted SlotCache")
            slot = heapq.heappop(self._free)
            self.last_distance = 0
            self.last_migration_cycles = 0
            self.last_domain = None
        self.owner[slot] = owner
        return slot

    def release(self, slot: int):
        self.owner.pop(slot, None)
        # A freed slot must not advertise a stale sequence: zeroing pos makes
        # the slot read as empty the moment it is reclaimed, so nothing can
        # attend over the previous owner's KV between claim and insert.
        self.cache["pos"] = self.cache["pos"].at[slot].set(0)
        if self.pools is not None:
            self.telemetry.record_release(self.pools.release(slot))
        else:
            heapq.heappush(self._free, slot)

    @property
    def active(self) -> list[int]:
        return sorted(self.owner)

    def slot_domain(self, slot: int) -> int | None:
        """Home domain of ``slot``'s pool (None on the baseline path) — the
        domain whose free list holds the KV written into this slot."""
        if self.pools is None:
            return None
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range")
        return self.pools.slot_domain[slot]

    def fit_single(self, single_cache):
        """Pad/trim a (batch=1) prefill cache so every leaf matches this
        cache's shapes with the batch axis forced to 1.  Stored prefix caches
        (``repro.serving.prefixkv``) go through this once at deposit time so
        all of them share one shape regardless of the prompt length they were
        built from — suffix ``decode_step`` calls then hit a single jit
        trace, and ``insert`` is a no-op refit."""
        new = {}
        for key in self.cache:
            if key == "pos":
                continue
            new[key] = jax.tree.map(
                lambda dst, src, ax: src if ax is None else _fit(jnp.asarray(src), dst, ax),
                self.cache[key], single_cache[key], self.axes[key],
            )
        new["pos"] = jnp.asarray(single_cache["pos"], jnp.int32)
        return new

    def extract(self, slot: int):
        """Inverse of ``insert``: copy ``slot``'s lane out of the batched
        pytree as a standalone (batch=1) cache, ``pos`` included as the
        scalar the model's prefill emits.  jax arrays are immutable, so the
        result is safe to stash (``PrefixKVStore``) or ship to another
        engine — the retirement-time deposit path uses exactly this."""
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range")
        if slot not in self.owner:
            # an unowned slot's lane is stale KV from its previous owner (or
            # zeros); silently handing that out as a cache let a caller
            # deposit/ship garbage under a live key — refuse instead
            raise ValueError(
                f"extract from unowned slot {slot}: claim/insert it first "
                "(released slots hold stale or zero KV)"
            )

        def take(src, ax):
            if ax is None:
                return src
            return jax.lax.dynamic_slice_in_dim(src, slot, 1, axis=ax)

        new = {}
        for key in self.cache:
            if key == "pos":
                continue
            new[key] = jax.tree.map(take, self.cache[key], self.axes[key])
        new["pos"] = self.cache["pos"][slot]
        return new

    def insert_row(self, slot: int, batched_cache, row: int):
        """Scatter lane ``row`` of another batched cache pytree (a packed
        prefill's output) into ``slot`` — per leaf, slice the source lane on
        its batch axis and ``dynamic_update_slice`` it into this cache's,
        with the same pad/trim ``_fit`` applies on the single-cache path.
        This is how a packed prefill call lands its rows in their claimed
        slots without materialising per-row intermediate caches."""

        def put(dst, src, ax):
            if ax is None:
                return dst
            lane = jax.lax.dynamic_slice_in_dim(jnp.asarray(src), row, 1, axis=ax)
            lane = _fit(lane, dst, ax)
            idx = [0] * dst.ndim
            idx[ax] = slot
            return jax.lax.dynamic_update_slice(dst, lane.astype(dst.dtype), tuple(idx))

        new = {}
        for key in self.cache:
            if key == "pos":
                continue
            new[key] = jax.tree.map(put, self.cache[key], batched_cache[key], self.axes[key])
        new["pos"] = self.cache["pos"].at[slot].set(
            jnp.asarray(batched_cache["pos"], jnp.int32)[row]
        )
        self.cache = new

    def insert(self, slot: int, single_cache):
        """Insert a (batch=1) prefill cache into ``slot``."""

        def put(dst, src, ax):
            if ax is None:
                return dst
            idx = [0] * dst.ndim
            idx[ax] = slot
            src = jnp.asarray(src)
            src = _fit(src, dst, ax)
            return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), tuple(idx))

        new = {}
        for key in self.cache:
            if key == "pos":
                continue
            new[key] = jax.tree.map(put, self.cache[key], single_cache[key], self.axes[key])
        new["pos"] = self.cache["pos"].at[slot].set(jnp.asarray(single_cache["pos"], jnp.int32))
        self.cache = new


def _fit(src, dst, batch_ax: int):
    """Pad/trim src so every axis matches dst (batch axis forced to 1)."""
    target = tuple(1 if i == batch_ax else s for i, s in enumerate(dst.shape))
    if src.shape == target:
        return src
    pads = [(0, max(0, t - s)) for s, t in zip(src.shape, target)]
    src = jnp.pad(src, pads)
    return src[tuple(slice(0, t) for t in target)]
