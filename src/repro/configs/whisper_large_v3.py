"""whisper-large-v3 [audio]: enc-dec, 32+32L d=1280 20H d_ff=5120 vocab=51866.
The conv/mel frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (batch, 1500, d_model); the 32-layer encoder and
32-layer decoder (self + cross attention) are fully implemented.  Whisper uses
absolute positions => pos="learned"."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv=20, d_ff=5120, vocab=51866,
    mlp="gelu", norm="layernorm", pos="learned", max_pos=32_768,
    enc_layers=32, enc_seq=1500, accum=2,
)

def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, enc_layers=2, d_model=64, n_heads=4,
                          n_kv=4, d_ff=128, vocab=512, enc_seq=30, max_pos=128,
                          accum=1, attn_chunk=32)
