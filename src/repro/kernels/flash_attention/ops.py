"""jit-able wrapper around the flash-attention Pallas kernel.

Handles layout (B,S,H,hd) <-> (B*H,S,hd), GQA head-group index mapping,
padding to block multiples, and backend selection (interpret=True off-TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x
    spec = [(0, 0)] * x.ndim
    spec[axis] = (0, pad)
    return jnp.pad(x, spec)


def _forward(q, k, v, causal, window, block_q, block_k, interpret):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = h // hkv

    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(8, skv))
    qp = _pad_to(jnp.transpose(q, (0, 2, 1, 3)).reshape(b * h, sq, hd), 1, bq)
    kp = _pad_to(jnp.transpose(k, (0, 2, 1, 3)).reshape(b * hkv, skv, hd), 1, bk)
    vp = _pad_to(jnp.transpose(v, (0, 2, 1, 3)).reshape(b * hkv, skv, hd), 1, bk)

    out = flash_attention_bhsd(
        qp, kp, vp,
        group=group, causal=causal, window=window,
        block_q=bq, block_k=bk,
        sq_valid=sq, skv_valid=skv,
        interpret=interpret,
    )
    out = out[:, :sq].reshape(b, h, sq, hd)
    return jnp.transpose(out, (0, 2, 1, 3))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _fa(q, k, v, causal, window, block_q, block_k, interpret):
    return _forward(q, k, v, causal, window, block_q, block_k, interpret)


def _fa_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    return _forward(q, k, v, causal, window, block_q, block_k, interpret), (q, k, v)


def _fa_bwd(causal, window, block_q, block_k, interpret, res, g):
    """Backward via the pure-jnp oracle's VJP (recompute-from-inputs, the
    flash strategy).  A dedicated backward Pallas kernel is the TPU hot-path
    extension; on the training path this keeps grads exact and memory-safe."""
    from .ref import attention_ref

    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: attention_ref(q, k, v, causal=causal, window=window), q, k, v)
    return vjp(g)


_fa.defvjp(_fa_fwd, _fa_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, Hkv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    return _fa(q, k, v, causal, window, block_q, block_k, interpret)
