"""Paper-reproduction example: regenerate the key figures from Section 7.

    PYTHONPATH=src:. python examples/locks_paper_repro.py
"""

import sys

sys.path.insert(0, ".")

from benchmarks.paper_figures import fig6, fig8, fig9, fig10

if __name__ == "__main__":
    res = fig6()
    fig8(res)
    fig9()
    fig10()
