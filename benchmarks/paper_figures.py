"""Reproduction of the paper's evaluation (Section 7) on the NUMA simulator.

One function per figure.  Each prints a CSV table and checks the paper's
qualitative claims (PASS/FAIL lines are collected into EXPERIMENTS.md §Repro):

  fig6  key-value map throughput, 2-socket, no external work
  fig7  LLC load-miss-rate proxy (remote transfers/op)
  fig8  long-term fairness factor
  fig9  key-value map with non-critical work (incl. CNA(opt) shuffle reduction)
  fig10 4-socket machine
  fig11 leveldb-like readrandom (short CS, some external work)
  fig12 kyoto-like wicked mode (long CS, zero scaling)
  fig13 locktorture (random CS lengths, occasional long delay)
  fig15 will-it-scale-like (scales until the spin lock contends)
"""

from __future__ import annotations

from repro.core.locks_sim import ALL_LOCKS
from repro.core.numasim import FOUR_SOCKET, TWO_SOCKET, CostModel, run_sweep
from dataclasses import replace

from .common import MAIN_LOCKS, THREADS_2S, THREADS_4S, claim, table

DUR = 8_000_000
# The paper keeps the lock local for ~thousands of handovers per flush on a
# 10s run; our simulated runs are ~10k-40k ops, so the threshold is scaled to
# keep (flushes / run) in the same regime.
KW = {"cna": {"threshold": 0xFF}, "cna_opt": {"threshold": 0xFF}}


def _sweep(names, threads, cm, *, noncs=None, seed=42, duration=DUR, cs=None):
    out = {}
    cm = cm if cs is None else replace(cm, cs_base=cs)
    for name in names:
        out[name] = run_sweep(
            ALL_LOCKS[name], threads, 4 if cm is FOUR_SOCKET else 2, cm,
            seed=seed, duration_cycles=duration, noncs_cycles=noncs,
            lock_kwargs=KW.get(name),
        )
    return out


def _tab(title, res, field):
    names = list(res)
    threads = [r.n_threads for r in res[names[0]]]
    rows = [[t] + [getattr(res[n][i], field) for n in names] for i, t in enumerate(threads)]
    table(title, ["threads"] + names, rows)
    return rows


def fig6():
    res = _sweep(MAIN_LOCKS, THREADS_2S, TWO_SOCKET, noncs=0)
    rows = _tab("fig6: key-value map throughput (ops/us), 2-socket, no external work",
                res, "throughput_ops_per_us")
    tp = {n: [r.throughput_ops_per_us for r in res[n]] for n in res}
    claim("fig6: MCS collapses 1->2 threads", tp["mcs"][1] < 0.55 * tp["mcs"][0],
          f"{tp['mcs'][0]:.2f}->{tp['mcs'][1]:.2f}")
    claim("fig6: CNA == MCS single-thread (<3% gap)",
          abs(tp["cna"][0] - tp["mcs"][0]) / tp["mcs"][0] < 0.03,
          f"cna={tp['cna'][0]:.2f} mcs={tp['mcs'][0]:.2f}")
    claim("fig6: CNA >= 1.35x MCS at 70 threads (paper: ~1.39x)",
          tp["cna"][-1] >= 1.35 * tp["mcs"][-1],
          f"speedup={tp['cna'][-1] / tp['mcs'][-1]:.2f}")
    claim("fig6: CNA within 15% of HMCS under contention",
          tp["cna"][-1] >= 0.85 * tp["hmcs"][-1],
          f"cna={tp['cna'][-1]:.2f} hmcs={tp['hmcs'][-1]:.2f}")
    return res


def fig7(res=None):
    res = res or _sweep(MAIN_LOCKS, THREADS_2S, TWO_SOCKET, noncs=0)
    _tab("fig7: remote-transfer rate per op (LLC-miss proxy)", res, "remote_rate")
    rr = {n: [r.remote_rate for r in res[n]] for n in res}
    claim("fig7: MCS remote rate >> CNA under contention (>=2x)",
          rr["mcs"][-1] >= 2.0 * rr["cna"][-1],
          f"mcs={rr['mcs'][-1]:.2f} cna={rr['cna'][-1]:.2f}")
    claim("fig7: miss rate jumps 1->2 threads (all locks)",
          rr["mcs"][1] > 5 * max(rr["mcs"][0], 1e-6), f"{rr['mcs'][0]:.3f}->{rr['mcs'][1]:.3f}")


def fig8(res=None):
    res = res or _sweep(MAIN_LOCKS, THREADS_2S, TWO_SOCKET, noncs=0)
    _tab("fig8: fairness factor (0.5 = strictly fair)", res, "fairness_factor")
    ff = {n: [r.fairness_factor for r in res[n]] for n in res}
    claim("fig8: MCS strictly fair (~0.5)", all(f < 0.53 for f in ff["mcs"][1:]),
          f"max={max(ff['mcs'][1:]):.3f}")
    claim("fig8: CNA preserves long-term fairness (< 0.62, paper: 'well below 60%')",
          all(f < 0.62 for f in ff["cna"][1:]), f"max={max(ff['cna'][1:]):.3f}")
    claim("fig8: C-BO-MCS unfair (-> 1)", max(ff["c-bo-mcs"][2:]) > 0.75,
          f"max={max(ff['c-bo-mcs'][2:]):.3f}")


def fig9():
    res = _sweep(MAIN_LOCKS, THREADS_2S, TWO_SOCKET, noncs=2500)
    rows = _tab("fig9: key-value map + external work (ops/us)", res, "throughput_ops_per_us")
    tp = {n: [r.throughput_ops_per_us for r in res[n]] for n in res}
    claim("fig9: benchmark scales 1->2 threads with MCS", tp["mcs"][1] > 1.2 * tp["mcs"][0],
          f"{tp['mcs'][0]:.2f}->{tp['mcs'][1]:.2f}")
    claim("fig9: CNA ~ +40% over MCS at high contention",
          tp["cna"][-1] >= 1.3 * tp["mcs"][-1], f"speedup={tp['cna'][-1]/tp['mcs'][-1]:.2f}")
    claim("fig9: shuffle reduction repairs the low-contention dip (cna_opt >= mcs @4)",
          tp["cna_opt"][2] >= 0.97 * tp["mcs"][2],
          f"cna_opt={tp['cna_opt'][2]:.2f} mcs={tp['mcs'][2]:.2f} cna={tp['cna'][2]:.2f}")


def fig10():
    res = _sweep(MAIN_LOCKS, THREADS_4S, FOUR_SOCKET, noncs=0)
    _tab("fig10: key-value map throughput, 4-socket (ops/us)", res, "throughput_ops_per_us")
    tp = {n: [r.throughput_ops_per_us for r in res[n]] for n in res}
    claim("fig10: CNA ~ 2x MCS at 142 threads (paper: +97%)",
          tp["cna"][-1] >= 1.7 * tp["mcs"][-1], f"speedup={tp['cna'][-1]/tp['mcs'][-1]:.2f}")
    drop2 = tp["mcs"][1] / tp["mcs"][0]
    claim("fig10: 1->2 thread drop deeper than 2-socket (higher remote cost)",
          drop2 < 0.45, f"retained={drop2:.2f}")


def fig11():
    # leveldb readrandom: short critical sections (snapshot + refcount), some
    # external work (the actual key lookup outside the central lock)
    res = _sweep(MAIN_LOCKS, THREADS_2S, TWO_SOCKET, noncs=1200, cs=250)
    _tab("fig11: leveldb-like readrandom (ops/us)", res, "throughput_ops_per_us")
    tp = {n: [r.throughput_ops_per_us for r in res[n]] for n in res}
    claim("fig11: CNA ~ +39% over MCS at max threads",
          tp["cna"][-1] >= 1.25 * tp["mcs"][-1], f"speedup={tp['cna'][-1]/tp['mcs'][-1]:.2f}")


def fig12():
    # kyoto wicked: long critical sections, no external work -> zero scaling
    res = _sweep(MAIN_LOCKS, THREADS_2S, TWO_SOCKET, noncs=0, cs=1500)
    _tab("fig12: kyoto-cabinet-like wicked mode (ops/us)", res, "throughput_ops_per_us")
    tp = {n: [r.throughput_ops_per_us for r in res[n]] for n in res}
    claim("fig12: best performance at 1 thread (no scaling)",
          tp["mcs"][0] >= max(tp["mcs"]), "")
    claim("fig12: CNA matches MCS at 1 thread",
          abs(tp["cna"][0] - tp["mcs"][0]) / tp["mcs"][0] < 0.03, "")
    claim("fig12: CNA ~ +28-43% over MCS at 36-70 threads",
          tp["cna"][-1] >= 1.2 * tp["mcs"][-1], f"speedup={tp['cna'][-1]/tp['mcs'][-1]:.2f}")


def fig13():
    # locktorture: tiny critical sections with occasional long delays
    res = _sweep(["mcs", "cna"], THREADS_2S, TWO_SOCKET, noncs=60, cs=120)
    _tab("fig13: locktorture-like (stock=mcs vs CNA, ops/us)", res, "throughput_ops_per_us")
    tp = {n: [r.throughput_ops_per_us for r in res[n]] for n in res}
    claim("fig13: CNA > stock beyond 4 threads (paper: +14%@70)",
          tp["cna"][-1] > 1.05 * tp["mcs"][-1], f"speedup={tp['cna'][-1]/tp['mcs'][-1]:.2f}")
    # lockstat mode: more shared data written in the CS => bigger CNA win
    res2 = _sweep(["mcs", "cna"], THREADS_2S, replace(TWO_SOCKET, n_write_lines=6), noncs=60, cs=120)
    _tab("fig13b: locktorture + lockstat (more shared writes)", res2, "throughput_ops_per_us")
    tp2 = {n: [r.throughput_ops_per_us for r in res2[n]] for n in res2}
    gain1 = tp["cna"][-1] / tp["mcs"][-1]
    gain2 = tp2["cna"][-1] / tp2["mcs"][-1]
    claim("fig13: lockstat (more shared writes) widens the CNA gap",
          gain2 > gain1, f"{gain1:.2f} -> {gain2:.2f}")


def fig15():
    # will-it-scale: scales with external work until the spin lock saturates
    res = _sweep(["mcs", "cna"], THREADS_2S, TWO_SOCKET, noncs=6000, cs=300)
    _tab("fig15: will-it-scale-like (ops/us)", res, "throughput_ops_per_us")
    tp = {n: [r.throughput_ops_per_us for r in res[n]] for n in res}
    claim("fig15: both scale at low threads", tp["mcs"][2] > 2.5 * tp["mcs"][0], "")
    claim("fig15: CNA ~ +42-57% over stock at 70 threads",
          tp["cna"][-1] >= 1.3 * tp["mcs"][-1], f"speedup={tp['cna'][-1]/tp['mcs'][-1]:.2f}")


def run_all():
    res6 = fig6()
    fig7(res6)
    fig8(res6)
    fig9()
    fig10()
    fig11()
    fig12()
    fig13()
    fig15()
