"""repro.router: the CNA-disciplined replica router, fleet controller, and
discrete-event fleet sim — including the cross-layer contract that a warm
federation routes a session to the same replica a global single index
(oracle) would."""

import random

import pytest

from repro.core.topology import flat, pod
from repro.router import (
    FederatedPrefixIndex,
    FleetController,
    ReplicaRouter,
    ReplicaSummary,
    Session,
    SimReplica,
    shared_prefix_sessions,
    simulate,
)
from repro.serving.prefixindex import PrefixIndex


def _fleet(n=3, slots=2, budget=400):
    return [SimReplica(r, slots, cache_budget=budget) for r in range(n)]


def _drain_completed(router, replicas):
    for sess, target, _d in router.dispatch():
        replicas[target].finish(sess)
        router.complete(sess, ttft=1)


# -- fleet controller ----------------------------------------------------------


def test_fleet_controller_caps_inflight_per_replica():
    fc = FleetController(2, initial=2)
    assert fc.can_admit(0) and fc.can_admit(1)
    fc.note_admit(0)
    fc.note_admit(0)
    assert not fc.can_admit(0) and fc.can_admit(1)
    fc.note_finish(0)
    assert fc.can_admit(0)
    with pytest.raises(ValueError):
        fc.note_finish(1)


def test_fleet_controller_ttft_collapse_pulls_cap_down():
    fc = FleetController(1, initial=8, window=8, tolerance=0)
    for _ in range(8):
        fc.observe_ttft(0, 10)       # establish the cheap floor
    for _ in range(64):
        fc.observe_ttft(0, 10_000)   # TTFT collapse
    assert fc.cap(0) < 8


def test_fleet_controller_validates():
    with pytest.raises(ValueError):
        FleetController(0)
    with pytest.raises(ValueError):
        FleetController(2, controllers=[None])


# -- router admission ----------------------------------------------------------


def test_router_routes_to_advertising_replica_and_counts_reuse():
    reps = _fleet()
    router = ReplicaRouter(reps, sync_every=0)
    reps[1].cache.insert((5, 5, 5, 5))
    router.sync()
    s = Session(sid=0, prompt=(5, 5, 5, 5, 9), decode_len=2)
    assert router.submit(s) == 1 and s.matched_len == 4
    sess, target, _ = router.dispatch_one()
    assert sess is s and target == 1 and s.replica == 1
    assert s.local_matched == 4
    assert router.stats.reprefill_tokens == 1  # only the suffix token


def test_router_sheds_to_nearest_when_home_is_full():
    reps = _fleet(n=4, slots=1)
    router = ReplicaRouter(reps, topology=pod(2, 2), sync_every=0)
    reps[0].cache.insert((1, 2, 3))  # only replica 0 advertises the prefix
    router.sync()
    reps[0].inflight = 1             # ...but it is full
    s = Session(sid=0, prompt=(1, 2, 3), decode_len=1)
    assert router.submit(s) == 0     # longest match still homes it there
    sess, target, _ = router.dispatch_one()
    assert sess is s
    assert target == 1               # same-pod sibling of 0 under pod(2,2)
    assert router.stats.sheds == 1


def test_router_dispatch_stops_when_fleet_is_full():
    reps = _fleet(n=2, slots=1)
    router = ReplicaRouter(reps, sync_every=0)
    for i in range(4):
        router.submit(Session(sid=i, prompt=(i,), decode_len=1))
    out = router.dispatch()
    assert len(out) == 2          # one per slot
    assert len(router) == 2       # rest wait queued
    assert router.dispatch_one() is None


def test_router_validates_topology_and_controller_size():
    reps = _fleet(n=3)
    with pytest.raises(ValueError):
        ReplicaRouter(reps, topology=flat(2))
    with pytest.raises(ValueError):
        ReplicaRouter(reps, controller=FleetController(2))
    with pytest.raises(ValueError):
        ReplicaRouter([])


def test_router_clusters_dispatches_by_home_replica():
    """The two-queue semantics one level up: with sessions interleaved
    across two warm homes, CNA dispatch order clusters same-home sessions
    (dispatch locality far above the alternation floor)."""
    reps = _fleet(n=2, slots=2, budget=600)
    router = ReplicaRouter(reps, sync_every=0, fairness_threshold=0xFF)
    reps[0].cache.insert((1, 1, 1, 1))
    reps[1].cache.insert((2, 2, 2, 2))
    router.sync()
    sid = 0
    for _ in range(40):  # strict alternation between the two homes
        for head in ((1, 1, 1, 1), (2, 2, 2, 2)):
            router.submit(Session(sid=sid, prompt=head + (900 + sid,), decode_len=1))
            sid += 1
        router.tick()
    # serve with ample capacity churn
    while len(router):
        _drain_completed(router, reps)
        router.tick()
    m = router.metrics
    assert m.admitted == 80
    assert m.locality > 0.8, f"dispatch locality {m.locality:.2f}"


# -- the oracle contract (acceptance) ------------------------------------------


def test_warm_federation_routes_like_global_index_oracle():
    """Cross-layer contract: replicas advertise disjoint warm prefixes; for
    any probe, a warm federation and an oracle holding ONE global index over
    the same content pick the same replica and matched_len — including the
    cold-miss fallback, which both resolve least-loaded."""
    n = 3
    reps = _fleet(n=n, slots=2)
    router = ReplicaRouter(reps, sync_every=0)
    warm = {0: (1, 2, 3, 4, 5), 1: (7, 8, 9), 2: (4, 4, 4, 4)}
    for r, seq in warm.items():
        reps[r].cache.insert(seq)
    router.sync()

    occ = lambda: {r.rid: r.occupancy for r in reps}
    oracle = PrefixIndex(n_domains=n, occupancy=occ)
    for r, seq in warm.items():
        oracle.record(seq, r)

    probes = [
        (1, 2, 3, 4, 5, 6), (1, 2, 3), (1, 9),        # prefix-0 family
        (7, 8, 9, 9), (7, 7),                          # prefix-1 family
        (4, 4, 4, 4, 1), (4, 4),                       # prefix-2 family
        (6, 6, 6), (),                                  # total misses
    ]
    for p in probes:
        assert router.federation.route(p, now=router.now) == oracle.home(p), p
    # loads shift the cold-miss fallback identically on both sides
    reps[0].inflight, reps[1].inflight, reps[2].inflight = 2, 0, 1
    assert router.federation.route((6, 6, 6)) == oracle.home((6, 6, 6)) == (1, 0)


# -- end-to-end sim ------------------------------------------------------------


def _mini_workload(n=80, seed=3):
    rng = random.Random(seed)
    draws = [rng.randrange(6) for _ in range(n)]
    return lambda: shared_prefix_sessions(draws, prefix_len=32, suffix_len=8,
                                          decode_len=8)


def test_sim_completes_all_sessions_and_is_deterministic():
    mk = _mini_workload()
    a = simulate("federated", mk(), n_replicas=3, n_slots=2, cache_budget=200,
                 inter_arrival=10, seed=5)
    b = simulate("federated", mk(), n_replicas=3, n_slots=2, cache_budget=200,
                 inter_arrival=10, seed=5)
    assert a.n_sessions == 80 and a.ticks > 0
    assert (a.reprefill_tokens, a.ticks, a.stall_p99, a.per_replica_served) == (
        b.reprefill_tokens, b.ticks, b.stall_p99, b.per_replica_served
    )


def test_sim_federated_beats_baselines_on_reprefill():
    """The bench claim at test scale: with finite per-replica KV memory,
    federated routing re-prefills fewer tokens than either baseline."""
    mk = _mini_workload(n=120, seed=9)
    res = {
        arm: simulate(arm, mk(), n_replicas=3, n_slots=2, cache_budget=150,
                      inter_arrival=12, seed=7)
        for arm in ("federated", "round_robin", "least_loaded")
    }
    fed = res["federated"].reprefill_tokens
    assert fed < res["round_robin"].reprefill_tokens
    assert fed < res["least_loaded"].reprefill_tokens


def test_sim_unknown_arm_raises():
    with pytest.raises(KeyError):
        simulate("random", [], n_replicas=2)


# -- replica cache (the sim's finite KV model) ---------------------------------


def test_replica_cache_budget_evicts_lru_and_charges_suffix_only():
    from repro.router import ReplicaCache

    c = ReplicaCache(20)
    assert c.insert((1, 2, 3, 4, 5, 6, 7, 8)) == 8
    assert c.insert((1, 2, 3, 4, 5, 6, 9, 9)) == 2   # shared prefix: suffix charge
    assert c.charged_tokens == 10
    assert c.match((1, 2, 3, 4, 5)) == 5
    c.insert(tuple(range(100, 112)))                  # 12 tokens: blows the budget
    assert c.charged_tokens <= 20 or len(c) == 1
    assert c.match(tuple(range(100, 112))) == 12      # newest entry survives


def test_replica_cache_match_refreshes_recency():
    from repro.router import ReplicaCache

    c = ReplicaCache(16)
    c.insert((1, 1, 1, 1))
    c.insert((2, 2, 2, 2))
    c.match((1, 1, 1, 1))         # touch the older entry
    c.insert((3, 3, 3, 3, 3, 3, 3, 3, 3, 3))  # forces eviction
    assert c.match((1, 1)) == 2   # refreshed entry survived
    assert c.match((2, 2)) == 0   # untouched entry evicted
