"""MoE dispatch invariants + CNA locality routing (beyond-paper feature)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback
    from _hypothesis_compat import given, settings, st

from repro.configs.base import ModelConfig
from repro.models.moe import _positions, declare_moe, moe_apply, moe_capacity
from repro.models.common import ParamBuilder


def _moe_cfg(**kw):
    base = dict(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=4, n_kv=4,
        d_ff=64, vocab=128, n_experts=8, top_k=2, moe_d_ff=48,
    )
    base.update(kw)
    return ModelConfig(**base)


@given(
    m=st.integers(1, 200),
    e=st.integers(1, 16),
    cap=st.integers(1, 32),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=100, deadline=None)
def test_positions_invariants(m, e, cap, seed):
    """(expert, pos) pairs are unique for kept entries; pos < capacity; and
    earlier tokens win slots (drop-later discipline)."""
    rng = np.random.default_rng(seed)
    e_ids = jnp.asarray(rng.integers(0, e, m), jnp.int32)
    pos, keep = _positions(e_ids, e, cap)
    pos, keep = np.asarray(pos), np.asarray(keep)
    assert (pos[keep] < cap).all()
    pairs = {(int(e_ids[i]), int(pos[i])) for i in range(m) if keep[i]}
    assert len(pairs) == int(keep.sum()), "slot collision"
    # per expert: kept entries are exactly the first min(count, cap) arrivals
    for ex in range(e):
        idx = [i for i in range(m) if int(e_ids[i]) == ex]
        expected_kept = set(idx[:cap])
        actual_kept = {i for i in idx if keep[i]}
        assert actual_kept == expected_kept


def test_moe_single_expert_equals_dense_mlp():
    """E=1, top_k=1, huge capacity => MoE == plain SwiGLU MLP of same weights."""
    cfg = _moe_cfg(n_experts=1, top_k=1, capacity_factor=2.0)
    pb = ParamBuilder(dtype=jnp.float32)
    declare_moe(pb, "moe", cfg)
    params = pb.init(jax.random.PRNGKey(0))["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    out, aux = moe_apply(params, x, cfg)
    # dense reference with the same expert weights
    h = jnp.einsum("bsd,df->bsf", x, params["wi"][0])
    g = jnp.einsum("bsd,df->bsf", x, params["wg"][0])
    ref = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h, params["wo"][0])
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-4)


def test_moe_grads_finite():
    cfg = _moe_cfg()
    pb = ParamBuilder(dtype=jnp.float32)
    declare_moe(pb, "moe", cfg)
    params = pb.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model), jnp.float32)

    def loss(p):
        out, aux = moe_apply(p["moe"], x, cfg)
        return jnp.sum(out**2) + aux

    g = jax.grad(loss)(params)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))
    # router must receive gradient (it's inside top-k weights)
    assert float(jnp.abs(g["moe"]["router"]).sum()) > 0


def test_cna_routing_bias_increases_locality():
    """The paper's main-queue preference, in the router: with the CNA bias on,
    more tokens route to experts homed on their own domain; the aux loss keeps
    remote experts alive (fairness threshold analogue)."""
    def locality(cna: bool, bias: float = 2.0):
        cfg = _moe_cfg(n_experts=8, top_k=2, cna_routing=cna,
                       cna_routing_bias=bias, cna_domains=4)
        pb = ParamBuilder(dtype=jnp.float32)
        declare_moe(pb, "moe", cfg)
        params = pb.init(jax.random.PRNGKey(0))["moe"]
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, cfg.d_model), jnp.float32)
        logits = jnp.einsum("bsd,de->bse", x, params["router"])
        if cna:
            b, e = 8, 8
            tok_dom = (jnp.arange(b) * 4) // b
            exp_dom = (jnp.arange(e) * 4) // e
            local = (tok_dom[:, None] == exp_dom[None, :]).astype(jnp.float32)
            logits = logits + bias * local[:, None, :]
        _, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), 2)
        tok_dom = (jnp.arange(8) * 4) // 8
        exp_dom = (jnp.arange(8) * 4) // 8
        return float(jnp.mean((exp_dom[idx] == tok_dom[:, None, None]).astype(jnp.float32)))

    assert locality(True) > locality(False) + 0.2


def test_capacity_formula():
    assert moe_capacity(4096, 6, 64, 1.25) == 480
    assert moe_capacity(1, 2, 8, 1.25) == 4  # decode floor
    assert moe_capacity(4096, 2, 8, 1.25) == 1280


def test_moe_decode_single_token():
    cfg = _moe_cfg()
    pb = ParamBuilder(dtype=jnp.float32)
    declare_moe(pb, "moe", cfg)
    params = pb.init(jax.random.PRNGKey(0))["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, cfg.d_model), jnp.float32)
    out, _ = moe_apply(params, x, cfg)
    assert out.shape == x.shape
    assert jnp.isfinite(out).all()
    # decode with top_k distinct experts should drop nothing: out is nonzero
    assert float(jnp.abs(out).sum()) > 0
