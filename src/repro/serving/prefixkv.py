"""Prefix-KV reuse: an LRU store of prefilled (batch=1) caches by prompt.

``PrefixIndex`` makes prefix reuse visible to *routing* (which domain holds a
prefix) and discounts the migration stall — but until this module the engine
still recomputed the whole prompt at prefill.  ``PrefixKVStore`` closes that
gap: after each admission the engine deposits the prompt's prefilled cache
here (jax arrays are immutable, so an entry is a bundle of references, not a
copy), and a later prompt that *extends* a stored prefix resumes from the
stored cache — the KV write position is seeded past the cached run and only
the uncached suffix is computed, one ``decode_step`` per suffix token.  That
is true prefix-cache reuse (RadixAttention-style), not just a stall discount;
``DecodeEngine.prefill_positions`` counts exactly how many positions were
computed so tests and benchmarks can pin the savings.

Keys are exact token prefixes: an entry is only usable when its key equals
``prompt[:len(key)]`` (the cache encodes those tokens and nothing else), so
lookup is longest-stored-prefix, not longest-common-run.  Entries are LRU
over a bounded count — each holds references to a full per-request cache, so
the bound is the memory knob.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any


class PrefixKVStore:
    """LRU ``token-prefix -> (cache, logits)`` store for prefill reuse."""

    def __init__(self, capacity: int = 16, *, min_plant: int = 4) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        # shortest common run worth planting a boundary entry for (shorter
        # runs are chance collisions: the split prefill would cost a jit
        # trace to save almost nothing)
        self.min_plant = min_plant
        self._lru: "OrderedDict[tuple[int, ...], tuple[Any, Any]]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.reused_tokens = 0

    @staticmethod
    def _key(tokens) -> tuple[int, ...]:
        return tuple(int(t) for t in tokens)

    def __len__(self) -> int:
        return len(self._lru)

    def __contains__(self, tokens) -> bool:
        return self._key(tokens) in self._lru

    def put(self, tokens, cache, logits) -> None:
        """Deposit the prefilled cache (+ next-token logits) for ``tokens``.
        Re-putting an existing key refreshes it (and its recency)."""
        key = self._key(tokens)
        if not key:
            return
        self._lru.pop(key, None)
        self._lru[key] = (cache, logits)
        while len(self._lru) > self.capacity:
            self._lru.popitem(last=False)

    def longest(self, tokens) -> tuple[int, Any, Any] | None:
        """Longest stored key that is an exact prefix of ``tokens`` ->
        ``(matched_len, cache, logits)``, or None.  The hit is touched so hot
        prefixes survive the LRU."""
        key = self._key(tokens)
        best = None
        for stored in self._lru:
            if len(stored) <= len(key) and stored == key[: len(stored)]:
                if best is None or len(stored) > len(best):
                    best = stored
        if best is None:
            self.misses += 1
            return None
        self.hits += 1
        self.reused_tokens += len(best)
        self._lru.move_to_end(best)
        cache, logits = self._lru[best]
        return len(best), cache, logits

    def peek(self, tokens) -> int:
        """Length (in tokens) of the longest stored key that exactly prefixes
        ``tokens`` — ``longest`` without the side effects: no hit/miss
        counting, no LRU touch.  The router prices ship/re-prefill decisions
        from this, and a price probe must not look like traffic."""
        key = self._key(tokens)
        best = 0
        for stored in self._lru:
            if len(stored) > best and len(stored) <= len(key) and stored == key[: len(stored)]:
                best = len(stored)
        return best

    def get(self, tokens) -> tuple[Any, Any] | None:
        """The ``(cache, logits)`` bundle stored under exactly ``tokens``,
        or None.  Touches recency (an export for shipping is a real use —
        the prefix is hot somewhere) but not the hit/miss counters, which
        count prefill-path lookups only."""
        key = self._key(tokens)
        if key not in self._lru:
            return None
        self._lru.move_to_end(key)
        return self._lru[key]

    def common_run(self, tokens) -> int:
        """Longest common token run between ``tokens`` and any stored key —
        the boundary-planting hint when no stored key is an exact prefix
        (shared-system-prompt traffic: stored ``P+s1`` vs incoming ``P+s2``
        share the run ``P`` but neither prefixes the other)."""
        key = self._key(tokens)
        best = 0
        for stored in self._lru:
            n = min(len(stored), len(key))
            k = 0
            while k < n and stored[k] == key[k]:
                k += 1
            best = max(best, k)
        return best

    def clear(self) -> None:
        self._lru.clear()
