"""repro.region: fleets-of-fleets under the CNA discipline — paired-arm
simulation invariants, elastic membership (no routing-error window), tenant
fairness (bounded starvation under an adversarial flood), and retirement
deposits serving conversation follow-ups."""

import statistics

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core.topology import region as region_topology
from repro.region import (
    RegionRouter,
    RegionSession,
    SimFleet,
    TenantFairness,
    simulate_region,
    to_sessions,
)
from repro.router.federation import FederatedPrefixIndex, ReplicaSummary
from repro.workload import TraceGenerator, uniform_tenants, with_flood


def _trace(seed=7, horizon=2048, rate=0.03, tenants=None, n_regions=2):
    gen = TraceGenerator(
        n_regions=n_regions,
        tenants=tenants or uniform_tenants(4, n_regions),
        seed=seed,
        base_rate=rate,
    )
    return gen.generate(horizon=horizon)


def _fleets(n=4, replicas=2, slots=2, **kw):
    return [SimFleet(f, replicas, n_slots=slots, **kw) for f in range(n)]


def _router(fleets, regions=2, **kw):
    per = len(fleets) // regions
    return RegionRouter(fleets, topology=region_topology(regions, per), **kw)


# -- topology ------------------------------------------------------------------


def test_region_topology_three_levels():
    t = region_topology(2, 3)
    assert t.n_domains == 6
    assert t.distance(0, 0) == 0
    assert t.distance(0, 1) == 1   # sibling fleet, same region
    assert t.distance(0, 3) == 2   # cross-region


# -- simulation invariants -----------------------------------------------------


def test_all_arms_conserve_sessions():
    tr = _trace()
    for arm in ("region", "least_loaded", "round_robin"):
        r = simulate_region(arm, tr, seed=11)
        assert r.served + r.rejected == len(tr)
        assert r.rejected == 0  # no caps -> nothing rejected
        assert sum(r.per_fleet_served) == r.served


def test_phase_conservation():
    """queue_wait + dispatch + ship_wait + prefill == total admission stall,
    exactly — the causal attribution invariant one level up."""
    r = simulate_region("region", _trace(), seed=11)
    assert sum(r.phase_cycles.values()) == r.admission_stall_total


def test_region_arm_beats_oblivious_on_reuse():
    tr = _trace(seed=7, horizon=4096, rate=0.02)
    region = simulate_region("region", tr, seed=11)
    base = simulate_region("least_loaded", tr, seed=11)
    assert region.reuse_fraction > base.reuse_fraction
    assert region.reprefill_tokens < base.reprefill_tokens


def test_simulate_region_is_deterministic():
    tr = _trace()
    a = simulate_region("region", tr, seed=5, tenant_caps=3)
    b = simulate_region("region", tr, seed=5, tenant_caps=3)
    assert a.headline() == b.headline()
    assert a.ttfts == b.ttfts


def test_fleet_admit_preserves_region_queue_identity():
    """Regression: the inner fleet router re-stamps submit_t/home/matched_len
    on submit; SimFleet.admit must restore the region-level values or all
    queueing time silently vanishes from stall accounting."""
    f = SimFleet(0, 2, n_slots=2)
    s = RegionSession(sid=1, prompt=tuple(range(100, 140)))
    s.submit_t, s.home, s.matched_len = 17, 0, 5
    f.admit(s, now=50)
    assert s.submit_t == 17
    assert s.home == 0
    assert s.matched_len == 5
    assert s.fleet == 0
    assert s.replica in (0, 1)  # inner member id, not the fleet id


def test_tenant_caps_require_region_arm():
    with pytest.raises(ValueError):
        simulate_region("least_loaded", _trace(), tenant_caps=2)
    with pytest.raises(ValueError):
        simulate_region("least_loaded", _trace(), elastic=[(10, "leave", 0)])


# -- elastic membership --------------------------------------------------------


def test_withdraw_removes_summary_and_bumps_version():
    fed = FederatedPrefixIndex(2, occupancy=lambda: {0: 0, 1: 0})
    fed.apply(ReplicaSummary(replica=1, t=0, occupancy=0, capacity=4,
                             prefixes=(((1, 2, 3), 1),)))
    assert fed.route([1, 2, 3])[0] == 1
    assert fed.withdraw(1)
    assert fed.stats.withdrawn == 1
    assert not fed.withdraw(1)  # idempotent: already gone
    # the prefix no longer matches anywhere; cold fallback, no error
    replica, matched = fed.route([1, 2, 3])
    assert matched == 0


def test_route_issued_mid_departure_degrades_never_errors():
    """The ISSUE regression: a session whose home fleet departs between
    route derivation and dispatch must degrade to a live fleet."""
    fleets = _fleets()
    router = _router(fleets)
    # warm fleet 1 so routes home there
    warm = RegionSession(sid=1, prompt=tuple(range(500, 540)))
    router.submit(warm)
    warm_home = warm.home
    router.dispatch_one()
    fleets[warm.fleet].finish(warm, deposit=True)
    router.complete(warm)
    router.sync()
    probe = RegionSession(sid=2, prompt=tuple(range(500, 540)))
    # departure happens before the probe's submit reads the summaries
    router.detach_fleet(warm_home)
    home = router.submit(probe)
    assert home is not None and home != warm_home
    assert router.active_fleets[home]
    d = router.dispatch_one()
    assert d is not None and d[0] is probe
    assert probe.fleet != warm_home


def test_parked_session_reroutes_when_home_departs():
    """A session parked by the tenant governor holds a home; if that fleet
    leaves while it waits, its release must re-route it live."""
    fleets = _fleets()
    router = _router(fleets, tenant_caps=1, tenant_park_bound=4)
    # warm one fleet so both tenant-3 sessions below route to the same home
    warm = RegionSession(sid=0, prompt=tuple(range(900, 940)), tenant=1)
    router.submit(warm)
    router.dispatch_one()
    fleets[warm.fleet].finish(warm, deposit=True)
    router.complete(warm)
    router.sync()
    first = RegionSession(sid=1, prompt=tuple(range(900, 940)), tenant=3)
    assert router.submit(first) is not None
    router.dispatch_one()
    home = first.home
    parked = RegionSession(sid=2, prompt=tuple(range(900, 940)), tenant=3)
    assert router.submit(parked) == home  # over cap -> parked toward home
    assert router.rstats.tenant_parked == 1
    router.detach_fleet(home)
    fleets[first.fleet].finish(first)
    router.complete(first)  # frees the slot -> unparks `parked`, re-routed
    assert router.rstats.tenant_unparked == 1
    assert router.rstats.rerouted_on_release == 1
    assert parked.home != home
    d = router.dispatch_one()
    assert d is not None and d[0] is parked


def test_all_fleets_detached_is_explicit_error():
    router = _router(_fleets())
    for f in range(4):
        router.detach_fleet(f)
    with pytest.raises(RuntimeError):
        router.submit(RegionSession(sid=1, prompt=(1, 2, 3)))


def test_attach_readvertises_immediately():
    fleets = _fleets()
    router = _router(fleets)
    s = RegionSession(sid=1, prompt=tuple(range(300, 340)))
    router.submit(s)
    router.dispatch_one()
    fleets[s.fleet].finish(s, deposit=True)
    router.complete(s)
    router.sync()
    held_by = s.fleet
    router.detach_fleet(held_by)
    router.attach_fleet(held_by)
    # no cold window: the re-applied summary routes the same prefix home
    probe = RegionSession(sid=2, prompt=tuple(range(300, 340)))
    assert router.submit(probe) == held_by


def test_elastic_schedule_in_simulation():
    tr = _trace(rate=0.05)
    r = simulate_region(
        "region", tr, seed=5,
        elastic=[(500, "leave", 1), (1400, "join", 1)],
    )
    assert r.detaches == 1 and r.attaches == 1
    assert r.served + r.rejected == len(tr)
    # fleet 1 served strictly less than its mirror fleet in the other region
    assert r.per_fleet_served[1] < max(r.per_fleet_served)


# -- tenant fairness -----------------------------------------------------------


def test_tenant_fairness_unit_admit_park_reject():
    tf = TenantFairness(cap=2, park_bound=2)
    sessions = [RegionSession(sid=i, prompt=(1,), tenant=0) for i in range(6)]
    verdicts = [tf.offer(s, fleet=0) for s in sessions[:5]]
    assert verdicts == ["admit", "admit", "park", "park", "reject"]
    assert tf.inflight(0, 0) == 2 and tf.parked(0, 0) == 2
    # releasing an admitted session unparks FIFO: sid 2 first
    released = tf.release(sessions[0])
    assert released is sessions[2]
    assert tf.inflight(0, 0) == 2 and tf.parked(0, 0) == 1
    # other pseudo-domains are independent
    assert tf.offer(sessions[5], fleet=1) == "admit"


def test_tenant_fairness_rejects_bad_config():
    with pytest.raises(ValueError):
        TenantFairness(cap=0)
    with pytest.raises(ValueError):
        TenantFairness(park_bound=-1)


def test_starvation_freedom_under_flood():
    """With caps on, every admitted-or-parked session completes (rejections
    are explicit), no session is left parked at drain, and only the flooding
    tenant is rejected."""
    tr = _trace(
        seed=3, horizon=2000, rate=0.12,
        tenants=with_flood(uniform_tenants(5, 2, suffix_len=24), weight=30.0),
    )
    r = simulate_region(
        "region", tr, seed=5, tenant_caps=3, tenant_park_bound=12,
        fleets_per_region=2, replicas_per_fleet=2, n_slots=2,
    )
    assert r.served + r.rejected == len(tr)
    assert r.tenant_parked == r.tenant_unparked  # everyone parked got out
    assert r.rejected_by_tenant.get(0, 0) == r.rejected  # flood pays, alone
    # every non-flood tenant still made progress
    for t in (1, 2, 3, 4):
        assert t in r.tenant_stalls


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16),
       weight=st.floats(min_value=20.0, max_value=60.0))
def test_property_caps_bound_victim_stall(seed, weight):
    """Adversarial single-tenant hot-prefix flood, any seed/intensity: with
    caps on, no victim tenant's p99 admission stall exceeds k x the fleet
    median (floored, so an idle-fleet median of ~0 cannot fabricate a
    violation)."""
    tr = _trace(
        seed=seed, horizon=1600, rate=0.12,
        tenants=with_flood(uniform_tenants(5, 2, suffix_len=24), weight=weight),
    )
    r = simulate_region(
        "region", tr, seed=5, tenant_caps=3, tenant_park_bound=12,
        fleets_per_region=2, replicas_per_fleet=2, n_slots=2,
    )
    p99 = r.tenant_p99()
    victims = {t: v for t, v in p99.items() if t != 0}
    if not victims:
        return
    med = statistics.median(p99.values())
    k, floor = 3.0, 500.0
    bound = k * max(med, floor)
    assert max(victims.values()) <= bound, (victims, med)


# -- retirement deposits -------------------------------------------------------


def test_deposits_cut_followup_reprefill():
    gen = TraceGenerator(
        n_regions=2,
        tenants=uniform_tenants(4, 2, followup_p=0.6, decode_len=24),
        seed=9, base_rate=0.02,
    )
    tr = gen.generate(horizon=4096)
    on = simulate_region("region", tr, seed=5, cache_budget=2000, deposits=True)
    off = simulate_region("region", tr, seed=5, cache_budget=2000, deposits=False)
    assert on.deposits == on.served and off.deposits == 0
    assert on.reprefill_tokens < off.reprefill_tokens
