"""Deterministic discrete-event simulator for the region tier.

``repro.router.sim`` drives one fleet of replicas; this module drives a
*region of fleets* with the same idiom — integer ticks, heapq event loop,
explicit seeds — consuming ``repro.workload`` traces so every routing arm
replays the identical request schedule (paired comparison).

Per event-loop iteration: trace arrivals submit to the region router
(federated ``RegionRouter`` or a region-oblivious baseline), the serialized
region dispatch pipe drains while free, and each dispatch runs the *whole
inner stack* — the target ``SimFleet``'s own federated ``ReplicaRouter``
routes the session onto a member ``SimReplica``.  A session's first token
waits for the max of its dispatch, its region-fabric transfer, and its
intra-fleet transfer; retirement optionally deposits ``prompt + output``
back into the serving replica's cache (the PR 5 retirement deposit), which
is what makes conversation follow-ups cheap.

Stall accounting is per tenant: ``RegionResult.tenant_stalls`` is a
``repro.obs.HistogramVector`` keyed by tenant — the observable the tenant-
fairness claims (no tenant's p99 admission stall beyond k x the fleet
median) are stated over.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field

from repro.core.topology import region as region_topology
from repro.obs import HistogramVector
from repro.router.kvship import ShipCostModel
from repro.router.router import Session
from repro.router.sim import FleetCostModel, _BaselineRouter
from repro.runtime.elastic import ElasticFleetSet
from repro.workload import Trace

from .fleet import SimFleet
from .router import RegionRouter

ARMS = ("region", "round_robin", "least_loaded")


@dataclass
class RegionSession(Session):
    """A routed session carrying its workload identity.  ``fleet`` is where
    it landed at region level (``replica`` is overwritten by the inner fleet
    router with the member id); ``inner_ship`` the intra-fleet transfer
    decision, if any."""

    tenant: int = 0
    region: int = 0
    conv: int = 0
    turn: int = 0
    fleet: int | None = None
    inner_ship: object = None
    pseudo: tuple | None = None


def to_sessions(trace: Trace) -> list[RegionSession]:
    """Fresh mutable sessions for one arm's run — call once *per arm*
    (routers mutate sessions); the schedule itself lives in the trace.
    ``sid == rid`` so retirement deposits and follow-up prompts agree on
    ``output_tokens``."""
    return [
        RegionSession(
            sid=r.rid, prompt=r.prompt, decode_len=r.decode_len,
            tenant=r.tenant, region=r.region, conv=r.conv, turn=r.turn,
        )
        for r in trace.requests
    ]


@dataclass
class RegionResult:
    """One region run's aggregates.  ``admission_stall_*`` run submit ->
    first token (parked time included); the conservation law
    ``sum(phase_cycles.values()) == admission_stall_total`` holds exactly
    for served sessions."""

    name: str
    n_sessions: int = 0
    served: int = 0
    rejected: int = 0
    ticks: int = 0
    reprefill_tokens: int = 0
    routed_tokens: int = 0
    reuse_fraction: float = 0.0
    hit_rate: float = 0.0
    sheds: int = 0
    dispatch_locality: float = 0.0
    admission_stall_total: int = 0
    admission_stall_p50: float = 0.0
    admission_stall_p99: float = 0.0
    per_fleet_served: list = field(default_factory=list)
    ttfts: list = field(default_factory=list)
    # tenant fairness
    tenant_stalls: HistogramVector = field(
        default_factory=lambda: HistogramVector("tenant")
    )
    tenant_parked: int = 0
    tenant_unparked: int = 0
    tenant_rejected: int = 0
    rejected_by_tenant: dict = field(default_factory=dict)
    # region-fabric shipping + intra-fleet shipping, separately
    region_ships: int = 0
    region_shipped_tokens: int = 0
    region_ship_cycles: int = 0
    intra_ships: int = 0
    intra_shipped_tokens: int = 0
    # retirement deposits
    deposits: int = 0
    deposit_tokens: int = 0
    # elastic membership
    detaches: int = 0
    attaches: int = 0
    phase_cycles: dict = field(default_factory=dict)

    def tenant_p99(self) -> dict:
        return {t: float(h.percentile(99)) for t, h in self.tenant_stalls.items()}

    def headline(self) -> dict:
        """The determinism-pinned summary: every number a bench publishes."""
        return {
            "served": self.served,
            "rejected": self.rejected,
            "ticks": self.ticks,
            "reuse_fraction": round(self.reuse_fraction, 9),
            "reprefill_tokens": self.reprefill_tokens,
            "admission_stall_p50": self.admission_stall_p50,
            "admission_stall_p99": self.admission_stall_p99,
            "region_ships": self.region_ships,
            "intra_ships": self.intra_ships,
            "deposits": self.deposits,
            "tenant_p99": {str(t): v for t, v in self.tenant_p99().items()},
        }


def make_region_router(
    arm: str, fleets, *, topology, seed: int = 0xF1EE7, tracer=None, **kw
):
    """Build the region routing arm: ``region`` (CNA-disciplined, federated,
    tenant-aware) or the region-oblivious ``round_robin`` / ``least_loaded``
    controls over the *same* fleet objects."""
    if arm == "region":
        return RegionRouter(fleets, topology=topology, seed=seed, tracer=tracer, **kw)
    if arm in ("round_robin", "least_loaded"):
        return _BaselineRouter(fleets, policy=arm, topology=topology, tracer=tracer)
    raise KeyError(f"unknown region arm {arm!r}; have {ARMS}")


def simulate_region(
    arm: str,
    trace: Trace,
    *,
    fleets_per_region: int = 2,
    replicas_per_fleet: int = 3,
    n_slots: int = 4,
    cache_budget: int = 600,
    cm: FleetCostModel | None = None,
    region_ship=None,
    fleet_ship=None,
    page_size: int | None = None,
    tenant_caps: int | None = None,
    tenant_park_bound: int = 8,
    deposits: bool = True,
    elastic=(),
    max_age: int | None = None,
    sync_every: int = 32,
    seed: int = 42,
    router_kwargs: dict | None = None,
    tracer=None,
    registry=None,
) -> RegionResult:
    """Run ``trace`` through a region of fleets under one routing arm.

    ``region_ship`` prices region-fabric KV shipping (a ``ShipCostModel``,
    or True for a default with an inter-region ladder ``(1, 1, 4)``);
    ``fleet_ship`` likewise for each fleet's *internal* fabric.  Both are
    region-arm concerns — the baselines never ship at region level (they
    have no federation to discover holders with), but their inner fleets run
    the identical stack.  ``tenant_caps`` enables (tenant x fleet) fairness
    (region arm only).  ``elastic`` is a schedule of membership events
    ``(t, "leave"|"join", fleet)`` driven through
    ``repro.runtime.elastic.ElasticFleetSet``.  ``deposits`` toggles the
    PR 5 retirement deposit (prompt + output re-enters the serving
    replica's cache at finish)."""
    cm = cm or FleetCostModel()
    n_fleets = trace.n_regions * fleets_per_region
    topo = region_topology(trace.n_regions, fleets_per_region)
    router_kwargs = dict(router_kwargs or {})

    scm = None
    if region_ship:
        if arm != "region":
            raise ValueError("region_ship requires the region arm (federated discovery)")
        from dataclasses import replace

        scm = (
            ShipCostModel(fabric_ladder=(1, 1, 4)) if region_ship is True else region_ship
        )
        scm = replace(scm, c_prefill=cm.c_prefill)
        router_kwargs["kv_ship"] = scm
    fcm = None
    if fleet_ship:
        from dataclasses import replace

        fcm = ShipCostModel() if fleet_ship is True else fleet_ship
        fcm = replace(fcm, c_prefill=cm.c_prefill)
    ps = (
        page_size
        or getattr(scm, "page_size", 0)
        or getattr(fcm, "page_size", 0)
        or 1
    )

    fleets = [
        SimFleet(
            f, replicas_per_fleet, n_slots=n_slots, cache_budget=cache_budget,
            page_size=ps, kv_ship=fcm, seed=seed, sync_every=sync_every,
        )
        for f in range(n_fleets)
    ]
    if arm == "region":
        router_kwargs.setdefault("tenant_caps", tenant_caps)
        router_kwargs.setdefault("tenant_park_bound", tenant_park_bound)
        router_kwargs.setdefault("max_age", max_age)
        router_kwargs.setdefault("sync_every", sync_every)
    elif tenant_caps is not None:
        raise ValueError("tenant_caps requires the region arm (the tenant governor)")
    router = make_region_router(
        arm, fleets, topology=topo, seed=seed, tracer=tracer, **router_kwargs
    )
    membership = ElasticFleetSet(router) if arm == "region" else None
    if elastic and membership is None:
        raise ValueError("elastic membership events require the region arm")

    sessions = to_sessions(trace)
    events: list[tuple[int, int, str, object]] = []
    seq = 0

    def push(t: int, kind: str, payload) -> None:
        nonlocal seq
        seq += 1
        heapq.heappush(events, (t, seq, kind, payload))

    for s, req in zip(sessions, trace.requests):
        push(req.t, "arrive", s)
    for t, op, fid in elastic:
        push(int(t), "elastic", (op, int(fid)))

    result = RegionResult(name=arm, n_sessions=len(sessions))
    stalls: list[int] = []
    phases = {"queue_wait": 0, "dispatch": 0, "ship_wait": 0, "prefill": 0}
    busy_until = 0
    last_t = 0
    while events:
        t, _, kind, payload = heapq.heappop(events)
        last_t = t
        router.advance(t)
        if kind == "arrive":
            if router.submit(payload) is None:
                result.rejected += 1
                tn = payload.tenant
                result.rejected_by_tenant[tn] = result.rejected_by_tenant.get(tn, 0) + 1
        elif kind == "elastic":
            op, fid = payload
            (membership.leave if op == "leave" else membership.join)(fid)
        elif kind == "finish":
            session, ttft = payload
            fleets[session.fleet].finish(session, ttft=ttft, deposit=deposits)
            router.complete(session, ttft=ttft)
            result.ttfts.append(ttft)
            result.served += 1
        # drain the serialized region dispatch pipe
        while busy_until <= t:
            d = router.dispatch_one()
            if d is None:
                break
            session, target, dist = d
            cost = cm.c_dispatch + cm.c_steer * dist
            start = t + cost
            busy_until = start
            uncached = len(session.prompt) - session.local_matched
            prefill = cm.c_prefill * uncached
            # first token waits for dispatch AND both fabrics (overlap: max)
            ready = start
            for ship in (session.ship, session.inner_ship):
                if ship is not None and ship.executed:
                    ready = max(ready, ship.fabric_end)
            first_tok = ready + prefill
            ttft = first_tok - session.dispatch_t
            stall = first_tok - session.submit_t
            stalls.append(stall)
            result.tenant_stalls.observe(session.tenant, stall)
            phases["queue_wait"] += t - session.submit_t
            phases["dispatch"] += cost
            phases["ship_wait"] += ready - start
            phases["prefill"] += prefill
            if tracer:
                root = tracer.open_span(session.sid, "session")
                sid = session.sid
                tracer.span("phase.queue_wait", sid, session.submit_t, t,
                            parent=root, cycles=t - session.submit_t)
                tracer.span("phase.dispatch", sid, t, start, parent=root, cycles=cost)
                tracer.span("phase.ship_wait", sid, start, ready,
                            parent=root, cycles=ready - start)
                tracer.span("phase.prefill", sid, ready, first_tok,
                            parent=root, cycles=prefill, uncached=uncached)
            finish_t = first_tok + cm.c_decode * session.decode_len
            push(finish_t, "finish", (session, ttft))
        if busy_until > t and len(router):
            push(busy_until, "drain", None)

    assert result.served + result.rejected == len(sessions), (
        f"{result.served} served + {result.rejected} rejected "
        f"!= {len(sessions)} submitted"
    )
    stats = router.stats
    result.ticks = last_t
    result.reprefill_tokens = stats.reprefill_tokens
    result.routed_tokens = stats.routed_tokens
    result.reuse_fraction = stats.reuse_fraction
    result.hit_rate = stats.hit_rate
    result.sheds = getattr(stats, "sheds", 0)
    m = getattr(router, "metrics", None)
    result.dispatch_locality = m.locality if m is not None else 0.0
    adm = sorted(stalls)
    if adm:
        result.admission_stall_total = sum(adm)
        result.admission_stall_p50 = float(adm[min(len(adm) - 1, int(0.50 * len(adm)))])
        result.admission_stall_p99 = float(adm[min(len(adm) - 1, int(0.99 * len(adm)))])
    result.per_fleet_served = [f.served for f in fleets]
    rstats = getattr(router, "rstats", None)
    if rstats is not None:
        result.tenant_parked = rstats.tenant_parked
        result.tenant_unparked = rstats.tenant_unparked
        result.tenant_rejected = rstats.tenant_rejected
        result.detaches = rstats.detaches
        result.attaches = rstats.attaches
    result.region_ships = getattr(stats, "ships", 0)
    result.region_shipped_tokens = getattr(stats, "shipped_tokens", 0)
    result.region_ship_cycles = getattr(stats, "ship_cycles", 0)
    result.intra_ships = sum(f.router.stats.ships for f in fleets)
    result.intra_shipped_tokens = sum(f.router.stats.shipped_tokens for f in fleets)
    result.deposits = sum(f.deposits for f in fleets)
    result.deposit_tokens = sum(f.deposit_tokens for f in fleets)
    result.phase_cycles = phases
    if registry is not None:
        stats.register_into(registry, prefix=f"{arm}_region_router")
        if m is not None:
            m.register_into(registry, prefix=f"{arm}_region_sched")
        if rstats is not None:
            rstats.register_into(registry, prefix=f"{arm}_region")
        tenants = getattr(router, "tenants", None)
        if tenants is not None:
            tenants.stats.register_into(registry, prefix=f"{arm}_tenant_gov")
        registry.attach(f"{arm}_tenant_stall", result.tenant_stalls)
        fabric = getattr(router, "fabric", None)
        if fabric is not None:
            fabric.stats.register_into(registry, prefix=f"{arm}_region_ship")
    return result
