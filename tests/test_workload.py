"""repro.workload: the trace generator's contracts — bit-determinism from
the seed, follow-up prompts that embed the parent's deterministic output
(the shape retirement deposits serve), phase-shifted diurnal waves, and
regional/tenant skew."""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.workload import (
    DiurnalWave,
    TenantProfile,
    TraceGenerator,
    output_tokens,
    prefix_tokens,
    uniform_tenants,
    with_flood,
)


def _gen(**kw):
    args = dict(n_regions=2, tenants=uniform_tenants(4, 2), seed=7, base_rate=0.03)
    args.update(kw)
    return TraceGenerator(**args)


# -- determinism ---------------------------------------------------------------


def test_same_seed_same_trace():
    a = _gen().generate(horizon=2048)
    b = _gen().generate(horizon=2048)
    assert a.requests == b.requests


def test_different_seed_different_trace():
    a = _gen(seed=7).generate(horizon=2048)
    b = _gen(seed=8).generate(horizon=2048)
    assert a.requests != b.requests


def test_regions_independent_streams():
    """Adding a region must not perturb existing regions' schedules — each
    region draws from its own (seed, region)-derived RNG."""
    two = _gen(n_regions=2, tenants=uniform_tenants(4, 2)).generate(horizon=2048)
    three = _gen(n_regions=3, tenants=uniform_tenants(4, 3)).generate(horizon=2048)
    # tenant homes shift with n_regions, which changes weights; compare the
    # pure arrival-time skeleton of region 0 with identical tenant homes
    t2 = _gen(n_regions=2, tenants=uniform_tenants(4, 1)).generate(horizon=2048)
    t3 = _gen(n_regions=3, tenants=uniform_tenants(4, 1)).generate(horizon=2048)
    assert [r.t for r in t2.requests if r.region == 0] == [
        r.t for r in t3.requests if r.region == 0
    ]
    assert len(two) > 0 and len(three) > 0


# -- structure -----------------------------------------------------------------


def test_rids_unique_and_time_sorted():
    tr = _gen().generate(horizon=2048)
    rids = [r.rid for r in tr.requests]
    assert len(set(rids)) == len(rids)
    ts = [r.t for r in tr.requests]
    assert ts == sorted(ts)


def test_followup_prompt_embeds_parent_output():
    """turn N's prompt == turn N-1's prompt + output_tokens(parent) + a fresh
    suffix — exactly what a retirement deposit of the parent contains."""
    tr = _gen(
        tenants=uniform_tenants(2, 2, followup_p=0.7), seed=3
    ).generate(horizon=2048)
    by_rid = {r.rid: r for r in tr.requests}
    followups = [r for r in tr.requests if r.turn > 0]
    assert followups, "trace produced no follow-up turns"
    for f in followups:
        parent = by_rid[f.parent]
        assert f.conv == parent.conv
        assert f.turn == parent.turn + 1
        assert f.t >= parent.t
        stem = parent.prompt + output_tokens(parent.rid, parent.decode_len)
        assert f.prompt[: len(stem)] == stem
        assert len(f.prompt) > len(stem)


def test_openers_draw_from_tenant_prefix_pool():
    tr = _gen().generate(horizon=2048)
    for r in tr.requests:
        if r.turn == 0:
            p = next(t for t in _gen().tenants if t.tenant == r.tenant)
            pools = {
                prefix_tokens(r.tenant, pid, p.prefix_len)
                for pid in range(p.n_prefixes)
            }
            assert r.prompt[: p.prefix_len] in pools


# -- traffic shape -------------------------------------------------------------


def test_diurnal_wave_phase_shifts_regions():
    """Region 1's arrivals peak half a period after region 0's (2 regions):
    compare mass inside each region's nominal peak window."""
    wave = DiurnalWave(period=2000, amplitude=0.95)
    tr = _gen(wave=wave, base_rate=0.05, seed=1).generate(horizon=2000)
    arr = tr.arrivals_by_region()
    # region 0 peaks at t=period/4, region 1 at t=3*period/4
    w0 = range(0, 1000)
    r0_early = sum(1 for t in arr[0] if t in w0) / max(1, len(arr[0]))
    r1_early = sum(1 for t in arr[1] if t in w0) / max(1, len(arr[1]))
    assert r0_early > 0.6
    assert r1_early < 0.4


def test_home_bias_concentrates_tenant_traffic():
    tr = _gen(
        tenants=uniform_tenants(2, 2, home_bias=9.0), base_rate=0.05
    ).generate(horizon=4096)
    for tenant in (0, 1):
        home = tenant % 2
        reqs = [r for r in tr.requests if r.tenant == tenant]
        at_home = sum(1 for r in reqs if r.region == home)
        assert at_home / len(reqs) > 0.6


def test_zipf_skew_concentrates_templates():
    p = TenantProfile(tenant=0, n_prefixes=16, prefix_skew=1.2, home_region=0)
    tr = _gen(tenants=[p], n_regions=1, base_rate=0.1).generate(horizon=4096)
    hot = prefix_tokens(0, 0, p.prefix_len)
    openers = [r for r in tr.requests if r.turn == 0]
    share = sum(1 for r in openers if r.prompt[: p.prefix_len] == hot) / len(openers)
    assert share > 0.2  # rank-1 under Zipf(1.2, 16) ~ 0.29


def test_with_flood_swamps_the_mix():
    tr = _gen(
        tenants=with_flood(uniform_tenants(6, 2), weight=40.0), base_rate=0.05
    ).generate(horizon=2048)
    share = sum(1 for r in tr.requests if r.tenant == 0) / len(tr)
    assert share > 0.7
    # and the flood's volume lands on one template
    flood = [r for r in tr.requests if r.tenant == 0 and r.turn == 0]
    assert len({r.prompt[:64] for r in flood}) == 1


# -- validation ----------------------------------------------------------------


def test_rejects_bad_configs():
    with pytest.raises(ValueError):
        TraceGenerator(n_regions=0, tenants=uniform_tenants(2, 1))
    with pytest.raises(ValueError):
        TraceGenerator(n_regions=1, tenants=[])
    with pytest.raises(ValueError):
        # tenant homed outside the region count
        TraceGenerator(n_regions=1, tenants=uniform_tenants(4, 2))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**20),
       followup=st.floats(min_value=0.0, max_value=0.7))
def test_property_trace_invariants(seed, followup):
    """Any seed: rids dense 0..n-1 in generation order, arrivals sorted,
    every follow-up's parent precedes it and shares tenant/user/conv."""
    gen = _gen(tenants=uniform_tenants(3, 2, followup_p=followup), seed=seed)
    tr = gen.generate(horizon=1024)
    assert sorted(r.rid for r in tr.requests) == list(range(len(tr)))
    by_rid = {r.rid: r for r in tr.requests}
    for r in tr.requests:
        if r.parent is not None:
            p = by_rid[r.parent]
            assert (p.tenant, p.user, p.conv) == (r.tenant, r.user, r.conv)
            assert p.rid < r.rid
