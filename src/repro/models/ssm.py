"""Mamba-2 SSD (state-space duality) blocks, arXiv:2405.21060.

The SSD layer computes, per head h with state size N and head dim P:

    s_t = exp(dt_t * A_h) * s_{t-1} + dt_t * B_t x_t^T        (s: (N, P))
    y_t = C_t^T s_t + D_h x_t

The chunked algorithm (paper Listing 1) splits the sequence into chunks of
length L: a quadratic *intra-chunk* term (masked decay matmul — MXU-friendly)
plus a linear *inter-chunk* state recurrence.  The paper's listing makes the
inter-chunk pass a (nc x nc) matmul, quadratic in chunk count — unusable at
500k tokens; we replace it with a ``lax.scan`` over chunks (linear, and the
natural TPU formulation).  The intra-chunk term is also available as a Pallas
kernel (repro/kernels/ssd_scan).

Shapes follow the Mamba-2 convention: X (B,S,H,P), dt (B,S,H), A (H,) < 0,
B/C (B,S,G,N) with G head-groups broadcast over H (G=1 here).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamBuilder, rmsnorm
from .sharding import shard


def declare_ssd(pb: ParamBuilder, prefix: str, cfg, stack: int = 0):
    lead = (stack,) if stack else ()
    lax = ("layers",) if stack else ()
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * n  # conv over [x, B, C]
    pb.declare(f"{prefix}/in_proj", lead + (d, 2 * di + 2 * n + h), lax + ("fsdp", "mlp"))
    pb.declare(f"{prefix}/conv_w", lead + (cfg.conv_width, conv_ch), lax + (None, None))
    pb.declare(f"{prefix}/conv_b", lead + (conv_ch,), lax + (None,), init="zeros")
    pb.declare(f"{prefix}/a_log", lead + (h,), lax + (None,), init="ssm_a")
    pb.declare(f"{prefix}/d_skip", lead + (h,), lax + (None,), init="ones")
    pb.declare(f"{prefix}/dt_bias", lead + (h,), lax + (None,), init="dt_bias")
    pb.declare(f"{prefix}/norm_w", lead + (di,), lax + (None,), init="zeros")
    pb.declare(f"{prefix}/out_proj", lead + (di, d), lax + ("mlp", "fsdp"))


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., L) -> (..., L, L) cumulative segment sums, -inf above diag."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    chunk: int = 128,
    s0: jax.Array | None = None,
    intra_impl: str = "jnp",
):
    """SSD scan.  x: (B,S,H,P) already dt-weighted is NOT expected — raw x.

    dt: (B,S,H) post-softplus; a: (H,) negative; b/c: (B,S,N) (G=1, broadcast
    over heads).  Returns (y (B,S,H,P), s_last (B,H,P,N))."""
    bs, s, h, p = x.shape
    n = b.shape[-1]
    l = min(chunk, s)
    if s % l:
        pad = l - s % l
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    s_pad = x.shape[1]
    nc = s_pad // l

    xd = (x * dt[..., None]).astype(jnp.float32)                    # dt-weighted input
    da = (dt.astype(jnp.float32) * a.astype(jnp.float32))           # (B,S,H)

    # chunk views
    xc = xd.reshape(bs, nc, l, h, p)
    dac = jnp.transpose(da.reshape(bs, nc, l, h), (0, 3, 1, 2))     # (B,H,nc,L)
    bc = b.reshape(bs, nc, l, n).astype(jnp.float32)
    cc = c.reshape(bs, nc, l, n).astype(jnp.float32)
    da_cs = jnp.cumsum(dac, axis=-1)                                 # (B,H,nc,L)

    # 1) intra-chunk (diagonal blocks) — the SSD Pallas kernel region: the
    # (L,L) decay matrix and chunk-local scores stay in VMEM on TPU
    with jax.named_scope("ssd_kernel_region"):
        if intra_impl == "pallas":
            from repro.kernels.ssd_scan import ops as ssd_ops

            y_diag = ssd_ops.ssd_intra(xc, dac, bc, cc)
        else:
            lmat = jnp.exp(_segsum(dac))                            # (B,H,nc,L,L)
            y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", cc, bc, lmat, xc)

        # 2) per-chunk input->state contribution
        decay_states = jnp.exp(da_cs[..., -1:] - da_cs)              # (B,H,nc,L)
        states = jnp.einsum("bcln,bhcl,bclhp->bchpn", bc, decay_states, xc)

    # 3) inter-chunk recurrence — lax.scan (linear in nc, vs paper's nc^2 matmul)
    chunk_decay = jnp.exp(da_cs[..., -1])                            # (B,H,nc)

    def step(s_prev, inp):
        st, dec = inp                                                # (B,H,P,N), (B,H)
        s_in = s_prev
        s_new = dec[..., None, None] * s_prev + st
        return s_new, s_in

    init = jnp.zeros((bs, h, p, n), jnp.float32) if s0 is None else s0.astype(jnp.float32)
    s_last, s_in = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 2, 0)),
    )
    s_enter = jnp.moveaxis(s_in, 0, 1)                               # (B,nc,H,P,N)

    # 4) state -> output within each chunk (same kernel family)
    with jax.named_scope("ssd_kernel_region"):
        out_decay = jnp.exp(da_cs)                                   # (B,H,nc,L)
        y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", cc, s_enter, out_decay)

    y = (y_diag + y_off).reshape(bs, s_pad, h, p)[:, :s]
    return y.astype(x.dtype), s_last


def ssd_step(x_t, dt_t, a, b_t, c_t, s_prev):
    """One decode step.  x_t: (B,H,P); dt_t: (B,H); b_t/c_t: (B,N);
    s_prev: (B,H,P,N) fp32 -> (y (B,H,P), s_new)."""
    da = jnp.exp(dt_t.astype(jnp.float32) * a.astype(jnp.float32))   # (B,H)
    inp = jnp.einsum("bhp,bn->bhpn", (x_t * dt_t[..., None]).astype(jnp.float32), b_t.astype(jnp.float32))
    s_new = da[..., None, None] * s_prev + inp
    y = jnp.einsum("bhpn,bn->bhp", s_new, c_t.astype(jnp.float32))
    return y.astype(x_t.dtype), s_new


def _split_proj(cfg, proj):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xin, b, c, dt = jnp.split(proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, xin, b, c, dt


def ssd_block(params: dict, x: jax.Array, cfg, *, intra_impl: str = "jnp"):
    """Full Mamba-2 block, train/prefill.  x: (B,S,D) -> (y, state)."""
    bsz, s, _ = x.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    proj = shard(proj, "batch", None, "mlp")
    z, xin, b, c, dt_raw = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xin, b, c], axis=-1)
    from .rglru import causal_conv1d  # same depthwise causal conv

    conv = jax.nn.silu(
        causal_conv1d(conv_in, params["conv_w"], params["conv_b"]).astype(jnp.float32)
    ).astype(x.dtype)
    xin, b, c = conv[..., :di], conv[..., di : di + n], conv[..., di + n :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xin.reshape(bsz, s, h, p)
    y, s_last = ssd_chunked(xh, dt, a, b, c, chunk=cfg.ssm_chunk, intra_impl=intra_impl)
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, di).astype(x.dtype)

    # gated RMSNorm then out-projection
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(z.dtype), params["norm_w"])
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    k = params["conv_w"].shape[0]
    conv_tail = conv_in[:, -(k - 1) :, :] if s >= k - 1 else jnp.pad(conv_in, ((0, 0), (k - 1 - s, 0), (0, 0)))
    return shard(out, "batch", "seq", "embed"), (s_last, conv_tail)


def ssd_block_step(params: dict, x_t: jax.Array, state, cfg):
    """Decode step.  x_t: (B,1,D); state = (s (B,H,P,N) fp32, conv (B,K-1,C))."""
    s_prev, conv_state = state
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xt = x_t[:, 0, :]
    proj = xt @ params["in_proj"]
    z, xin, b, c, dt_raw = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xin, b, c], axis=-1)
    from .rglru import conv1d_step

    conv, conv_state = conv1d_step(conv_in, conv_state.astype(conv_in.dtype), params["conv_w"], params["conv_b"])
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x_t.dtype)
    xin, b, c = conv[..., :di], conv[..., di : di + n], conv[..., di + n :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xin.reshape(-1, h, p)
    y, s_new = ssd_step(xh, dt, a, b, c, s_prev)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(-1, di).astype(x_t.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(z.dtype), params["norm_w"])
    out = y @ params["out_proj"]
    return out[:, None, :], (s_new, conv_state)
