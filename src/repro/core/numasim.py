"""Deterministic discrete-event NUMA simulator for lock-algorithm evaluation.

The paper evaluates CNA on 2- and 4-socket Xeons.  This container has one CPU
core, so wall-clock lock benchmarks are meaningless; instead we reproduce the
paper's *dynamics* with a seeded discrete-event simulation whose cost model has
exactly the ingredients the paper reasons about:

  * an atomic RMW (SWAP/CAS) on the lock word,
  * cache-line transfer latency, local (same socket) vs remote (cross socket),
  * per-critical-section shared-data lines whose home socket follows the last
    writer (this is what makes NUMA-aware *admission order* matter),
  * global-spinning coherence storms that scale with the number of spinners
    (TAS/ticket/HBO), vs local spinning (MCS/CNA/cohort),
  * queue-node scan costs for CNA's find_successor.

Time is in CPU cycles; throughput is reported in ops/us assuming ``freq_ghz``.
Everything is driven by one ``random.Random(seed)`` => bit-for-bit
reproducible.  The simulator is intentionally *not* a cycle-accurate cache
model — it is the smallest model that exhibits the paper's phenomena
(Figs. 6-10): MCS collapse from 1->2 threads, flat MCS under contention,
CNA == MCS single-thread, CNA ~ hierarchical locks contended, fairness factors,
and remote-miss-rate separation.
"""

from __future__ import annotations

import heapq
import random
from dataclasses import dataclass, field, replace

from .topology import Topology, flat


@dataclass(frozen=True)
class CostModel:
    """Cycle costs.  Presets calibrated against the paper's two machines."""

    freq_ghz: float = 2.3
    c_atomic: int = 30          # uncontended atomic RMW
    c_l1: int = 8               # hit on socket-local (or own) line
    c_local_xfer: int = 60      # cache-line transfer within a socket
    c_remote_xfer: int = 400    # cache-line transfer across sockets
    c_cross_xfer: int = 1000    # cache-line transfer across groups (e.g. pods)
    c_storm: int = 18           # extra per-spinner cost for global spinning
    c_scan_local: int = 10      # CNA find_successor: inspect local node
    c_scan_remote: int = 70     # CNA find_successor: inspect remote node
    c_preempt: int = 10_000     # effective cycles lost when the grantee was
                                # descheduled (oversubscription, n_cores set).
                                # Fitted against the published GCR collapse
                                # curves — an order-of-magnitude throughput
                                # drop at 2x oversubscription (Dice & Kogan
                                # 2019, Figs. 1-2); the grid fit lives in
                                # benchmarks/restriction_bench.py calibrate()
                                # and asserts this default stays the argmin.
    cs_base: int = 450          # critical-section compute (AVL ops etc.)
    n_write_lines: int = 2      # shared lines written per CS (migrate w/ owner)
    n_read_lines: int = 4       # shared lines read per CS (miss if dirty-remote)
    noncs_base: int = 150       # non-critical work between ops ("external work")

    def xfer(self, s_from: int, s_to: int) -> int:
        return self.c_local_xfer if s_from == s_to else self.c_remote_xfer


# Two machines from the paper (Section 7).  The 4-socket machine has a higher
# remote-miss cost — the paper infers this from the deeper 1->2 thread drop.
TWO_SOCKET = CostModel()
FOUR_SOCKET = replace(TWO_SOCKET, c_remote_xfer=700, c_scan_remote=100)


@dataclass
class SimResult:
    name: str
    n_threads: int
    n_sockets: int
    ops: int
    cycles: int
    per_thread_ops: list[int] = field(default_factory=list)
    remote_transfers: int = 0
    local_transfers: int = 0
    handovers: int = 0
    shuffles: int = 0
    preemptions: int = 0

    @property
    def throughput_ops_per_us(self) -> float:
        if self.cycles == 0:
            return 0.0
        us = self.cycles / (TWO_SOCKET.freq_ghz * 1000.0)
        return self.ops / us

    @property
    def fairness_factor(self) -> float:
        """Paper Section 7.1.1: sort per-thread op counts descending; fraction
        of total ops done by the top half of threads.  0.5 = strictly fair."""
        counts = sorted(self.per_thread_ops, reverse=True)
        total = sum(counts)
        if total == 0:
            return 1.0
        half = max(1, len(counts) // 2)
        return sum(counts[:half]) / total

    @property
    def remote_rate(self) -> float:
        """Remote cache-line transfers per operation — the LLC-miss proxy."""
        return self.remote_transfers / max(1, self.ops)


class LockSim:
    """Base class for simulated lock disciplines.

    Subclasses see only: thread arrival, release, and the shared RNG/cost
    model; they return grant decisions and charge transfer costs through
    the provided ``charge`` callbacks so accounting stays centralised.
    """

    name = "base"

    def __init__(self, sim: "Simulator") -> None:
        self.sim = sim
        self.cm = sim.cm
        self.rng = sim.rng
        # tids currently passivated (blocked in the kernel, not runnable);
        # maintained by concurrency-restricting disciplines, read by the
        # simulator's oversubscription/preemption model.
        self.parked: set[int] = set()

    # returns cycles-until-grant if the arriving thread acquires immediately,
    # or None if it must wait.
    def arrive(self, tid: int) -> int | None:
        raise NotImplementedError

    # returns (next_tid, handover_cycles) or None if the lock becomes free.
    def release(self, tid: int) -> tuple[int, int] | None:
        raise NotImplementedError

    # Called by the event loop with the *total* handover latency (discipline
    # cost + any preemption penalty) after every handover.  Adaptive locks
    # forward this to their concurrency controller; the default is a no-op.
    def observe_handover(self, cycles: int) -> None:
        pass

    def socket(self, tid: int) -> int:
        return self.sim.socket_of(tid)


class Simulator:
    """Event loop.  Threads cycle: non-CS work -> arrive -> (wait) -> CS -> release."""

    def __init__(
        self,
        lock_cls,
        n_threads: int,
        n_sockets: int | None = None,
        cm: CostModel | None = None,
        *,
        seed: int = 42,
        duration_cycles: int = 20_000_000,
        noncs_cycles: int | None = None,
        lock_kwargs: dict | None = None,
        topology: Topology | None = None,
        n_cores: int | None = None,
    ) -> None:
        if topology is None:
            topology = flat(n_sockets if n_sockets is not None else 2)
        elif n_sockets is not None and n_sockets != topology.n_domains:
            raise ValueError(
                f"n_sockets={n_sockets} conflicts with topology "
                f"{topology.name!r} ({topology.n_domains} domains); pass one"
            )
        self.topology = topology
        self.cm = cm or TWO_SOCKET
        self.rng = random.Random(seed)
        self.n_threads = n_threads
        self.n_sockets = topology.n_domains
        self.duration = duration_cycles
        self.noncs = self.cm.noncs_base if noncs_cycles is None else noncs_cycles
        # n_cores models oversubscription: when more threads are runnable than
        # cores, a granted thread may have been descheduled and eats a quantum
        # (c_preempt) before it notices the handover — the collapse mechanism
        # concurrency restriction exists to avoid.  None disables the model.
        self.n_cores = n_cores
        self.lock = lock_cls(self, **(lock_kwargs or {}))
        # shared-data line ownership (tid of last writer); -1 = clean.
        # Core granularity matters: a line written by another core on the
        # *same* socket still costs an L2/LLC transfer (c_local_xfer), which
        # is why contended-local CS is slower than single-thread CS.
        self._write_owner = [-1] * self.cm.n_write_lines
        self._read_dirty = [-1] * self.cm.n_read_lines
        self.result = SimResult(
            name=self.lock.name,
            n_threads=n_threads,
            n_sockets=self.n_sockets,  # topology's domain count, never None
            ops=0,
            cycles=0,
            per_thread_ops=[0] * n_threads,
        )
        self._events: list[tuple[int, int, str, int]] = []  # (time, seq, kind, tid)
        self._seq = 0

    # Thread placement is the topology's business (the paper does not pin
    # threads; flat round-robin approximates a loaded scheduler's spread).
    def socket_of(self, tid: int) -> int:
        return self.topology.domain_of(tid)

    # -- accounting helpers used by lock disciplines -------------------------
    def charge_xfer(self, s_from: int, s_to: int) -> int:
        if s_from == s_to:
            self.result.local_transfers += 1
            return self.cm.c_local_xfer
        self.result.remote_transfers += 1
        return self.topology.xfer_cycles(self.cm, s_from, s_to)

    def preempt_penalty(self) -> int:
        """Grantee-wakeup penalty under oversubscription (0 if n_cores unset,
        so pre-existing seeds consume an identical RNG stream)."""
        if self.n_cores is None:
            return 0
        runnable = self.n_threads - len(self.lock.parked)
        if runnable <= self.n_cores:
            return 0
        if self.rng.random() < 1.0 - self.n_cores / runnable:
            self.result.preemptions += 1
            return self.cm.c_preempt
        return 0

    def _push(self, t: int, kind: str, tid: int) -> None:
        self._seq += 1
        heapq.heappush(self._events, (t, self._seq, kind, tid))

    def _cs_cycles(self, tid: int) -> int:
        """Critical-section cost under the data-locality model."""
        s = self.socket_of(tid)
        cm = self.cm
        c = cm.cs_base

        def line_cost(owner_tid: int) -> int:
            if owner_tid in (-1, tid):
                return cm.c_l1
            return self.charge_xfer(self.socket_of(owner_tid), s)

        for i in range(cm.n_write_lines):
            c += line_cost(self._write_owner[i])
            self._write_owner[i] = tid
        for i in range(cm.n_read_lines):
            c += line_cost(self._read_dirty[i])
            self._read_dirty[i] = -1  # read pulls the line into shared state
        # occasionally a read line is written (update ops) => dirty again
        if self.rng.random() < 0.25:
            self._read_dirty[self.rng.randrange(cm.n_read_lines)] = tid
        return c

    def _noncs_cycles(self) -> int:
        if self.noncs == 0:
            return self.rng.randrange(20, 60)  # loop overhead/jitter
        return int(self.noncs * self.rng.uniform(0.9, 1.1))

    # -- main loop ------------------------------------------------------------
    def run(self) -> SimResult:
        for tid in range(self.n_threads):
            self._push(self._noncs_cycles(), "arrive", tid)
        now = 0
        while self._events:
            now, _, kind, tid = heapq.heappop(self._events)
            if now >= self.duration:
                break
            if kind == "arrive":
                delay = self.lock.arrive(tid)
                if delay is not None:
                    self._push(now + delay, "enter", tid)
            elif kind == "enter":  # lock granted; run the critical section
                self._push(now + self._cs_cycles(tid), "release", tid)
            elif kind == "release":
                self.result.ops += 1
                self.result.per_thread_ops[tid] += 1
                nxt = self.lock.release(tid)
                if nxt is not None:
                    ntid, cost = nxt
                    cost += self.preempt_penalty()
                    self.result.handovers += 1
                    self.lock.observe_handover(cost)
                    self._push(now + cost, "enter", ntid)
                self._push(now + self._noncs_cycles(), "arrive", tid)
        self.result.cycles = min(now, self.duration)
        return self.result


def run_sweep(
    lock_cls,
    thread_counts,
    n_sockets: int | None = None,
    cm: CostModel | None = None,
    *,
    seed: int = 42,
    duration_cycles: int = 20_000_000,
    noncs_cycles: int | None = None,
    lock_kwargs: dict | None = None,
    topology: Topology | None = None,
    n_cores: int | None = None,
) -> list[SimResult]:
    out = []
    for n in thread_counts:
        sim = Simulator(
            lock_cls,
            n,
            n_sockets,
            cm,
            seed=seed,
            duration_cycles=duration_cycles,
            noncs_cycles=noncs_cycles,
            lock_kwargs=lock_kwargs,
            topology=topology,
            n_cores=n_cores,
        )
        out.append(sim.run())
    return out
