"""Logical-axis sharding: MaxText-style rules mapping logical axes to mesh axes.

Params and activations are annotated with *logical* axis names; a rules table
maps those to mesh axes (with automatic divisibility fallback to replication).
On a single-device CPU (smoke tests) the context is unset and every constraint
is a no-op, so model code is identical between tests and the 512-device
dry-run.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (str, tuple of axes, or None)
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),     # global batch across pod+data
    "seq": "model",               # residual-stream sequence sharding (Megatron-SP)
    "embed": None,                # residual d_model stays unsharded
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "kv_seq": "model",            # decode KV-cache sequence sharding
    "mlp": "model",
    "fsdp": "data",               # weight-matrix dim sharded ZeRO-style
    "expert": "data",             # expert parallelism (when divisible)
    "layers": None,
    "conv": None,
    "state": None,
    "stack": None,
}


@dataclass
class MeshContext:
    mesh: Mesh
    rules: dict[str, Any] = field(default_factory=lambda: dict(DEFAULT_RULES))

    def axis_size(self, name) -> int:
        if name is None:
            return 1
        if isinstance(name, (tuple, list)):
            out = 1
            for n in name:
                out *= self.axis_size(n)
            return out
        return self.mesh.shape[name] if name in self.mesh.axis_names else 0


_ctx = threading.local()
_NO_MESH = object()          # sentinel: traces ran with no mesh context
# last mesh traced under (for cache invalidation); starts at the no-mesh
# sentinel so the first use_mesh entry also invalidates anything traced at
# top level before it (costs one clear of a cold cache at process start)
_last_mesh: list = [_NO_MESH]


def current_ctx() -> MeshContext | None:
    return getattr(_ctx, "value", None)


def _note_mesh(mesh) -> None:
    """Invalidate jax's trace caches when the effective mesh changes.

    jax's internal trace caches key on function identity + avals, NOT on our
    mesh context, so a re-trace under a *different* mesh (or under none, via
    the ``_NO_MESH`` sentinel) can reuse a jaxpr whose sharding constraints
    reference the old device set (the elastic-restart bug).  Clearing only on
    an actual mesh change keeps the common single-mesh path at full cache
    speed.  The sentinel (and jax's caches) are process-global, so a workload
    that alternates meshes — across iterations or threads — recompiles on
    every switch; give such a workload one mesh per *process*.  Known hole:
    tracing at top level (outside any ``use_mesh``) after mesh use is not a
    hookable transition — enter ``use_mesh(None)`` to trace mesh-free."""
    if mesh != _last_mesh[0]:
        jax.clear_caches()
        _last_mesh[0] = mesh


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None, rules: dict[str, Any] | None = None):
    old = getattr(_ctx, "value", None)
    if mesh is None:
        _note_mesh(_NO_MESH)
        _ctx.value = None
    else:
        _note_mesh(mesh)
        r = dict(DEFAULT_RULES)
        if rules:
            r.update(rules)
        _ctx.value = MeshContext(mesh, r)
    try:
        yield _ctx.value
    finally:
        _ctx.value = old
        if old is not None:
            # re-entering an outer context is also a mesh transition: code
            # after a nested `use_mesh(B)` block traces under A again
            _note_mesh(old.mesh)


def _resolve(logical, dim: int, ctx: MeshContext):
    """Map one logical axis to a mesh axis, replicating when not divisible."""
    if logical is None:
        return None
    mesh_axis = ctx.rules.get(logical, None)
    if mesh_axis is None:
        return None
    size = ctx.axis_size(mesh_axis)
    if size == 0:  # mesh axis absent (e.g. no 'pod' on single-pod mesh)
        if isinstance(mesh_axis, (tuple, list)):
            present = tuple(a for a in mesh_axis if a in ctx.mesh.axis_names)
            if not present:
                return None
            sz = 1
            for a in present:
                sz *= ctx.mesh.shape[a]
            if sz and dim % sz == 0:
                return present if len(present) > 1 else present[0]
        return None
    if dim % size != 0:
        return None
    return tuple(mesh_axis) if isinstance(mesh_axis, list) else mesh_axis


def spec_for(shape: tuple[int, ...], logical_axes: tuple[Any, ...]) -> P:
    ctx = current_ctx()
    if ctx is None:
        return P()
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    used: set = set()
    parts = []
    for dim, ax in zip(shape, logical_axes):
        resolved = _resolve(ax, dim, ctx)
        # one mesh axis may appear only once in a spec
        flat = resolved if isinstance(resolved, tuple) else (resolved,)
        if resolved is not None and any(f in used for f in flat):
            resolved = None
        if resolved is not None:
            used.update(flat)
        parts.append(resolved)
    return P(*parts)


def shard(x: jax.Array, *logical_axes) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh ctx."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = spec_for(x.shape, tuple(logical_axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def sharding_for(shape: tuple[int, ...], logical_axes: tuple[Any, ...]):
    ctx = current_ctx()
    if ctx is None:
        return None
    return NamedSharding(ctx.mesh, spec_for(shape, logical_axes))


def tree_shardings(abstract_tree, logical_tree):
    """Build a NamedSharding pytree for (abstract shapes, logical axes)."""
    ctx = current_ctx()
    if ctx is None:
        return None
    return jax.tree.map(
        lambda a, l: NamedSharding(ctx.mesh, spec_for(a.shape, tuple(l))),
        abstract_tree,
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(i, (str, type(None))) for i in x),
    )
