"""Deterministic trace-style workload generator for the region tier.

The benches so far drove routers with synthetic session lists drawn from one
Zipf; a region of fleets needs the traffic shape that actually stresses the
third hierarchy level:

  * **millions of simulated users** — user ids are drawn from a large space
    (``user_space``, default 10M); what matters is that per-request state
    cannot be keyed per-user, only per-tenant prefix pools stay warm;
  * **per-tenant Zipf prefix mixes** — each ``TenantProfile`` owns a private
    pool of prompt templates ("system prompts") and draws from it with its
    own skew, so tenants have disjoint working sets and a router that mixes
    them across fleets thrashes every fleet's KV budget;
  * **diurnal arrival waves, phase-shifted per region** — arrival intensity
    follows a sinusoid over ``DiurnalWave.period`` ticks, with region ``r``'s
    peak shifted by ``r / n_regions`` of a period (the sun moves), so fleet
    load is never uniform and the region tier always has a busy side;
  * **conversation follow-ups** — a request spawns later turns with
    probability ``followup_p``; the child prompt is the parent prompt plus
    the parent's (deterministic) output tokens plus a fresh user suffix, the
    exact shape the serving engine's retirement deposits (PR 5) make cheap:
    a fleet that deposited ``prompt + output`` at retirement serves the
    follow-up's re-prefill almost for free;
  * **regional skew** — each tenant has a home region where its traffic
    concentrates (``home_bias``); conversations stay in the region they
    started in.

Everything is driven by explicit ``random.Random`` instances derived from
one seed — no module-level RNG, no wall clock — so ``generate()`` is a pure
function of its arguments and the *same* ``Trace`` object replays the same
schedule to every routing arm (paired comparisons; see
``benchmarks/region_bench.py``).

``output_tokens(rid, n)`` is the one shared convention: the generator builds
follow-up prompts from it, and the region simulator deposits exactly those
tokens at session retirement — so a deposit-on arm's caches hold precisely
what the next turn's prompt re-uses.
"""

from __future__ import annotations

import bisect
import math
import random
from dataclasses import dataclass, replace


def output_tokens(rid: int, n: int) -> tuple:
    """The deterministic decode output of request ``rid`` (``n`` tokens).

    Shared between the generator (follow-up prompts embed the parent's
    output) and the region simulator (retirement deposits insert it), so the
    two sides agree bit-for-bit without any channel between them."""
    return tuple(800_000_000 + rid * 1_009 + j for j in range(n))


def prefix_tokens(tenant: int, pid: int, n: int) -> tuple:
    """Template ``pid`` of ``tenant``'s prompt pool — tenant-namespaced so
    pools never collide across tenants."""
    base = 1_000_000 * (tenant + 1) + 1_000 * pid
    return tuple(base + j for j in range(n))


@dataclass(frozen=True)
class TenantProfile:
    """One tenant's traffic shape.  ``weight`` is its share of arrivals
    (before regional bias), ``n_prefixes``/``prefix_skew`` its private Zipf
    prompt-template mix, ``home_region``/``home_bias`` the regional skew
    (bias multiplies its weight in the home region), ``followup_p`` the
    per-turn probability a conversation continues."""

    tenant: int
    weight: float = 1.0
    n_prefixes: int = 8
    prefix_skew: float = 0.9
    prefix_len: int = 64
    suffix_len: int = 12
    decode_len: int = 16
    home_region: int = 0
    home_bias: float = 4.0
    followup_p: float = 0.0
    think_time: int = 200      # mean ticks between a reply and the next turn


@dataclass(frozen=True)
class DiurnalWave:
    """Sinusoidal arrival intensity: rate(t) = base * (1 + amplitude *
    sin(2pi * (t/period - phase))), phase = region / n_regions."""

    period: int = 2048
    amplitude: float = 0.8


@dataclass(frozen=True)
class TraceRequest:
    """One scheduled request.  ``t`` is the arrival tick; ``conv`` names the
    conversation (the opener's ``rid``), ``turn`` its position in it, and
    ``parent`` the previous turn's ``rid`` (None for openers)."""

    rid: int
    t: int
    tenant: int
    user: int
    region: int
    prompt: tuple
    decode_len: int
    conv: int
    turn: int = 0
    parent: int | None = None


@dataclass(frozen=True)
class Trace:
    """An immutable, fully materialized request schedule (time-sorted)."""

    requests: tuple
    n_regions: int
    seed: int
    horizon: int

    def __len__(self) -> int:
        return len(self.requests)

    def tenants(self) -> list[int]:
        return sorted({r.tenant for r in self.requests})

    def arrivals_by_region(self) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {r: [] for r in range(self.n_regions)}
        for req in self.requests:
            out[req.region].append(req.t)
        return out


class _Zipf:
    """Inverse-CDF Zipf sampler over ``n`` items (rank-1 hottest)."""

    def __init__(self, n: int, skew: float) -> None:
        w = [1.0 / (k + 1) ** skew for k in range(n)]
        tot = sum(w)
        acc, self._cdf = 0.0, []
        for x in w:
            acc += x / tot
            self._cdf.append(acc)

    def draw(self, rng: random.Random) -> int:
        # clamp: fp rounding can leave cdf[-1] a hair under 1.0
        return min(bisect.bisect_left(self._cdf, rng.random()), len(self._cdf) - 1)


class TraceGenerator:
    """Seeded diurnal multi-tenant trace generator (see module docstring).

    ``base_rate`` is mean arrivals per tick per region at wave midline; the
    per-region arrival streams are sampled by thinning a homogeneous Poisson
    process at the wave's peak rate, each from its own ``random.Random``
    derived from (seed, region) — so adding a region, or re-ordering the
    tenant list, never perturbs another region's stream."""

    def __init__(
        self,
        *,
        n_regions: int,
        tenants,
        seed: int = 0,
        wave: DiurnalWave | None = None,
        base_rate: float = 0.04,
        user_space: int = 10_000_000,
        service_estimate: int = 150,
    ) -> None:
        if n_regions < 1:
            raise ValueError("need at least one region")
        self.n_regions = n_regions
        self.tenants = tuple(tenants)
        if not self.tenants:
            raise ValueError("need at least one tenant profile")
        for p in self.tenants:
            if not 0 <= p.home_region < n_regions:
                raise ValueError(
                    f"tenant {p.tenant} homed in region {p.home_region}, "
                    f"but the trace has {n_regions} regions"
                )
        self.seed = seed
        self.wave = wave or DiurnalWave()
        self.base_rate = base_rate
        self.user_space = user_space
        self.service_estimate = service_estimate
        self._zipf = {p.tenant: _Zipf(p.n_prefixes, p.prefix_skew) for p in self.tenants}

    def rate(self, region: int, t: int) -> float:
        """Instantaneous arrival intensity of ``region`` at tick ``t``."""
        w = self.wave
        phase = region / self.n_regions
        return self.base_rate * (
            1.0 + w.amplitude * math.sin(2.0 * math.pi * (t / w.period - phase))
        )

    def _tenant_weights(self, region: int) -> tuple[list[float], list[TenantProfile]]:
        profs = list(self.tenants)
        weights = [
            p.weight * (p.home_bias if p.home_region == region else 1.0) for p in profs
        ]
        return weights, profs

    def generate(self, horizon: int) -> Trace:
        """Materialize the schedule over ``[0, horizon)`` ticks (follow-up
        turns may land past the horizon; they are kept — a conversation that
        started inside the window finishes)."""
        reqs: list[TraceRequest] = []
        rid = 0
        peak = self.base_rate * (1.0 + self.wave.amplitude)
        for region in range(self.n_regions):
            rng = random.Random((self.seed << 8) ^ (0xA11CE + region))
            weights, profs = self._tenant_weights(region)
            t = 0.0
            while True:
                t += rng.expovariate(peak) if peak > 0 else horizon
                if t >= horizon:
                    break
                # thinning: accept with prob rate(t)/peak -> inhomogeneous
                # Poisson with the region's phase-shifted diurnal intensity
                if rng.random() * peak > self.rate(region, int(t)):
                    continue
                p = rng.choices(profs, weights=weights, k=1)[0]
                user = rng.randrange(self.user_space)
                pid = self._zipf[p.tenant].draw(rng)
                prompt = prefix_tokens(p.tenant, pid, p.prefix_len) + tuple(
                    500_000_000 + rid * 1_009 + j for j in range(p.suffix_len)
                )
                conv = rid
                reqs.append(
                    TraceRequest(
                        rid=rid, t=int(t), tenant=p.tenant, user=user,
                        region=region, prompt=prompt, decode_len=p.decode_len,
                        conv=conv,
                    )
                )
                rid += 1
                # conversation chain: geometric number of follow-up turns,
                # each thinking after the previous turn's estimated reply
                cur_prompt, cur_t, parent, turn = prompt, t, conv, 1
                while p.followup_p > 0 and rng.random() < p.followup_p:
                    cur_t += self.service_estimate + rng.expovariate(
                        1.0 / max(1, p.think_time)
                    )
                    cur_prompt = (
                        cur_prompt
                        + output_tokens(parent, p.decode_len)
                        + tuple(500_000_000 + rid * 1_009 + j for j in range(p.suffix_len))
                    )
                    reqs.append(
                        TraceRequest(
                            rid=rid, t=int(cur_t), tenant=p.tenant, user=user,
                            region=region, prompt=cur_prompt,
                            decode_len=p.decode_len, conv=conv, turn=turn,
                            parent=parent,
                        )
                    )
                    parent = rid
                    rid += 1
                    turn += 1
        reqs.sort(key=lambda r: (r.t, r.rid))
        return Trace(
            requests=tuple(reqs), n_regions=self.n_regions,
            seed=self.seed, horizon=horizon,
        )


def uniform_tenants(
    n_tenants: int,
    n_regions: int,
    *,
    followup_p: float = 0.0,
    **overrides,
) -> list[TenantProfile]:
    """Equal-weight tenants homed round-robin over regions — the baseline
    multi-tenant mix benches start from.  ``overrides`` apply to every
    profile (e.g. ``prefix_len=96``)."""
    return [
        TenantProfile(
            tenant=i, home_region=i % n_regions, followup_p=followup_p, **overrides
        )
        for i in range(n_tenants)
    ]


def with_flood(tenants, *, tenant: int = 0, weight: float = 30.0,
               n_prefixes: int = 1) -> list[TenantProfile]:
    """Turn one tenant into an adversarial hot-prefix flood: its weight
    swamps the mix and its whole volume lands on a single prompt template —
    the scenario tenant fairness caps exist for."""
    out = []
    for p in tenants:
        if p.tenant == tenant:
            p = replace(p, weight=weight, n_prefixes=n_prefixes)
        out.append(p)
    return out
