"""CNA expert-parallel MoE: equivalence with the TP layer + locality wins."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import ParamBuilder
from repro.models.moe import declare_moe
from repro.models.moe_ep import ep_routing_stats

from _subproc import REPO_ROOT, run_env


def _cfg(**kw):
    base = dict(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=4, n_kv=4,
        d_ff=64, vocab=128, n_experts=8, top_k=2, moe_d_ff=48,
        capacity_factor=4.0, ep_remote_capacity_factor=1.0,
    )
    base.update(kw)
    return ModelConfig(**base)


_EP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelConfig
    from repro.models.common import ParamBuilder
    from repro.models.moe import declare_moe, moe_apply
    from repro.models.moe_ep import moe_apply_ep
    from repro.models.sharding import use_mesh

    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32, n_heads=4,
                      n_kv=4, d_ff=64, vocab=128, n_experts=8, top_k=2, moe_d_ff=48,
                      capacity_factor=4.0, ep_remote_capacity_factor=2.0)
    pb = ParamBuilder(dtype=jnp.float32)
    declare_moe(pb, "moe", cfg)
    params = pb.init(jax.random.PRNGKey(0))["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32), jnp.float32)

    # reference: the TP (local-dispatch) layer, generous capacity, no mesh
    ref, _ = moe_apply(params, x, cfg)

    mesh = jax.make_mesh((4,), ("data",))
    with use_mesh(mesh):
        out, aux = jax.jit(lambda p, x: moe_apply_ep(p, x, cfg))(params, x)
    err = float(jnp.max(jnp.abs(out - ref)))
    # generous capacities => no drops on either path => near-exact agreement
    assert err < 1e-4, err
    print("EP_OK", err)
""")


def test_ep_matches_tp_reference():
    proc = subprocess.run(
        [sys.executable, "-c", _EP_SCRIPT], capture_output=True, text=True, timeout=600,
        env=run_env(), cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "EP_OK" in proc.stdout


def test_cna_bias_raises_locality_and_cuts_drops():
    """The paper's main-queue preference: biased routing keeps most tokens on
    their own shard, so the remote exchange can be provisioned smaller at the
    same drop rate."""
    key = jax.random.PRNGKey(0)
    pb = ParamBuilder(dtype=jnp.float32)
    cfg0 = _cfg(cna_routing=False, ep_remote_capacity_factor=0.5)
    declare_moe(pb, "moe", cfg0)
    params = pb.init(key)["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, 32), jnp.float32)

    s_off = ep_routing_stats(params, x, cfg0, n_ep=4)
    cfg1 = _cfg(cna_routing=True, cna_routing_bias=2.0, ep_remote_capacity_factor=0.5)
    s_on = ep_routing_stats(params, x, cfg1, n_ep=4)

    assert s_on["locality"] > s_off["locality"] + 0.2, (s_on["locality"], s_off["locality"])
    assert s_on["drop_rate"] <= s_off["drop_rate"] + 1e-9
