"""granite-3-8b [dense]: 40L d=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
Source: hf:ibm-granite family (GQA, SwiGLU, RoPE)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv=8, d_ff=12800, vocab=49155,
    mlp="swiglu", accum=2,
)

def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                          vocab=512, accum=1, attn_chunk=64)
