"""CNA admission vs FIFO in the serving scheduler (the paper's policy carried
to the decode engine).  Two levels:

  * policy-level (fast): thousands of requests through the scheduler with a
    simulated switch cost — throughput/locality/fairness curves vs the
    fairness threshold (the paper's Fig. 6/8 trade-off, serving edition);
  * engine-level (slower): a real reduced-config model decode on CPU.
"""

from __future__ import annotations

import numpy as np

from repro.serving.scheduler import CNAScheduler, FIFOScheduler

from .common import claim, table


def policy_level(n_requests=4000, domains=4, switch_cost=8, service=1, seed=7):
    rows = []
    results = {}
    for name, mk in [
        ("fifo", lambda: FIFOScheduler()),
        ("cna_thr3", lambda: CNAScheduler(fairness_threshold=0x3, seed=seed)),
        ("cna_thrF", lambda: CNAScheduler(fairness_threshold=0xF, seed=seed)),
        ("cna_thrFF", lambda: CNAScheduler(fairness_threshold=0xFF, seed=seed)),
        ("cna_thrFFFF", lambda: CNAScheduler(fairness_threshold=0xFFFF, seed=seed)),
        # GCR-style admission control: only 16 requests circulate in the CNA
        # queues at once, the rest wait passivated.
        ("cna_rcr16", lambda: CNAScheduler(fairness_threshold=0xFF, seed=seed, max_active=16)),
    ]:
        rng = np.random.default_rng(seed)
        s = mk()
        t = 0
        # Poisson-ish arrivals, random domains; serve one request per grant
        arrivals = list(rng.integers(0, domains, n_requests))
        ai = 0
        served = 0
        while served < n_requests:
            # arrivals trickle in (2 per tick) so the queue has depth
            for _ in range(2):
                if ai < n_requests:
                    s.submit(f"r{ai}", int(arrivals[ai]))
                    ai += 1
            if len(s):
                before = s.current_domain
                s.next_request()
                served += 1
                t += service + (switch_cost if s.current_domain != before else 0)
            s.tick()
        m = s.metrics
        waits = np.array(m.waits)
        rows.append([name, n_requests / t, m.locality, m.domain_switches,
                     m.fairness_factor(), float(waits.mean()), float(np.percentile(waits, 99))])
        results[name] = (n_requests / t, m.locality, m.fairness_factor())
    table(
        f"serving scheduler policy level ({n_requests} reqs, {domains} domains, switch={switch_cost})",
        ["policy", "throughput", "locality", "switches", "fairness", "wait_mean", "wait_p99"],
        rows,
    )
    claim("serving: CNA throughput > FIFO (switch-cost amortised)",
          results["cna_thrFF"][0] > 1.5 * results["fifo"][0],
          f"{results['cna_thrFF'][0]:.3f} vs {results['fifo'][0]:.3f}")
    claim("serving: CNA locality >> FIFO",
          results["cna_thrFF"][1] > 0.8 > results["fifo"][1], "")
    claim("serving: fairness knob works (thr3 fairer than thrFFFF)",
          results["cna_thr3"][2] <= results["cna_thrFFFF"][2] + 1e-9,
          f"{results['cna_thr3'][2]:.3f} vs {results['cna_thrFFFF'][2]:.3f}")


def engine_level():
    import jax

    from repro.configs.base import get_reduced_config
    from repro.models.registry import build_model
    from repro.serving.engine import DecodeEngine, Request

    cfg = get_reduced_config("granite_3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    base = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                max_new=4, domain=i % 2)
        for i in range(16)
    ]
    rows = []
    stats = {}
    for name, sched in [("cna", CNAScheduler(fairness_threshold=0xF)), ("fifo", FIFOScheduler())]:
        reqs = [Request(r.rid, r.prompt, r.max_new, r.domain) for r in base]
        eng = DecodeEngine(model, params, n_slots=4, cache_len=32,
                           scheduler=sched, domain_switch_cost=8)
        eng.run(reqs)
        m = eng.scheduler.metrics
        rows.append([name, eng.sim_time, m.locality, m.domain_switches, m.fairness_factor()])
        stats[name] = eng.sim_time
    table("serving engine level (reduced granite, real decode)",
          ["policy", "sim_time", "locality", "switches", "fairness"], rows)
    claim("serving engine: CNA completes sooner than FIFO",
          stats["cna"] < stats["fifo"], f"{stats['cna']} vs {stats['fifo']}")


def run_all():
    policy_level()
    engine_level()
