"""Observability tier: the cost of watching, and the books balancing.

Two claims ride here, both jax-free (the fleet simulator is the workload, so
this module sits in the CI smoke lane):

  * zero-cost-off / bounded-overhead — a fleet run with a ``repro.obs.Tracer``
    attached must produce a numerically identical ``FleetResult`` (same grant
    orders, same stalls, to the last integer: the tracer never consumes shared
    RNG and never takes a branch the untraced run doesn't), and the traced
    run's wall-clock must stay within a generous bound of the untraced one
    (span emission is dataclass appends next to real event-loop work);
  * attribution conservation — per session AND in aggregate, the four phase
    spans (``queue_wait + dispatch + ship_wait + prefill``) sum *exactly* to
    the admission stall (submit -> first token).  No cycle invented, none
    lost.  The property-test version lives in tests/test_obs.py; this is the
    same law checked at bench scale with KV shipping on (the hardest arm:
    ship waits and partial prefills in the mix).

The section's headline numbers are sourced from the unified
``repro.obs.MetricsRegistry`` (``common.headline_registry``) — the same
registry the stat surfaces register into as live views — and the per-request
flame summary demonstrates the exporter path end to end.
"""

from __future__ import annotations

import random
import time
from dataclasses import asdict

from repro.obs import MetricsRegistry, Tracer, flame, render_prometheus
from repro.router import ShipCostModel, shared_prefix_sessions, simulate

from . import common
from .common import ascii_plot, claim, smoke, table, zipf_draws


def _workload(n_sessions, seed):
    rng = random.Random(seed)
    draws = zipf_draws(n_sessions, 12, 0.7, rng)
    return lambda: shared_prefix_sessions(draws, 96, 16, 32)


def tracing_overhead(n_sessions=600, n_replicas=4, seed=31):
    n_sessions = smoke(n_sessions, 150)
    mk = _workload(n_sessions, seed)
    kw = dict(n_replicas=n_replicas, inter_arrival=12, seed=seed,
              kv_ship=ShipCostModel())

    simulate("federated", mk(), **kw)  # warm imports out of the timing
    t0 = time.perf_counter()
    off = simulate("federated", mk(), **kw)
    off_wall = time.perf_counter() - t0

    tr = Tracer()
    reg = MetricsRegistry()
    t0 = time.perf_counter()
    on = simulate("federated", mk(), tracer=tr, registry=reg, **kw)
    on_wall = time.perf_counter() - t0
    overhead = on_wall / max(off_wall, 1e-9)

    table("tracing overhead (federated + KV shipping, fleet sim)",
          ["arm", "wall_s", "spans", "admission_stall"],
          [["tracer_off", f"{off_wall:.3f}", 0, off.admission_stall_total],
           ["tracer_on", f"{on_wall:.3f}", len(tr.spans), on.admission_stall_total]])
    claim("obs: fleet results identical with tracer on (zero-cost-off)",
          asdict(off) == asdict(on), "")
    claim("obs: fleet tracing overhead bounded (<= 2.5x wall)",
          overhead <= 2.5, f"{overhead:.2f}x for {len(tr.spans)} spans")
    claim("obs: every span closed at drain", not tr.check(),
          f"{len(tr.check())} open")
    common.headline(tracing_overhead_x=overhead, spans=len(tr.spans))
    common.headline_registry(reg)
    return on, tr, reg


def conservation(result, tracer):
    """queue_wait + dispatch + ship_wait + prefill == admission stall,
    exactly — per session and in aggregate."""
    agg_ok = sum(result.phase_cycles.values()) == result.admission_stall_total
    bad = 0
    for trace in tracer.traces():
        phases = tracer.phase_cycles(trace)
        spans = {s.name: s for s in tracer.for_trace(trace)}
        root, prefill = spans.get("session"), spans.get("phase.prefill")
        if root is None or prefill is None or (
            sum(phases.values()) != prefill.end - root.start
        ):
            bad += 1
    table("latency attribution (cycles, summed over sessions)",
          ["phase", "cycles"],
          [[k, v] for k, v in result.phase_cycles.items()]
          + [["= admission_stall_total", result.admission_stall_total]])
    claim("obs: attribution conserves cycles in aggregate", agg_ok,
          f"sum={sum(result.phase_cycles.values())} "
          f"stall={result.admission_stall_total}")
    claim("obs: attribution conserves cycles per session", bad == 0,
          f"{bad} sessions off")
    common.headline(**{f"phase_{k}": v for k, v in result.phase_cycles.items()})
    # the attribution, session by session: total stall and its queue-wait
    # share, sorted by stall — the flame summary's aggregate sibling
    per = sorted(
        (sum(tracer.phase_cycles(t).values()),
         tracer.phase_cycles(t).get("queue_wait", 0))
        for t in tracer.traces()
    )
    ascii_plot("admission stall attribution per session (sorted by stall)",
               list(range(len(per))),
               {"stall": [p[0] for p in per], "queue_wait": [p[1] for p in per]})


def exporters(tracer, registry):
    """Exercise the exporter surface at bench scale: the Prometheus text
    rendering and one per-request flame summary (deepest session)."""
    prom = render_prometheus(registry)
    claim("obs: prometheus rendering covers the registry",
          all(n.split("{")[0] or True for n in registry.names())
          and len(prom.splitlines()) >= len(registry.names()),
          f"{len(prom.splitlines())} lines / {len(registry.names())} metrics")
    deepest = max(tracer.traces(), key=lambda t: len(tracer.for_trace(t)))
    print()
    print(flame(tracer, deepest))


def run_all():
    result, tracer, registry = tracing_overhead()
    conservation(result, tracer)
    exporters(tracer, registry)
