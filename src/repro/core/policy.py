"""The CNA admission policy as a reusable, domain-generic queue.

This is the load-bearing abstraction that carries the paper's idea into the
rest of the framework: a queue of work items, each tagged with a *locality
domain* (NUMA socket in the paper; TPU pod / KV-cache home in this framework),
served with CNA's discipline:

  * items whose domain matches the current holder's domain are served in FIFO
    order from the **main queue**;
  * on a grant, skipped remote-domain items move to the **secondary queue**
    (paper Fig. 4/5, find_successor);
  * the secondary queue is spliced back in front of the main queue when no
    local item exists, or pseudo-randomly with P = 1/(threshold+1)
    (``keep_lock_local``) — the starvation bound;
  * the **shuffle-reduction** fast path skips the scan when the secondary
    queue is empty (paper Section 6).

State is compact by construction: two deques and a counter — no per-domain
structure, which is the whole point of the paper (contrast a "cohort
scheduler" that would keep one queue per pod).
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from collections import deque
from typing import Any, Generic, Iterable, TypeVar

T = TypeVar("T")

THRESHOLD = 0xFFFF
THRESHOLD2 = 0xFF


@dataclass
class PolicyStats:
    grants: int = 0
    local_grants: int = 0
    flushes: int = 0
    shuffles: int = 0
    scanned: int = 0

    @property
    def locality(self) -> float:
        return self.local_grants / max(1, self.grants)


@dataclass
class _Item(Generic[T]):
    value: T
    domain: int


class CNAAdmissionQueue(Generic[T]):
    def __init__(
        self,
        *,
        threshold: int = THRESHOLD,
        shuffle_reduction: bool = False,
        threshold2: int = THRESHOLD2,
        seed: int = 0xC0A,
    ) -> None:
        # NOTE (adaptation decision): in the *lock*, shuffle reduction exists
        # to avoid the memory-system cost of restructuring the waiter queue
        # at low contention.  In a *scheduler*, restructuring is a couple of
        # deque ops — negligible next to a request handover — while skipping
        # the scan forfeits locality whenever items complete (they never
        # rejoin, so the secondary queue stays empty and the fast path pins
        # the discipline at FIFO).  Hence default off; the flag remains for
        # the faithful-lock benchmarks.
        self._main: deque[_Item[T]] = deque()
        self._secondary: deque[_Item[T]] = deque()
        self._threshold = threshold
        self._threshold2 = threshold2
        self._shuffle_reduction = shuffle_reduction
        self._rng = random.Random(seed)
        self.stats = PolicyStats()

    def __len__(self) -> int:
        return len(self._main) + len(self._secondary)

    def push(self, value: T, domain: int) -> None:
        """New arrivals always join the main queue (paper Section 4)."""
        self._main.append(_Item(value, domain))

    def extend(self, values: Iterable[tuple[T, int]]) -> None:
        for v, d in values:
            self.push(v, d)

    def _keep_lock_local(self) -> bool:
        return bool(self._rng.getrandbits(30) & self._threshold)

    def _flush_secondary(self) -> None:
        """Splice the secondary queue in *front* of the main queue (L45)."""
        if self._secondary:
            self._secondary.extend(self._main)
            self._main = self._secondary
            self._secondary = deque()
            self.stats.flushes += 1

    def pop(self, current_domain: int) -> tuple[T, int] | None:
        """Grant the next item under the CNA discipline.

        Returns ``(value, domain)`` or ``None`` if empty.  ``current_domain``
        plays the lock holder's socket.
        """
        if not self._main:
            if not self._secondary:
                return None
            self._flush_secondary()  # L28: secondary becomes main

        # Shuffle-reduction fast path (paper Section 6): with the secondary
        # queue empty, hand to the immediate successor — whatever its domain —
        # with high probability, skipping the scan entirely.
        if (
            self._shuffle_reduction
            and not self._secondary
            and (self._rng.getrandbits(30) & self._threshold2)
        ):
            item = self._main.popleft()
            self._record(item, current_domain)
            return item.value, item.domain

        if self._keep_lock_local():
            for i, item in enumerate(self._main):
                self.stats.scanned += 1
                if item.domain == current_domain:
                    for _ in range(i):
                        self._secondary.append(self._main.popleft())
                    if i:
                        self.stats.shuffles += 1
                    item = self._main.popleft()
                    self._record(item, current_domain)
                    return item.value, item.domain
            # no local item: fall through to a fairness flush

        self._flush_secondary()
        item = self._main.popleft()
        self._record(item, current_domain)
        return item.value, item.domain

    def _record(self, item: _Item[T], current_domain: int) -> None:
        self.stats.grants += 1
        if item.domain == current_domain:
            self.stats.local_grants += 1

    def drain(self) -> list[tuple[T, int]]:
        out = [(i.value, i.domain) for i in self._main]
        out += [(i.value, i.domain) for i in self._secondary]
        self._main.clear()
        self._secondary.clear()
        return out


class FIFOAdmissionQueue(Generic[T]):
    """Baseline discipline (MCS analogue) with the same interface."""

    def __init__(self, **_: Any) -> None:
        self._q: deque[_Item[T]] = deque()
        self.stats = PolicyStats()

    def __len__(self) -> int:
        return len(self._q)

    def push(self, value: T, domain: int) -> None:
        self._q.append(_Item(value, domain))

    def extend(self, values: Iterable[tuple[T, int]]) -> None:
        for v, d in values:
            self.push(v, d)

    def pop(self, current_domain: int) -> tuple[T, int] | None:
        if not self._q:
            return None
        item = self._q.popleft()
        self.stats.grants += 1
        if item.domain == current_domain:
            self.stats.local_grants += 1
        return item.value, item.domain

    def drain(self) -> list[tuple[T, int]]:
        out = [(i.value, i.domain) for i in self._q]
        self._q.clear()
        return out
