# The paper's primary contribution: the CNA discipline (one pure transition
# core in ``discipline``; thread-lock / discrete-event / admission-queue
# drivers around it) with pluggable locality topologies.
from .discipline import (  # noqa: F401
    CNADiscipline,
    DisciplineConfig,
    DisciplineStats,
    Grant,
    RestrictedDiscipline,
    Scan,
    SecondaryFlush,
    Shuffle,
    decide,
)
from .topology import Topology, flat, get_topology, pod, table  # noqa: F401
from .cna import CNALock, CNANode, MCSLock, run_lock_stress  # noqa: F401
from .policy import CNAAdmissionQueue, FIFOAdmissionQueue  # noqa: F401
from .numasim import CostModel, Simulator, SimResult, TWO_SOCKET, FOUR_SOCKET, run_sweep  # noqa: F401
from .locks_sim import ALL_LOCKS  # noqa: F401
