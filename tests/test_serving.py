"""Serving engine + CNA scheduler: correctness is admission-order-invariant,
locality/throughput favor CNA, fairness is preserved."""

import jax
import numpy as np
import pytest

from repro.configs.base import get_reduced_config
from repro.models.registry import build_model
from repro.serving.engine import DecodeEngine, Request
from repro.serving.scheduler import CNAScheduler, FIFOScheduler


@pytest.fixture(scope="module")
def small_model():
    cfg = get_reduced_config("granite_3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n=8, domains=2, seed=0, plen=8, max_new=5):
    rng = np.random.default_rng(seed)
    return [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
                max_new=max_new, domain=i % domains)
        for i in range(n)
    ]


def _greedy_reference(model, params, prompt, n_new):
    """Free-running single-request decode (no batching)."""
    import jax.numpy as jnp

    logits, cache = jax.jit(model.prefill)(params, {"tokens": jnp.asarray(prompt)[None]})
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        logits, cache = jax.jit(model.decode_step)(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32)
        )
        out.append(int(jnp.argmax(logits[0])))
    return out


def test_outputs_match_unbatched_reference(small_model):
    cfg, model, params = small_model
    reqs = _requests(cfg, n=5, seed=1)
    eng = DecodeEngine(model, params, n_slots=3, cache_len=64)
    eng.run(reqs)
    for r in reqs:
        ref = _greedy_reference(model, params, r.prompt, r.max_new)
        assert r.out[: r.max_new] == ref, f"rid={r.rid}: {r.out} vs {ref}"


def test_outputs_invariant_to_scheduler(small_model):
    """Per-request generations are identical under CNA and FIFO admission —
    the policy reorders work, never changes results."""
    cfg, model, params = small_model
    base = _requests(cfg, n=8, seed=2)
    outs = {}
    for name, sched in [("cna", CNAScheduler(fairness_threshold=0xF)), ("fifo", FIFOScheduler())]:
        reqs = [Request(r.rid, r.prompt, r.max_new, r.domain) for r in base]
        DecodeEngine(model, params, n_slots=3, cache_len=64, scheduler=sched).run(reqs)
        outs[name] = {r.rid: tuple(r.out) for r in reqs}
    assert outs["cna"] == outs["fifo"]


def test_cna_beats_fifo_on_locality_and_switch_cost(small_model):
    cfg, model, params = small_model
    base = _requests(cfg, n=12, domains=2, seed=3)
    stats = {}
    for name, sched in [("cna", CNAScheduler(fairness_threshold=0xF)), ("fifo", FIFOScheduler())]:
        reqs = [Request(r.rid, r.prompt, r.max_new, r.domain) for r in base]
        eng = DecodeEngine(model, params, n_slots=3, cache_len=64,
                           scheduler=sched, domain_switch_cost=8)
        eng.run(reqs)
        stats[name] = (eng.scheduler.metrics.locality, eng.scheduler.metrics.domain_switches, eng.sim_time)
    assert stats["cna"][0] > stats["fifo"][0]       # higher locality
    assert stats["cna"][1] < stats["fifo"][1]       # fewer domain switches
    assert stats["cna"][2] < stats["fifo"][2]       # lower simulated time


def test_fairness_no_domain_starves(small_model):
    """With a small fairness threshold, every domain gets served even when
    domain 0 floods the queue (the paper's long-term fairness property)."""
    cfg, model, params = small_model
    reqs = [
        Request(rid=i, prompt=np.arange(4, dtype=np.int32), max_new=2,
                domain=0 if i < 20 else 1)
        for i in range(24)
    ]
    eng = DecodeEngine(model, params, n_slots=2, cache_len=32,
                       scheduler=CNAScheduler(fairness_threshold=0x3, seed=5))
    eng.run(reqs)
    per_dom = eng.scheduler.metrics.per_domain
    assert per_dom.get(0, 0) == 20 and per_dom.get(1, 0) == 4
    assert all(r.done for r in reqs)


def test_slot_reuse_and_release(small_model):
    cfg, model, params = small_model
    reqs = _requests(cfg, n=9, seed=4, max_new=3)
    eng = DecodeEngine(model, params, n_slots=2, cache_len=32)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert len(eng.slots.free) == 2 and not eng.active_req


def test_released_slot_does_not_leak_stale_kv(small_model):
    """Regression: SlotCache.release must zero the slot's position so a
    re-claimed slot reads as empty (no stale KV visible) until insert, and a
    request served from a reused slot decodes identically to a fresh one."""
    cfg, model, params = small_model
    # two requests forced through the same single slot, back to back
    reqs = _requests(cfg, n=2, seed=6, plen=6, max_new=4)
    eng = DecodeEngine(model, params, n_slots=1, cache_len=32)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert int(eng.slots.cache["pos"][0]) == 0  # released slot reads empty
    for r in reqs:
        ref = _greedy_reference(model, params, r.prompt, r.max_new)
        assert r.out[: r.max_new] == ref


def test_scheduler_rejects_out_of_range_domain():
    from repro.core.topology import pod
    from repro.serving.scheduler import FIFOScheduler as FS

    s = FS(topology=pod(2, 2))
    with pytest.raises(ValueError, match="domain 7 out of range"):
        s.submit("r", 7)
    s.submit("r", 3)  # in range: 4 domains


def test_engine_rejects_conflicting_scheduler_and_topology():
    from repro.core.topology import pod
    from repro.serving.scheduler import FIFOScheduler as FS

    with pytest.raises(ValueError, match="topology via the scheduler"):
        DecodeEngine(None, None, scheduler=FS(), topology=pod(2, 2))


def test_placement_engine_outputs_invariant_and_telemetry(small_model):
    """A placement-aware SlotCache changes WHERE caches live, never what gets
    decoded: outputs match the baseline engine, and per-domain telemetry is
    surfaced through the scheduler metrics."""
    from repro.core.topology import pod

    cfg, model, params = small_model
    base = _requests(cfg, n=10, domains=4, seed=7)
    outs = {}
    for name, kw in [
        ("baseline", {}),
        ("placed", dict(scheduler=CNAScheduler(fairness_threshold=0xF, topology=pod(2, 2)),
                        placement="nearest_spill")),
    ]:
        reqs = [Request(r.rid, r.prompt, r.max_new, r.domain) for r in base]
        eng = DecodeEngine(model, params, n_slots=4, cache_len=64, **kw)
        eng.run(reqs)
        outs[name] = {r.rid: tuple(r.out) for r in reqs}
        if name == "placed":
            tel = eng.scheduler.metrics.placement
            assert tel is eng.slots.telemetry
            assert tel.placements == 10 and tel.releases == 10
            assert tel.placements == tel.local_placements + tel.spills
            assert tel.handover_samples == 10  # one sample per admission
            assert sum(tel.per_domain_occupancy.values()) == 0  # all released
    assert outs["placed"] == outs["baseline"]


def test_placement_requires_topology():
    with pytest.raises(ValueError, match="placement needs a topology"):
        DecodeEngine(None, None, placement="nearest_spill")


def test_engine_rejects_overlength_prompt(small_model):
    """Regression: a prompt with len(prompt) >= cache_len used to be admitted
    unguarded — prefill returned pos > cache_len, ``_fit`` silently trimmed
    the KV, and the decode write clamped onto the last cache entry.  It must
    be rejected at submit; the longest fitting prompt still decodes."""
    cfg, model, params = small_model
    eng = DecodeEngine(model, params, n_slots=1, cache_len=16)
    bad = Request(rid=0, prompt=np.arange(16, dtype=np.int32) % cfg.vocab, max_new=2)
    with pytest.raises(ValueError, match="cannot fit cache_len"):
        eng.submit(bad)
    assert len(eng.scheduler) == 0  # nothing half-queued
    ok = Request(rid=1, prompt=np.arange(15, dtype=np.int32) % cfg.vocab, max_new=2)
    eng.run([ok])
    assert ok.done


def test_slotcache_claim_validates_domain_and_exhaustion():
    """Regression: under placement, claim() used to coerce domain=None to 0
    (skewing domain-0 telemetry) and let out-of-range domains surface as an
    opaque IndexError inside the pools; the baseline path's exhausted-cache
    error was heapq's bare 'index out of range'."""
    import jax.numpy as jnp

    from repro.core.topology import pod
    from repro.serving.kvcache import SlotCache

    def mk(**kw):
        return SlotCache({"pos": jnp.zeros((2,), jnp.int32)}, {"pos": None}, 2, **kw)

    sc = mk(topology=pod(2, 1))
    with pytest.raises(ValueError, match="domain=None"):
        sc.claim("r0")
    with pytest.raises(ValueError, match="domain 5 out of range"):
        sc.claim("r0", 5)
    with pytest.raises(ValueError, match="domain -1 out of range"):
        sc.claim("r0", -1)
    assert sc.telemetry.placements == 0 and not sc.owner  # rejects left no trace
    assert sc.claim("r0", 1) is not None and sc.slot_domain(0) == 0
    sc.claim("r1", 1)
    with pytest.raises(IndexError, match="claim from an exhausted SlotCache"):
        sc.claim("r2", 1)

    base = mk()
    base.claim("a"), base.claim("b")
    assert base.slot_domain(0) is None  # baseline: no domains
    with pytest.raises(IndexError, match="claim from an exhausted SlotCache"):
        base.claim("c")


def test_adaptive_scheduler_in_engine_feeds_controller(small_model):
    """CNAScheduler(max_active=AdaptiveController) in a real engine run: the
    engine feeds one handover sample per admission and decode output is
    unchanged by the adaptive cap."""
    from repro.core.topology import pod
    from repro.placement import AdaptiveController

    cfg, model, params = small_model
    base = _requests(cfg, n=8, domains=4, seed=8)
    ctrl = AdaptiveController(initial=2, max_cap=8, window=4)
    sched = CNAScheduler(fairness_threshold=0xF, topology=pod(2, 2), max_active=ctrl)
    reqs = [Request(r.rid, r.prompt, r.max_new, r.domain) for r in base]
    eng = DecodeEngine(model, params, n_slots=2, cache_len=64, scheduler=sched)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert sched.controller is ctrl and ctrl.samples == 8
    for r in reqs:
        ref = _greedy_reference(model, params, r.prompt, r.max_new)
        assert r.out[: r.max_new] == ref


def test_topology_scheduler_scales_switch_cost(small_model):
    """Cross-pod admissions stall the engine twice as long as same-pod ones
    under a hierarchical topology."""
    from repro.core.topology import pod
    from repro.serving.scheduler import FIFOScheduler as FS

    cfg, model, params = small_model
    topo = pod(2, 2)
    # domains 0,2 are in different pods; 0,1 share a pod
    far = [Request(rid=i, prompt=np.arange(4, dtype=np.int32), max_new=2,
                   domain=[0, 2][i % 2]) for i in range(4)]
    near = [Request(rid=i, prompt=np.arange(4, dtype=np.int32), max_new=2,
                    domain=[0, 1][i % 2]) for i in range(4)]
    times = {}
    for name, reqs in [("far", far), ("near", near)]:
        eng = DecodeEngine(model, params, n_slots=1, cache_len=32,
                           scheduler=FS(topology=topo), domain_switch_cost=10)
        eng.run(reqs)
        times[name] = eng.sim_time
        assert eng.scheduler.metrics.domain_switches > 0
    assert times["far"] > times["near"]
