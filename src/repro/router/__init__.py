"""The router tier: CNA-disciplined routing over a fleet of decode replicas.

The paper's two-queue discipline applied fractally one level up — replicas
are top-level locality domains, the federation of prefix summaries says
where each session is warm, and admission runs through the same
``repro.core.discipline`` machinery the lock, the simulator, and the
single-engine scheduler already share:

  ``federation``   ``FederatedPrefixIndex``: per-replica top-K prefix
                   summaries merged into one routable index;
  ``router``       ``ReplicaRouter``: CNA admission over a replica-level
                   ``Topology``, capacity gating, shed-before-stall;
  ``replica``      the replica protocol: ``EngineReplica`` (a real
                   ``DecodeEngine``) and ``FleetController`` (per-replica
                   TTFT-driven admission caps — GCR at fleet granularity);
  ``kvship``       priced prefix-KV shipping: ``min(re-prefill, ship)`` per
                   dispatch, charged as admission stall, serialized over a
                   finite-bandwidth ``Fabric``;
  ``sim``          jax-free discrete-event fleet simulator + control arms
                   (round-robin, least-loaded) for the benchmarks.
"""

from .federation import FederatedPrefixIndex, FederationStats, ReplicaSummary
from .kvship import Fabric, ShipCostModel, ShipDecision, ShipStats, decide
from .replica import EngineReplica, FleetController
from .router import ReplicaRouter, RouterStats, Session
from .sim import (
    FleetCostModel,
    FleetResult,
    ReplicaCache,
    SimReplica,
    make_router,
    shared_prefix_sessions,
    simulate,
)

__all__ = [
    "EngineReplica",
    "Fabric",
    "FederatedPrefixIndex",
    "FederationStats",
    "FleetController",
    "FleetCostModel",
    "FleetResult",
    "ReplicaCache",
    "ReplicaRouter",
    "ReplicaSummary",
    "RouterStats",
    "Session",
    "ShipCostModel",
    "ShipDecision",
    "ShipStats",
    "SimReplica",
    "decide",
    "make_router",
    "shared_prefix_sessions",
    "simulate",
]
