"""Shared helpers for the benchmark suite: CSV tables + claim checks."""

from __future__ import annotations

import sys
import time
from contextlib import contextmanager


def table(title: str, header: list[str], rows: list[list]):
    print(f"\n## {title}")
    print(",".join(str(h) for h in header))
    for r in rows:
        print(",".join(f"{x:.4g}" if isinstance(x, float) else str(x) for x in r))
    sys.stdout.flush()


def claim(name: str, ok: bool, detail: str = ""):
    status = "PASS" if ok else "FAIL"
    print(f"CLAIM [{status}] {name}  {detail}")
    return ok


@contextmanager
def timed(name: str):
    t0 = time.time()
    yield
    print(f"({name}: {time.time() - t0:.1f}s)")


THREADS_2S = [1, 2, 4, 8, 16, 24, 36, 48, 70]
THREADS_4S = [1, 2, 4, 8, 16, 36, 72, 108, 142]
LOCK_SET = ["mcs", "cna", "cna_opt", "c-bo-mcs", "hmcs", "tas", "ticket", "hbo"]
MAIN_LOCKS = ["mcs", "cna", "cna_opt", "c-bo-mcs", "hmcs"]


# -- subprocess harness (mirrors tests/_subproc.py — keep the two in sync) ----
# Subprocesses must not inherit hardcoded machine paths, and must pin
# JAX_PLATFORMS=cpu: with libtpu installed but no TPU attached, an unpinned
# jax spends minutes probing TPU metadata endpoints.
import os as _os

REPO_ROOT = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))


def subproc_env() -> dict:
    env = dict(_os.environ)
    env["PYTHONPATH"] = _os.path.join(REPO_ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    return env
