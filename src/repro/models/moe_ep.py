"""CNA expert parallelism: the paper's two-queue discipline as an MoE layer.

Mapping (DESIGN.md §2): an EP shard is a NUMA socket; a token is a thread; the
expert it wants is the lock.  The standard EP layer sends *every* routed token
through one uniform all-to-all.  CNA-EP splits the dispatch exactly like the
paper splits waiters:

  main queue      tokens routed to experts resident on their own shard are
                  dispatched *locally* — no collective at all (the same-socket
                  handover);
  secondary queue tokens routed to remote experts go through an all-to-all
                  whose per-destination capacity ``C_rem`` is provisioned for
                  the *residual* (post-bias) remote traffic — the wire bytes
                  shrink with the achieved locality;
  fairness        the router's load-balancing aux loss plus the bounded bias
                  keep remote experts fed (no expert starves) — the
                  keep_lock_local threshold analogue.

With ``cna_routing`` on, the router adds a bounded bias toward same-shard
experts, so the locality fraction λ rises from ~1/n_ep to ~0.5-0.9 and
``remote_capacity_factor`` can be provisioned ~4x smaller at the same drop
rate: all-to-all wire bytes fall proportionally (benchmarks/moe_ep_bench.py,
EXPERIMENTS.md §Perf deepseek hillclimb).

Implemented with ``jax.shard_map`` manual over the EP axes; the 'model' axis
stays auto (GSPMD).  Expert weights are sharded over the EP axes on the
expert dim; e.g. deepseek's 64 experts over 16 data shards = 4 experts/shard
(x 2 pods = 2/shard on the multi-pod mesh, experts contiguous per shard so a
pod is a super-domain).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.jax_compat import shard_map

from .moe import _positions, moe_capacity
from .mlp import mlp_apply
from .sharding import current_ctx, shard


def ep_axes_for(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _swiglu(buf, wi, wg, wo):
    h = jnp.einsum("ecd,edf->ecf", buf, wi)
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    return jnp.einsum("ecf,efd->ecd", h, wo)


def moe_apply_ep(params: dict, x: jax.Array, cfg):
    """x: (B, S, D) -> (out, aux).  Falls back to the TP layer without a mesh
    context or when the expert count does not divide the EP shards."""
    ctx = current_ctx()
    if ctx is None:
        from .moe import moe_apply

        return moe_apply(params, x, cfg)
    mesh = ctx.mesh
    axes = ep_axes_for(mesh)
    n_ep = 1
    for a in axes:
        n_ep *= mesh.shape[a]
    e = cfg.n_experts
    if n_ep <= 1 or e % n_ep or x.shape[0] % n_ep:
        from .moe import moe_apply

        return moe_apply(params, x, cfg)

    e_loc = e // n_ep
    k = cfg.top_k
    b, s, d = x.shape
    g_l = (b // n_ep) * s                      # tokens per EP shard
    c_loc = moe_capacity(g_l, k, e_loc, cfg.capacity_factor)
    r = cfg.ep_remote_capacity_factor
    c_rem = max(4, int(math.ceil(g_l * k * r / n_ep / 4)) * 4)
    c_rin = max(4, int(math.ceil(n_ep * c_rem * cfg.capacity_factor / e_loc / 4)) * 4)

    local_fn = partial(
        _ep_local, cfg=cfg, axes=axes, n_ep=n_ep, e_loc=e_loc,
        c_loc=c_loc, c_rem=c_rem, c_rin=c_rin,
    )
    out, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(axes, None, None),       # x: batch over EP shards
            P(None, None),             # router weights replicated
            P(axes, None, None),       # wi: experts over EP shards
            P(axes, None, None),
            P(axes, None, None),
        ),
        out_specs=(P(axes, None, None), P()),
        axis_names=set(axes),
        check_vma=False,
    )(x, params["router"], params["wi"], params["wg"], params["wo"])

    if cfg.n_shared_experts:
        out = out + mlp_apply(params["shared"], x, "swiglu")
    return shard(out, "batch", "seq", "embed"), aux


def _ep_local(x_l, router, wi, wg, wo, *, cfg, axes, n_ep, e_loc, c_loc, c_rem, c_rin):
    """Per-EP-shard body.  x_l: (Bl, S, D); wi/wg/wo: (e_loc, D, ff)."""
    e, k = cfg.n_experts, cfg.top_k
    bl, s, d = x_l.shape
    g = bl * s
    my = jax.lax.axis_index(axes)

    # -- routing (with the CNA main-queue bias toward resident experts) ------
    xt = x_l.reshape(g, d)
    logits = jnp.einsum("gd,de->ge", xt.astype(jnp.float32), router.astype(jnp.float32))
    exp_shard = jnp.arange(e, dtype=jnp.int32) // e_loc          # home shard per expert
    if cfg.cna_routing:
        logits = logits + cfg.cna_routing_bias * (exp_shard == my).astype(jnp.float32)[None, :]
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = (w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)).astype(x_l.dtype)
    # load-balance aux (global mean via psum — the fairness threshold)
    f = jnp.mean(jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=1), axis=0)
    p = jnp.mean(probs, axis=0)
    f = jax.lax.pmean(f, axes)
    p = jax.lax.pmean(p, axes)
    aux = e * jnp.sum(f * p) * cfg.router_aux_coef

    e_all = idx.reshape(-1)                                       # (g*k,)
    w_all = w.reshape(-1)
    tok = jnp.repeat(jnp.arange(g, dtype=jnp.int32), k)
    dest = e_all // e_loc
    is_local = dest == my

    # -- main queue: same-shard dispatch, no collective ----------------------
    e_main = jnp.where(is_local, e_all % e_loc, e_loc)            # e_loc = dummy row
    pos_m, keep_m = _positions(e_main, e_loc + 1, c_loc)
    keep_m &= is_local
    buf_m = jnp.zeros((e_loc + 1, c_loc, d), x_l.dtype)
    buf_m = buf_m.at[e_main, pos_m].add(jnp.where(keep_m[:, None], xt[tok], 0))

    # -- secondary queue: remote tokens through the provisioned all-to-all ---
    d_sec = jnp.where(is_local, n_ep, dest)                       # n_ep = dummy row
    pos_s, keep_s = _positions(d_sec, n_ep + 1, c_rem)
    keep_s &= ~is_local
    send_x = jnp.zeros((n_ep + 1, c_rem, d), x_l.dtype)
    send_x = send_x.at[d_sec, pos_s].add(jnp.where(keep_s[:, None], xt[tok], 0))
    send_e = jnp.full((n_ep + 1, c_rem), e_loc, jnp.int32)        # dummy expert
    send_e = send_e.at[d_sec, pos_s].set(jnp.where(keep_s, e_all % e_loc, e_loc))
    recv_x = jax.lax.all_to_all(send_x[:n_ep], axes, split_axis=0, concat_axis=0, tiled=True)
    recv_e = jax.lax.all_to_all(send_e[:n_ep], axes, split_axis=0, concat_axis=0, tiled=True)

    flat_e = recv_e.reshape(-1)
    pos_r, keep_r = _positions(flat_e, e_loc + 1, c_rin)
    keep_r &= flat_e < e_loc
    buf_r = jnp.zeros((e_loc + 1, c_rin, d), x_l.dtype)
    buf_r = buf_r.at[flat_e, pos_r].add(jnp.where(keep_r[:, None], recv_x.reshape(-1, d), 0))

    # -- expert FFN over [main | remote] capacity regions --------------------
    buf = jnp.concatenate([buf_m[:e_loc], buf_r[:e_loc]], axis=1)  # (e_loc, c_loc+c_rin, D)
    out_buf = _swiglu(buf, wi, wg, wo)
    out_m, out_r = out_buf[:, :c_loc], out_buf[:, c_loc:]

    # -- combine: main directly; secondary back through the all-to-all -------
    y = jnp.zeros((g, d), x_l.dtype)
    y_m = out_m[jnp.minimum(e_main, e_loc - 1), jnp.minimum(pos_m, c_loc - 1)]
    y = y.at[tok].add(jnp.where(keep_m[:, None], y_m * w_all[:, None], 0))

    back = jnp.zeros((n_ep * c_rem, d), x_l.dtype)
    y_r = out_r[jnp.minimum(flat_e, e_loc - 1), jnp.minimum(pos_r, c_rin - 1)]
    back = jnp.where(keep_r[:, None], y_r, 0)
    back = jax.lax.all_to_all(back.reshape(n_ep, c_rem, d), axes, split_axis=0, concat_axis=0, tiled=True)
    back = jnp.concatenate([back, jnp.zeros((1, c_rem, d), x_l.dtype)], axis=0)
    y_s = back[jnp.minimum(d_sec, n_ep), jnp.minimum(pos_s, c_rem - 1)]
    y = y.at[tok].add(jnp.where(keep_s[:, None], y_s * w_all[:, None], 0))

    return y.reshape(bl, s, d), aux


def ep_routing_stats(params, x, cfg, n_ep: int):
    """Offline routing statistics (numpy-friendly): locality fraction and the
    drop rates at the provisioned capacities — used by the benchmark to pick
    remote_capacity_factor (no mesh needed)."""
    e, k = cfg.n_experts, cfg.top_k
    e_loc = e // n_ep
    b, s, d = x.shape
    bl = b // n_ep
    g = bl * s
    c_rem = max(4, int(math.ceil(g * k * cfg.ep_remote_capacity_factor / n_ep / 4)) * 4)
    stats = {"local": 0.0, "dropped": 0.0, "total": 0.0,
             "a2a_bytes": 2.0 * n_ep * c_rem * d * x.dtype.itemsize, "c_rem": c_rem}
    for shard_i in range(n_ep):
        x_l = x[shard_i * bl : (shard_i + 1) * bl].reshape(g, d)
        logits = jnp.einsum("gd,de->ge", x_l.astype(jnp.float32), params["router"].astype(jnp.float32))
        if cfg.cna_routing:
            exp_shard = jnp.arange(e) // e_loc
            logits = logits + cfg.cna_routing_bias * (exp_shard == shard_i).astype(jnp.float32)[None, :]
        _, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), k)
        e_all = idx.reshape(-1)
        dest = e_all // e_loc
        is_local = dest == shard_i
        stats["local"] += float(jnp.sum(is_local))
        stats["total"] += float(e_all.shape[0])
        d_sec = jnp.where(is_local, n_ep, dest)
        pos, keep = _positions(d_sec, n_ep + 1, c_rem)
        keep &= ~is_local
        stats["dropped"] += float(jnp.sum(~is_local) - jnp.sum(keep))
    stats["locality"] = stats["local"] / stats["total"]
    stats["drop_rate"] = stats["dropped"] / max(1.0, stats["total"] - stats["local"])
    return stats
