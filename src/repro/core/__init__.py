# The paper's primary contribution: the CNA lock (faithful host-side
# implementation + deterministic NUMA simulation) and its admission policy
# lifted to TPU-pod locality domains (scheduler + collective schedules).
from .cna import CNALock, CNANode, MCSLock, run_lock_stress  # noqa: F401
from .policy import CNAAdmissionQueue, FIFOAdmissionQueue  # noqa: F401
from .numasim import CostModel, Simulator, SimResult, TWO_SOCKET, FOUR_SOCKET, run_sweep  # noqa: F401
from .locks_sim import ALL_LOCKS  # noqa: F401
