"""Property tests for the CNA admission policy (the reusable abstraction)."""

import random

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback
    from _hypothesis_compat import given, settings, st

from repro.core.policy import CNAAdmissionQueue, FIFOAdmissionQueue


@given(
    items=st.lists(st.tuples(st.integers(0, 10_000), st.integers(0, 3)), max_size=200),
    threshold=st.sampled_from([0, 1, 0xF, 0xFFFF]),
    shuffle=st.booleans(),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=150, deadline=None)
def test_conservation_no_item_lost_or_duplicated(items, threshold, shuffle, seed):
    """Every pushed item is popped exactly once, regardless of discipline
    parameters — the queue-splicing must never drop or duplicate work."""
    q = CNAAdmissionQueue(threshold=threshold, shuffle_reduction=shuffle, seed=seed)
    for v, d in items:
        q.push(v, d)
    popped = []
    dom = 0
    while len(q):
        v, d = q.pop(dom)
        popped.append(v)
        dom = d  # the served item's domain becomes the holder's domain
    assert sorted(popped) == sorted(v for v, _ in items)


@given(
    n=st.integers(1, 100),
    domains=st.integers(1, 4),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=100, deadline=None)
def test_local_items_served_before_remote_when_threshold_high(n, domains, seed):
    """With an effectively-infinite threshold and all items present, every
    domain-0 item is served before any remote item when the holder is 0
    (pure locality mode)."""
    q = CNAAdmissionQueue(threshold=(1 << 29) - 1, shuffle_reduction=False, seed=seed)
    rng = random.Random(seed)
    vals = [(i, rng.randrange(domains)) for i in range(n)]
    for v, d in vals:
        q.push(v, d)
    served = []
    while len(q):
        served.append(q.pop(0))
    local = [v for v, d in vals if d == 0]
    assert [v for v, d in served[: len(local)]] == local


def test_starvation_bound_via_threshold():
    """With threshold=0 (keep_lock_local always false), the discipline
    degenerates to FIFO-with-flushes: remote items are never deferred more
    than one flush."""
    q = CNAAdmissionQueue(threshold=0, shuffle_reduction=False)
    for i in range(10):
        q.push(i, i % 2)
    served = [q.pop(0)[0] for _ in range(10)]
    assert served == list(range(10))


def test_locality_stat_beats_fifo_on_alternating_stream():
    rng = random.Random(0)
    stream = [(i, rng.randrange(2)) for i in range(4000)]
    cna = CNAAdmissionQueue(threshold=0xFF, seed=1)
    fifo = FIFOAdmissionQueue()
    for impl in (cna, fifo):
        dom = 0
        i = 0
        # steady state: keep ~32 items queued, pop one at a time
        for v, d in stream:
            impl.push(v, d)
            i += 1
            if i >= 32:
                out = impl.pop(dom)
                dom = out[1]
        while len(impl):
            out = impl.pop(dom)
            dom = out[1]
    assert cna.stats.locality > 0.9
    assert fifo.stats.locality < 0.6


def test_drain_returns_everything():
    q = CNAAdmissionQueue(threshold=(1 << 29) - 1, seed=3)
    for i in range(20):
        q.push(i, i % 3)
    q.pop(0)
    rest = q.drain()
    assert len(rest) == 19
    assert len(q) == 0
