"""Elastic re-meshing: survive pod/host loss by re-sharding from checkpoint.

``plan_mesh`` picks the largest usable mesh for the devices that remain
(drop the pod axis when a pod dies; shrink the data axis for partial loss —
the model axis is preserved because TP degree is baked into layouts/Pallas
block shapes, while the batch axes are free).

``ElasticTrainer`` is the restart loop used by launch/train.py and the fault
tests: run -> (failure) -> plan_mesh over survivors -> restore checkpoint
with the *new* shardings (CheckpointManager stores unsharded arrays, so this
is one device_put per leaf) -> rescale the data loader (same global stream,
new host partition) -> continue.

``ElasticFleetSet`` is the same elasticity contract one level up, for the
region tier (``repro.region``): whole fleets join and leave a
``RegionRouter`` at runtime.  It is jax-free — the module's jax/training
imports are lazy so the serving-side membership path works in the
dependency-light smoke lane.  A departure *withdraws* the fleet's summary
from the region federation immediately (no routing-error window: in-flight
routes degrade to the least-loaded live fleet, never KeyError), and a join
re-advertises a fresh summary in the same call so the rejoiner attracts
traffic without waiting for the next periodic sync.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence


def plan_mesh(n_devices: int, *, model_parallel: int, want_pods: int = 1):
    """-> (shape tuple, axis names) for the largest mesh on n_devices.

    Keeps ``model_parallel`` fixed; gives the rest to data; re-adds the pod
    axis only if at least 2 full pods survive."""
    if n_devices % model_parallel:
        raise ValueError(f"{n_devices} devices not divisible by TP={model_parallel}")
    rest = n_devices // model_parallel
    if want_pods >= 2 and rest % want_pods == 0 and rest // want_pods >= 1:
        return (want_pods, rest // want_pods, model_parallel), ("pod", "data", "model")
    return (rest, model_parallel), ("data", "model")


def make_mesh_from_plan(shape: Sequence[int], axes: Sequence[str], devices=None):
    import jax

    devices = devices if devices is not None else jax.devices()
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(tuple(shape), tuple(axes), devices=devices[:n])


@dataclass
class ElasticTrainer:
    """Restart loop driver (see tests/test_elastic.py for the 8->4 scenario)."""

    model: object
    cfg: object
    ckpt: object          # CheckpointManager
    model_parallel: int

    def restore_on(self, devices, *, want_pods: int = 1):
        """Restore the latest checkpoint onto a mesh built from ``devices``."""
        from repro.models.sharding import use_mesh
        from repro.training.step import state_abstract, state_logical, tree_shardings

        shape, axes = plan_mesh(len(devices), model_parallel=self.model_parallel, want_pods=want_pods)
        mesh = make_mesh_from_plan(shape, axes, devices)
        step = self.ckpt.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint to restore")
        abs_state = state_abstract(self.model, self.cfg)
        with use_mesh(mesh):
            shardings = tree_shardings(abs_state, state_logical(self.model))
            state, extra = self.ckpt.restore(step, abs_state, shardings=shardings, extra=True)
        return mesh, state, extra


@dataclass
class ElasticFleetSet:
    """Fleet membership driver for the region tier (jax-free).

    Wraps a ``repro.region.RegionRouter`` (any object with
    ``attach_fleet``/``detach_fleet``/``active_fleets``) and narrates
    membership changes through it, keeping an epoch counter and join/leave
    telemetry so tests and benches can pin the no-error-window contract:
    every ``leave`` is immediately routable-around, every ``join``
    re-advertises before returning."""

    router: object
    epoch: int = 0
    joins: int = 0
    leaves: int = 0
    log: list = field(default_factory=list)  # (epoch, "join"|"leave", fleet)

    def leave(self, fleet: int) -> None:
        """Detach ``fleet``: withdraw its federated summary and stop
        steering/shedding to it.  Sessions already admitted there finish
        normally; queued sessions homed there shed to live fleets."""
        self.router.detach_fleet(fleet)
        self.epoch += 1
        self.leaves += 1
        self.log.append((self.epoch, "leave", fleet))

    def join(self, fleet: int) -> None:
        """(Re-)attach ``fleet`` and re-advertise its summary in the same
        call — a rejoiner attracts matched traffic without a cold window."""
        self.router.attach_fleet(fleet)
        self.epoch += 1
        self.joins += 1
        self.log.append((self.epoch, "join", fleet))

    @property
    def active(self) -> list[int]:
        return [f for f, a in enumerate(self.router.active_fleets) if a]
