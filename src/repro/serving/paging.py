"""Paged KV: one refcounted page table under the whole serving stack.

The contiguous tier stored a prefix shared by 100 sessions up to 100 times:
every ``PrefixKVStore`` entry was a full ``fit_single`` cache, every ship a
whole bundle.  This module replaces that storage tier with fixed-size KV
*pages* — the compact-state move of the paper applied to memory: instead of
per-sequence copies (the per-socket-hierarchy analogue), one page table
holds each distinct prefix once and everything that shares it holds a
reference.

Three layers, deliberately split by dependency:

``PageTable``
    Pure bookkeeping, jax-free: per-page refcounts, a free heap (or
    per-domain page pools over ``repro.placement.DomainFreeLists`` when a
    topology is given), and the gauges the memory-compaction claim is
    scraped from (``pages_total`` / ``pages_shared`` / ``pages_free`` /
    ``kv_bytes_held``).  The fleet sim and the ``serving_paging`` bench run
    entirely on this layer.

``PagedPrefixKVStore``
    The ``PrefixKVStore`` contract (``put``/``longest``/``get``/``peek``/
    ``common_run``) re-based on page references.  A deposit shares every
    full page of the longest already-stored prefix (refcount bump, zero
    bytes) and writes only the divergent pages; the partial boundary page is
    *copied*, never mutated — that is copy-on-write at page granularity, and
    it is why a page with refcount > 1 is immutable.  Byte movement is
    delegated to a pluggable pool: the jax ``PagedKVPool`` in production,
    ``pool=None`` for accounting-only (sim/bench) use.

``PagedSlotCache`` / ``PagedKVPool`` (see ``paging_jax``)
    The decode-facing view.  Import through this module
    (``repro.serving.paging.PagedSlotCache``) — resolution is lazy so the
    table/store layer stays importable without jax.

Sharing by token identity is sharing by byte identity here: a position's KV
is a deterministic function of the token prefix up to it (the packed-prefill
bitwise contract pins this), so two sequences agreeing on ``tokens[:n]``
agree on the first ``n`` KV positions, and substituting one's pages for the
other's is exactly the substitution the prefix-reuse resume path already
performs — now paid for once instead of per holder.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Any, Callable

from .prefixkv import PrefixKVStore


@dataclass(frozen=True)
class PageBundle:
    """A sequence's view of the table: ordered physical page ids covering
    ``length`` tokens (the last page may be partial).  Immutable — holding a
    bundle means holding one refcount on each of its pages."""

    pages: tuple[int, ...]
    length: int

    @property
    def n_pages(self) -> int:
        return len(self.pages)


class PageTable:
    """Refcounts + free-page pools for a fixed population of KV pages.

    ``alloc`` hands out pages at refcount 1, ``retain`` is the sharing bump,
    ``release`` the symmetric drop (a page returns to the free pool only at
    refcount 0, so releasing a shared prefix can never free pages another
    sequence still references).  With a ``topology`` the free pages are
    NUMA-homed through the same ``DomainFreeLists`` the slot cache uses —
    ``alloc(domain=...)`` prefers the caller's home pool and spills nearest-
    first, so page placement follows the paper's locality discipline instead
    of growing its own.

    ``bytes_per_page`` is only for the ``kv_bytes_held`` gauge; the jax pool
    computes it from real leaf dtypes, jax-free users pass an estimate (or
    leave 0 and read page counts).
    """

    def __init__(
        self, n_pages: int, page_size: int, *, topology=None,
        bytes_per_page: int = 0,
    ):
        if n_pages < 1 or page_size < 1:
            raise ValueError("need n_pages >= 1 and page_size >= 1")
        self.n_pages = n_pages
        self.page_size = page_size
        self.bytes_per_page = bytes_per_page
        self.refs = [0] * n_pages
        if topology is None:
            self.pools = None
            self._free = list(range(n_pages))  # a fresh range is a valid heap
        else:
            from repro.placement import DomainFreeLists

            self.pools = DomainFreeLists(n_pages, topology)
            self._free = None
        # lifetime counters (monotonic; the gauges above are levels)
        self.allocs = 0
        self.shares = 0
        self.cow_copies = 0

    # -- levels (the scrapeable gauges) ---------------------------------------
    @property
    def pages_free(self) -> int:
        return len(self.pools) if self.pools is not None else len(self._free)

    @property
    def pages_held(self) -> int:
        return self.n_pages - self.pages_free

    @property
    def pages_total(self) -> int:
        return self.n_pages

    @property
    def pages_shared(self) -> int:
        """Pages held by more than one sequence — the compaction win: each
        of these would be a full copy per holder in the contiguous tier."""
        return sum(1 for r in self.refs if r > 1)

    @property
    def kv_bytes_held(self) -> int:
        return self.pages_held * self.bytes_per_page

    # -- transitions ----------------------------------------------------------
    def alloc(self, n: int = 1, domain: int | None = None) -> list[int]:
        """Claim ``n`` free pages at refcount 1 (all-or-nothing).  With
        per-domain pools a ``domain`` hint places pages in (or nearest to)
        that home; without one the lowest-id pool spills first."""
        if n < 0:
            raise ValueError("alloc of a negative page count")
        if self.pages_free < n:
            raise IndexError(
                f"page table exhausted: need {n} pages, {self.pages_free} free"
            )
        if domain is not None and self.pools is not None:
            if not 0 <= domain < self.pools.topology.n_domains:
                raise ValueError(f"domain {domain} out of range")
        out = []
        for _ in range(n):
            if self.pools is not None:
                # claim_* return (page, page_domain); the free-count guard
                # above means neither can come back None
                p = (
                    self.pools.claim_nearest(domain)
                    if domain is not None
                    else self.pools.claim_lowest()
                )[0]
            else:
                p = heapq.heappop(self._free)
            self.refs[p] = 1
            out.append(p)
        self.allocs += n
        return out

    def retain(self, pages) -> None:
        """Sharing bump: one more holder for each of ``pages``."""
        for p in pages:
            if self.refs[p] <= 0:
                raise ValueError(f"retain of free page {p}")
            self.refs[p] += 1
            self.shares += 1

    def release(self, pages) -> list[int]:
        """Drop one reference per page; pages reaching refcount 0 return to
        the free pool.  Returns the pages actually freed."""
        freed = []
        for p in pages:
            if self.refs[p] <= 0:
                raise ValueError(f"release of free page {p}")
            self.refs[p] -= 1
            if self.refs[p] == 0:
                if self.pools is not None:
                    self.pools.release(p)
                else:
                    heapq.heappush(self._free, p)
                freed.append(p)
        return freed

    def refcount(self, page: int) -> int:
        return self.refs[page]

    def writable(self, page: int) -> bool:
        """The copy-on-write rule in one predicate: bytes may land in a page
        only while exactly one holder references it."""
        return self.refs[page] == 1

    # -- invariants (the property-test surface) -------------------------------
    def check(self) -> None:
        """Assert the conservation laws the hypothesis suite sweeps:
        free + referenced partition the population exactly, and no page is
        simultaneously free and referenced."""
        free = set(
            self.pools.free_slots() if self.pools is not None else self._free
        )
        if len(free) != self.pages_free:
            raise AssertionError("free pool holds duplicate pages")
        referenced = {p for p, r in enumerate(self.refs) if r > 0}
        if free & referenced:
            raise AssertionError(f"pages both free and referenced: {free & referenced}")
        if len(free) + len(referenced) != self.n_pages:
            raise AssertionError(
                f"page conservation violated: {len(free)} free + "
                f"{len(referenced)} referenced != {self.n_pages} total"
            )
        if any(r < 0 for r in self.refs):
            raise AssertionError("negative refcount")

    def register_into(self, registry, prefix: str = "kv") -> None:
        """Thin live views into a ``repro.obs.MetricsRegistry`` — the
        memory-compaction claim as scrapeable numbers: ``pages_total`` /
        ``pages_shared`` / ``pages_free`` / ``kv_bytes_held``."""
        registry.gauge(f"{prefix}_pages_total", fn=lambda: self.pages_total)
        registry.gauge(f"{prefix}_pages_shared", fn=lambda: self.pages_shared)
        registry.gauge(f"{prefix}_pages_free", fn=lambda: self.pages_free)
        registry.gauge(f"{prefix}_kv_bytes_held", fn=lambda: self.kv_bytes_held)


def pages_for(length: int, page_size: int) -> int:
    """Pages covering ``length`` tokens (the last one possibly partial)."""
    return -(-length // page_size)


class PagedPrefixKVStore(PrefixKVStore):
    """``PrefixKVStore`` re-based on page references.

    Entries map token prefixes to ``(PageBundle, logits)`` instead of
    materialized caches.  ``put`` of a dense (batch=1) cache *pages* it:
    every full page of the longest already-stored prefix of the key is
    shared (refcount bump — zero bytes), only the suffix pages are written,
    and the partial boundary page is copied rather than mutated (page-
    granularity copy-on-write; a shared page is immutable).  Re-depositing
    an existing key is free.  ``longest``/``get`` materialize a dense cache
    back through the pool on demand — byte-identical to what was deposited,
    so the engine's resume path is unchanged and bitwise-exact.

    ``pool`` moves the actual bytes (``PagedKVPool``); ``pool=None`` runs
    the identical bookkeeping with no arrays at all — the fleet sim and the
    jax-free bench share this store's accounting that way.  Eviction (LRU
    over entry count, plus on page-pool pressure) releases page references;
    pages shared with a live slot or a newer entry survive their entry.
    """

    def __init__(
        self, capacity: int = 16, *, table: PageTable, pool=None,
        min_plant: int = 4,
        on_evict: Callable[[tuple[int, ...], PageBundle], None] | None = None,
    ):
        super().__init__(capacity, min_plant=min_plant)
        self.table = table
        self.pool = pool
        self.page_size = table.page_size
        self.on_evict = on_evict
        # where fresh pages should land (per-domain pools only); the engine
        # points this at the admitting request's home around each deposit
        self.alloc_domain: int | None = None
        # deposit economics: pages actually written vs deposits that cost
        # nothing because every byte was already held
        self.pages_written = 0
        self.zero_page_deposits = 0
        self.dropped_deposits = 0
        self.evictions = 0

    # -- bundle plumbing -------------------------------------------------------
    def bundle(self, tokens) -> PageBundle | None:
        """The stored bundle under exactly ``tokens`` (no recency touch) —
        how a live slot pins its sequence's pages."""
        entry = self._lru.get(self._key(tokens))
        return entry[0] if entry is not None else None

    @property
    def logical_pages(self) -> int:
        """Sum of per-entry page counts (shared pages counted once per
        holder) — against ``table.pages_held`` this is the sharing ratio."""
        return sum(b.n_pages for b, _ in self._lru.values())

    def _evict_oldest(self) -> None:
        key, (bundle, _logits) = self._lru.popitem(last=False)
        if self.on_evict is not None:
            self.on_evict(key, bundle)
        self.table.release(bundle.pages)
        self.evictions += 1

    # -- the PrefixKVStore contract -------------------------------------------
    def put(self, tokens, cache, logits) -> None:
        """Deposit ``tokens``'s cache as pages.  ``cache`` is a dense
        (batch=1, ``fit_single``-shaped) pytree on the jax path, or anything
        (ignored) with ``pool=None``.  Already-stored keys refresh recency
        at zero page cost."""
        key = self._key(tokens)
        if not key:
            return
        ps = self.page_size
        if key in self._lru:
            # same tokens -> same bytes (KV is a deterministic function of
            # the token prefix): nothing to write, just touch recency
            self._lru.move_to_end(key)
            self.zero_page_deposits += 1
            return
        # share every full page of the longest stored prefix of this key
        base = None
        for stored in self._lru:
            if len(stored) <= len(key) and stored == key[: len(stored)]:
                if base is None or len(stored) > len(base):
                    base = stored
        shared: tuple[int, ...] = ()
        start = 0
        if base is not None:
            n_full = len(base) // ps
            shared = self._lru[base][0].pages[:n_full]
            start = n_full * ps
            self.table.retain(shared)  # before eviction below can drop base
        n_new = pages_for(len(key), ps) - len(shared)
        # make room: the count bound first, then page pressure (evicting an
        # entry releases references; pages shared elsewhere stay resident)
        while len(self._lru) >= self.capacity:
            self._evict_oldest()
        while self.table.pages_free < n_new and self._lru:
            self._evict_oldest()
        if self.table.pages_free < n_new:
            # nothing left to evict and still no room: deposits are
            # best-effort, drop this one rather than corrupt the table
            self.table.release(shared)
            self.dropped_deposits += 1
            return
        new_pages = self.table.alloc(n_new, domain=self.alloc_domain)
        if base is not None and len(base) % ps:
            # the boundary page diverges mid-page: its prefix bytes are
            # re-written into a fresh page (copy-on-write) — the shared
            # original is never touched
            self.table.cow_copies += 1
        if self.pool is not None and n_new:
            self.pool.write(cache, start, len(key), new_pages)
        self.pages_written += n_new
        self.zero_page_deposits += n_new == 0
        self._lru[key] = (PageBundle(shared + tuple(new_pages), len(key)), logits)

    def longest(self, tokens) -> tuple[int, Any, Any] | None:
        key = self._key(tokens)
        best = None
        for stored in self._lru:
            if len(stored) <= len(key) and stored == key[: len(stored)]:
                if best is None or len(stored) > len(best):
                    best = stored
        if best is None:
            self.misses += 1
            return None
        self.hits += 1
        self.reused_tokens += len(best)
        self._lru.move_to_end(best)
        bundle, logits = self._lru[best]
        return len(best), self._materialize(bundle), logits

    def get(self, tokens) -> tuple[Any, Any] | None:
        key = self._key(tokens)
        if key not in self._lru:
            return None
        self._lru.move_to_end(key)
        bundle, logits = self._lru[key]
        return self._materialize(bundle), logits

    def _materialize(self, bundle: PageBundle):
        if self.pool is None:
            return None  # accounting-only mode: nobody reads bytes
        return self.pool.read(bundle)

    def clear(self) -> None:
        while self._lru:
            self._evict_oldest()


def __getattr__(name):
    # the jax layer resolves lazily so PageTable/PagedPrefixKVStore stay
    # importable in the numpy-only lanes (docs, bench smoke, fleet sim)
    if name in ("PagedSlotCache", "PagedKVPool"):
        from . import paging_jax

        return getattr(paging_jax, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
