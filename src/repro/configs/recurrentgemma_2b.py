"""recurrentgemma-2b [hybrid]: 26L d=2560 10H (MQA kv=1) d_ff=7680 vocab=256000.
RG-LRU + local (sliding-window) attention in a 2:1 pattern (arXiv:2402.19427:
two recurrent blocks followed by one local-attention block)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv=1, d_ff=7680, vocab=256000,
    mlp="geglu", block_pattern=("rec", "rec", "attn"), lru_width=2560,
    conv_width=4, window=2048, accum=2,
)

def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=3, d_model=64, n_heads=4, n_kv=1, d_ff=128,
                          vocab=512, lru_width=64, window=32, accum=1, attn_chunk=32)
