"""A whole fleet behind the replica protocol: the region tier's "replica".

``RegionRouter`` treats fleets exactly as ``ReplicaRouter`` treats replicas
— the replica protocol (``capacity`` / ``occupancy`` / ``has_capacity`` /
``admit`` / ``summary`` and the shipping hooks) is the recursion boundary.
``SimFleet`` implements it by *composition*: inside each fleet sits a real
``ReplicaRouter`` over real ``SimReplica``s, so a region run exercises the
whole PR 4-8 stack per fleet (federated intra-fleet routing, GCR admission
caps, priced intra-fleet shipping) while the region tier disciplines
dispatch *across* fleets.

Summaries-of-summaries: ``summary()`` merges the member replicas' hottest
prefixes (freshest stamp first) into one fleet-level ``ReplicaSummary`` —
the same compact shape the fleet federation ingests, re-advertised one level
up.  The region federation therefore knows *which fleet* holds a prefix;
which member replica serves it is the inner router's business.
"""

from __future__ import annotations

from repro.core.topology import flat
from repro.router.federation import ReplicaSummary
from repro.router.router import ReplicaRouter, Session
from repro.router.sim import SimReplica

from repro.workload import output_tokens


class SimFleet:
    """One simulated fleet: ``n_replicas`` SimReplicas behind a federated
    ``ReplicaRouter``, fronted as a single region-level replica.

    ``admit`` runs the inner submit + dispatch synchronously — the region
    tier's ``has_capacity`` gate guarantees some member replica has headroom,
    so the inner CNA queue never holds a session across region ticks.
    ``kv_ship`` enables *intra-fleet* shipping over a flat member topology
    (the region fabric, with its inter-region ladder, is the
    ``RegionRouter``'s — two pipes, two price books)."""

    def __init__(
        self,
        fid: int,
        n_replicas: int,
        *,
        n_slots: int = 4,
        cache_budget: int = 600,
        page_size: int = 1,
        kv_ship=None,
        seed: int = 0xF1EE7,
        sync_every: int = 32,
        top_k: int = 8,
        tracer=None,
    ) -> None:
        self.fid = fid
        self.members = [
            SimReplica(r, n_slots, cache_budget=cache_budget, page_size=page_size)
            for r in range(n_replicas)
        ]
        self.router = ReplicaRouter(
            self.members,
            topology=flat(n_replicas, f"fleet{fid}"),
            seed=seed + 0x51 * fid,
            sync_every=sync_every,
            top_k=top_k,
            kv_ship=kv_ship,
            tracer=tracer,
        )
        self.served = 0
        self.deposits = 0
        self.deposit_tokens = 0

    # -- replica protocol ------------------------------------------------------
    @property
    def capacity(self) -> int:
        return sum(m.capacity for m in self.members)

    @property
    def occupancy(self) -> int:
        return sum(m.occupancy for m in self.members)

    def has_capacity(self) -> bool:
        r = self.router
        return any(r._has_headroom(i) for i in range(len(self.members)))

    def summary(self, top_k: int, now: int) -> ReplicaSummary:
        """Summaries-of-summaries: the fleet's hottest prefixes across every
        member, freshest stamp first, as one region-level advertisement."""
        merged: list = []
        for m in self.members:
            merged.extend(m.cache.hottest(top_k))
        merged.sort(key=lambda ts: -ts[1])
        seen, out = set(), []
        for tokens, stamp in merged:
            if tokens in seen:
                continue
            seen.add(tokens)
            out.append((tokens, stamp))
            if len(out) >= top_k:
                break
        return ReplicaSummary(
            replica=self.fid, t=now, occupancy=self.occupancy,
            capacity=self.capacity, prefixes=tuple(out),
        )

    def admit(self, session: Session, now: int) -> int:
        """Route ``session`` through the inner fleet and admit it there.

        The region tier stamped ``session.ship`` with *its* decision; the
        inner dispatch would overwrite it with the intra-fleet one, so both
        are preserved: the region decision stays on ``session.ship`` (the
        region event loop prices first-token waits from it) and the inner
        one moves to ``session.inner_ship``."""
        # the inner submit re-stamps the session's queue identity (submit_t,
        # home, matched_len) as if it had just arrived at the fleet — but the
        # session has been waiting in the *region* queue since submit_t, and
        # stall accounting (region stats and the event loop's admission-stall
        # histograms) is measured from there.  Preserve and restore.
        region_submit_t = session.submit_t
        region_home = session.home
        region_matched = session.matched_len
        region_ship = session.ship
        if region_ship is not None and region_ship.executed:
            # the session's own prefill starts no earlier than its region
            # transfer completes (the region loop holds its first token until
            # fabric_end), so the shipped bundle is legitimately deliverable
            # now — and a sync makes the inner federation route to it
            for m in self.members:
                m._deliver(region_ship.fabric_end)
            self.router.sync()
        session.ship = None
        self.router.advance(now)
        session.fleet = self.fid
        self.router.submit(session)
        d = self.router.dispatch_one()
        # region-level headroom gating makes the inner dispatch immediate;
        # a None here means a member broke the has_capacity contract
        assert d is not None and d[0] is session, "inner fleet failed to dispatch"
        session.inner_ship, session.ship = session.ship, region_ship
        session.submit_t = region_submit_t
        session.home = region_home
        session.matched_len = region_matched
        self.served += 1
        return session.local_matched

    # -- KV shipping hooks (region fabric) -------------------------------------
    def peek_match(self, prompt, now: int = 0) -> int:
        """Longest cached run of ``prompt`` anywhere in the fleet."""
        return max((m.peek_match(prompt, now) for m in self.members), default=0)

    def export_kv(self, prompt):
        """Export from the member holding the longest run."""
        best = max(self.members, key=lambda m: m.cache.peek(prompt))
        return best.export_kv(prompt)

    def import_kv(self, tokens, payload, ready_t: int = 0) -> bool:
        """Land a region-shipped bundle on the least-loaded member (the one
        an inner cold route would pick), embargoed until ``ready_t``."""
        target = min(self.members, key=lambda m: (m.occupancy, m.rid))
        return target.import_kv(tokens, payload, ready_t=ready_t)

    # -- completion ------------------------------------------------------------
    def finish(self, session: Session, *, ttft: int | None = None,
               deposit: bool = False) -> None:
        """Retire ``session`` on its member replica; ``deposit=True`` models
        the PR 5 retirement deposit — the session's prompt *plus its decode
        output* enters the serving replica's cache, so a conversation
        follow-up (whose prompt embeds exactly those output tokens — see
        ``repro.workload.output_tokens``) re-prefills almost nothing."""
        member = self.members[session.replica]
        member.finish(session)
        if deposit:
            deposited = session.prompt + output_tokens(session.sid, session.decode_len)
            charged = member.cache.insert(deposited)
            self.deposits += 1
            self.deposit_tokens += charged
        self.router.complete(session, ttft=ttft)
