"""jit wrapper for the SSD intra-chunk kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import ssd_intra_bchlpn


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra(xc, dac, bc, cc, *, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ssd_intra_bchlpn(xc, dac, bc, cc, interpret=interpret)
