"""The trip-count-aware HLO analyzer: validated against cost_analysis() on
scan-free programs, and against known loop structure on scanned ones."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import REPO_ROOT, run_env
from repro.core.jax_compat import cost_analysis_dict
from repro.launch.hlo_analysis import (
    _parse_groups,
    _wire_bytes,
    analyze_hlo,
    parse_module,
)


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


_cost = cost_analysis_dict  # normalises the dict-vs-[dict] jax API drift


def test_dot_flops_match_cost_analysis_scan_free():
    a = jnp.zeros((256, 512), jnp.float32)
    b = jnp.zeros((512, 128), jnp.float32)
    comp = _compile(lambda a, b: a @ b, a, b)
    got = analyze_hlo(comp.as_text()).flops
    want = _cost(comp)["flops"]
    assert got == pytest.approx(want, rel=1e-6)
    assert got == pytest.approx(2 * 256 * 512 * 128, rel=1e-6)


def test_scan_flops_scale_with_trip_count():
    w = jnp.zeros((64, 64), jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    comp = _compile(f, x, w)
    got = analyze_hlo(comp.as_text()).flops
    per_iter = 2 * 8 * 64 * 64
    # cost_analysis counts the body once; the analyzer must count 10x
    assert got == pytest.approx(10 * per_iter, rel=0.05)
    assert _cost(comp)["flops"] < got


def test_nested_scan_multiplicity():
    w = jnp.zeros((32, 32), jnp.float32)

    def f(w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        out, _ = jax.lax.scan(outer, jnp.eye(32), None, length=3)
        return out

    comp = _compile(f, w)
    got = analyze_hlo(comp.as_text()).flops
    assert got == pytest.approx(12 * 2 * 32**3, rel=0.05)


def test_parse_iota_replica_groups():
    n, g = _parse_groups("replica_groups=[4,2]<=[8]")
    assert n == 2 and g.shape == (4, 2)
    np.testing.assert_array_equal(g, np.arange(8).reshape(4, 2))
    n, g = _parse_groups("replica_groups=[2,4]<=[4,2]T(1,0)")
    assert n == 4 and g.shape == (2, 4)
    np.testing.assert_array_equal(g, np.arange(8).reshape(4, 2).T.reshape(2, 4))


def test_parse_explicit_replica_groups():
    n, g = _parse_groups("replica_groups={{0,1,2},{3,4,5}}")
    assert n == 3
    np.testing.assert_array_equal(g, [[0, 1, 2], [3, 4, 5]])


def test_cross_pod_classification():
    # groups spanning id 255->256 are cross-pod at chips_per_pod=256
    hlo = """
HloModule m
ENTRY %main (p: f32[512]) -> f32[512] {
  %p = f32[512]{0} parameter(0)
  ROOT %ar = f32[512]{0} all-reduce(%p), replica_groups=[256,2]<=[2,256]T(1,0), to_apply=%add
}
"""
    cost = analyze_hlo(hlo, chips_per_pod=256)
    assert cost.dcn_wire > 0 and cost.ici_wire == 0
    hlo_local = hlo.replace("[256,2]<=[2,256]T(1,0)", "[2,256]<=[512]")
    cost2 = analyze_hlo(hlo_local, chips_per_pod=256)
    assert cost2.ici_wire > 0 and cost2.dcn_wire == 0


def test_wire_byte_models():
    assert _wire_bytes("all-reduce", 100, 4) == pytest.approx(150.0)
    assert _wire_bytes("all-gather", 100, 4) == pytest.approx(75.0)
    assert _wire_bytes("reduce-scatter", 25, 4) == pytest.approx(75.0)
    assert _wire_bytes("collective-permute", 100, 2) == 100.0
    assert _wire_bytes("all-reduce", 100, 1) == 0.0


def test_collectives_inside_scan_multiply():
    """A psum inside a scanned body must be charged trip_count times."""
    import subprocess, sys, textwrap

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze_hlo
        mesh = jax.make_mesh((4,), ("data",))
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        def f(x, w):
            def body(c, _):
                y = c @ w
                return y - y.mean(), None   # mean over sharded rows -> all-reduce
            out, _ = jax.lax.scan(body, x, None, length=6)
            return out
        comp = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)), None)).lower(x, w).compile()
        cost = analyze_hlo(comp.as_text(), chips_per_pod=256)
        ar = {k: v for k, v in cost.collectives.items() if "all-reduce" in k}
        counts = sum(v["count"] for v in ar.values())
        print("COUNTS", counts)
        assert counts >= 6, (counts, cost.collectives)
    """)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=300,
        env=run_env(), cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
