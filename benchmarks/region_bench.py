"""Region tier: fleets-of-fleets under the diurnal multi-tenant trace.

The third hierarchy level's claims, each stated over the *paired* trace (the
``repro.workload`` generator emits one schedule, every arm replays it):

  * ``region_routing`` — region-federated routing (summaries-of-summaries +
    CNA discipline over fleets) beats region-oblivious least-loaded and
    round-robin on prefix locality (fraction of routed tokens already cached
    on the serving member) and on p99 admission stall under a phase-shifted
    diurnal wave;
  * ``tenant_flood`` — per-(tenant x fleet) caps (``RestrictedDiscipline``
    pseudo-domains, arXiv 1905.10818) bound starvation: under an adversarial
    single-tenant hot-prefix flood every tenant's p99 admission stall stays
    <= k x the fleet median, victims' p99 improves vs uncapped, and the
    flood alone pays the rejections;
  * ``diurnal_followups`` — conversation follow-ups (whose prompts embed the
    parent's decode output) re-prefill less when fleets deposit
    ``prompt + output`` at retirement (the PR 5 deposit, one level up);
  * ``determinism`` — the same seed reproduces identical headline numbers,
    twice, including across arms (the workload/sim stack has no hidden RNG
    and no wall-clock dependence).

All jax-free (workload generator + discrete-event simulators), so the whole
section runs in the CI smoke lane.
"""

from __future__ import annotations

import statistics

from repro.region import simulate_region
from repro.workload import (
    DiurnalWave,
    TraceGenerator,
    uniform_tenants,
    with_flood,
)

from .common import ascii_plot, claim, headline, smoke, table

# fleet shape shared by every scenario: 2 regions x 2 fleets x 2 replicas
# x 2 slots = 16 concurrent sessions
FLEET = dict(fleets_per_region=2, replicas_per_fleet=2, n_slots=2)
K_FAIRNESS = 3.0        # tenant p99 bound: k x max(fleet median, floor)
STALL_FLOOR = 500.0     # idle-fleet medians must not fabricate violations


def _diurnal_trace(seed=7):
    gen = TraceGenerator(
        n_regions=2,
        tenants=uniform_tenants(4, 2, followup_p=0.4, suffix_len=24),
        seed=seed,
        wave=DiurnalWave(period=smoke(2048, 512), amplitude=0.8),
        base_rate=0.03,
    )
    return gen.generate(horizon=smoke(6000, 1200))


def _flood_trace(seed=3):
    gen = TraceGenerator(
        n_regions=2,
        tenants=with_flood(
            uniform_tenants(6, 2, suffix_len=32, decode_len=24), weight=40.0
        ),
        seed=seed,
        base_rate=0.15,
    )
    return gen.generate(horizon=smoke(3000, 900))


def _followup_trace(seed=9):
    gen = TraceGenerator(
        n_regions=2,
        tenants=uniform_tenants(4, 2, followup_p=0.6, decode_len=24),
        seed=seed,
        base_rate=0.02,
    )
    return gen.generate(horizon=smoke(4096, 1400))


def region_routing(seed=11):
    tr = _diurnal_trace()
    rows, results = [], {}
    for arm in ("region", "least_loaded", "round_robin"):
        r = simulate_region(arm, tr, seed=seed, **FLEET)
        results[arm] = r
        rows.append([
            arm, r.served, f"{r.reuse_fraction:.3f}", r.reprefill_tokens,
            r.admission_stall_p50, r.admission_stall_p99, r.sheds,
        ])
    table(
        f"region routing under the diurnal trace ({len(tr)} requests, "
        f"2 regions x 2 fleets x 2 replicas)",
        ["arm", "served", "locality", "reprefill_tok", "stall_p50", "stall_p99",
         "sheds"],
        rows,
    )
    reg, ll, rr = results["region"], results["least_loaded"], results["round_robin"]
    claim(
        "region: federated routing beats least-loaded on prefix locality",
        reg.reuse_fraction > ll.reuse_fraction,
        f"{reg.reuse_fraction:.3f} vs {ll.reuse_fraction:.3f}",
    )
    claim(
        "region: federated routing beats round-robin on prefix locality",
        reg.reuse_fraction > rr.reuse_fraction,
        f"{reg.reuse_fraction:.3f} vs {rr.reuse_fraction:.3f}",
    )
    claim(
        "region: federated routing beats region-oblivious baselines on p99 "
        "admission stall",
        reg.admission_stall_p99 < ll.admission_stall_p99
        and reg.admission_stall_p99 < rr.admission_stall_p99,
        f"{reg.admission_stall_p99:.0f} vs ll={ll.admission_stall_p99:.0f} "
        f"rr={rr.admission_stall_p99:.0f}",
    )
    headline(
        region_requests=len(tr),
        region_locality=reg.reuse_fraction,
        region_locality_least_loaded=ll.reuse_fraction,
        region_stall_p99=reg.admission_stall_p99,
        region_stall_p99_least_loaded=ll.admission_stall_p99,
        region_reprefill_tokens=reg.reprefill_tokens,
        region_reprefill_tokens_least_loaded=ll.reprefill_tokens,
    )
    # the diurnal wave itself, per region: arrivals histogram over time
    buckets = 32
    hz = max(r.t for r in tr.requests) + 1
    series = {}
    for region in (0, 1):
        counts = [0] * buckets
        for req in tr.requests:
            if req.region == region:
                counts[min(buckets - 1, req.t * buckets // hz)] += 1
        series[f"region{region}"] = counts
    ascii_plot(
        "diurnal arrivals per region (phase-shifted)",
        list(range(buckets)), series, height=10,
    )
    return results


def tenant_flood(seed=5):
    tr = _flood_trace()
    flood_share = sum(1 for r in tr.requests if r.tenant == 0) / len(tr)
    uncapped = simulate_region("region", tr, seed=seed, **FLEET)
    capped = simulate_region(
        "region", tr, seed=seed, tenant_caps=3, tenant_park_bound=12, **FLEET
    )
    rows = []
    for tag, r in (("uncapped", uncapped), ("capped", capped)):
        p99 = r.tenant_p99()
        victims = {t: v for t, v in p99.items() if t != 0}
        rows.append([
            tag, r.served, r.rejected, r.tenant_parked,
            f"{p99.get(0, 0):.0f}", f"{max(victims.values()):.0f}",
            f"{statistics.median(p99.values()):.0f}",
        ])
    table(
        f"single-tenant hot-prefix flood ({len(tr)} requests, "
        f"{flood_share:.0%} from tenant 0; caps=3/fleet, park<=12)",
        ["arm", "served", "rejected", "parked", "flood_p99", "victim_p99_max",
         "median_p99"],
        rows,
    )
    p99c = capped.tenant_p99()
    med = statistics.median(p99c.values())
    bound = K_FAIRNESS * max(med, STALL_FLOOR)
    worst = max(p99c.values())
    claim(
        f"region: with caps, every tenant's p99 stall <= {K_FAIRNESS:.0f}x "
        "fleet median under flood",
        worst <= bound,
        f"worst={worst:.0f} bound={bound:.0f} (median={med:.0f})",
    )
    vic_un = max(v for t, v in uncapped.tenant_p99().items() if t != 0)
    vic_cap = max(v for t, v in p99c.items() if t != 0)
    claim(
        "region: caps improve victim tenants' p99 stall vs uncapped",
        vic_cap < vic_un,
        f"{vic_cap:.0f} vs {vic_un:.0f} uncapped",
    )
    claim(
        "region: the flooding tenant alone pays the rejections",
        capped.rejected > 0
        and capped.rejected_by_tenant.get(0, 0) == capped.rejected,
        f"rejected={capped.rejected} by_tenant={capped.rejected_by_tenant}",
    )
    headline(
        flood_victim_p99_capped=vic_cap,
        flood_victim_p99_uncapped=vic_un,
        flood_median_p99_capped=med,
        flood_rejected=capped.rejected,
    )
    return uncapped, capped


def diurnal_followups(seed=5):
    tr = _followup_trace()
    n_follow = sum(1 for r in tr.requests if r.turn > 0)
    on = simulate_region(
        "region", tr, seed=seed, cache_budget=2000, deposits=True, **FLEET
    )
    off = simulate_region(
        "region", tr, seed=seed, cache_budget=2000, deposits=False, **FLEET
    )
    table(
        f"retirement deposits vs follow-up re-prefill ({len(tr)} requests, "
        f"{n_follow} follow-up turns)",
        ["deposits", "reprefill_tok", "locality", "stall_p50", "deposited_tok"],
        [
            ["on", on.reprefill_tokens, f"{on.reuse_fraction:.3f}",
             on.admission_stall_p50, on.deposit_tokens],
            ["off", off.reprefill_tokens, f"{off.reuse_fraction:.3f}",
             off.admission_stall_p50, 0],
        ],
    )
    claim(
        "region: retirement deposits cut follow-up re-prefill under the "
        "diurnal conversation trace",
        on.reprefill_tokens < off.reprefill_tokens,
        f"{on.reprefill_tokens} vs {off.reprefill_tokens} without deposits",
    )
    headline(
        followup_turns=n_follow,
        followup_reprefill_deposits_on=on.reprefill_tokens,
        followup_reprefill_deposits_off=off.reprefill_tokens,
    )
    return on, off


def determinism(seed=11):
    tr = _diurnal_trace()
    a = simulate_region("region", tr, seed=seed, tenant_caps=4, **FLEET)
    b = simulate_region("region", tr, seed=seed, tenant_caps=4, **FLEET)
    same = a.headline() == b.headline() and a.ttfts == b.ttfts
    claim(
        "region: same seed reproduces identical headline numbers twice",
        same,
        f"served={a.served} p99={a.admission_stall_p99:.0f}",
    )
    # and the generator side: regenerating the trace is bit-identical
    tr2 = _diurnal_trace()
    claim(
        "workload: same seed regenerates the identical trace",
        tr2.requests == tr.requests,
        f"{len(tr)} requests",
    )
    return a


def run_all():
    region_routing()
    tenant_flood()
    diurnal_followups()
    determinism()


if __name__ == "__main__":
    run_all()
