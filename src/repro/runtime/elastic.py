"""Elastic re-meshing: survive pod/host loss by re-sharding from checkpoint.

``plan_mesh`` picks the largest usable mesh for the devices that remain
(drop the pod axis when a pod dies; shrink the data axis for partial loss —
the model axis is preserved because TP degree is baked into layouts/Pallas
block shapes, while the batch axes are free).

``ElasticTrainer`` is the restart loop used by launch/train.py and the fault
tests: run -> (failure) -> plan_mesh over survivors -> restore checkpoint
with the *new* shardings (CheckpointManager stores unsharded arrays, so this
is one device_put per leaf) -> rescale the data loader (same global stream,
new host partition) -> continue.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax

from repro.models.sharding import use_mesh
from repro.training.step import state_abstract, state_logical, tree_shardings


def plan_mesh(n_devices: int, *, model_parallel: int, want_pods: int = 1):
    """-> (shape tuple, axis names) for the largest mesh on n_devices.

    Keeps ``model_parallel`` fixed; gives the rest to data; re-adds the pod
    axis only if at least 2 full pods survive."""
    if n_devices % model_parallel:
        raise ValueError(f"{n_devices} devices not divisible by TP={model_parallel}")
    rest = n_devices // model_parallel
    if want_pods >= 2 and rest % want_pods == 0 and rest // want_pods >= 1:
        return (want_pods, rest // want_pods, model_parallel), ("pod", "data", "model")
    return (rest, model_parallel), ("data", "model")


def make_mesh_from_plan(shape: Sequence[int], axes: Sequence[str], devices=None):
    devices = devices if devices is not None else jax.devices()
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(tuple(shape), tuple(axes), devices=devices[:n])


@dataclass
class ElasticTrainer:
    """Restart loop driver (see tests/test_elastic.py for the 8->4 scenario)."""

    model: object
    cfg: object
    ckpt: object          # CheckpointManager
    model_parallel: int

    def restore_on(self, devices, *, want_pods: int = 1):
        """Restore the latest checkpoint onto a mesh built from ``devices``."""
        shape, axes = plan_mesh(len(devices), model_parallel=self.model_parallel, want_pods=want_pods)
        mesh = make_mesh_from_plan(shape, axes, devices)
        step = self.ckpt.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint to restore")
        abs_state = state_abstract(self.model, self.cfg)
        with use_mesh(mesh):
            shardings = tree_shardings(abs_state, state_logical(self.model))
            state, extra = self.ckpt.restore(step, abs_state, shardings=shardings, extra=True)
        return mesh, state, extra
