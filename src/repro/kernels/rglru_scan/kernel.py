"""RG-LRU linear-scan Pallas TPU kernel.

The recurrence h_t = a_t h_{t-1} + b_t is bandwidth-bound (3 streams in, one
out, O(1) FLOPs/byte), so the kernel's job on TPU is purely to keep the
recurrent state resident in VMEM while streaming (a, b) tiles HBM->VMEM:

  * grid = (B, n_w_blocks, n_s_blocks); the sequence dimension is the
    innermost (sequential) grid axis, so the (block_w,) state vector carries
    across sequence tiles in VMEM scratch.
  * within a tile, a ``fori_loop`` walks block_s steps of the recurrence on
    the VPU; each step is an (8,128)-lane fused multiply-add.
  * channel blocks (block_w = 128 lanes by default) are independent, giving
    the second parallel grid axis.

Contrast with the GPU formulation (warp-parallel Blelloch scan): on TPU the
sequential-grid + VMEM-carry pattern is both simpler and optimal once the
kernel is bandwidth-bound; the log-depth tree adds no speedup when a single
pass already saturates HBM.  (DESIGN.md, hardware-adaptation notes.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(a_ref, b_ref, h0_ref, o_ref, h_scr, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    def step(t, h):
        a_t = a_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)
        h = a_t * h + b_t
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, block_s, step, h_scr[...])


def linear_scan_bsw(
    a: jax.Array,   # (B, S, W) fp32
    b: jax.Array,   # (B, S, W)
    h0: jax.Array,  # (B, W)
    *,
    block_s: int = 256,
    block_w: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bsz, s, w = a.shape
    block_s = min(block_s, s)
    block_w = min(block_w, w)
    assert s % block_s == 0 and w % block_w == 0, (s, w, block_s, block_w)
    grid = (bsz, w // block_w, s // block_s)
    return pl.pallas_call(
        functools.partial(_scan_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda b, wi, si: (b, si, wi)),
            pl.BlockSpec((1, block_s, block_w), lambda b, wi, si: (b, si, wi)),
            pl.BlockSpec((1, block_w), lambda b, wi, si: (b, wi)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w), lambda b, wi, si: (b, si, wi)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
