"""Pluggable slot-placement policies over ``DomainFreeLists``.

Where the admission scheduler decides *when* a request runs (the paper's
lock-handover order), the placement policy decides *where* its decode cache
lives.  A slot in the request's KV/prefix home domain costs nothing extra; a
slot elsewhere charges a distance-aware migration (the prefix/KV blocks move
across the fabric once, at claim time) priced by ``Topology.xfer_cycles`` —
the same local/remote/cross ladder the lock simulator charges for cache-line
transfers.

Policies:

  ``lowest_free``     the seed baseline: globally lowest free slot, blind to
                      domains (kept as the benchmarks' control arm);
  ``home_domain``     home pool first, otherwise fall back to the global
                      lowest slot (locality when easy, no search otherwise);
  ``nearest_spill``   home pool first, then nearest-domain spill in
                      (distance, index) order — the NUMA-allocator rule.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.numasim import TWO_SOCKET, CostModel

from .freelists import DomainFreeLists


@dataclass(frozen=True)
class Placement:
    """One placement decision: where the slot landed and what the miss cost."""

    slot: int
    slot_domain: int
    home_domain: int
    distance: int
    migration_cycles: int

    @property
    def local(self) -> bool:
        return self.distance == 0


class PlacementPolicy:
    """Strategy interface: pick a free slot for a request homed in ``home``."""

    name = "base"

    def pick(self, pools: DomainFreeLists, home: int) -> tuple[int, int] | None:
        raise NotImplementedError

    def place(
        self, pools: DomainFreeLists, home: int, cm: CostModel | None = None
    ) -> Placement | None:
        """Claim a slot for ``home`` and price the migration; None when full."""
        out = self.pick(pools, home)
        if out is None:
            return None
        slot, dom = out
        topo = pools.topology
        dist = topo.distance(home, dom)
        cycles = 0 if dist == 0 else topo.xfer_cycles(cm or TWO_SOCKET, home, dom)
        return Placement(slot, dom, home, dist, cycles)


class LowestFree(PlacementPolicy):
    name = "lowest_free"

    def pick(self, pools: DomainFreeLists, home: int):
        return pools.claim_lowest()


class HomeDomain(PlacementPolicy):
    name = "home_domain"

    def pick(self, pools: DomainFreeLists, home: int):
        slot = pools.claim_in(home)
        if slot is not None:
            return slot, home
        return pools.claim_lowest()


class NearestSpill(PlacementPolicy):
    name = "nearest_spill"

    def pick(self, pools: DomainFreeLists, home: int):
        return pools.claim_nearest(home)


POLICIES = {cls.name: cls for cls in (LowestFree, HomeDomain, NearestSpill)}


def get_policy(spec) -> PlacementPolicy:
    """Coerce a PlacementPolicy | registry name | class to a policy instance."""
    if isinstance(spec, PlacementPolicy):
        return spec
    if isinstance(spec, type) and issubclass(spec, PlacementPolicy):
        return spec()
    if isinstance(spec, str):
        try:
            return POLICIES[spec]()
        except KeyError:
            raise KeyError(f"unknown placement policy {spec!r}; have {sorted(POLICIES)}") from None
    raise TypeError(f"cannot interpret {spec!r} as a placement policy")
