"""Unified observability: causal request traces + one metrics registry.

The paper's whole argument is an attribution claim — CNA wins because lock
handovers stay on-socket, and you can *count* where the cycles went.  This
package is that discipline applied to the repo itself:

  ``trace``     ``Tracer``/``Span``: causally-linked, deterministic-clock
                spans per request (``submit → home-derivation → queue-wait →
                shed → ship(price/wait/transfer) → admit → migrate →
                prefill(fresh|cont|reuse) → decode → retire``), with
                discipline-level events (``Grant``/``Shuffle``/
                ``SecondaryFlush``) attached as span events;
  ``registry``  ``MetricsRegistry``: counters, gauges, and bounded
                histograms (p50/p99 under a memory cap) that the four legacy
                stat surfaces (``SchedulerMetrics``, ``PlacementTelemetry``,
                ``RouterStats``, ``ShipStats``) register into as thin views
                — no call-site API changes;
  ``export``    JSONL trace dump, Prometheus-style text rendering, and an
                ASCII per-request flame summary.

Zero-cost-off is a hard contract: every instrumentation site guards on the
tracer's truthiness (``NULL_TRACER`` is falsy), never consumes shared RNG
streams, and never changes control flow — tracing disabled is bitwise
identical to the pre-instrumentation code, and the cross-driver grant-order
tests pin it.
"""

from .export import flame, render_prometheus, to_jsonl
from .registry import BoundedHistogram, Counter, Gauge, HistogramVector, MetricsRegistry
from .trace import NULL_TRACER, NullTracer, Span, Tracer, trace_key

__all__ = [
    "BoundedHistogram",
    "Counter",
    "Gauge",
    "HistogramVector",
    "MetricsRegistry",
    "NULL_TRACER",
    "NullTracer",
    "Span",
    "Tracer",
    "flame",
    "render_prometheus",
    "to_jsonl",
    "trace_key",
]
