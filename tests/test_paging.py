"""Paged KV with copy-on-write prefix sharing: page-table invariants
(property-tested), the paged prefix store's sharing/eviction semantics, page-
granular ship pricing and multi-source planning, the router's prefetch and
victim-caching movers, and the engine-level bitwise-equality contract (a
paged engine is indistinguishable from the slot engine on outputs and
position accounting).

The jax-free tests exercise ``repro.serving.paging`` in accounting mode
(``pool=None``) — identical bookkeeping, no arrays — which is the same
surface the fleet sim and the bench smoke lane rely on.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback
    from _hypothesis_compat import given, settings, st

from repro.serving.paging import PagedPrefixKVStore, PageTable, pages_for


# -- page-table invariants (property-tested) ----------------------------------


def _assert_conservation(t: PageTable) -> None:
    """free + referenced partitions the table, and nothing is negative."""
    t.check()  # raises on: overlap, negative refs, bad partition
    referenced = sum(1 for r in t.refs if r > 0)
    assert t.pages_free + referenced == t.pages_total


@settings(max_examples=25, deadline=None)
@given(
    ops=st.lists(
        st.tuples(st.integers(min_value=0, max_value=2),
                  st.integers(min_value=1, max_value=6)),
        min_size=1, max_size=50,
    )
)
def test_refcount_conservation_under_random_ops(ops):
    """alloc/retain/release in any order: free + referenced == total after
    every step, no page both free and referenced, no negative refcounts."""
    t = PageTable(24, 8)
    held = []  # live references we own: each entry is one retain's worth
    for kind, n in ops:
        if kind == 0:
            try:
                held.append(tuple(t.alloc(n)))
            except IndexError:
                pass  # exhausted: all-or-nothing, table must stay intact
        elif kind == 1 and held:
            run = held[n % len(held)]
            t.retain(run)
            held.append(run)
        elif kind == 2 and held:
            t.release(held.pop(n % len(held)))
        _assert_conservation(t)
    for run in held:
        t.release(run)
    _assert_conservation(t)
    assert t.pages_free == t.pages_total


def test_alloc_is_all_or_nothing():
    t = PageTable(4, 8)
    t.alloc(3)
    with pytest.raises(IndexError):
        t.alloc(2)  # only 1 free
    assert t.pages_free == 1  # the failed alloc leaked nothing
    _assert_conservation(t)


def test_release_below_zero_refuses():
    t = PageTable(4, 8)
    (p,) = t.alloc(1)
    t.release([p])
    with pytest.raises(ValueError):
        t.release([p])


class _RecordingPool:
    """Pool stub that asserts the COW contract at the write boundary: every
    page handed to ``write`` must be exclusively owned (refcount 1) — a
    write to a shared page would corrupt every other holder bitwise."""

    def __init__(self, table: PageTable):
        self.table = table
        self.writes = []

    def write(self, cache, start, end, pages):
        for p in pages:
            assert self.table.refcount(p) == 1, (
                f"COW violation: write to page {p} with "
                f"refcount {self.table.refcount(p)}"
            )
        self.writes.append((start, end, tuple(pages)))

    def read(self, bundle):
        return {"pos": bundle.length}


@settings(max_examples=25, deadline=None)
@given(
    picks=st.lists(
        st.tuples(st.integers(min_value=0, max_value=3),
                  st.integers(min_value=1, max_value=40)),
        min_size=1, max_size=30,
    )
)
def test_cow_never_mutates_a_shared_page(picks):
    """Random deposits of overlapping prefixes: every page the store writes
    is freshly allocated (refcount 1).  Shared pages are immutable — the
    partial boundary page of a shared prefix is *copied*, never extended in
    place."""
    t = PageTable(64, 8)
    store = PagedPrefixKVStore(8, table=t, pool=_RecordingPool(t))
    for fam, length in picks:
        key = tuple(10_000 * fam + j for j in range(length))
        store.put(key, {"pos": length}, None)
        _assert_conservation(t)
    store.clear()
    _assert_conservation(t)
    assert t.pages_free == t.pages_total


@settings(max_examples=25, deadline=None)
@given(
    lengths=st.lists(st.integers(min_value=1, max_value=40),
                     min_size=2, max_size=24),
    capacity=st.integers(min_value=2, max_value=6),
)
def test_release_of_shared_prefix_never_frees_referenced_pages(lengths, capacity):
    """LRU churn evicts entries whose pages other entries still share; every
    surviving entry's pages must stay referenced (refcount >= 1) no matter
    which holder was dropped."""
    t = PageTable(48, 8)
    store = PagedPrefixKVStore(capacity, table=t)
    base = tuple(range(100))
    for i, ln in enumerate(lengths):
        # nested prefixes of one family + a divergent suffix per deposit,
        # so entries share pages aggressively and evictions hit shared runs
        store.put(base[:ln] + (1_000 + i,), None, None)
        _assert_conservation(t)
        for _key, (bundle, _logits) in store._lru.items():
            for p in bundle.pages:
                assert t.refcount(p) >= 1, f"page {p} freed under a live entry"


# -- paged store sharing semantics (jax-free accounting mode) -----------------


def test_extensions_share_full_prefix_pages():
    t = PageTable(32, 8)
    store = PagedPrefixKVStore(8, table=t)
    base = tuple(range(16))  # exactly 2 pages
    store.put(base, None, None)
    for s in (101, 202):
        store.put(base + (s,) * 8, None, None)  # +1 page each
    # 2 base pages held once (refcount 3), one suffix page per extension
    assert t.pages_held == 2 + 2
    assert store.logical_pages == 2 + 3 + 3
    assert t.pages_shared == 2
    assert [t.refcount(p) for p in store.bundle(base).pages] == [3, 3]
    _assert_conservation(t)


def test_reput_of_stored_key_costs_zero_pages():
    t = PageTable(32, 8)
    store = PagedPrefixKVStore(8, table=t)
    key = tuple(range(20))
    store.put(key, None, None)
    held = t.pages_held
    store.put(key, None, None)
    assert t.pages_held == held
    assert store.zero_page_deposits == 1


def test_unaligned_prefix_pays_one_cow_page():
    t = PageTable(32, 8)
    store = PagedPrefixKVStore(8, table=t)
    base = tuple(range(12))  # 1 full page + 4 tokens into page 2
    store.put(base, None, None)
    store.put(base + (7,) * 4, None, None)  # extends within page 2
    # full page shared; the partial page is copied, not mutated
    assert t.cow_copies == 1
    assert t.refcount(store.bundle(base).pages[0]) == 2
    assert t.refcount(store.bundle(base).pages[1]) == 1  # still exclusive
    _assert_conservation(t)


def test_eviction_keeps_pages_other_entries_share():
    t = PageTable(32, 8)
    store = PagedPrefixKVStore(2, table=t)
    base = tuple(range(16))
    store.put(base, None, None)
    store.put(base + (1,) * 8, None, None)
    store.put(base + (2,) * 8, None, None)  # capacity 2: evicts base entry
    assert store.bundle(base) is None
    # the evicted entry's pages survive through the extensions' references
    for key in (base + (1,) * 8, base + (2,) * 8):
        b = store.bundle(key)
        assert b is not None and all(t.refcount(p) >= 1 for p in b.pages)
    _assert_conservation(t)


def test_deposit_dropped_when_pool_exhausted():
    t = PageTable(4, 8)
    store = PagedPrefixKVStore(8, table=t)
    store.put(tuple(range(32)), None, None)  # 4 pages: fills the table
    store.put(tuple(9_000 + j for j in range(40)), None, None)  # needs 5
    assert store.dropped_deposits == 1
    _assert_conservation(t)  # the failed deposit leaked nothing


def test_pages_for():
    assert pages_for(0, 8) == 0
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2


def test_page_gauges_register():
    from repro.obs import MetricsRegistry

    t = PageTable(16, 8, bytes_per_page=64)
    t.alloc(3)
    reg = MetricsRegistry()
    t.register_into(reg, prefix="kv")
    snap = reg.collect()
    assert snap["kv_pages_total"] == 16
    assert snap["kv_pages_free"] == 13
    assert snap["kv_pages_shared"] == 0
    assert snap["kv_kv_bytes_held"] == 3 * 64


# -- page-granular ship pricing (repro.router.kvship) -------------------------


def test_decide_page_pricing_trims_target_held_pages():
    from repro.router.kvship import ShipCostModel, decide

    kw = dict(prompt_len=100, local_matched=20, src_matched=80, src=1, dst=0,
              distance=1)
    legacy = decide(cm=ShipCostModel(page_size=0), **kw)
    paged = decide(cm=ShipCostModel(page_size=16), **kw)
    # ps=0 is byte-for-byte the whole-bundle charge
    assert legacy.ship_tokens == legacy.tokens_to_move == 80
    # ps=16: the target's 20 tokens cover one full page -> 16 fewer ship
    assert paged.ship_tokens == 80 - 16
    assert paged.ship_cycles < legacy.ship_cycles


def test_plan_ship_sources_disjoint_page_ranges():
    from repro.router.kvship import ShipCostModel, plan_ship

    cm = ShipCostModel(page_size=16)
    d = plan_ship(
        prompt_len=128, local_matched=0, holders={1: 32, 2: 96}, dst=0,
        distance_of=lambda s: 1 if s == 1 else 2, cm=cm,
    )
    # the near holder ships the pages it has; the far one only the rest
    assert [(s.src, s.start_tok, s.end_tok) for s in d.segments] == [
        (1, 0, 32), (2, 32, 96),
    ]
    assert d.ship_tokens == 96 and d.src_matched == 96
    # each segment is priced separately (fragmentation pays its setup)
    assert d.ship_cycles == cm.xfer_cycles(32, 1) + cm.xfer_cycles(64, 2)
    assert d.choice == "ship"


def test_plan_ship_starts_at_target_page_boundary():
    from repro.router.kvship import ShipCostModel, plan_ship

    d = plan_ship(
        prompt_len=128, local_matched=37, holders={1: 96}, dst=0,
        distance_of=lambda s: 1, cm=ShipCostModel(page_size=16),
    )
    # 37 held tokens cover 2 full pages: shipping starts at token 32
    assert d.segments[0].start_tok == 32
    assert d.ship_tokens == 96 - 32


def test_plan_ship_requires_page_pricing():
    from repro.router.kvship import ShipCostModel, plan_ship

    with pytest.raises(ValueError, match="page_size"):
        plan_ship(prompt_len=8, local_matched=0, holders={1: 8}, dst=0,
                  distance_of=lambda s: 1, cm=ShipCostModel(page_size=0))


# -- router: multi-source execution, prefetch, victim caching -----------------


def _router(replicas, **kw):
    from repro.router.router import ReplicaRouter

    return ReplicaRouter(replicas, sync_every=0, **kw)


def test_paged_ship_executes_multi_source_segments():
    from repro.router.kvship import ShipCostModel
    from repro.router.router import Session
    from repro.router.sim import SimReplica

    reps = [SimReplica(r, 1, cache_budget=600) for r in range(3)]
    base = tuple(range(96))
    reps[1].cache.insert(base[:32])
    reps[2].cache.insert(base)
    reps[1].inflight = reps[2].inflight = 1  # full: only replica 0 can take it
    router = _router(reps, kv_ship=ShipCostModel(page_size=16))
    router.sync()
    s = Session(sid=0, prompt=base + (7, 8, 9, 10), decode_len=1)
    router.submit(s)
    out = router.dispatch_one()
    assert out is not None and out[1] == 0
    d = s.ship
    assert d is not None and d.executed
    # flat topology: equal distances, ties to the lower id -> replica 1
    # ships the pages it covers, replica 2 only the remainder
    assert [(seg.src, seg.start_tok, seg.end_tok) for seg in d.segments] == [
        (1, 0, 32), (2, 32, 96),
    ]
    assert router.stats.ships == 1
    assert router.stats.ship_segments == 2
    assert router.stats.shipped_tokens == 96
    # the imports landed: replica 0 resumed from the full shipped prefix
    assert s.local_matched == 96


def test_prefetch_ships_hot_prefix_ahead_of_shed():
    from repro.router.kvship import ShipCostModel
    from repro.router.sim import SimReplica

    reps = [SimReplica(0, 2, cache_budget=600), SimReplica(1, 2, cache_budget=600)]
    hot = tuple(range(48))
    reps[0].cache.insert(hot)
    reps[0].inflight = 2  # at cap: the next dispatch would shed to replica 1
    router = _router(reps, kv_ship=ShipCostModel(page_size=16), prefetch=True)
    assert router.stats.prefetch_ships == 0
    router.sync()
    assert router.stats.prefetch_ships == 1
    assert router.stats.prefetch_tokens == 48
    # the prefix is resident on the shed target before any session needs it
    assert reps[1].peek_match(hot, now=10_000) == 48
    # deduped: a second sync does not re-ship the same prefix
    router.sync()
    assert router.stats.prefetch_ships == 1


def test_prefetch_idle_fleet_ships_nothing():
    from repro.router.kvship import ShipCostModel
    from repro.router.sim import SimReplica

    reps = [SimReplica(r, 2, cache_budget=600) for r in range(2)]
    reps[0].cache.insert(tuple(range(48)))
    router = _router(reps, kv_ship=ShipCostModel(page_size=16), prefetch=True)
    router.sync()  # nobody near cap: no speculation
    assert router.stats.prefetch_ships == 0


def test_victim_cache_rehomes_last_fleet_copy():
    from repro.router.kvship import ShipCostModel
    from repro.router.sim import SimReplica

    reps = [SimReplica(0, 2, cache_budget=40), SimReplica(1, 2, cache_budget=600)]
    victim = tuple(range(32))
    router = _router(reps, kv_ship=ShipCostModel(page_size=16), victim_cache=True)
    reps[0].cache.insert(victim)
    reps[0].cache.insert(tuple(9_000 + j for j in range(32)))  # evicts victim
    assert reps[0].peek_match(victim) == 0  # gone from the evictor
    router.sync()
    assert router.stats.victim_ships == 1
    assert router.stats.victim_tokens == 32
    assert reps[1].peek_match(victim, now=10_000) == 32


def test_victim_still_held_elsewhere_is_dropped():
    from repro.router.kvship import ShipCostModel
    from repro.router.sim import SimReplica

    reps = [SimReplica(0, 2, cache_budget=40),
            SimReplica(1, 2, cache_budget=600),
            SimReplica(2, 2, cache_budget=600)]
    victim = tuple(range(32))
    reps[1].cache.insert(victim)  # a sibling already holds it
    router = _router(reps, kv_ship=ShipCostModel(page_size=16), victim_cache=True)
    router.sync()  # replica 1 advertises the run
    reps[0].cache.insert(victim)
    reps[0].cache.insert(tuple(9_000 + j for j in range(32)))
    router.sync()
    assert router.stats.victim_ships == 0  # not the last copy: just drop


def test_speculative_movers_require_a_fabric():
    from repro.router.sim import SimReplica

    reps = [SimReplica(r, 2, cache_budget=100) for r in range(2)]
    with pytest.raises(ValueError, match="kv_ship"):
        _router(reps, prefetch=True)
    with pytest.raises(ValueError, match="kv_ship"):
        _router(reps, victim_cache=True)


# -- engine-level contract (jax) ----------------------------------------------


@pytest.fixture(scope="module")
def small_model():
    import jax

    from repro.configs.base import get_reduced_config
    from repro.models.registry import build_model

    cfg = get_reduced_config("granite_3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _shared_prefix_requests(cfg, n=6, plen=12, shared=8, max_new=4, seed=3):
    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab, shared)
    return [
        Request(
            rid=i,
            prompt=np.concatenate(
                [prefix, rng.integers(0, cfg.vocab, plen - shared)]
            ).astype(np.int32),
            max_new=max_new,
            domain=i % 2,
        )
        for i in range(n)
    ]


def test_extract_unowned_slot_raises(small_model):
    """Regression: extracting a released (or never-claimed) slot used to
    hand out the previous owner's stale KV as a live cache."""
    import jax

    from repro.serving.kvcache import SlotCache

    cfg, model, params = small_model
    slots = SlotCache.zeros(model, 2, 16)
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": np.zeros((1, 4), np.int32)}
    )
    slot = slots.claim("req")
    slots.insert(slot, slots.fit_single(cache))
    slots.extract(slot)  # owned: fine
    slots.release(slot)
    with pytest.raises(ValueError, match="unowned slot"):
        slots.extract(slot)
    with pytest.raises(ValueError, match="unowned slot"):
        slots.extract(1)  # never claimed


def test_paged_engine_bitwise_equals_slot_engine(small_model):
    """The tentpole contract: a paged engine produces bitwise-identical
    outputs to the slot engine on a shared-prefix workload, with the same
    ``prefill_positions + reused_positions`` conservation — and leaves a
    consistent page table with every slot's pin released."""
    from repro.serving.engine import DecodeEngine, Request

    cfg, model, params = small_model
    base = _shared_prefix_requests(cfg)

    def run(**kw):
        reqs = [Request(r.rid, r.prompt, r.max_new, r.domain) for r in base]
        eng = DecodeEngine(model, params, n_slots=3, cache_len=32, **kw)
        eng.run(reqs)
        return eng, {r.rid: tuple(r.out) for r in reqs}

    ref, ref_out = run(prefix_kv=True)
    paged, paged_out = run(paging=True, page_size=8)
    assert paged_out == ref_out
    assert paged.prefill_positions == ref.prefill_positions
    assert paged.reused_positions == ref.reused_positions
    assert paged.reused_positions > 0  # the workload actually shared
    t = paged.slots.table
    t.check()
    assert t.pages_shared > 0  # prefixes landed on shared physical pages
    assert paged.slots.seq_pages == {}  # every retired slot dropped its pin


def test_paged_engine_refuses_non_dense_families():
    import jax

    from repro.configs.base import get_reduced_config
    from repro.models.registry import build_model
    from repro.serving.engine import DecodeEngine

    cfg = get_reduced_config("mamba2_130m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="dense-attention"):
        DecodeEngine(model, params, n_slots=2, cache_len=32, paging=True)


def test_paged_engine_rejects_external_prefix_store(small_model):
    from repro.serving.engine import DecodeEngine
    from repro.serving.prefixkv import PrefixKVStore

    cfg, model, params = small_model
    with pytest.raises(ValueError, match="page-backed"):
        DecodeEngine(model, params, n_slots=2, cache_len=32, paging=True,
                     prefix_kv=PrefixKVStore())


def test_paged_engine_registers_page_gauges(small_model):
    from repro.obs import MetricsRegistry
    from repro.serving.engine import DecodeEngine

    cfg, model, params = small_model
    eng = DecodeEngine(model, params, n_slots=2, cache_len=32, paging=True,
                       page_size=8)
    eng.run(_shared_prefix_requests(cfg, n=3))
    reg = MetricsRegistry()
    eng.register_metrics(reg)
    snap = reg.collect()
    for g in ("engine_pages_total", "engine_pages_shared", "engine_pages_free",
              "engine_kv_bytes_held"):
        assert g in snap, g
    assert snap["engine_pages_total"] > 0
    assert snap["engine_kv_bytes_held"] > 0
    assert "pages_total" in reg.render_prometheus()
