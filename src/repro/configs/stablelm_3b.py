"""stablelm-3b [dense]: 32L d=2560 32H (kv=32, i.e. MHA) d_ff=6912 vocab=50304.
Source: hf:stabilityai/stablelm family."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv=32, d_ff=6912, vocab=50304,
    mlp="swiglu", norm="layernorm", accum=1,
)

def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128,
                          vocab=512, accum=1, attn_chunk=64)
