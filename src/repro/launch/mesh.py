"""Production mesh definitions.

A *function*, not a module-level constant — importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any device
query; tests/smoke runs see the real 1-device CPU).

Mesh geometry (TPU v5e pods):

  single-pod:  (16, 16)    axes (data, model)          = 256 chips
  multi-pod:   (2, 16, 16) axes (pod, data, model)     = 512 chips

The ``pod`` axis is the CNA locality domain: ICI inside a pod (fast,
"same-socket" handover), DCN across pods (slow, the remote-socket transfer
the paper's admission policy avoids).
"""

from __future__ import annotations

import jax

from repro.core.jax_compat import axis_types_kw


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **axis_types_kw(len(shape)))


def make_host_mesh(model_parallel: int = 1):
    """Largest mesh on the visible devices (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh(
        (n // model_parallel, model_parallel),
        ("data", "model"),
        **axis_types_kw(2),
    )


# -- hardware constants (TPU v5e per chip; see EXPERIMENTS.md §Roofline) -----
PEAK_FLOPS_BF16 = 197e12         # FLOP/s
HBM_BW = 819e9                   # bytes/s
ICI_BW_PER_LINK = 50e9           # bytes/s/link (one direction)
ICI_LINKS_PER_AXIS = 2           # bidirectional ring on one torus axis
ICI_BW = ICI_LINKS_PER_AXIS * ICI_BW_PER_LINK   # ring-collective BW per chip
DCN_BW = 25e9                    # bytes/s per chip across pods (assumption)
CHIPS_PER_POD = 256
