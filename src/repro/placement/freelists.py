"""Domain-partitioned free lists over a ``Topology``.

A NUMA allocator does not keep one global free list: each socket owns a pool
of local pages, and an allocation that cannot be satisfied locally spills to
the *nearest* socket (Linux's zonelist fallback order).  This module is that
structure for decode-cache slots: every slot has a fixed home domain (the
topology's placement rule — round-robin or block, exactly how the simulator
places threads on sockets), each domain keeps its free slots in a min-heap,
and ``claim_nearest`` walks domains in precomputed (distance, index) order.

The heaps keep every path O(log n_slots) per claim/release — the same bound
the baseline ``SlotCache`` heap path now has — and lowest-slot-first within
a domain keeps placement deterministic for tests.
"""

from __future__ import annotations

import heapq

from repro.core.topology import Topology, get_topology


class DomainFreeLists:
    """Per-domain slot pools with distance-ordered spill."""

    def __init__(self, n_slots: int, topology: Topology, slot_domain=None) -> None:
        self.topology = get_topology(topology)
        self.n_slots = n_slots
        if slot_domain is None:
            slot_domain = [self.topology.domain_of(s) for s in range(n_slots)]
        else:
            slot_domain = list(slot_domain)
            if len(slot_domain) != n_slots:
                raise ValueError("slot_domain must have one entry per slot")
            bad = [d for d in slot_domain if not 0 <= d < self.topology.n_domains]
            if bad:
                raise ValueError(f"slot_domain references unknown domains: {bad}")
        self.slot_domain = tuple(slot_domain)
        self._pools: list[list[int]] = [[] for _ in range(self.topology.n_domains)]
        for slot in range(n_slots):
            heapq.heappush(self._pools[self.slot_domain[slot]], slot)
        self._free = n_slots
        # Linux-zonelist-style fallback order: for each home domain, every
        # domain sorted by (distance from home, domain index).
        n = self.topology.n_domains
        self.spill_order = tuple(
            tuple(sorted(range(n), key=lambda d: (self.topology.distance(home, d), d)))
            for home in range(n)
        )

    def __len__(self) -> int:
        return self._free

    def free_count(self, domain: int) -> int:
        return len(self._pools[domain])

    def free_slots(self) -> list[int]:
        """All free slots, ascending (introspection/tests; not the hot path)."""
        return sorted(s for pool in self._pools for s in pool)

    def claim_in(self, domain: int) -> int | None:
        """Pop the lowest free slot homed in ``domain`` (None if exhausted)."""
        pool = self._pools[domain]
        if not pool:
            return None
        self._free -= 1
        return heapq.heappop(pool)

    def claim_nearest(self, home: int) -> tuple[int, int] | None:
        """Pop a free slot from the nearest non-empty domain to ``home``;
        returns ``(slot, slot_domain)`` or None when everything is claimed."""
        for dom in self.spill_order[home]:
            pool = self._pools[dom]
            if pool:
                self._free -= 1
                return heapq.heappop(pool), dom
        return None

    def claim_lowest(self) -> tuple[int, int] | None:
        """Pop the globally lowest free slot (the seed baseline's rule),
        regardless of domain; returns ``(slot, slot_domain)``."""
        best = None
        for dom, pool in enumerate(self._pools):
            if pool and (best is None or pool[0] < self._pools[best][0]):
                best = dom
        if best is None:
            return None
        self._free -= 1
        return heapq.heappop(self._pools[best]), best

    def release(self, slot: int) -> int:
        """Return ``slot`` to its home pool; returns that domain."""
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range")
        dom = self.slot_domain[slot]
        if slot in self._pools[dom]:
            raise ValueError(f"slot {slot} is already free")
        heapq.heappush(self._pools[dom], slot)
        self._free += 1
        return dom
