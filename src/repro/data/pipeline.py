"""Deterministic, resumable, shard-aware synthetic data pipeline.

Every batch is a pure function of ``(seed, step)`` — there is no consumable
iterator state, so:

  * **resume** after restart is exact: restore ``step`` from the checkpoint
    and the stream continues bit-for-bit (tested);
  * **sharding** is by index arithmetic: host h of H materialises rows
    ``[h*B/H, (h+1)*B/H)`` of the global batch — no coordination, no overlap;
  * **elastic rescale** (H changes) re-partitions the same global stream, so
    a 2-pod run restarted on 1 pod sees identical global batches (tested).

``BigramLMDataset`` draws token streams from a fixed random bigram chain so
that a small LM has learnable structure (examples/train_lm.py shows the loss
dropping toward the chain's conditional entropy); ``UniformLMDataset`` is
i.i.d. uniform (pure-throughput benchmarks).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class _Spec:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int


class UniformLMDataset:
    """i.i.d. uniform tokens.  batch(step) -> {tokens, labels} (B, S) int32."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int, seed: int = 0):
        self.spec = _Spec(vocab, seq_len, global_batch, seed)

    def batch(self, step: int, *, host: int = 0, n_hosts: int = 1) -> dict:
        sp = self.spec
        assert sp.global_batch % n_hosts == 0
        rows = sp.global_batch // n_hosts
        rng = np.random.Generator(np.random.Philox(key=sp.seed, counter=step))
        toks = rng.integers(0, sp.vocab, (sp.global_batch, sp.seq_len + 1), dtype=np.int32)
        toks = toks[host * rows : (host + 1) * rows]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class BigramLMDataset:
    """Tokens from a fixed random bigram chain (learnable structure).

    The transition table is derived from ``seed`` alone; batches are a pure
    function of (seed, step).  ``branching`` next-token candidates per token
    => conditional entropy = log(branching) nats (the loss floor)."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int, seed: int = 0, branching: int = 8):
        self.spec = _Spec(vocab, seq_len, global_batch, seed)
        self.branching = branching
        table_rng = np.random.Generator(np.random.Philox(key=seed ^ 0xB16A))
        self.table = table_rng.integers(0, vocab, (vocab, branching), dtype=np.int32)

    @property
    def entropy_floor(self) -> float:
        return float(np.log(self.branching))

    def batch(self, step: int, *, host: int = 0, n_hosts: int = 1) -> dict:
        sp = self.spec
        assert sp.global_batch % n_hosts == 0
        rows = sp.global_batch // n_hosts
        rng = np.random.Generator(np.random.Philox(key=sp.seed, counter=step))
        start = rng.integers(0, sp.vocab, (sp.global_batch,), dtype=np.int32)
        picks = rng.integers(0, self.branching, (sp.global_batch, sp.seq_len), dtype=np.int32)
        toks = np.empty((sp.global_batch, sp.seq_len + 1), np.int32)
        toks[:, 0] = start
        for t in range(sp.seq_len):
            toks[:, t + 1] = self.table[toks[:, t], picks[:, t]]
        toks = toks[host * rows : (host + 1) * rows]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class ShardedLoader:
    """Host-local view of a dataset + the resume/rescale bookkeeping."""

    def __init__(self, dataset, *, host: int = 0, n_hosts: int = 1, start_step: int = 0):
        self.dataset = dataset
        self.host = host
        self.n_hosts = n_hosts
        self.step = start_step

    def __next__(self) -> dict:
        b = self.dataset.batch(self.step, host=self.host, n_hosts=self.n_hosts)
        self.step += 1
        return b

    def state(self) -> dict:
        return {"step": self.step}

    @classmethod
    def resume(cls, dataset, state: dict, *, host: int = 0, n_hosts: int = 1):
        return cls(dataset, host=host, n_hosts=n_hosts, start_step=state["step"])
