"""§Roofline table from the dry-run JSON records (single-pod per assignment)."""

from __future__ import annotations

import glob
import json
import os


def _fmt(x, w=10):
    if isinstance(x, float):
        return f"{x:{w}.3e}" if (abs(x) < 1e-3 or abs(x) >= 1e4) and x != 0 else f"{x:{w}.4f}"
    return f"{str(x):>{w}}"


def load(results_dir="results/dryrun", mesh="16x16"):
    recs = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        r = json.load(open(f))
        if r.get("mesh") == mesh:
            recs.append(r)
    return recs


def report(results_dir="results/dryrun", mesh="16x16"):
    recs = load(results_dir, mesh)
    hdr = ["arch", "shape", "GB/dev", "compute_s", "memory_s", "collect_s",
           "dominant", "useful", "mfu"]
    print(f"\n## §Roofline single-pod table (mesh {mesh})")
    print(" | ".join(f"{h:>10}" for h in hdr))
    for r in recs:
        if r["status"] != "ok":
            print(f"{r['arch']:>10} | {r['shape']:>10} | {r['status'].upper()}: {r.get('why','')[:70]}")
            continue
        rr = r["roofline"]
        row = [r["arch"][:14], r["shape"], r["memory"]["peak_per_device_gb"],
               rr["compute_s"], rr["memory_s"], rr["collective_s"],
               rr["dominant"], rr["useful_ratio"], rr["mfu"]]
        print(" | ".join(_fmt(x) for x in row))
    ok = [r for r in recs if r["status"] == "ok"]
    if ok:
        fits = sum(1 for r in ok if r["memory"]["peak_per_device_gb"] <= 16.0)
        print(f"\ncells ok={len(ok)} skipped={len(recs)-len(ok)} fit16GB={fits}/{len(ok)}")


def run_all():
    import os

    dirs = [
        ("BASELINE (paper-faithful substrate, pre-§Perf)", "results/dryrun"),
        ("OPTIMIZED (post-§Perf iterations)", "results/dryrun_opt"),
    ]
    any_found = False
    for label, d in dirs:
        if not os.path.isdir(d) or not load(d, "16x16"):
            continue
        any_found = True
        print(f"\n==== {label} ====")
        for mesh in ("16x16", "2x16x16"):
            if load(d, mesh):
                report(d, mesh)
    if not any_found:
        print("(no dry-run records; run repro.launch.dryrun first)")
