"""CNA admission vs FIFO in the serving scheduler (the paper's policy carried
to the decode engine).  Three levels:

  * policy-level (fast): thousands of requests through the scheduler with a
    simulated switch cost — throughput/locality/fairness curves vs the
    fairness threshold (the paper's Fig. 6/8 trade-off, serving edition);
  * shared-prefix (fast, jax-free): a Zipf workload over a pool of common
    system-prompt prefixes through the scheduler + placement stack, comparing
    request homes *derived* from the prefix index (what production traffic
    has) against the caller-oracle (what the PR-2 benchmarks assumed) and a
    static domain-0 baseline;
  * engine-level (slower): a real reduced-config model decode on CPU.
"""

from __future__ import annotations

import random

import numpy as np

from repro.serving.scheduler import CNAScheduler, FIFOScheduler

from . import common
from .common import claim, smoke, table, zipf_draws


def policy_level(n_requests=4000, domains=4, switch_cost=8, service=1, seed=7):
    rows = []
    results = {}
    for name, mk in [
        ("fifo", lambda: FIFOScheduler()),
        ("cna_thr3", lambda: CNAScheduler(fairness_threshold=0x3, seed=seed)),
        ("cna_thrF", lambda: CNAScheduler(fairness_threshold=0xF, seed=seed)),
        ("cna_thrFF", lambda: CNAScheduler(fairness_threshold=0xFF, seed=seed)),
        ("cna_thrFFFF", lambda: CNAScheduler(fairness_threshold=0xFFFF, seed=seed)),
        # GCR-style admission control: only 16 requests circulate in the CNA
        # queues at once, the rest wait passivated.
        ("cna_rcr16", lambda: CNAScheduler(fairness_threshold=0xFF, seed=seed, max_active=16)),
    ]:
        rng = np.random.default_rng(seed)
        s = mk()
        t = 0
        # Poisson-ish arrivals, random domains; serve one request per grant
        arrivals = list(rng.integers(0, domains, n_requests))
        ai = 0
        served = 0
        while served < n_requests:
            # arrivals trickle in (2 per tick) so the queue has depth
            for _ in range(2):
                if ai < n_requests:
                    s.submit(f"r{ai}", int(arrivals[ai]))
                    ai += 1
            if len(s):
                before = s.current_domain
                s.next_request()
                served += 1
                t += service + (switch_cost if s.current_domain != before else 0)
            s.tick()
        m = s.metrics
        waits = np.array(m.waits)
        rows.append([name, n_requests / t, m.locality, m.domain_switches,
                     m.fairness_factor(), float(waits.mean()), float(np.percentile(waits, 99))])
        results[name] = (n_requests / t, m.locality, m.fairness_factor())
    table(
        f"serving scheduler policy level ({n_requests} reqs, {domains} domains, switch={switch_cost})",
        ["policy", "throughput", "locality", "switches", "fairness", "wait_mean", "wait_p99"],
        rows,
    )
    claim("serving: CNA throughput > FIFO (switch-cost amortised)",
          results["cna_thrFF"][0] > 1.5 * results["fifo"][0],
          f"{results['cna_thrFF'][0]:.3f} vs {results['fifo'][0]:.3f}")
    claim("serving: CNA locality >> FIFO",
          results["cna_thrFF"][1] > 0.8 > results["fifo"][1], "")
    claim("serving: fairness knob works (thr3 fairer than thrFFFF)",
          results["cna_thr3"][2] <= results["cna_thrFFFF"][2] + 1e-9,
          f"{results['cna_thr3'][2]:.3f} vs {results['cna_thrFFFF'][2]:.3f}")


# -- shared-prefix workload: derived homes vs oracle vs static ----------------


def _shared_prefix_reqs(n, n_prefixes, prefix_len, suffix_len, skew, rng):
    """Zipf draw over a pool of common system-prompt prefixes; every request
    is one shared prefix plus a unique per-request suffix."""
    prefixes = [
        [1_000 * p + j for j in range(prefix_len)] for p in range(n_prefixes)
    ]
    return [
        (pid, prefixes[pid] + [900_000 + i * suffix_len + j for j in range(suffix_len)])
        for i, pid in enumerate(zipf_draws(n, n_prefixes, skew, rng))
    ]


def _prefix_sim(arm, reqs, *, topo, n_slots, seed):
    """CNA admission + NUMA placement over one shared-prefix trace.  ``arm``
    picks where request homes come from: ``derived`` (PrefixIndex, fed from
    actual placements/retirements — the engine's wiring), ``oracle`` (a
    caller that tracks each prefix's true last-held pool — the label
    production traffic doesn't have), or ``static0``.  Returns warm-phase
    (second-half) locality and migration cycles plus the telemetry."""
    from repro.placement import DomainFreeLists, PlacementTelemetry, get_policy
    from repro.core.numasim import TWO_SOCKET
    from repro.serving.prefixindex import PrefixIndex

    pools = DomainFreeLists(n_slots, topo)
    policy = get_policy("nearest_spill")
    tel = PlacementTelemetry(n_domains=topo.n_domains)
    sched = CNAScheduler(fairness_threshold=0xFF, seed=seed, topology=topo)
    index = PrefixIndex(n_domains=topo.n_domains,
                        occupancy=lambda: tel.per_domain_occupancy)
    oracle_home = {}

    def cold_home():
        # the oracle arm's cold-start rule; the derived arm's comes from
        # PrefixIndex._fallback (same least-occupied convention) so the two
        # arms start from the same place
        occ = tel.per_domain_occupancy
        return min(range(topo.n_domains), key=lambda d: (occ.get(d, 0), d))

    rng = random.Random(seed)
    active = []  # (retire_t, slot, tokens)
    t = i = placed = 0
    half = len(reqs) // 2
    snap = None
    while placed < len(reqs):
        t += 1
        sched.tick()
        for entry in [a for a in active if a[0] <= t]:
            _, slot, tokens = entry
            if arm == "derived":
                # the engine's retirement hook: the pool held the full
                # sequence until this release
                index.record(tokens, pools.slot_domain[slot])
            tel.record_release(pools.release(slot))
            active.remove(entry)
        if i < len(reqs):  # arrivals pace just under service capacity: homes
            pid, tokens = reqs[i]  # only matter when pools have headroom
            if arm == "derived":
                home, matched = index.home(tokens)  # int: n_domains is set
                tel.record_derived_home(matched, len(tokens))
            elif arm == "oracle":
                home = oracle_home.get(pid)
                if home is None:
                    home = cold_home()
            else:
                home = 0
            sched.submit((pid, tokens, home), home)
            i += 1
        while len(pools) and len(sched):
            out = sched.next_request()
            if out is None:
                break
            pid, tokens, home = out
            p = policy.place(pools, home, TWO_SOCKET)
            tel.record_placement(p)
            if arm == "derived":
                index.record(tokens, p.slot_domain)  # re-home to reality
            elif arm == "oracle":
                oracle_home[pid] = p.slot_domain
            active.append((t + rng.randrange(6, 18), p.slot, tokens))
            placed += 1
            if placed == half:
                snap = (tel.placements, tel.local_placements, tel.migration_cycles)
    n0, l0, m0 = snap
    warm_loc = (tel.local_placements - l0) / max(1, tel.placements - n0)
    warm_mig = tel.migration_cycles - m0
    return warm_loc, warm_mig, tel


def shared_prefix(n_requests=4000, n_prefixes=12, prefix_len=24, suffix_len=8,
                  skew=1.1, seed=11):
    from repro.core.topology import pod

    topo = pod(2, 2)
    n_requests = smoke(n_requests, 300)
    rng = random.Random(seed)
    reqs = _shared_prefix_reqs(n_requests, n_prefixes, prefix_len, suffix_len, skew, rng)
    rows, results = [], {}
    for arm in ("derived", "oracle", "static0"):
        loc, mig, tel = _prefix_sim(arm, reqs, topo=topo, n_slots=16, seed=seed)
        results[arm] = (loc, mig)
        rows.append([arm, loc, mig, tel.locality, tel.migration_cycles,
                     tel.cross_spills,
                     tel.prefix_hit_rate if arm == "derived" else ""])
    table(
        f"shared-prefix serving workload on pod(2,2) ({n_requests} reqs, "
        f"{n_prefixes} prefixes, zipf {skew}; warm = second half)",
        ["homes", "warm_locality", "warm_migr_cycles", "locality", "migr_cycles",
         "cross_spills", "prefix_hit_rate"],
        rows,
    )
    # claims print at smoke scale too (they only gate full runs, per the
    # common.SMOKE contract) so the CI lane still shows the comparison
    d, o, s = results["derived"], results["oracle"], results["static0"]
    claim(
        "serving prefix: derived homes match the caller-oracle locality within 5% (warm)",
        d[0] >= 0.95 * o[0],
        f"derived={d[0]:.3f} oracle={o[0]:.3f}",
    )
    claim(
        "serving prefix: derived homes beat static domain-0 on locality",
        d[0] > s[0],
        f"derived={d[0]:.3f} static0={s[0]:.3f}",
    )
    claim(
        "serving prefix: derived homes beat static domain-0 on migration cycles",
        d[1] < s[1],
        f"derived={d[1]} static0={s[1]}",
    )
    return results


def engine_level():
    import jax

    from repro.configs.base import get_reduced_config
    from repro.models.registry import build_model
    from repro.serving.engine import DecodeEngine, Request

    cfg = get_reduced_config("granite_3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    base = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                max_new=4, domain=i % 2)
        for i in range(16)
    ]
    rows = []
    stats = {}
    for name, sched in [("cna", CNAScheduler(fairness_threshold=0xF)), ("fifo", FIFOScheduler())]:
        reqs = [Request(r.rid, r.prompt, r.max_new, r.domain) for r in base]
        eng = DecodeEngine(model, params, n_slots=4, cache_len=32,
                           scheduler=sched, domain_switch_cost=8)
        eng.run(reqs)
        m = eng.scheduler.metrics
        rows.append([name, eng.sim_time, m.locality, m.domain_switches, m.fairness_factor()])
        stats[name] = eng.sim_time
    table("serving engine level (reduced granite, real decode)",
          ["policy", "sim_time", "locality", "switches", "fairness"], rows)
    claim("serving engine: CNA completes sooner than FIFO",
          stats["cna"] < stats["fifo"], f"{stats['cna']} vs {stats['fifo']}")


# -- continuous batching: bucketed/packed/AOT-warmed prefill vs per-request ---


def _drive_arrivals(eng, reqs, arrival_ticks):
    """Drive one engine under a fixed arrival schedule (tick -> submits),
    wall-clock timed.  Returns (wall seconds, total tokens, TTFT list) —
    TTFT is submit-to-first-token in wall seconds, queueing included, which
    is what a serving SLO sees."""
    import time as _time

    submit_at, ttft = {}, {}
    i = tick = 0
    t0 = _time.perf_counter()
    while i < len(reqs) or len(eng.scheduler) or eng.active_req:
        while i < len(reqs) and arrival_ticks[i] <= tick:
            submit_at[reqs[i].rid] = _time.perf_counter()
            eng.submit(reqs[i])
            i += 1
        eng.step()
        for r in reqs:
            if r.rid not in ttft and r.out:
                ttft[r.rid] = _time.perf_counter() - submit_at[r.rid]
        tick += 1
    wall = _time.perf_counter() - t0
    return wall, sum(len(r.out) for r in reqs), [ttft[r.rid] for r in reqs]


def continuous(n_requests=48, n_slots=8, cache_len=64, max_new=16, rate=0.5,
               seed=23, json_path=None):
    """The tentpole's acceptance bench: identical Poisson arrivals through a
    per-request engine (prefill per admission, traces paid in the serving
    loop) and a batched one (bucketed + packed + AOT-warmed, at most one
    packed call per step).  Reports wall-clock tokens/sec and TTFT
    percentiles; the batched engine must emit bitwise-identical tokens while
    doing it >= 2x faster with strictly lower p99 TTFT, and its prefill
    trace count must stay <= log2(cache_len)."""
    import math

    import jax

    from repro.configs.base import get_reduced_config
    from repro.models.registry import build_model
    from repro.serving.engine import DecodeEngine, Request

    n_requests = smoke(n_requests, 10)
    max_new = smoke(max_new, 4)
    cache_len = smoke(cache_len, 32)
    n_slots = smoke(n_slots, 4)

    cfg = get_reduced_config("granite_3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    lens = rng.integers(2, cache_len - 1, n_requests)
    gaps = rng.exponential(1.0 / rate, n_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(int).tolist()

    def mk():
        r2 = np.random.default_rng(seed + 1)
        return [
            Request(rid=i, prompt=r2.integers(0, cfg.vocab, int(l)).astype(np.int32),
                    max_new=max_new, domain=i % 2)
            for i, l in enumerate(lens)
        ]

    # batched arm first (cold CPU), per-request baseline second (warm): any
    # cache/turbo warm-up bias then favours the baseline, so the >=2x claim
    # is measured conservatively.
    bat_eng = DecodeEngine(model, params, n_slots=n_slots, cache_len=cache_len,
                           batching=True)  # AOT warm-up happens here, untimed
    bat_reqs = mk()
    bat_wall, bat_toks, bat_ttft = _drive_arrivals(bat_eng, bat_reqs, arrivals)

    base_eng = DecodeEngine(model, params, n_slots=n_slots, cache_len=cache_len)
    base_reqs = mk()
    base_wall, base_toks, base_ttft = _drive_arrivals(base_eng, base_reqs, arrivals)

    stats = {}
    rows = []
    for name, wall, toks, ttft, eng in [
        ("per_request", base_wall, base_toks, base_ttft, base_eng),
        ("batched", bat_wall, bat_toks, bat_ttft, bat_eng),
    ]:
        cc = eng.compile_counts
        traces = cc["prefill"] + cc.get("packed_prefill", 0) + cc.get("cont_prefill", 0)
        stats[name] = {
            "tokens_per_sec": toks / wall,
            "ttft_p50": float(np.percentile(ttft, 50)),
            "ttft_p99": float(np.percentile(ttft, 99)),
            "prefill_traces": traces,
        }
        rows.append([name, f"{toks / wall:.1f}", f"{np.percentile(ttft, 50) * 1e3:.0f}ms",
                     f"{np.percentile(ttft, 99) * 1e3:.0f}ms", traces, cc["decode"]])
    table(
        f"continuous batching (reduced granite, {n_requests} reqs, poisson rate "
        f"{rate}/tick, cache_len={cache_len}, {n_slots} slots, max_new={max_new})",
        ["engine", "tokens/sec", "ttft_p50", "ttft_p99", "prefill_traces", "decode_traces"],
        rows,
    )
    b, p = stats["batched"], stats["per_request"]
    claim("continuous: batched >= 2x tokens/sec vs per-request baseline",
          b["tokens_per_sec"] >= 2 * p["tokens_per_sec"],
          f"{b['tokens_per_sec']:.1f} vs {p['tokens_per_sec']:.1f} tok/s")
    claim("continuous: batched p99 TTFT strictly lower",
          b["ttft_p99"] < p["ttft_p99"],
          f"{b['ttft_p99'] * 1e3:.0f}ms vs {p['ttft_p99'] * 1e3:.0f}ms")
    claim("continuous: prefill traces bounded by log2(cache_len)",
          b["prefill_traces"] <= math.log2(cache_len),
          f"{b['prefill_traces']} traces, log2({cache_len})={math.log2(cache_len):.0f}")
    claim("continuous: packed outputs bitwise-equal to per-request reference",
          all(x.out == y.out for x, y in zip(base_reqs, bat_reqs)), "")
    payload = {
        "config": {"n_requests": n_requests, "n_slots": n_slots,
                   "cache_len": cache_len, "max_new": max_new, "rate": rate},
        "engines": stats,
        "speedup": b["tokens_per_sec"] / p["tokens_per_sec"],
        "outputs_bitwise_equal": all(
            x.out == y.out for x, y in zip(base_reqs, bat_reqs)
        ),
    }
    # inside run.py the active bench_section carries this into
    # BENCH_serving.json; standalone invocations still write json_path
    common.emit_json(payload, json_path)
    if json_path and common._SECTION is None:
        print(f"\n[wrote {json_path}]")
    return stats


def tracing(n_requests=12, max_new=4, cache_len=32, n_slots=4, seed=5):
    """The zero-cost-off / bounded-overhead contract at engine level: the
    same workload through an untraced and a traced engine must emit bitwise
    identical tokens (tracing never perturbs admission or decode), and the
    traced run's wall-clock overhead must stay within a generous bound (the
    spans are python dataclass appends next to real jax decode steps)."""
    import time as _time

    import jax

    from repro.configs.base import get_reduced_config
    from repro.models.registry import build_model
    from repro.obs import MetricsRegistry, Tracer
    from repro.serving.engine import DecodeEngine, Request

    n_requests = smoke(n_requests, 8)
    cfg = get_reduced_config("granite_3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    base = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                max_new=max_new, domain=i % 2)
        for i in range(n_requests)
    ]

    def run(tracer):
        reqs = [Request(r.rid, r.prompt, r.max_new, r.domain) for r in base]
        eng = DecodeEngine(model, params, n_slots=n_slots, cache_len=cache_len,
                           scheduler=CNAScheduler(fairness_threshold=0xF),
                           domain_switch_cost=8, tracer=tracer)
        t0 = _time.perf_counter()
        eng.run(reqs)
        return _time.perf_counter() - t0, reqs, eng

    run(None)  # warm the jit caches so neither timed arm pays compilation
    off_wall, off_reqs, _ = run(None)
    tr = Tracer()
    on_wall, on_reqs, eng = run(tr)
    overhead = on_wall / max(off_wall, 1e-9)
    table("engine tracing overhead (reduced granite, real decode)",
          ["arm", "wall_s", "spans"],
          [["tracer_off", f"{off_wall:.3f}", 0],
           ["tracer_on", f"{on_wall:.3f}", len(tr.spans)]])
    claim("obs: engine outputs bitwise-identical with tracer on",
          all(x.out == y.out for x, y in zip(off_reqs, on_reqs)), "")
    claim("obs: engine tracing overhead bounded (<= 1.5x wall)",
          overhead <= 1.5, f"{overhead:.2f}x, {len(tr.spans)} spans")
    claim("obs: every engine span closed at drain",
          not tr.check(), f"{len(tr.check())} open")
    reg = MetricsRegistry()
    eng.register_metrics(reg)
    common.headline_registry(reg, prefix="tracing_")
    common.headline(tracing_overhead_x=overhead, tracing_spans=len(tr.spans))


# -- paged KV with copy-on-write prefix sharing (jax-free accounting) ---------


def paging(n_requests=600, n_prefixes=8, prefix_len=112, suffix_len=32,
           skew=1.1, page_size=16, seed=13):
    """The paged-KV headline, entirely jax-free.

    Memory half: drive the page-table-backed prefix store
    (``PagedPrefixKVStore`` in accounting mode, no jax pool) and the
    contiguous ``PrefixKVStore`` through the same Zipf shared-prefix deposit
    stream — boundary (shared prefix) plus full prompt per request, the
    engine's planting + retirement pattern — and compare tokens of KV held.
    The contiguous number is the *unpadded* sum of entry lengths, which
    undercounts the slot engine (``fit_single`` pads every entry to
    cache_len), so the claim is conservative.

    Fabric half: the fleet sim over two-level prefixes (one fleet-wide base,
    per-group extensions, unique suffixes) with KV shipping priced whole-
    bundle (``page_size=0``) vs page-granular — a target that already holds
    the base prefix receives only the pages it lacks, so shipped tokens must
    strictly drop at the same bandwidth."""
    from repro.obs import MetricsRegistry
    from repro.router.kvship import ShipCostModel
    from repro.router.router import Session
    from repro.router.sim import simulate
    from repro.serving.paging import PagedPrefixKVStore, PageTable
    from repro.serving.prefixkv import PrefixKVStore

    n_requests = smoke(n_requests, 150)
    rng = random.Random(seed)
    reqs = _shared_prefix_reqs(n_requests, n_prefixes, prefix_len, suffix_len,
                               skew, rng)

    table_ = PageTable(256, page_size)
    paged_store = PagedPrefixKVStore(16, table=table_)
    flat_store = PrefixKVStore(16)
    for _pid, prompt in reqs:
        for store in (paged_store, flat_store):
            store.put(prompt[:prefix_len], None, None)  # boundary planting
            store.put(prompt, None, None)               # retirement deposit
    table_.check()
    paged_tokens = table_.pages_held * page_size
    flat_tokens = sum(len(k) for k in flat_store._lru)
    share = prefix_len / (prefix_len + suffix_len)
    reg = MetricsRegistry()
    table_.register_into(reg, prefix="paging")
    table(
        f"paged vs contiguous prefix store ({n_requests} reqs, "
        f"{n_prefixes} prefixes, zipf {skew}, share {share:.2f}, "
        f"page_size {page_size})",
        ["store", "entries", "kv_tokens_held", "pages_shared", "cow_copies",
         "zero_page_deposits"],
        [
            ["paged", len(paged_store), paged_tokens, table_.pages_shared,
             table_.cow_copies, paged_store.zero_page_deposits],
            ["contiguous", len(flat_store), flat_tokens, 0, 0, 0],
        ],
    )
    claim(
        "paging: pages held < 0.5x the contiguous store's KV footprint "
        f"at >=0.6 prefix share (share={share:.2f})",
        share >= 0.6 and paged_tokens < 0.5 * flat_tokens,
        f"paged={paged_tokens} tokens, contiguous={flat_tokens} tokens "
        f"({paged_tokens / max(1, flat_tokens):.2f}x, unpadded baseline)",
    )
    claim(
        "paging: page-table invariants hold after Zipf churn",
        True,  # table_.check() above raises on violation
        f"{table_.pages_total} pages, {table_.pages_free} free, "
        f"{table_.pages_shared} shared",
    )

    # fabric half: two-level prefixes so ship targets hold partial prefixes
    def nested_sessions(n):
        base = tuple(range(64))
        out = []
        for i, pid in enumerate(zipf_draws(n, 6, skew, random.Random(seed))):
            p = base \
                + tuple(10_000 * (pid + 1) + j for j in range(32)) \
                + tuple(900_000 + i * 16 + j for j in range(16))
            out.append(Session(sid=i, prompt=p, decode_len=8))
        return out

    n_sessions = smoke(200, 80)
    sim_kw = dict(seed=5, n_replicas=4, n_slots=2, cache_budget=400,
                  inter_arrival=8)
    whole = simulate("federated", nested_sessions(n_sessions),
                     kv_ship=ShipCostModel(), **sim_kw)
    paged = simulate("federated", nested_sessions(n_sessions),
                     kv_ship=ShipCostModel(page_size=page_size), **sim_kw)
    spec = simulate("federated", nested_sessions(n_sessions),
                    kv_ship=ShipCostModel(page_size=page_size),
                    router_kwargs=dict(prefetch=True, victim_cache=True),
                    **sim_kw)
    table(
        f"kv shipping: whole-bundle vs page-granular ({n_sessions} sessions, "
        "two-level prefixes, default bandwidth)",
        ["pricing", "ships", "segments", "shipped_tokens", "ship_cycles",
         "reuse_fraction", "prefetch_ships", "victim_ships"],
        [
            ["whole-bundle", whole.ships, whole.ship_segments,
             whole.shipped_tokens, whole.ship_cycles,
             whole.reuse_fraction, 0, 0],
            ["paged", paged.ships, paged.ship_segments, paged.shipped_tokens,
             paged.ship_cycles, paged.reuse_fraction, 0, 0],
            ["paged+spec", spec.ships, spec.ship_segments, spec.shipped_tokens,
             spec.ship_cycles, spec.reuse_fraction, spec.prefetch_ships,
             spec.victim_ships],
        ],
    )
    claim(
        "paging: page-granular shipping moves strictly fewer tokens than "
        "whole-bundle at default bandwidth",
        paged.ships > 0 and paged.shipped_tokens < whole.shipped_tokens,
        f"paged={paged.shipped_tokens} whole={whole.shipped_tokens} "
        f"({paged.ships} ships)",
    )
    common.headline_registry(reg)
    common.headline(
        paging_kv_tokens_paged=paged_tokens,
        paging_kv_tokens_contiguous=flat_tokens,
        paging_footprint_x=round(paged_tokens / max(1, flat_tokens), 4),
        paging_cow_copies=table_.cow_copies,
        paging_zero_page_deposits=paged_store.zero_page_deposits,
        paging_shipped_tokens_whole=whole.shipped_tokens,
        paging_shipped_tokens_paged=paged.shipped_tokens,
        paging_ship_segments=paged.ship_segments,
        paging_prefetch_ships=spec.prefetch_ships,
        paging_prefetch_tokens=spec.prefetch_tokens,
        paging_victim_ships=spec.victim_ships,
        paging_victim_tokens=spec.victim_tokens,
    )


def run_all(json_path=None):
    # NB: paging() is not called here — run.py gives it its own
    # bench_section so BENCH_serving_paging.json is always a separate record
    policy_level()
    shared_prefix()
    engine_level()
    continuous(json_path=json_path)
    tracing()
