"""Atomic, async, mesh-elastic checkpointing.

Layout:  <root>/step_<N>/{manifest.json, 000000.npy, 000001.npy, ...}
         one .npy per pytree leaf, flat-indexed in key-sorted order.

Guarantees:

  * **atomic**   — written to ``step_<N>.tmp`` then ``os.rename``d; a crash
    mid-write never leaves a readable-but-corrupt step directory, and
    ``latest_step`` only considers committed directories.
  * **async**    — ``save(..., blocking=False)`` snapshots to host RAM
    (device_get) on the caller thread, then writes on a background thread;
    ``wait()`` joins.  Training continues during the write (the paper-scale
    failure model: checkpoint cadence must not stall the step loop).
  * **elastic**  — arrays are stored *unsharded* (gathered); ``restore`` takes
    an optional shardings tree and ``device_put``s each leaf, so a checkpoint
    written on one mesh restores onto any other mesh/topology (tested 8->4
    devices).  At true 1000-node scale this becomes per-shard files + a
    reshard pass; the manifest already records shape/dtype per leaf to allow
    that extension.
  * **retention** — ``keep`` newest steps survive garbage collection.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

SEP = "/"
_NATIVE_DTYPES = {
    "bool", "int8", "int16", "int32", "int64", "uint8", "uint16", "uint32",
    "uint64", "float16", "float32", "float64", "complex64", "complex128",
}


def _flatten_with_paths(tree):
    # jax.tree.flatten_with_path only exists from jax 0.4.36ish onwards;
    # tree_util has carried the same function for much longer.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["".join(_fmt(k) for k in path) for path, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


def _fmt(k) -> str:
    if hasattr(k, "key"):
        return f"{SEP}{k.key}"
    if hasattr(k, "idx"):
        return f"{SEP}{k.idx}"
    return f"{SEP}{k}"


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- write ----------------------------------------------------------------
    def save(self, step: int, tree, *, extra: dict | None = None, blocking: bool = True):
        self.wait()
        paths, leaves, _ = _flatten_with_paths(tree)
        # snapshot to host memory on the caller thread (device state may be
        # donated/overwritten by the next train step)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        manifest = {
            "step": int(step),
            "paths": paths,
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
            "extra": extra or {},
        }
        # np.save cannot represent ml_dtypes (bfloat16/fp8); store raw bytes
        # and reconstruct from the manifest's shape/dtype on restore
        host_leaves = [
            l if l.dtype.name in _NATIVE_DTYPES else l.view(np.uint8).reshape(-1)
            for l in host_leaves
        ]
        if blocking:
            self._write(step, manifest, host_leaves)
        else:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, manifest, host_leaves), daemon=True
            )
            self._thread.start()

    def _write_guarded(self, step, manifest, host_leaves):
        try:
            self._write(step, manifest, host_leaves)
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _write(self, step, manifest, host_leaves):
        final = os.path.join(self.root, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for i, leaf in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"{i:06d}.npy"), leaf)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"), ignore_errors=True)

    # -- read -----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, d, "manifest.json")):
                    out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, *, shardings=None, extra: bool = False):
        """Restore into the structure of ``target_tree`` (values ignored).

        ``shardings``: optional pytree of jax.sharding.Sharding (same
        structure) — each leaf is device_put with its sharding, which is what
        makes restore *elastic* across meshes."""
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        paths, _, treedef = _flatten_with_paths(target_tree)
        by_path = {p: i for i, p in enumerate(manifest["paths"])}
        missing = [p for p in paths if p not in by_path]
        if missing:
            raise KeyError(f"checkpoint {step} missing leaves: {missing[:5]}...")

        def load_leaf(p):
            i = by_path[p]
            arr = np.load(os.path.join(d, f"{i:06d}.npy"))
            want_dtype, want_shape = manifest["dtypes"][i], tuple(manifest["shapes"][i])
            if arr.dtype == np.uint8 and want_dtype not in _NATIVE_DTYPES:
                import ml_dtypes

                arr = arr.view(np.dtype(getattr(ml_dtypes, want_dtype))).reshape(want_shape)
            return arr

        leaves = [load_leaf(p) for p in paths]
        if shardings is not None:
            shard_leaves = treedef.flatten_up_to(shardings)
            leaves = [
                jax.device_put(l, s) if s is not None else jax.device_put(l)
                for l, s in zip(leaves, shard_leaves)
            ]
        else:
            leaves = [jax.device_put(l) for l in leaves]
        tree = jax.tree.unflatten(treedef, leaves)
        if extra:
            return tree, manifest["extra"]
        return tree
