"""The docs lane: executable documentation that cannot rot.

``docs/architecture.md``'s fenced ```python blocks are a narrative of the
five layers *and* a test suite: this module extracts them and executes them
in order, top to bottom, sharing one namespace per document (later blocks
may use names defined by earlier ones, exactly as a reader reads them).
Every block is jax-free by construction — the narrative runs through the
simulator-backed paths — so the CI ``docs`` lane runs this file with numpy
only, next to the bench smoke lane.

Cross-references are checked too: every relative markdown link in ``docs/``
and ``README.md`` must resolve to a real file, so a moved document breaks CI
instead of readers.
"""

import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = os.path.join(REPO, "docs")

_FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)
_LINK = re.compile(r"\[[^\]]*\]\(([^)]+)\)")


def _doc_files():
    return sorted(
        os.path.join(DOCS, f) for f in os.listdir(DOCS) if f.endswith(".md")
    )


def _blocks(path):
    with open(path) as f:
        return _FENCE.findall(f.read())


def test_docs_exist_and_have_examples():
    paths = _doc_files()
    names = {os.path.basename(p) for p in paths}
    assert {"architecture.md", "benchmarks.md"} <= names
    arch = os.path.join(DOCS, "architecture.md")
    assert len(_blocks(arch)) >= 5, "the narrative lost its runnable examples"


@pytest.mark.parametrize(
    "path", _doc_files(), ids=[os.path.basename(p) for p in _doc_files()]
)
def test_doc_python_blocks_execute(path):
    """Run the document's python blocks in order in one shared namespace —
    the assertions inside them are the documentation's contract with the
    code.  A document without blocks passes trivially."""
    ns = {"__name__": f"docs:{os.path.basename(path)}"}
    for i, block in enumerate(_blocks(path)):
        try:
            exec(compile(block, f"{path}#block{i}", "exec"), ns)
        except Exception as e:  # pragma: no cover - failure path
            pytest.fail(
                f"{os.path.basename(path)} block {i} failed: {e!r}\n{block}"
            )


def _relative_links(path):
    with open(path) as f:
        text = f.read()
    for target in _LINK.findall(text):
        target = target.strip()
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        yield target.split("#", 1)[0]


@pytest.mark.parametrize(
    "path",
    _doc_files() + [os.path.join(REPO, "README.md")],
    ids=lambda p: os.path.relpath(p, REPO),
)
def test_doc_relative_links_resolve(path):
    base = os.path.dirname(path)
    missing = [
        t for t in _relative_links(path)
        if t and not os.path.exists(os.path.normpath(os.path.join(base, t)))
    ]
    assert not missing, f"dangling links in {os.path.basename(path)}: {missing}"
