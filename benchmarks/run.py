"""Benchmark orchestrator: one section per paper table/figure + the framework
benches (serving scheduler, slot placement, collective schedules, roofline).

    PYTHONPATH=src python -m benchmarks.run [--smoke] [section ...]

Sections: paper, locks, restriction, placement, serving, serving_prefix,
serving_continuous, serving_paging, router, fastpath, region, obs,
collectives, moe_ep, roofline.  Default: all.
``region`` (fleets-of-fleets under the diurnal multi-tenant trace,
``benchmarks/region_bench.py``) is jax-free and smoke-lane-safe.
``serving_prefix`` is the jax-free shared-prefix slice of the serving section
(prefix-index build/lookup/re-home) so the dependency-light smoke lane can
cover it; ``serving`` already includes it.  ``router`` (fleet routing on the
jax-free discrete-event simulator) and ``obs`` (tracing overhead + the
attribution conservation law, ``benchmarks/obs_bench.py``) are
smoke-lane-safe as well.
``serving_continuous`` is the continuous-batching slice (needs jax): it — and
the full ``serving`` section — emits machine-readable ``BENCH_serving.json``
(tokens/sec, TTFT p50/p99, prefill trace count) so the perf trajectory is
tracked across PRs; the CI bench lane runs it at smoke scale.

Every section runs inside ``benchmarks.common.bench_section`` and emits a
``BENCH_<section>.json`` record in one shared schema — claims, headline
metrics (sourced from the unified ``repro.obs.MetricsRegistry`` where the
section keeps one), pass/fail — so the bench trajectory file set covers the
whole suite, not just serving.  ``fastpath`` (the fissile contention-adaptive
fast path on the fleet router, ``benchmarks/fastpath_bench.py``) is jax-free
and smoke-lane-safe.

``--smoke`` shrinks every iteration knob (see benchmarks.common.smoke) so CI
can exercise each benchmark's code path in seconds; claims still print but do
not gate the exit code at smoke scale (the curves need full durations).  In a
full run, any failed CLAIM makes the process exit 1 so regressions cannot
scroll by silently.

docs/benchmarks.md documents every section — the claim each bench asserts
and how to read the ASCII figures.
"""

from __future__ import annotations

import sys
import time


def locks_hostlevel():
    """The faithful host-threads CNA implementation under stress (GIL-bound:
    correctness + admission-order behaviour, not wall-clock)."""
    from repro.core.cna import CNALock, MCSLock, run_lock_stress

    from . import common
    from .common import claim, table

    iters = common.smoke(300, 40)
    rows = []
    for name, factory in [
        ("cna", lambda sock: CNALock(numa_node_of=sock, threshold=0xF)),
        ("cna_opt", lambda sock: CNALock(numa_node_of=sock, threshold=0xF, shuffle_reduction=True)),
        ("mcs", lambda sock: MCSLock()),
    ]:
        t0 = time.time()
        shared = run_lock_stress(factory, n_threads=8, n_sockets=2, iters=iters)
        dt = time.time() - t0
        ok = shared.counter == 8 * iters
        rows.append([name, shared.counter, f"{dt:.2f}s", "OK" if ok else "RACE!"])
        claim(f"locks: mutual exclusion holds under stress ({name})", ok,
              f"counter={shared.counter}")
    table(f"host-threads lock stress (8 threads x {iters} iters, 2 virtual sockets)",
          ["lock", "counter", "time", "status"], rows)


def main() -> int:
    from . import common

    args = sys.argv[1:]
    if "--smoke" in args:
        args.remove("--smoke")
        common.SMOKE = True
    sections = args or [
        "paper", "locks", "restriction", "placement", "serving", "router",
        "fastpath", "region", "obs", "collectives", "moe_ep", "roofline",
    ]  # "serving" subsumes serving_prefix and serving_continuous
    t0 = time.time()
    # every section runs inside bench_section so it emits BENCH_<name>.json
    # in the shared schema (claims, headline metrics, pass/fail)
    if "paper" in sections:
        from . import paper_figures

        with common.bench_section("paper"):
            paper_figures.run_all()
    if "locks" in sections:
        with common.bench_section("locks"):
            locks_hostlevel()
    if "restriction" in sections:
        from . import restriction_bench

        with common.bench_section("restriction"):
            restriction_bench.run_all()
    if "placement" in sections:
        from . import placement_bench

        with common.bench_section("placement"):
            placement_bench.run_all()
    if "serving" in sections:
        from . import serving_bench

        with common.bench_section("serving"):
            serving_bench.run_all(json_path="BENCH_serving.json")
    else:
        if "serving_prefix" in sections:
            from . import serving_bench

            with common.bench_section("serving_prefix"):
                serving_bench.shared_prefix()
        if "serving_continuous" in sections:
            from . import serving_bench

            with common.bench_section("serving"):
                serving_bench.continuous(json_path="BENCH_serving.json")
    if "serving" in sections or "serving_paging" in sections:
        # always its own record (jax-free): the paged-KV headline must stay
        # comparable across PRs even when only the smoke lane runs
        from . import serving_bench

        with common.bench_section("serving_paging"):
            serving_bench.paging()
    if "router" in sections:
        from . import router_bench

        with common.bench_section("router"):
            router_bench.run_all()
    if "fastpath" in sections:
        from . import fastpath_bench

        with common.bench_section("fastpath"):
            fastpath_bench.run_all()
    if "region" in sections:
        from . import region_bench

        with common.bench_section("region"):
            region_bench.run_all()
    if "obs" in sections:
        from . import obs_bench

        with common.bench_section("obs"):
            obs_bench.run_all()
    if "collectives" in sections:
        from . import collectives_bench

        with common.bench_section("collectives"):
            collectives_bench.run_all()
    if "moe_ep" in sections:
        from . import moe_ep_bench

        with common.bench_section("moe_ep"):
            moe_ep_bench.run_all()
    if "roofline" in sections:
        from . import roofline_report

        with common.bench_section("roofline"):
            roofline_report.run_all()
    print(f"\n(total: {time.time() - t0:.1f}s)")
    if common.FAILED_CLAIMS:
        print(f"{len(common.FAILED_CLAIMS)} claim(s) FAILED:")
        for name in common.FAILED_CLAIMS:
            print(f"  - {name}")
        if not common.SMOKE:
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
