import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory/cost/collective evidence.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun

Each cell produces a JSON record: memory_analysis (proves it fits),
cost_analysis (FLOPs/bytes for §Roofline), the collective schedule (op kind /
bytes / group size / ICI-vs-DCN), and the three roofline terms.
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build_model, input_specs
from repro.models.sharding import use_mesh
from repro.training.step import (
    make_train_step,
    state_abstract,
    state_logical,
    tree_shardings,
)


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *, hlo_dir: str | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name, "status": "skipped", "why": why}

    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    with use_mesh(mesh):
        specs, logical = input_specs(cfg, shape, model)
        in_sh = tree_shardings(specs, logical)
        p_abs = model.abstract_params()
        p_sh = tree_shardings(p_abs, model.logical_tree())

        if shape.kind == "train":
            step = make_train_step(model, cfg)
            st_abs = state_abstract(model, cfg)
            st_sh = tree_shardings(st_abs, state_logical(model))
            lowered = jax.jit(
                step, in_shardings=(st_sh, in_sh), donate_argnums=0
            ).lower(st_abs, specs)
        elif shape.kind == "prefill":
            lowered = jax.jit(
                model.prefill, in_shardings=(p_sh, in_sh)
            ).lower(p_abs, specs)
        else:  # decode
            lowered = jax.jit(
                model.decode_step,
                in_shardings=(p_sh, in_sh["cache"], in_sh["tokens"]),
                donate_argnums=1,
            ).lower(p_abs, specs["cache"], specs["tokens"])
        t_lower = time.time() - t0

        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    print(mem)                       # proves it fits (per-device bytes)
    from repro.core.jax_compat import cost_analysis_dict

    ca = cost_analysis_dict(compiled)
    print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})

    r, hc = rl.analyze(compiled, arch=arch, shape=shape, cfg=cfg, mesh_name=mesh_name, chips=chips)
    by_kind = hc.collectives

    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        with open(os.path.join(hlo_dir, f"{arch}.{shape_name}.{mesh_name}.hlo.txt"), "w") as f:
            f.write(compiled.as_text())

    out = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_gb": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3),
        },
        "cost": {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))},
        "collectives": by_kind,
        "roofline": r.to_dict(),
    }
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--hlo-dump", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" or args.all else args.arch.replace("-", "_").replace(".", "").split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                path = os.path.join(args.out, f"{arch}.{shape_name}.{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    continue
                tag = f"[{arch} {shape_name} {mesh_name}]"
                print(f"{tag} lowering...", flush=True)
                try:
                    rec = lower_cell(arch, shape_name, multi, hlo_dir=args.hlo_dump)
                except Exception as e:  # noqa: BLE001 — a failed cell is a bug to report
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "ok":
                    rr = rec["roofline"]
                    print(
                        f"{tag} OK compile={rec['compile_s']}s "
                        f"mem/dev={rec['memory']['peak_per_device_gb']}GB "
                        f"c={rr['compute_s']:.4f} m={rr['memory_s']:.4f} x={rr['collective_s']:.4f} "
                        f"dom={rr['dominant']} mfu={rr['mfu']:.3f}",
                        flush=True,
                    )
                else:
                    print(f"{tag} {rec['status'].upper()}: {rec.get('why') or rec.get('error')}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
