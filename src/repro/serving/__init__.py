"""Serving layer: continuous-batching engine, CNA admission, prefix reuse.

The engine (and its slot cache) needs jax; everything else here — the
schedulers, the prefix index, the prefix-KV store's bookkeeping — is pure
python.  The jax-dependent names load lazily so dependency-light consumers
(the router tier, the benchmark smoke lane) can import this package without
an accelerator stack installed.
"""

from .paging import PageBundle, PagedPrefixKVStore, PageTable  # noqa: F401
from .prefixindex import PrefixIndex  # noqa: F401
from .prefixkv import PrefixKVStore  # noqa: F401
from .scheduler import CNAScheduler, FIFOScheduler, SchedulerMetrics  # noqa: F401

_LAZY = ("DecodeEngine", "Request", "SlotCache", "PagedSlotCache")


def __getattr__(name):
    if name in ("DecodeEngine", "Request"):
        from . import engine

        return getattr(engine, name)
    if name == "SlotCache":
        from .kvcache import SlotCache

        return SlotCache
    if name == "PagedSlotCache":
        from .paging_jax import PagedSlotCache

        return PagedSlotCache
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
