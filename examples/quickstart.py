"""Quickstart: the CNA lock, its admission policy, and the LM framework.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# 1. The paper's lock, faithfully (Figures 2-5), on real threads
# ---------------------------------------------------------------------------
from repro.core.cna import CNALock, run_lock_stress

shared = run_lock_stress(
    lambda socket_of: CNALock(numa_node_of=socket_of, threshold=0xF),
    n_threads=4, n_sockets=2, iters=200,
)
assert shared.counter == 800
print(f"[1] CNA lock: 4 threads x 200 criticals, counter={shared.counter} (exact)")

# ---------------------------------------------------------------------------
# 2. The simulator reproduces the paper's throughput separation
# ---------------------------------------------------------------------------
from repro.core.locks_sim import ALL_LOCKS
from repro.core.numasim import Simulator

for name in ("mcs", "cna"):
    r = Simulator(ALL_LOCKS[name], n_threads=32, n_sockets=2,
                  duration_cycles=2_000_000, noncs_cycles=0,
                  lock_kwargs={"threshold": 0xFF} if name == "cna" else None).run()
    print(f"[2] {name}: {r.throughput_ops_per_us:.2f} ops/us, "
          f"remote transfers/op {r.remote_rate:.2f}, fairness {r.fairness_factor:.3f}")

# ---------------------------------------------------------------------------
# 3. The same policy as a scheduler building block
# ---------------------------------------------------------------------------
from repro.core.policy import CNAAdmissionQueue

q = CNAAdmissionQueue(threshold=0xF)
for i in range(8):
    q.push(f"req{i}", domain=i % 2)
order = []
dom = 0
while len(q):
    v, dom = q.pop(dom)
    order.append(v)
print(f"[3] CNA admission order (alternating arrivals): {order}")

# ---------------------------------------------------------------------------
# 4. A model from the assigned pool: train 5 steps, then prefill+decode
# ---------------------------------------------------------------------------
from repro.configs.base import get_reduced_config
from repro.data.pipeline import BigramLMDataset
from repro.models.registry import build_model
from repro.training.step import init_state, make_train_step

cfg = get_reduced_config("granite_3_8b").replace(vocab=64, accum=1)
model = build_model(cfg)
ds = BigramLMDataset(cfg.vocab, seq_len=32, global_batch=8)
step = jax.jit(make_train_step(model, cfg, lr_fn=lambda s: 5e-3, weight_decay=0.0))
state = init_state(model, jax.random.PRNGKey(0), cfg)
for i in range(5):
    state, m = step(state, ds.batch(i))
    print(f"[4] train step {i} loss {float(m['loss']):.4f}")

logits, cache = jax.jit(model.prefill)(state["params"], {"tokens": jnp.arange(8, dtype=jnp.int32)[None] % cfg.vocab})
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
logits, cache = jax.jit(model.decode_step)(state["params"], cache, tok)
print(f"[4] prefill+decode ok; next-token argmax = {int(jnp.argmax(logits[0]))}")
print("quickstart done.")
