"""CNA continuous-batching admission scheduler.

This is the paper's algorithm carried verbatim into the serving runtime via
``repro.core.policy.CNAAdmissionQueue`` (itself a thin adapter over the shared
``repro.core.discipline`` core):

  paper                      | serving
  ---------------------------+------------------------------------------
  lock                       | a free decode slot (the serialised resource)
  thread                     | a queued request
  NUMA socket of a thread    | the locality domain of the request — the pod
                             | holding its prefix/KV-cache home (caller-given,
                             | or derived from the longest cached prefix by
                             | ``repro.serving.prefixindex`` when a request
                             | is submitted with ``domain=None``)
  socket of the lock holder  | the engine's *current* domain (domain of the
                             | most recently admitted request)
  main queue                 | CNA main queue (arrivals always join it)
  secondary queue            | CNA secondary queue (remote-domain requests
                             | parked by find_successor)
  keep_lock_local threshold  | fairness_threshold (starvation bound)
  remote cache miss          | domain switch => KV/prefix migration cost
  machine topology           | ``repro.core.topology.Topology``: domains are
                             | named positions in a fabric, and a switch's
                             | cost scales with inter-domain *distance*
                             | (same pod vs cross pod), not a constant

State is compact by construction (two deques + a counter), the paper's
argument against per-domain ("cohort") scheduler structures.

``max_active`` enables GCR-style concurrency restriction (admission control):
only that many queued requests circulate in the CNA queues, the rest wait on
a passivation list until slots of the active set drain.  Passing an
``repro.placement.AdaptiveController`` instead of an int turns the cap into
the GCR feedback loop: the engine (or any driver) feeds
``observe_handover(latency)`` after each admission and the cap tracks the
observed handover cost — the *same* controller implementation the lock
simulator's ``cna_rcr_adapt`` drives.

``SchedulerMetrics`` counts domain switches and per-domain service so
benchmarks can reproduce the paper's throughput/fairness trade-off curves in
the serving setting (benchmarks/serving_bench.py); ``metrics.placement``
carries the slot-placement telemetry when the engine runs a placement-aware
``SlotCache``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.policy import CNAAdmissionQueue, FIFOAdmissionQueue
from repro.core.topology import Topology, get_topology
from repro.obs import NULL_TRACER, BoundedHistogram, trace_key


@dataclass
class SchedulerMetrics:
    admitted: int = 0
    local_admits: int = 0
    domain_switches: int = 0
    switch_distance: int = 0   # sum of topology distances over switches
    per_domain: dict = field(default_factory=dict)
    # bounded wait-time reservoir: list-compatible (append/len/index/iterate)
    # but capped, so a long-running serve can't leak one entry per admission;
    # exact quantiles while under the cap (every bench stays under it)
    waits: BoundedHistogram = field(default_factory=BoundedHistogram)
    # slot-placement telemetry (repro.placement.PlacementTelemetry) when the
    # engine runs a placement-aware SlotCache; None otherwise
    placement: object = None

    @property
    def locality(self) -> float:
        return self.local_admits / max(1, self.admitted)

    def fairness_factor(self) -> float:
        """Paper Section 7.1.1, over domains instead of threads."""
        counts = sorted(self.per_domain.values(), reverse=True)
        tot = sum(counts)
        if not counts or tot == 0:
            return 1.0
        half = max(1, len(counts) // 2)
        return sum(counts[:half]) / tot

    def register_into(self, registry, prefix: str = "sched") -> None:
        """Expose this surface through a ``repro.obs.MetricsRegistry`` as
        thin live views — the dataclass stays the single source of truth."""
        registry.adopt(prefix, self, props=("locality",))
        registry.gauge(f"{prefix}_fairness_factor", fn=self.fairness_factor)
        if self.placement is not None:
            self.placement.register_into(registry, prefix=f"{prefix}_placement")


class _BaseScheduler:
    def __init__(self, queue, topology: Topology | None = None, tracer=None):
        self._q = queue
        self.topology = get_topology(topology) if topology is not None else None
        self.current_domain = 0
        self.metrics = SchedulerMetrics()
        self._clock = 0
        # causal span sink (repro.obs.Tracer); NULL_TRACER is falsy, so every
        # instrumentation site below is one truthiness check when disabled
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # distance of the most recent admission's switch (0 when local);
        # the engine charges migration cost from this instead of recomputing
        self.last_admit_distance = 0
        # per-grant distances of the most recent next_batch (see next_batch)
        self.last_batch_distances: list[int] = []

    @property
    def now(self) -> int:
        """Current scheduler tick (public: callers must not poke _clock)."""
        return self._clock

    @property
    def controller(self):
        """The adaptive concurrency controller, or None under a static cap."""
        return self._q.controller

    @property
    def max_active(self) -> int | None:
        return self._q.max_active

    def observe_handover(self, latency) -> None:
        """Feed one admission-handover latency sample (domain-switch stall +
        slot-migration cost, in engine time units) to the adaptive controller;
        no-op without one.  Records into placement telemetry when present."""
        self._q.observe_handover(latency)
        if self.metrics.placement is not None:
            self.metrics.placement.record_handover(latency)

    def fast_ready(self) -> bool:
        """True when the next admission is an uncontended fissile fast-path
        grant (always False without ``fissile=True``) — callers such as the
        fleet router gate their own pipeline bypasses on this, so a skipped
        side effect can only coincide with a grant the discipline core never
        saw either."""
        f = getattr(self._q, "fast_ready", None)
        return f() if f is not None else False

    def fast_peek(self):
        """The ``(request, domain)`` an uncontended fissile fast-path grant
        would admit next, or None — lets the router confirm headroom at the
        request's home before committing to its bypass."""
        f = getattr(self._q, "fast_peek", None)
        out = f() if f is not None else None
        if out is None:
            return None
        (request, _t_submit), domain = out
        return request, domain

    def distance_to(self, domain: int) -> int:
        """Distance of a hypothetical switch from the current domain: 0 when
        local, 1 under a flat (or absent) topology, 2 across groups."""
        if domain == self.current_domain:
            return 0
        if self.topology is None:
            return 1
        return self.topology.distance(self.current_domain, domain)

    def submit(self, request, domain: int):
        if self.topology is not None and not 0 <= domain < self.topology.n_domains:
            raise ValueError(
                f"domain {domain} out of range for topology "
                f"{self.topology.name!r} ({self.topology.n_domains} domains)"
            )
        self._q.push((request, self._clock), domain)

    def __len__(self):
        return len(self._q)

    def next_request(self):
        """Admit the next request into a free slot (or None)."""
        out = self._q.pop(self.current_domain)
        if out is None:
            return None
        (request, t_submit), domain = out
        self.metrics.admitted += 1
        self.metrics.waits.append(self._clock - t_submit)
        self.metrics.per_domain[domain] = self.metrics.per_domain.get(domain, 0) + 1
        local = domain == self.current_domain
        if local:
            self.metrics.local_admits += 1
            self.last_admit_distance = 0
        else:
            self.metrics.domain_switches += 1
            self.last_admit_distance = self.distance_to(domain)
            self.metrics.switch_distance += self.last_admit_distance
            self.current_domain = domain
        if self.tracer:
            g = getattr(self._q, "last_grant", None)
            sp = self.tracer.span(
                "queue_wait", trace_key(request), t_submit, self._clock,
                domain=domain, local=local, distance=self.last_admit_distance,
                kind=getattr(g, "kind", None),
            )
            if g is not None:
                self.tracer.discipline_events(sp, g.events, self._clock)
        return request

    def next_batch(self, k: int) -> list:
        """Grant up to ``k`` requests in admission order — the packer's pack.

        Each grant goes through ``next_request`` so metrics, fairness and
        the current-domain walk are identical to one-at-a-time admission;
        the per-grant switch distances (``last_admit_distance`` snapshots,
        which a batch caller would otherwise lose) are kept in
        ``last_batch_distances`` aligned with the returned list."""
        out = []
        self.last_batch_distances = []
        while len(out) < k:
            req = self.next_request()
            if req is None:
                break
            out.append(req)
            self.last_batch_distances.append(self.last_admit_distance)
        return out

    def tick(self):
        self._clock += 1


class CNAScheduler(_BaseScheduler):
    def __init__(
        self,
        *,
        fairness_threshold: int = 0xFFFF,
        shuffle_reduction: bool = False,
        seed: int = 0xC0A,
        topology: Topology | None = None,
        max_active=None,  # int | repro.placement.AdaptiveController | None
        rotate_after: int = 64,
        fissile: bool = False,  # fissile fast path over the discipline stack
        tracer=None,  # repro.obs.Tracer | None (None => zero-cost off)
    ):
        super().__init__(
            CNAAdmissionQueue(
                threshold=fairness_threshold,
                shuffle_reduction=shuffle_reduction,
                seed=seed,
                max_active=max_active,
                rotate_after=rotate_after,
                fissile=fissile,
            ),
            topology=topology,
            tracer=tracer,
        )


class FIFOScheduler(_BaseScheduler):
    """MCS-admission baseline: strict arrival order, domain-oblivious.

    Takes exactly the kwargs that keep the baseline comparable to
    ``CNAScheduler`` — the topology and the GCR restriction knobs (honoured
    via ``RestrictedDiscipline`` over the FIFO core).  Anything else raises:
    an earlier ``**_`` swallowed unknown kwargs, so ``controller=...`` or a
    misspelled ``fairness_threshold=`` silently ran a different experiment."""

    def __init__(
        self,
        *,
        topology: Topology | None = None,
        max_active=None,  # int | repro.placement.AdaptiveController | None
        rotate_after: int = 64,
        tracer=None,  # repro.obs.Tracer | None (None => zero-cost off)
    ):
        super().__init__(
            FIFOAdmissionQueue(max_active=max_active, rotate_after=rotate_after),
            topology=topology,
            tracer=tracer,
        )
