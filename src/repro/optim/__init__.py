from .adamw import adamw_init, adamw_update  # noqa: F401
from .schedule import warmup_cosine  # noqa: F401
