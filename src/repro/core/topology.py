"""Locality topologies: the one place that maps ids to domains and domains
to distances.

The paper's machines are flat socket sets (every remote socket equally far),
so the seed code hardcoded ``tid % n_sockets`` in three places.  This module
replaces that with named topologies so the same discipline core can serve

  * the paper's machines        — ``two_socket`` / ``four_socket`` / ``flat(n)``,
  * hierarchical fabrics        — ``pod(n_pods, sockets_per_pod)``: sockets
    grouped into pods, cross-pod transfers costlier than cross-socket,
  * arbitrary test schedules    — ``table(assignments)``: an explicit id ->
    domain map (used by the grant-order equivalence tests).

A ``Topology`` answers exactly two questions:

  ``domain_of(tid)``    which leaf locality domain an id lands on
                        (thread -> socket in the lock; request -> KV/prefix
                        home in the serving scheduler);
  ``distance(a, b)``    0 = same domain, 1 = sibling domain (same group),
                        2 = cross-group.  ``xfer_cycles`` maps distances to
                        the cost model's local/remote/cross latencies.

The CNA discipline itself only ever compares domains for equality (the paper's
``socket == my_socket``); distances matter to the *drivers* that charge
transfer costs (``numasim``) or migration penalties (serving engine).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Topology:
    """Leaf domains, their grouping, and the id -> domain placement rule."""

    name: str
    n_domains: int
    # parent group of each domain; flat topologies put every domain in group 0
    # (all sockets mutually "remote", the paper's model).
    group_of: tuple[int, ...]
    # ids map round-robin over domains in blocks of ``block`` (block=1 is the
    # seed's tid % n mapping; block=k places k consecutive ids per domain,
    # i.e. "cores fill a socket before spilling").
    block: int = 1
    # explicit id -> domain table (cycled); overrides the round-robin rule.
    assignment: tuple[int, ...] | None = None

    def __post_init__(self) -> None:
        if len(self.group_of) != self.n_domains:
            raise ValueError("group_of must have one entry per domain")
        if self.assignment is not None:
            bad = [d for d in self.assignment if not 0 <= d < self.n_domains]
            if bad:
                raise ValueError(f"assignment references unknown domains: {bad}")

    def domain_of(self, tid: int) -> int:
        if self.assignment is not None:
            return self.assignment[tid % len(self.assignment)]
        return (tid // self.block) % self.n_domains

    def distance(self, a: int, b: int) -> int:
        if a == b:
            return 0
        return 1 if self.group_of[a] == self.group_of[b] else 2

    def xfer_cycles(self, cm, a: int, b: int) -> int:
        """Distance-aware cache-line/migration transfer cost under ``cm``."""
        d = self.distance(a, b)
        if d == 0:
            return cm.c_local_xfer
        if d == 1:
            return cm.c_remote_xfer
        return cm.c_cross_xfer


def flat(n_domains: int, name: str | None = None) -> Topology:
    """``n_domains`` mutually-remote domains — the paper's socket model."""
    return Topology(name or f"flat{n_domains}", n_domains, (0,) * n_domains)


def pod(n_pods: int, sockets_per_pod: int, cores_per_socket: int = 1) -> Topology:
    """Two-level fabric: sockets nested in pods.  Same-pod transfers cost
    ``c_remote_xfer``; cross-pod ``c_cross_xfer``.  ``cores_per_socket`` > 1
    switches placement to block mode (consecutive ids share a socket)."""
    n = n_pods * sockets_per_pod
    return Topology(
        f"pod{n_pods}x{sockets_per_pod}",
        n,
        tuple(d // sockets_per_pod for d in range(n)),
        block=cores_per_socket,
    )


def region(n_regions: int, fleets_per_region: int, name: str | None = None) -> Topology:
    """Third hierarchy level: whole fleets nested in geographic regions.

    Structurally a ``pod`` topology one level up — the *domains* are fleets
    and the *groups* are regions — so ``distance`` answers the region
    ladder: 0 = same fleet, 1 = sibling fleet (intra-region fabric),
    2 = cross-region (the expensive hop ``ShipCostModel.fabric_ladder``
    prices separately).  ``repro.region.RegionRouter`` disciplines dispatch
    over this exactly as ``ReplicaRouter`` does over replica topologies."""
    n = n_regions * fleets_per_region
    return Topology(
        name or f"region{n_regions}x{fleets_per_region}",
        n,
        tuple(f // fleets_per_region for f in range(n)),
    )


def table(assignment, n_domains: int | None = None, name: str = "table") -> Topology:
    """Explicit id -> domain schedule (cycled past its length)."""
    assignment = tuple(assignment)
    n = n_domains if n_domains is not None else max(assignment) + 1
    return Topology(name, n, (0,) * n, assignment=assignment)


TWO_SOCKET_TOPO = flat(2, "two_socket")
FOUR_SOCKET_TOPO = flat(4, "four_socket")

TOPOLOGIES = {
    "two_socket": TWO_SOCKET_TOPO,
    "four_socket": FOUR_SOCKET_TOPO,
}


def get_topology(spec) -> Topology:
    """Coerce a Topology | registry name | int (-> flat(n)) to a Topology."""
    if isinstance(spec, Topology):
        return spec
    if isinstance(spec, str):
        try:
            return TOPOLOGIES[spec]
        except KeyError:
            raise KeyError(f"unknown topology {spec!r}; have {sorted(TOPOLOGIES)}") from None
    if isinstance(spec, int):
        return flat(spec)
    raise TypeError(f"cannot interpret {spec!r} as a topology")
