"""Tenant-aware admission fairness: (tenant x fleet) pseudo-domains under
the concurrency-restriction discipline.

The GCR paper (arXiv 1905.10818, PR 2) restricts how many threads actively
contend for a lock and parks the rest; ``RestrictedDiscipline`` implements
that over any inner discipline.  Here the same machinery caps how many of a
*tenant's* sessions may be in flight toward one *fleet* at once: each
(tenant, fleet) pair is a pseudo-domain with its own
``RestrictedDiscipline(FIFODiscipline(), max_active=cap)`` — up to ``cap``
sessions proceed into the region CNA queue, the rest park in the
discipline's passive set (bounded by ``park_bound``), and anything beyond
that is rejected outright.  Rotation (``rotate_after``) keeps the parked set
from ossifying, exactly as it keeps parked threads from starving at the
lock.

Why this bounds starvation (the property the tests pin): a session parks
only while its pseudo-domain has ``cap`` sessions in flight, every
completion releases exactly one parked session (FIFO within the tenant), and
the park queue is bounded — so by Little's law a victim tenant's p99
admission stall cannot exceed ~(park_bound / cap) service times, while the
flooding tenant's *excess* volume is rejected instead of queued, never
counted as stall.  The flood pays; the victims do not.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.discipline import FIFODiscipline, RestrictedDiscipline


@dataclass
class TenantFairnessStats:
    """Counters over every pseudo-domain (per-tenant splits live in the
    region result's per-tenant histograms)."""

    offered: int = 0
    admitted: int = 0      # straight through (under cap)
    parked: int = 0
    unparked: int = 0
    rejected: int = 0
    max_parked: int = 0    # high-water mark of any one pseudo-domain's park

    def register_into(self, registry, prefix: str = "tenant") -> None:
        registry.adopt(prefix, self)


class TenantFairness:
    """Per-(tenant x fleet) concurrency caps over ``RestrictedDiscipline``.

    ``offer(session, fleet)`` -> ``"admit" | "park" | "reject"``; the caller
    queues admitted sessions, holds parked ones (they are inside the
    pseudo-domain's discipline), and drops rejected ones.  ``release`` on a
    session's completion frees its slot and returns the next parked session
    of the same pseudo-domain, if any — the caller re-queues it.  Sessions
    keep their original ``submit_t``, so parked time is admission stall, not
    invisible."""

    def __init__(self, *, cap: int = 4, park_bound: int = 8, rotate_after: int = 16) -> None:
        if cap < 1:
            raise ValueError("cap must be >= 1 (a zero cap admits nothing ever)")
        if park_bound < 0:
            raise ValueError("park_bound must be >= 0")
        self.cap = cap
        self.park_bound = park_bound
        self.rotate_after = rotate_after
        self.stats = TenantFairnessStats()
        self._gov: dict[tuple, RestrictedDiscipline] = {}
        self._inflight: dict[tuple, int] = {}
        self._parked: dict[tuple, int] = {}

    def _governor(self, key: tuple) -> RestrictedDiscipline:
        g = self._gov.get(key)
        if g is None:
            g = RestrictedDiscipline(
                FIFODiscipline(),
                max_active=self.cap,
                rotate_after=self.rotate_after,
            )
            self._gov[key] = g
        return g

    def inflight(self, tenant, fleet: int) -> int:
        return self._inflight.get((tenant, fleet), 0)

    def parked(self, tenant, fleet: int) -> int:
        return self._parked.get((tenant, fleet), 0)

    def offer(self, session, fleet: int) -> str:
        """Gate ``session`` (which must carry ``.tenant``) toward ``fleet``."""
        key = (session.tenant, fleet)
        session.pseudo = key
        self.stats.offered += 1
        if self._inflight.get(key, 0) < self.cap:
            self._inflight[key] = self._inflight.get(key, 0) + 1
            self.stats.admitted += 1
            return "admit"
        if self._parked.get(key, 0) >= self.park_bound:
            self.stats.rejected += 1
            return "reject"
        # park inside the pseudo-domain's restricted discipline: arrive()
        # beyond the active cap goes passive (a Park event), and release()
        # later grants in FIFO order with periodic rotation
        g = self._governor(key)
        g.arrive(session, 0)
        self._parked[key] = self._parked.get(key, 0) + 1
        self.stats.parked += 1
        self.stats.max_parked = max(self.stats.max_parked, self._parked[key])
        return "park"

    def release(self, session):
        """A gated session completed: free its pseudo-domain slot and pop
        the next parked session of that pseudo-domain (or None).  The caller
        owns re-queueing the returned session."""
        key = getattr(session, "pseudo", None)
        if key is None:
            return None
        self._inflight[key] = max(0, self._inflight.get(key, 0) - 1)
        g = self._gov.get(key)
        if g is None or self._parked.get(key, 0) <= 0:
            return None
        grant = g.release(0)  # one pseudo-domain per governor: domain is moot
        if grant is None:
            return None
        self._parked[key] -= 1
        self._inflight[key] += 1
        self.stats.unparked += 1
        return grant.item

    def total_parked(self) -> int:
        return sum(self._parked.values())
