"""Feed-forward blocks: SwiGLU / GEGLU / GELU / squared-ReLU (nemotron)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamBuilder
from .sharding import shard


def declare_mlp(pb: ParamBuilder, prefix: str, d_model: int, d_ff: int, kind: str, stack: int = 0):
    """Declare FFN params under ``prefix``; optional leading stack dim."""
    lead = (stack,) if stack else ()
    lax = ("layers",) if stack else ()
    gated = kind in ("swiglu", "geglu")
    pb.declare(f"{prefix}/wi", lead + (d_model, d_ff), lax + ("fsdp", "mlp"))
    if gated:
        pb.declare(f"{prefix}/wg", lead + (d_model, d_ff), lax + ("fsdp", "mlp"))
    pb.declare(f"{prefix}/wo", lead + (d_ff, d_model), lax + ("mlp", "fsdp"))


def mlp_apply(params: dict, x: jax.Array, kind: str) -> jax.Array:
    """x: (B, S, D).  Hidden activations sharded on the 'mlp' logical axis."""
    h = jnp.einsum("bsd,df->bsf", x, params["wi"])
    h = shard(h, "batch", None, "mlp")
    if kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, params["wg"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    elif kind == "geglu":
        g = jnp.einsum("bsd,df->bsf", x, params["wg"])
        h = jax.nn.gelu(g.astype(jnp.float32), approximate=True).astype(h.dtype) * h
    elif kind == "gelu":
        h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(h.dtype)
    elif kind == "relu2":  # nemotron-4: squared ReLU
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(h.dtype)
    else:
        raise ValueError(kind)
    out = jnp.einsum("bsf,fd->bsd", h, params["wo"])
    return shard(out, "batch", "seq", "embed")
