"""Serving engine + CNA scheduler: correctness is admission-order-invariant,
locality/throughput favor CNA, fairness is preserved."""

import jax
import numpy as np
import pytest

from repro.configs.base import get_reduced_config
from repro.models.registry import build_model
from repro.serving.engine import DecodeEngine, Request
from repro.serving.scheduler import CNAScheduler, FIFOScheduler


@pytest.fixture(scope="module")
def small_model():
    cfg = get_reduced_config("granite_3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n=8, domains=2, seed=0, plen=8, max_new=5):
    rng = np.random.default_rng(seed)
    return [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
                max_new=max_new, domain=i % domains)
        for i in range(n)
    ]


def _greedy_reference(model, params, prompt, n_new):
    """Free-running single-request decode (no batching)."""
    import jax.numpy as jnp

    logits, cache = jax.jit(model.prefill)(params, {"tokens": jnp.asarray(prompt)[None]})
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        logits, cache = jax.jit(model.decode_step)(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32)
        )
        out.append(int(jnp.argmax(logits[0])))
    return out


def test_outputs_match_unbatched_reference(small_model):
    cfg, model, params = small_model
    reqs = _requests(cfg, n=5, seed=1)
    eng = DecodeEngine(model, params, n_slots=3, cache_len=64)
    eng.run(reqs)
    for r in reqs:
        ref = _greedy_reference(model, params, r.prompt, r.max_new)
        assert r.out[: r.max_new] == ref, f"rid={r.rid}: {r.out} vs {ref}"


def test_outputs_invariant_to_scheduler(small_model):
    """Per-request generations are identical under CNA and FIFO admission —
    the policy reorders work, never changes results."""
    cfg, model, params = small_model
    base = _requests(cfg, n=8, seed=2)
    outs = {}
    for name, sched in [("cna", CNAScheduler(fairness_threshold=0xF)), ("fifo", FIFOScheduler())]:
        reqs = [Request(r.rid, r.prompt, r.max_new, r.domain) for r in base]
        DecodeEngine(model, params, n_slots=3, cache_len=64, scheduler=sched).run(reqs)
        outs[name] = {r.rid: tuple(r.out) for r in reqs}
    assert outs["cna"] == outs["fifo"]


def test_cna_beats_fifo_on_locality_and_switch_cost(small_model):
    cfg, model, params = small_model
    base = _requests(cfg, n=12, domains=2, seed=3)
    stats = {}
    for name, sched in [("cna", CNAScheduler(fairness_threshold=0xF)), ("fifo", FIFOScheduler())]:
        reqs = [Request(r.rid, r.prompt, r.max_new, r.domain) for r in base]
        eng = DecodeEngine(model, params, n_slots=3, cache_len=64,
                           scheduler=sched, domain_switch_cost=8)
        eng.run(reqs)
        stats[name] = (eng.scheduler.metrics.locality, eng.scheduler.metrics.domain_switches, eng.sim_time)
    assert stats["cna"][0] > stats["fifo"][0]       # higher locality
    assert stats["cna"][1] < stats["fifo"][1]       # fewer domain switches
    assert stats["cna"][2] < stats["fifo"][2]       # lower simulated time


def test_fairness_no_domain_starves(small_model):
    """With a small fairness threshold, every domain gets served even when
    domain 0 floods the queue (the paper's long-term fairness property)."""
    cfg, model, params = small_model
    reqs = [
        Request(rid=i, prompt=np.arange(4, dtype=np.int32), max_new=2,
                domain=0 if i < 20 else 1)
        for i in range(24)
    ]
    eng = DecodeEngine(model, params, n_slots=2, cache_len=32,
                       scheduler=CNAScheduler(fairness_threshold=0x3, seed=5))
    eng.run(reqs)
    per_dom = eng.scheduler.metrics.per_domain
    assert per_dom.get(0, 0) == 20 and per_dom.get(1, 0) == 4
    assert all(r.done for r in reqs)


def test_slot_reuse_and_release(small_model):
    cfg, model, params = small_model
    reqs = _requests(cfg, n=9, seed=4, max_new=3)
    eng = DecodeEngine(model, params, n_slots=2, cache_len=32)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert len(eng.slots.free) == 2 and not eng.active_req


def test_released_slot_does_not_leak_stale_kv(small_model):
    """Regression: SlotCache.release must zero the slot's position so a
    re-claimed slot reads as empty (no stale KV visible) until insert, and a
    request served from a reused slot decodes identically to a fresh one."""
    cfg, model, params = small_model
    # two requests forced through the same single slot, back to back
    reqs = _requests(cfg, n=2, seed=6, plen=6, max_new=4)
    eng = DecodeEngine(model, params, n_slots=1, cache_len=32)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert int(eng.slots.cache["pos"][0]) == 0  # released slot reads empty
    for r in reqs:
        ref = _greedy_reference(model, params, r.prompt, r.max_new)
        assert r.out[: r.max_new] == ref


def test_scheduler_rejects_out_of_range_domain():
    from repro.core.topology import pod
    from repro.serving.scheduler import FIFOScheduler as FS

    s = FS(topology=pod(2, 2))
    with pytest.raises(ValueError, match="domain 7 out of range"):
        s.submit("r", 7)
    s.submit("r", 3)  # in range: 4 domains


def test_engine_rejects_conflicting_scheduler_and_topology():
    from repro.core.topology import pod
    from repro.serving.scheduler import FIFOScheduler as FS

    with pytest.raises(ValueError, match="topology via the scheduler"):
        DecodeEngine(None, None, scheduler=FS(), topology=pod(2, 2))


def test_placement_engine_outputs_invariant_and_telemetry(small_model):
    """A placement-aware SlotCache changes WHERE caches live, never what gets
    decoded: outputs match the baseline engine, and per-domain telemetry is
    surfaced through the scheduler metrics."""
    from repro.core.topology import pod

    cfg, model, params = small_model
    base = _requests(cfg, n=10, domains=4, seed=7)
    outs = {}
    for name, kw in [
        ("baseline", {}),
        ("placed", dict(scheduler=CNAScheduler(fairness_threshold=0xF, topology=pod(2, 2)),
                        placement="nearest_spill")),
    ]:
        reqs = [Request(r.rid, r.prompt, r.max_new, r.domain) for r in base]
        eng = DecodeEngine(model, params, n_slots=4, cache_len=64, **kw)
        eng.run(reqs)
        outs[name] = {r.rid: tuple(r.out) for r in reqs}
        if name == "placed":
            tel = eng.scheduler.metrics.placement
            assert tel is eng.slots.telemetry
            assert tel.placements == 10 and tel.releases == 10
            assert tel.placements == tel.local_placements + tel.spills
            assert tel.handover_samples == 10  # one sample per admission
            assert sum(tel.per_domain_occupancy.values()) == 0  # all released
    assert outs["placed"] == outs["baseline"]


def test_placement_requires_topology():
    with pytest.raises(ValueError, match="placement needs a topology"):
        DecodeEngine(None, None, placement="nearest_spill")


def test_engine_rejects_overlength_prompt(small_model):
    """Regression: a prompt with len(prompt) >= cache_len used to be admitted
    unguarded — prefill returned pos > cache_len, ``_fit`` silently trimmed
    the KV, and the decode write clamped onto the last cache entry.  It must
    be rejected at submit; the longest fitting prompt still decodes."""
    cfg, model, params = small_model
    eng = DecodeEngine(model, params, n_slots=1, cache_len=16)
    bad = Request(rid=0, prompt=np.arange(16, dtype=np.int32) % cfg.vocab, max_new=2)
    with pytest.raises(ValueError, match="cannot fit cache_len"):
        eng.submit(bad)
    assert len(eng.scheduler) == 0  # nothing half-queued
    ok = Request(rid=1, prompt=np.arange(15, dtype=np.int32) % cfg.vocab, max_new=2)
    eng.run([ok])
    assert ok.done


def test_slotcache_claim_validates_domain_and_exhaustion():
    """Regression: under placement, claim() used to coerce domain=None to 0
    (skewing domain-0 telemetry) and let out-of-range domains surface as an
    opaque IndexError inside the pools; the baseline path's exhausted-cache
    error was heapq's bare 'index out of range'."""
    import jax.numpy as jnp

    from repro.core.topology import pod
    from repro.serving.kvcache import SlotCache

    def mk(**kw):
        return SlotCache({"pos": jnp.zeros((2,), jnp.int32)}, {"pos": None}, 2, **kw)

    sc = mk(topology=pod(2, 1))
    with pytest.raises(ValueError, match="domain=None"):
        sc.claim("r0")
    with pytest.raises(ValueError, match="domain 5 out of range"):
        sc.claim("r0", 5)
    with pytest.raises(ValueError, match="domain -1 out of range"):
        sc.claim("r0", -1)
    assert sc.telemetry.placements == 0 and not sc.owner  # rejects left no trace
    assert sc.claim("r0", 1) is not None and sc.slot_domain(0) == 0
    sc.claim("r1", 1)
    with pytest.raises(IndexError, match="claim from an exhausted SlotCache"):
        sc.claim("r2", 1)

    base = mk()
    base.claim("a"), base.claim("b")
    assert base.slot_domain(0) is None  # baseline: no domains
    with pytest.raises(IndexError, match="claim from an exhausted SlotCache"):
        base.claim("c")


def test_adaptive_scheduler_in_engine_feeds_controller(small_model):
    """CNAScheduler(max_active=AdaptiveController) in a real engine run: the
    engine feeds one handover sample per admission and decode output is
    unchanged by the adaptive cap."""
    from repro.core.topology import pod
    from repro.placement import AdaptiveController

    cfg, model, params = small_model
    base = _requests(cfg, n=8, domains=4, seed=8)
    ctrl = AdaptiveController(initial=2, max_cap=8, window=4)
    sched = CNAScheduler(fairness_threshold=0xF, topology=pod(2, 2), max_active=ctrl)
    reqs = [Request(r.rid, r.prompt, r.max_new, r.domain) for r in base]
    eng = DecodeEngine(model, params, n_slots=2, cache_len=64, scheduler=sched)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert sched.controller is ctrl and ctrl.samples == 8
    for r in reqs:
        ref = _greedy_reference(model, params, r.prompt, r.max_new)
        assert r.out[: r.max_new] == ref


def test_topology_scheduler_scales_switch_cost(small_model):
    """Cross-pod admissions stall the engine twice as long as same-pod ones
    under a hierarchical topology."""
    from repro.core.topology import pod
    from repro.serving.scheduler import FIFOScheduler as FS

    cfg, model, params = small_model
    topo = pod(2, 2)
    # domains 0,2 are in different pods; 0,1 share a pod
    far = [Request(rid=i, prompt=np.arange(4, dtype=np.int32), max_new=2,
                   domain=[0, 2][i % 2]) for i in range(4)]
    near = [Request(rid=i, prompt=np.arange(4, dtype=np.int32), max_new=2,
                    domain=[0, 1][i % 2]) for i in range(4)]
    times = {}
    for name, reqs in [("far", far), ("near", near)]:
        eng = DecodeEngine(model, params, n_slots=1, cache_len=32,
                           scheduler=FS(topology=topo), domain_switch_cost=10)
        eng.run(reqs)
        times[name] = eng.sim_time
        assert eng.scheduler.metrics.domain_switches > 0
    assert times["far"] > times["near"]


# -- prefix-KV reuse (matched_len-aware prefill) -------------------------------


def test_prefix_kv_store_exact_prefix_lookup_and_lru():
    from repro.serving.prefixkv import PrefixKVStore

    s = PrefixKVStore(capacity=2)
    s.put([1, 2, 3], "c123", "l123")
    s.put([1, 2], "c12", "l12")
    # longest *exact* prefix wins; a shared run that diverges is not a hit
    assert s.longest([1, 2, 3, 4]) == (3, "c123", "l123")
    assert s.longest([1, 2, 9]) == (2, "c12", "l12")
    assert s.longest([9, 9]) is None
    assert s.common_run([1, 2, 9]) == 2
    s.put([7, 7, 7], "c777", "l777")  # capacity 2: LRU ([1,2,3]? no — it was
    # touched last by the [1,2,3,4] lookup before [1,2] was) evicts oldest
    assert len(s) == 2
    with pytest.raises(ValueError):
        PrefixKVStore(capacity=0)


def _greedy_reference_split(model, params, prompt, split, n_new):
    """Free-running reference that prefills ``prompt[:split]`` and feeds the
    rest token-by-token — the *incremental* decomposition prefix-KV reuse
    performs.  (Batched prefill and incremental decode agree only to the
    bf16 cache resolution, so greedy argmax on a random reduced config can
    legitimately flip between decompositions; reuse reuses the *identical*
    stored KV, so it must match the reference with the same split exactly.)"""
    import jax.numpy as jnp

    pf, st = jax.jit(model.prefill), jax.jit(model.decode_step)
    if split >= len(prompt):
        logits, cache = pf(params, {"tokens": jnp.asarray(prompt)[None]})
    else:
        logits, cache = pf(params, {"tokens": jnp.asarray(prompt[:split])[None]})
        for t in prompt[split:]:
            logits, cache = st(params, cache, jnp.asarray([[int(t)]], jnp.int32))
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        logits, cache = st(params, cache, jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(logits[0])))
    return out


def test_matched_len_aware_prefill_skips_cached_positions(small_model):
    """The ROADMAP unlock, pinned by counting prefill positions: with a
    PrefixKVStore the engine computes each shared prefix once; later prompts
    sharing it prefill only their suffix — and decode exactly what the
    incremental reference decodes."""
    cfg, model, params = small_model
    rng = np.random.default_rng(12)
    P = rng.integers(0, cfg.vocab, 12).astype(np.int32)
    prompts = [np.concatenate([P, rng.integers(0, cfg.vocab, 4).astype(np.int32)])
               for _ in range(3)]

    from repro.core.topology import pod

    eng = DecodeEngine(model, params, n_slots=1, cache_len=64,
                       topology=pod(1, 2), placement="nearest_spill",
                       prefix_index=True, prefix_kv=True)
    reqs = [Request(rid=i, prompt=p, max_new=3, domain=None)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    # req0: full (16).  req1: no exact-prefix entry yet, but the common run
    # with the stored full prompt plants the boundary — 12 + 4 computed.
    # req2: resumes from the boundary — only its 4-token suffix.
    assert eng.prefill_positions == 16 + 16 + 4
    assert eng.reused_positions == 12
    assert eng.prefix_kv.hits == 1
    splits = {0: 16, 1: 12, 2: 12}  # the decomposition each request ran
    for r in reqs:
        ref = _greedy_reference_split(model, params, r.prompt, splits[r.rid], r.max_new)
        assert r.out[: r.max_new] == ref, f"rid={r.rid}"


def test_prefill_reuse_on_conversation_extension(small_model):
    """A prompt that extends a previously served prompt resumes from its
    stored cache directly (no boundary planting needed)."""
    cfg, model, params = small_model
    rng = np.random.default_rng(13)
    first = rng.integers(0, cfg.vocab, 10).astype(np.int32)
    eng = DecodeEngine(model, params, n_slots=1, cache_len=64, prefix_kv=True)
    r1 = Request(rid=0, prompt=first, max_new=3)
    eng.run([r1])
    ext = np.concatenate([first, rng.integers(0, cfg.vocab, 5).astype(np.int32)])
    r2 = Request(rid=1, prompt=ext, max_new=3)
    before = eng.prefill_positions
    eng.run([r2])
    assert eng.prefill_positions - before == 5        # only the extension
    ref = _greedy_reference_split(model, params, ext, len(first), r2.max_new)
    assert r2.out[: r2.max_new] == ref


# -- FIFO scheduler kwargs (regression) ----------------------------------------


def test_fifo_scheduler_rejects_unknown_kwargs():
    """Regression: FIFOScheduler(**_) used to swallow anything — a misspelled
    fairness_threshold= or a controller= silently ran a different experiment."""
    with pytest.raises(TypeError):
        FIFOScheduler(fairness_threshold=0xF)
    with pytest.raises(TypeError):
        FIFOScheduler(controller=object())
    with pytest.raises(TypeError):
        FIFOScheduler(fairness_treshold=3)  # the misspelling, explicitly


def test_fifo_scheduler_honours_restriction_kwargs():
    """The shared GCR knobs are accepted AND honoured: a capped FIFO parks
    excess arrivals (visible in the queue stats) while preserving FIFO grant
    order."""
    s = FIFOScheduler(max_active=2)
    for i in range(5):
        s.submit(f"r{i}", i % 2)
    assert s.max_active == 2
    assert s._q.stats.parked == 3
    granted = [s.next_request() for _ in range(5)]
    assert granted == [f"r{i}" for i in range(5)]  # order unchanged
    from repro.placement import AdaptiveController

    ctl = AdaptiveController(initial=3)
    s2 = FIFOScheduler(max_active=ctl)
    assert s2.controller is ctl and s2.max_active == 3
    s2.observe_handover(7)
    assert ctl.samples == 1


# -- engine replicas behind the router -----------------------------------------


def test_engine_replicas_behind_router(small_model):
    """End-to-end: two DecodeEngine replicas behind ReplicaRouter — summaries
    flow to the federation, sessions route and complete, fleet inflight
    drains to zero, and prefix-KV reuse shows up as skipped prefill."""
    from repro.core.topology import pod
    from repro.router import EngineReplica, ReplicaRouter, Session

    cfg, model, params = small_model
    rng = np.random.default_rng(21)
    shared = [rng.integers(0, cfg.vocab, 8).astype(np.int32) for _ in range(2)]
    sessions = [
        Session(sid=i,
                prompt=tuple(int(t) for t in np.concatenate(
                    [shared[i % 2], rng.integers(0, cfg.vocab, 3).astype(np.int32)])),
                decode_len=2)
        for i in range(8)
    ]
    replicas = [
        EngineReplica(r, DecodeEngine(
            model, params, n_slots=2, cache_len=32,
            scheduler=CNAScheduler(topology=pod(1, 2)),
            placement="nearest_spill", prefix_index=True, prefix_kv=True))
        for r in range(2)
    ]
    router = ReplicaRouter(replicas, sync_every=2)
    i = done = 0
    for _ in range(500):
        router.tick()
        if i < len(sessions):
            router.submit(sessions[i])
            i += 1
        router.dispatch()
        for rep in replicas:
            for session, ttft in rep.step():
                assert ttft >= 1
                router.complete(session, ttft=ttft)
                done += 1
        if done == len(sessions):
            break
    assert done == len(sessions)
    assert router.fleet.inflight == [0, 0]
    assert router.stats.dispatched == len(sessions)
    assert router.federation.stats.applied >= 2      # summaries flowed
    served = [r.engine.scheduler.metrics.admitted for r in replicas]
    assert sum(served) == len(sessions)
    total_prompt = sum(len(s.prompt) for s in sessions)
    computed = sum(r.engine.prefill_positions for r in replicas)
    assert computed < total_prompt                   # real prefill skipped
    assert all(s.finish_t >= 0 for s in sessions)


def test_engine_replica_requires_prefix_index(small_model):
    from repro.router import EngineReplica

    cfg, model, params = small_model
    eng = DecodeEngine(model, params, n_slots=1, cache_len=32)
    with pytest.raises(ValueError, match="prefix index"):
        EngineReplica(0, eng)


# -- controller-coupled shedding through the engine ----------------------------


def test_engine_auto_wires_controller_shedding(small_model):
    """Regression for the shed-before-spill ordering at the engine level:
    with placement + an adaptive controller, the engine wires the
    controller's occupancy view and a saturated home re-homes new
    submissions to its same-pod sibling (no migration) before nearest_spill
    is forced cross-pod."""
    from repro.core.topology import pod
    from repro.placement import AdaptiveController

    cfg, model, params = small_model
    ctl = AdaptiveController(initial=8)
    eng = DecodeEngine(
        model, params, n_slots=8, cache_len=32,
        scheduler=CNAScheduler(topology=pod(2, 2), max_active=ctl),
        placement="nearest_spill",
    )
    assert ctl.occupancy is not None          # auto-wired
    assert ctl.domain_capacity == (2, 2, 2, 2)
    assert ctl.shed_topology is eng.scheduler.topology
    tel = eng.slots.telemetry

    def feed(rid):  # submit homed at 0, admit immediately, never retires
        r = Request(rid=rid, prompt=np.arange(4, dtype=np.int32), max_new=30, domain=0)
        eng.submit(r)
        eng.step()
        return r

    homes = [feed(i).domain for i in range(5)]
    assert homes == [0, 0, 1, 1, 0]           # home, home, shed, shed, pod full
    assert tel.sheds == 2
    assert tel.cross_spills == 1 and tel.sibling_spills == 0
    assert tel.migration_cycles > 0           # only the final cross-pod spill
