"""Correctness tests for the faithful (threaded) CNA lock implementation."""

import threading

import pytest

from repro.core.cna import CNALock, CNANode, MCSLock, run_lock_stress


@pytest.mark.parametrize("n_threads,n_sockets", [(2, 1), (4, 2), (8, 2), (9, 3), (16, 4)])
def test_mutual_exclusion(n_threads, n_sockets):
    shared = run_lock_stress(
        lambda sock: CNALock(numa_node_of=sock),
        n_threads,
        n_sockets,
        iters=300,
    )
    assert shared.counter == n_threads * 300


def test_mutual_exclusion_small_threshold_exercises_flush_paths():
    # threshold=1 => keep_lock_local is frequently false => secondary-queue
    # flush path (L43-46) runs constantly.
    shared = run_lock_stress(
        lambda sock: CNALock(numa_node_of=sock, threshold=1),
        8,
        2,
        iters=300,
    )
    assert shared.counter == 8 * 300


def test_mutual_exclusion_shuffle_reduction():
    shared = run_lock_stress(
        lambda sock: CNALock(numa_node_of=sock, shuffle_reduction=True, threshold2=3),
        8,
        2,
        iters=300,
    )
    assert shared.counter == 8 * 300


def test_mcs_baseline_mutual_exclusion():
    shared = run_lock_stress(lambda sock: MCSLock(), 8, 2, iters=300)
    assert shared.counter == 8 * 300


def test_no_starvation_every_thread_completes():
    shared = run_lock_stress(
        lambda sock: CNALock(numa_node_of=sock, threshold=0xF),
        8,
        2,
        iters=200,
    )
    assert sorted(shared.per_thread.values()) == [200] * 8


def test_single_thread_uncontended_path_records_no_socket():
    lock = CNALock(numa_node_of=lambda: 7)
    node = CNANode()
    lock.acquire(node)
    # uncontended: L8 fast path, socket never read (stays -1), spin set to 1
    assert node.socket == -1
    assert node.spin == 1
    lock.release(node)
    assert lock.tail is None


def test_handover_passes_secondary_head_through_spin_field():
    """Deterministic 3-thread interleaving reproducing Fig. 1 (a)-(b):
    holder on socket 0, queue = [remote(1), local(0)] => the remote waiter
    moves to the secondary queue and the local waiter receives its head via
    the spin field."""
    sockets = {}
    lock = CNALock(numa_node_of=lambda: sockets[threading.get_ident()])

    n_holder, n_remote, n_local = CNANode(), CNANode(), CNANode()
    order = []
    ready = threading.Barrier(3)
    release_holder = threading.Event()

    def holder():
        sockets[threading.get_ident()] = 0
        lock.acquire(n_holder)
        ready.wait()
        release_holder.wait()
        lock.release(n_holder)

    def remote():
        sockets[threading.get_ident()] = 1
        ready.wait()
        lock.acquire(n_remote)
        order.append("remote")
        lock.release(n_remote)

    def local():
        sockets[threading.get_ident()] = 0
        ready.wait()
        # enqueue strictly after the remote thread
        while lock.tail is not n_remote:
            pass
        lock.acquire(n_local)
        order.append("local")
        lock.release(n_local)

    ts = [threading.Thread(target=f) for f in (holder, remote, local)]
    for t in ts:
        t.start()
    # wait until both waiters are linked in
    while n_remote.next is not n_local:
        pass
    release_holder.set()
    for t in ts:
        t.join()

    # the local (socket-0) thread must have been served first, and it must
    # have received the secondary-queue head (the remote node) in its spin
    # field, per the paper's pointer-reuse trick.
    assert order == ["local", "remote"]
    assert lock.stats.local_handovers >= 1
    assert lock.stats.shuffles >= 1
    assert lock.tail is None


def test_stats_locality_under_contention():
    lock_holder = {}

    def factory(sock):
        lock = CNALock(numa_node_of=sock)
        lock_holder["lock"] = lock
        return lock

    run_lock_stress(factory, 8, 2, iters=400)
    lock = lock_holder["lock"]
    # under contention most handovers should be socket-local
    if lock.stats.handovers > 100:
        assert lock.stats.local_handovers / lock.stats.handovers > 0.5
