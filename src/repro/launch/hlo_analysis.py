"""Trip-count-aware analysis of post-SPMD optimized HLO.

``compiled.cost_analysis()`` visits every computation **once**, so anything
inside a ``while`` body (scan-over-layers, grad-accumulation, KV-chunk
streaming) is undercounted by its trip count — for a 96-layer scanned model
that is a ~100x error.  XLA:CPU helpfully records
``backend_config={"known_trip_count":{"n":...}}`` on each while op, so this
module rebuilds the cost *with multiplicities*:

  1. parse the HLO text into computations and per-instruction symbol tables;
  2. per instruction, charge FLOPs (dot/conv via contracting-dim math),
     HBM bytes (operands + result, with gather/DUS/slice special-cased to
     touched bytes, bookkeeping ops skipped), and collective wire bytes
     (ring-collective models, ICI/DCN split via replica-group pod spans);
  3. walk the call graph (while bodies x trip count, fusions/calls/to_apply
     x 1) accumulating multiplicity from ENTRY down.

Validated against cost_analysis() on scan-free programs (tests/test_hlo_analysis.py)
where the two agree on dot FLOPs exactly.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

import numpy as np

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPNAME2 = re.compile(r"^\s*([\w\-]+)\(")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLREF = re.compile(r"(?:calls|to_apply|body|condition)=%([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_GROUPS_EXPL = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCHDIMS = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

SKIP_BYTES_OPS = {
    "get-tuple-element", "tuple", "parameter", "constant", "bitcast",
    "after-all", "iota", "while", "conditional", "call", "custom-call",
    "partition-id", "replica-id",
    # XLA:CPU legalizes bf16 compute via explicit f32 converts; on the TPU
    # target converts fuse into their producer/consumer and never hit HBM.
    "convert",
}
COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute"}


def _split_ret(rhs: str) -> tuple[str, str]:
    """Split '<ret-type> <op>(...)' — the ret type may be a tuple containing
    /*index=N*/ comments, so bracket-match rather than regex."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rhs[: i + 1], rhs[i + 1 :]
        return rhs, ""
    depth = 0
    for i, ch in enumerate(rhs):
        if ch in "[{":
            depth += 1
        elif ch in "]}":
            depth -= 1
        elif ch == " " and depth == 0:
            return rhs[:i], rhs[i:]
    return rhs, ""


def _shapes_of(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE.findall(text):
        if dt in DTYPE_BYTES:
            out.append((dt, tuple(int(x) for x in dims.split(",") if x)))
    return out


def _nbytes(shapes) -> int:
    return sum(int(np.prod(d, dtype=np.int64)) * DTYPE_BYTES[t] for t, d in shapes)


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    ret_shapes: list
    operands: list[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    symbols: dict          # %name -> ret shapes
    calls: list            # (callee, factor)
    root_name: str | None = None

    @property
    def root(self):
        if self.root_name is not None:
            for ins in self.instrs:
                if ins.name == self.root_name:
                    return ins
        return self.instrs[-1] if self.instrs else None


def parse_module(text: str):
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                name = m.group(2)
                cur = Computation(name, [], {}, [])
                if m.group(1):
                    entry = name
                # parameters declared in the header
                for pm in re.finditer(r"%?([\w.\-]+):\s*(\(?[a-z0-9]+\[[^,)]*\)?)", m.group(3)):
                    cur.symbols[pm.group(1)] = _shapes_of(pm.group(2))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        ret, rest = _split_ret(rhs)
        om = _OPNAME2.match(rest)
        if not om:
            continue
        op = om.group(1)
        # operand names: %refs inside the op's own parentheses
        paren = rest.find("(") + 1
        depth, i = 1, paren
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        operands = _OPERANDS.findall(rest[paren : i - 1])
        ins = Instr(name, op, _shapes_of(ret), operands, rhs)
        cur.instrs.append(ins)
        cur.symbols[name] = ins.ret_shapes
        if line.lstrip().startswith("ROOT"):
            cur.root_name = name
        # call graph edges
        trip = 1
        tm = _TRIP.search(rhs)
        if op == "while":
            trip = int(tm.group(1)) if tm else 1
        for cm in _CALLREF.finditer(rhs):
            cur.calls.append((cm.group(1), trip if op == "while" else 1))
        bm = _BRANCHES.search(rhs)
        if bm:
            for b in _OPERANDS.findall(bm.group(1)):
                cur.calls.append((b, 1))
    return comps, entry


def _multiplicities(comps, entry) -> dict[str, float]:
    """Kahn's algorithm over the (acyclic) computation call graph; a callee's
    multiplicity is the sum over call sites of caller_mult x edge factor
    (factor = trip count for while body/condition edges, else 1)."""
    from collections import deque

    reach: set[str] = set()
    dq = deque([entry])
    while dq:
        c = dq.popleft()
        if c in reach:
            continue
        reach.add(c)
        for callee, _ in comps[c].calls:
            if callee in comps:
                dq.append(callee)
    indeg = {c: 0 for c in reach}
    for c in reach:
        for callee, _ in comps[c].calls:
            if callee in reach:
                indeg[callee] += 1
    mult = {c: 0.0 for c in reach}
    mult[entry] = 1.0
    dq = deque([c for c in reach if indeg[c] == 0])
    while dq:
        c = dq.popleft()
        for callee, factor in comps[c].calls:
            if callee in reach:
                mult[callee] += mult[c] * factor
                indeg[callee] -= 1
                if indeg[callee] == 0:
                    dq.append(callee)
    return mult


def _dot_flops(ins: Instr, symbols) -> float:
    out_elems = sum(int(np.prod(d, dtype=np.int64)) for _, d in ins.ret_shapes)
    cm = _CONTRACT.search(ins.line)
    k = 1
    if cm and ins.operands:
        lhs = symbols.get(ins.operands[0])
        if lhs:
            dims = lhs[0][1]
            for ci in (int(x) for x in cm.group(1).split(",") if x):
                if ci < len(dims):
                    k *= dims[ci]
    return 2.0 * out_elems * k


def _conv_flops(ins: Instr, symbols) -> float:
    # output elems * 2 * kernel_spatial * in_channels (approx; convs here are
    # tiny depthwise frontends)
    out_elems = sum(int(np.prod(d, dtype=np.int64)) for _, d in ins.ret_shapes)
    if len(ins.operands) >= 2:
        rhs = symbols.get(ins.operands[1])
        if rhs:
            return 2.0 * out_elems * int(np.prod(rhs[0][1], dtype=np.int64)) / max(1, rhs[0][1][-1])
    return 2.0 * out_elems


def _instr_bytes(ins: Instr, symbols, comps=None) -> float:
    if ins.op in SKIP_BYTES_OPS:
        return 0.0
    res = _nbytes(ins.ret_shapes)
    if ins.op == "dynamic-update-slice":
        upd = symbols.get(ins.operands[1]) if len(ins.operands) > 1 else None
        return 2.0 * _nbytes(upd) if upd else res
    if ins.op in ("dynamic-slice", "slice"):
        return 2.0 * res
    if ins.op == "gather":
        idx = symbols.get(ins.operands[1]) if len(ins.operands) > 1 else None
        return 2.0 * res + (_nbytes(idx) if idx else 0)
    if ins.op == "scatter":
        upd = symbols.get(ins.operands[-1]) if ins.operands else None
        return res + 2.0 * (_nbytes(upd) if upd else 0)
    if ins.op == "fusion" and comps is not None:
        # in-place fusions (dynamic-update-slice root — the scan ys write
        # pattern) touch only the updated slice, not the whole buffer; and a
        # fusion reads at most O(result) from each operand for the loop/output
        # fusions XLA:CPU builds (reductions excepted — acceptable error).
        cm = _CALLREF.search(ins.line)
        write = res
        if cm and cm.group(1) in comps:
            fused = comps[cm.group(1)]
            root = fused.root
            # walk through trivial wrappers (convert/bitcast/copy) to a DUS root
            by_name = {i.name: i for i in fused.instrs}
            hops = 0
            while root is not None and root.op in ("convert", "bitcast", "copy") and root.operands and hops < 4:
                root = by_name.get(root.operands[0])
                hops += 1
            if root is not None and root.op == "dynamic-update-slice" and len(root.operands) > 1:
                upd = fused.symbols.get(root.operands[1])
                if upd:
                    write = 2.0 * _nbytes(upd)
        cap = max(write, res if write != res else res)
        total = float(write)
        for o in ins.operands:
            s = symbols.get(o)
            if s:
                total += min(float(_nbytes(s)), float(cap))
        return total
    total = float(res)
    for o in ins.operands:
        s = symbols.get(o)
        if s:
            total += _nbytes(s)
    return total


def _wire_bytes(kind: str, nbytes: float, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * nbytes * (n - 1) / n
    if kind == "all-gather":
        return nbytes * (n - 1) / n
    if kind == "reduce-scatter":
        return nbytes * (n - 1)
    if kind == "all-to-all":
        return nbytes * (n - 1) / n
    if kind == "collective-permute":
        return float(nbytes)
    return 0.0


def _parse_groups(line: str):
    m = _GROUPS_IOTA.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims)))
        ids = ids.reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(x) for x in m.group(4).split(",")])
        return s, ids.reshape(g, s)
    m = _GROUPS_EXPL.search(line)
    if m:
        groups = [[int(x) for x in grp.split(",") if x.strip()] for grp in re.findall(r"\{([^}]*)\}", m.group(1))]
        if groups and groups[0]:
            width = max(len(g) for g in groups)
            arr = np.array([g + [g[0]] * (width - len(g)) for g in groups])
            return width, arr
    return 1, None


@dataclasses.dataclass
class HLOCost:
    flops: float = 0.0
    bytes: float = 0.0
    ici_wire: float = 0.0
    dcn_wire: float = 0.0
    flops_by_op: dict = dataclasses.field(default_factory=dict)
    bytes_by_op: dict = dataclasses.field(default_factory=dict)
    collectives: dict = dataclasses.field(default_factory=dict)  # kind/loc -> {count, wire}

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze_hlo(
    text: str,
    *,
    chips_per_pod: int = 256,
    unroll_while: bool = True,
    kernel_scopes: tuple[str, ...] = (),
) -> HLOCost:
    """``unroll_while=False`` reproduces cost_analysis() semantics (every
    computation once) — used to calibrate the byte model against XLA's.

    ``kernel_scopes``: jax.named_scope markers whose instructions model a
    Pallas kernel region — a perfect fusion whose intermediates (scores,
    online-softmax carries) stay in VMEM.  In-scope instructions charge FLOPs
    (the MXU still does the work) but **zero HBM bytes**; the region's
    boundary tensors (q/k/v in, o out) are already charged by the
    out-of-scope producer/consumer ops.  This is how the TPU-target memory
    term is derived from a CPU-compiled artifact — see EXPERIMENTS.md
    §Roofline methodology."""
    comps, entry = parse_module(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    if unroll_while:
        mult = _multiplicities(comps, entry)
    else:
        mult = {c: 1.0 for c in comps}
    cost = HLOCost()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instrs:
            in_kernel = bool(kernel_scopes) and any(s in ins.line for s in kernel_scopes)
            f = 0.0
            if ins.op == "dot":
                f = _dot_flops(ins, comp.symbols)
            elif ins.op == "convolution":
                f = _conv_flops(ins, comp.symbols)
            if f:
                cost.flops += m * f
                key = f"{ins.op}/kernel" if in_kernel else ins.op
                cost.flops_by_op[key] = cost.flops_by_op.get(key, 0.0) + m * f
            b = 0.0 if in_kernel else _instr_bytes(ins, comp.symbols, comps)
            if b:
                cost.bytes += m * b
                cost.bytes_by_op[ins.op] = cost.bytes_by_op.get(ins.op, 0.0) + m * b
            if ins.op in COLLECTIVES or (ins.op.endswith("-start") and ins.op[:-6] in COLLECTIVES):
                kind = ins.op[:-6] if ins.op.endswith("-start") else ins.op
                nbytes = _nbytes(ins.ret_shapes)
                gsize, groups = _parse_groups(ins.line)
                cross = False
                if groups is not None:
                    cross = bool((groups // chips_per_pod != groups[:, :1] // chips_per_pod).any())
                wire = _wire_bytes(kind, nbytes, gsize)
                key = f"{kind}/{'dcn' if cross else 'ici'}"
                agg = cost.collectives.setdefault(key, {"count": 0.0, "wire_bytes": 0.0})
                agg["count"] += m
                agg["wire_bytes"] += m * wire
                if cross:
                    cost.dcn_wire += m * wire
                else:
                    cost.ici_wire += m * wire
    return cost
