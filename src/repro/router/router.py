"""The replica router: CNA-disciplined admission over a fleet of replicas.

The serving scheduler carried the paper's discipline to one engine's
admission queue; this module carries it one hierarchy level up.  The mapping,
at fleet granularity:

  paper                      | router tier
  ---------------------------+------------------------------------------
  lock                       | the dispatch pipe (admissions are steered
                             | one at a time; steering a different replica
                             | than the last costs setup/transfer work)
  thread                     | a queued session
  NUMA socket of a thread    | the session's *home replica* — where the
                             | federation says its prefix is warm
  socket of the lock holder  | the most recently dispatched replica
  main/secondary queues      | the same two CNA queues, reused verbatim
                             | via ``CNAScheduler`` over a replica-level
                             | ``Topology`` (replicas can be grouped into
                             | cells/pods like sockets into pods)

Sessions homed on the granted replica are "local"; others wait exactly as
the paper's remote waiters do, with the same fairness threshold bounding
starvation.  On top of the discipline the router adds what a fleet needs and
a lock does not:

  * capacity gating — a session is only dispatched when some replica has
    headroom, and at most ``FleetController.cap(r)`` admissions are in
    flight per replica (the GCR loop at fleet granularity, fed from
    time-to-first-token);
  * federation-steered homes — ``FederatedPrefixIndex.route`` assigns each
    session's home from replica summaries at submit;
  * shed-before-stall — when the granted session's home replica is
    saturated, the dispatch sheds to the nearest replica (by the replica
    topology) with headroom instead of stalling the pipe, mirroring the
    placement layer's shed-before-spill;
  * priced KV shipping (``kv_ship=``) — a dispatch whose target lacks a
    prefix some other replica still holds prices ``min(re-prefill, ship)``
    over the fabric (``repro.router.kvship``) and moves the stored bundle
    when shipping wins, so a shed stops implying a full re-prefill.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.topology import Topology, flat, get_topology
from repro.obs import BoundedHistogram
from repro.serving.scheduler import CNAScheduler

from .federation import FederatedPrefixIndex
from .kvship import Fabric, ShipCostModel, ShipDecision
from .replica import FleetController


@dataclass
class Session:
    """One routed unit of work: a prompt plus decode budget.

    Times (``submit_t``/``dispatch_t``/``finish_t``) are router-clock ticks;
    ``matched_len``/``local_matched`` are token counts.  ``ship`` carries the
    priced KV-ship decision for this dispatch when the router ran one
    (either outcome — tests recompute the argmin from it), None when
    shipping is off or nothing was worth pricing."""

    sid: int
    prompt: tuple
    decode_len: int = 8
    submit_t: int = -1
    dispatch_t: int = -1
    finish_t: int = -1
    home: int | None = None       # federation-routed replica
    replica: int | None = None    # where it actually landed (after shedding)
    matched_len: int = 0          # federation's believed cached prefix (tokens)
    local_matched: int = 0        # target replica's actual cached prefix (tokens)
    ship: ShipDecision | None = None
    fast: bool = False            # dispatched via the fissile fast path

    @property
    def stall(self) -> int:
        """Admission stall: router ticks from submit to dispatch."""
        return self.dispatch_t - self.submit_t


@dataclass
class RouterStats:
    """Router-level counters beyond the scheduler's admission metrics."""

    dispatched: int = 0
    fast_dispatches: int = 0      # fissile fast path: headroom-home grants
                                  # that skipped candidates/shed/ship pricing
    sheds: int = 0
    syncs: int = 0
    reprefill_tokens: int = 0     # prompt tokens the target replica had to
    routed_tokens: int = 0        # recompute, vs all routed prompt tokens
    local_hits: int = 0           # dispatches whose target held >=1 token
    # bounded stall reservoir: list-compatible (append/len/index/iterate)
    # but capped, so a long-running router can't leak one entry per dispatch;
    # quantiles stay exact while under the cap (every bench stays under it)
    stalls: BoundedHistogram = field(default_factory=BoundedHistogram)
    # KV shipping (repro.router.kvship); tokens in tokens, cycles in router
    # ticks.  reprefill_avoided counts prompt tokens the target would have
    # recomputed had the shipped prefix not arrived first.
    ships: int = 0
    ship_declined: int = 0        # argmin chose re-prefill (price, not failure)
    ship_failed: int = 0          # argmin chose ship, but export/import refused
    shipped_tokens: int = 0
    ship_cycles: int = 0
    reprefill_avoided: int = 0
    # page-granular shipping (ShipCostModel.page_size > 0): per-source page
    # ranges moved by planned ships; a single-source ship counts 1 segment
    ship_segments: int = 0
    # speculative pre-dispatch transfers (``prefetch=``): hottest shippable
    # prefix of a near-capacity replica moved to its likely shed target ahead
    # of any dispatch — charged to the fabric pipe, never to a session
    prefetch_ships: int = 0
    prefetch_tokens: int = 0
    # fleet victim caching (``victim_cache=``): last-fleet-copy prefixes a
    # replica evicted, re-homed to a sibling over the fabric instead of
    # silently dropping the only copy
    victim_ships: int = 0
    victim_tokens: int = 0

    @property
    def hit_rate(self) -> float:
        return self.local_hits / max(1, self.dispatched)

    @property
    def reuse_fraction(self) -> float:
        """Fraction of routed prompt tokens already cached on the replica
        that served them — the fleet-level locality number."""
        return 1.0 - self.reprefill_tokens / max(1, self.routed_tokens)

    def register_into(self, registry, prefix: str = "router") -> None:
        """Expose this surface through a ``repro.obs.MetricsRegistry`` as
        thin live views — the dataclass stays the single source of truth."""
        registry.adopt(prefix, self, props=("hit_rate", "reuse_fraction"))


class ReplicaRouter:
    """Front N replicas as top-level locality domains.

    ``replicas`` implement the replica protocol (``repro.router.replica``):
    ``capacity``, ``occupancy``, ``has_capacity()``,
    ``admit(session, now) -> matched_len`` and ``summary(top_k, now)``;
    with ``kv_ship`` enabled they additionally need the shipping hooks
    ``peek_match`` / ``export_kv`` / ``import_kv``.

    Units: the router clock (``now``, ``Session.submit_t``/``dispatch_t``,
    every ``*_cycles`` stat) counts router ticks — the same unit the fleet
    simulator's ``FleetCostModel`` charges; ``matched_len`` /
    ``*_tokens`` count prompt tokens."""

    def __init__(
        self,
        replicas,
        *,
        topology: Topology | None = None,
        fairness_threshold: int = 0xFF,
        seed: int = 0xF1EE7,
        sync_every: int = 32,
        top_k: int = 8,
        max_age: int | None = None,
        controller: FleetController | None = None,
        kv_ship: "bool | ShipCostModel | None" = None,
        prefetch: bool = False,
        prefetch_margin: int = 1,
        victim_cache: bool = False,
        fissile: bool = False,
        tracer=None,  # repro.obs.Tracer | None (None => zero-cost off)
    ) -> None:
        self.replicas = list(replicas)
        n = len(self.replicas)
        if n < 1:
            raise ValueError("need at least one replica")
        topo = get_topology(topology) if topology is not None else flat(n, "replicas")
        if topo.n_domains != n:
            raise ValueError(
                f"topology {topo.name!r} has {topo.n_domains} domains "
                f"but {n} replicas were given"
            )
        self.topology = topo
        self.federation = FederatedPrefixIndex(
            n,
            occupancy=lambda: {r: self.replicas[r].occupancy for r in range(n)},
            max_age=max_age,
        )
        # fissile: the admission discipline runs behind the fast path
        # (repro.core.discipline.FissileDiscipline) and the router gates its
        # own pipeline bypass on scheduler.fast_ready() — see dispatch_one
        self._fissile = bool(fissile)
        self.scheduler = CNAScheduler(
            fairness_threshold=fairness_threshold, seed=seed, topology=topo,
            fissile=fissile, tracer=tracer,
        )
        # one tracer for router + scheduler (NULL_TRACER when off): session
        # root spans open here, the scheduler's queue_wait spans nest inside
        self.tracer = self.scheduler.tracer
        self.fleet = (
            controller
            if controller is not None
            else FleetController(
                n, initial=max(1, max(r.capacity for r in self.replicas))
            )
        )
        if self.fleet.n_replicas != n:
            raise ValueError(
                f"controller spans {self.fleet.n_replicas} replicas, fleet has {n}"
            )
        self.sync_every = sync_every
        self.top_k = top_k
        self.stats = RouterStats()
        self._last_target = 0  # where the dispatch pipe currently points
        # kv_ship: price shipping a remote replica's stored prefix KV to the
        # dispatch target against re-prefilling it there, and take the argmin
        # (repro.router.kvship).  True -> default ShipCostModel; a
        # ShipCostModel instance sets the pricing; None/False -> off (PR 4's
        # shed-before-stall behaviour, every shed re-prefills).
        if kv_ship is True:
            kv_ship = ShipCostModel()
        self.fabric = Fabric(topo, kv_ship) if kv_ship else None
        # prefetch ships: when a replica's occupancy is within
        # ``prefetch_margin`` admissions of its cap at sync time, its hottest
        # advertised prefix is speculatively shipped to the replica a shed
        # would pick — so when the shed actually happens, the prefix is
        # already resident.  Fabric-charged (reserve), session-free.
        self.prefetch = bool(prefetch)
        self.prefetch_margin = int(prefetch_margin)
        self._prefetched: set = set()
        # fleet victim caching: replicas that expose ``set_victim_hook``
        # report evicted prefix runs here; sync() re-homes the ones no other
        # replica still holds (last fleet copy) when the price is right.
        self.victim_cache = bool(victim_cache)
        if (self.prefetch or self.victim_cache) and self.fabric is None:
            raise ValueError(
                "prefetch/victim_cache move KV over the fabric — enable "
                "kv_ship (a ShipCostModel or True) to use them"
            )
        from collections import deque

        self._victims: "deque[tuple[int, tuple]]" = deque(maxlen=32)
        if self.victim_cache:
            for rid, rep in enumerate(self.replicas):
                hook = getattr(rep, "set_victim_hook", None)
                if hook is not None:
                    hook(lambda tokens, r=rid: self._victims.append((r, tuple(tokens))))

    # -- clock -----------------------------------------------------------------
    @property
    def now(self) -> int:
        return self.scheduler.now

    @property
    def metrics(self):
        """Admission-side metrics (locality/switches/fairness) — the same
        vocabulary every other driver of the discipline reports."""
        return self.scheduler.metrics

    def tick(self) -> None:
        """Advance the router clock one tick; summaries re-sync every
        ``sync_every`` ticks (0 disables periodic sync — call ``sync()``)."""
        self.scheduler.tick()
        if self.sync_every and self.now % self.sync_every == 0:
            self.sync()

    def advance(self, now: int) -> None:
        """Tick the clock forward to ``now`` (event-driven callers)."""
        while self.now < now:
            self.tick()

    # -- summaries -------------------------------------------------------------
    def sync(self) -> None:
        """Pull a fresh summary from every replica into the federation; with
        a fabric attached this is also where the two speculative movers run
        (victims first — a re-homed victim is then visible to prefetch)."""
        for rid, rep in enumerate(self.replicas):
            self.federation.apply(rep.summary(self.top_k, self.now))
        self.stats.syncs += 1
        if self.fabric is not None:
            if self.victim_cache:
                self._drain_victims()
            if self.prefetch:
                self._prefetch()

    # -- admission -------------------------------------------------------------
    def submit(self, session: Session, home: int | None = None) -> int:
        """Home ``session`` via the federation and queue it under the CNA
        discipline; returns the home replica.  An explicit ``home`` pins the
        session instead of routing it (scripted drivers — the cross-driver
        grant-order contract — steer the discipline with exact domains)."""
        if home is None:
            home, matched = self.federation.route(session.prompt, now=self.now)
        else:
            matched = 0
        session.home, session.matched_len = home, matched
        session.submit_t = self.now
        if self.tracer:
            self.tracer.begin(
                "session", session.sid, self.now, prompt_len=len(session.prompt)
            )
            self.tracer.span(
                "home_derivation", session.sid, self.now, self.now,
                home=home, matched=matched,
            )
        self.federation.note_steered(home)
        self.scheduler.submit(session, home)
        return home

    def __len__(self) -> int:
        return len(self.scheduler)

    def _has_headroom(self, r: int) -> bool:
        return self.replicas[r].has_capacity() and self.fleet.can_admit(r)

    def dispatch_one(self) -> tuple[Session, int, int] | None:
        """Grant the next session under the CNA discipline and steer it to a
        replica; returns ``(session, replica, steer_distance)`` or None when
        the queue is empty or no replica has headroom.  ``steer_distance``
        is the replica-topology distance from the previously steered replica
        (0 when the pipe stays on the same replica) — the cost drivers
        charge for re-pointing the dispatch pipe."""
        if not len(self.scheduler):
            return None
        if self._fissile:
            peek = self.scheduler.fast_peek()
            if peek is not None and self._has_headroom(peek[1]):
                # fissile fast path: the lone uncontended session goes to its
                # own home, which has headroom — no candidate scan, no pipe
                # repoint, no shed, no ship pricing, no federation lookup.
                # The grant itself is forced (one waiter), so everything
                # skipped is bitwise-invisible to the discipline; all *real*
                # accounting (admit, fleet in-flight, stats, stall) is booked
                # exactly as on the full pipeline below.
                return self._dispatch_fast()
        candidates = [r for r in range(len(self.replicas)) if self._has_headroom(r)]
        if not candidates:
            return None
        prev = self._last_target
        if not self._has_headroom(self.scheduler.current_domain):
            # The paper's "socket of the lock holder" is where the freed
            # resource lives: point the pipe at the nearest replica with
            # headroom *before* granting, so the discipline prefers sessions
            # homed where capacity actually is.  Without this, a saturated
            # fleet keeps granting sessions homed on the just-granted (full)
            # replica and sheds nearly every dispatch — a locality-destroying
            # feedback loop.
            self.scheduler.current_domain = min(
                candidates,
                key=lambda r: (self.topology.distance(prev, r),
                               self.fleet.inflight[r], r),
            )
        session = self.scheduler.next_request()
        if session is None:
            return None
        target = session.home
        if not self._has_headroom(target):
            # shed-before-stall: nearest replica (then least inflight) with
            # headroom takes the session rather than blocking the pipe
            target = min(
                candidates,
                key=lambda r: (self.topology.distance(session.home, r),
                               self.fleet.inflight[r], r),
            )
            self.stats.sheds += 1
            if self.tracer:
                self.tracer.span(
                    "shed", session.sid, self.now, self.now,
                    home=session.home, to=target,
                    distance=self.topology.distance(session.home, target),
                )
        dist = 0 if target == prev else self.topology.distance(prev, target)
        self._last_target = target
        session.replica = target
        session.dispatch_t = self.now
        if self.tracer:
            self.tracer.span(
                "dispatch", session.sid, self.now, self.now,
                replica=target, steer_distance=dist,
            )
        session.ship = self._maybe_ship(session, target)
        # admit first: if the replica rejects (raises), the fleet controller
        # must not be left with a phantom in-flight admission nobody will
        # ever note_finish
        session.local_matched = self.replicas[target].admit(session, self.now)
        self.fleet.note_admit(target)
        self.stats.dispatched += 1
        self.stats.routed_tokens += len(session.prompt)
        self.stats.reprefill_tokens += len(session.prompt) - session.local_matched
        if session.local_matched:
            self.stats.local_hits += 1
        self.stats.stalls.append(session.stall)
        return session, target, dist

    def _dispatch_fast(self) -> tuple[Session, int, int]:
        """The fissile bypass: grant the fast-slot session straight to its
        home replica.  Caller has already confirmed ``fast_peek()`` is live
        and the home has headroom.  ``session.ship`` stays None and no
        federation/fabric state is touched — the regression tests pin that a
        headroom-home dispatch books zero phantom pricing."""
        session = self.scheduler.next_request()
        target = session.home
        prev = self._last_target
        dist = 0 if target == prev else self.topology.distance(prev, target)
        self._last_target = target
        session.replica = target
        session.dispatch_t = self.now
        session.fast = True
        if self.tracer:
            self.tracer.span(
                "dispatch", session.sid, self.now, self.now,
                replica=target, steer_distance=dist, fast=True,
            )
        session.local_matched = self.replicas[target].admit(session, self.now)
        self.fleet.note_admit(target)
        self.stats.dispatched += 1
        self.stats.fast_dispatches += 1
        self.stats.routed_tokens += len(session.prompt)
        self.stats.reprefill_tokens += len(session.prompt) - session.local_matched
        if session.local_matched:
            self.stats.local_hits += 1
        self.stats.stalls.append(session.stall)
        return session, target, dist

    def _maybe_ship(self, session: Session, target: int) -> "ShipDecision | None":
        """Price moving a remote replica's stored prefix KV to ``target``
        before admitting ``session`` there; execute the transfer when it wins
        the argmin.  Returns the decision (either outcome) or None when
        shipping is off / nothing beyond the target's own holding exists.

        Discovery runs on the federation's advertised lengths (stale-able),
        but the price uses the source's *live* store (``peek_match``) — a
        summary that over-promises must not buy fabric time.  On a ship the
        source exports its stored bundle and the target imports it before
        ``admit`` runs, so the target's ordinary prefill-reuse path finds
        the prefix as if it had computed it locally."""
        if self.fabric is None or not len(session.prompt):
            return None
        prompt = session.prompt
        local = self.replicas[target].peek_match(prompt, self.now)
        if self.fabric.cm.page_size > 0:
            # page pricing on: plan disjoint page ranges over every live
            # holder instead of picking one source
            return self._ship_paged(session, target, prompt, local)
        # source selection: longest advertised holding first, then *nearest
        # to the target* — distance multiplies the priced bytes, so between
        # equal holders the far one can flip the argmin to re-prefill and
        # lose a profitable ship; source load is irrelevant (an export
        # copies references, it does not occupy the source)
        candidates = [
            (m, r)
            for r, m in self.federation.holders(prompt, now=self.now).items()
            if r != target and m > local
        ]
        if not candidates:
            return None
        src = min(
            candidates,
            key=lambda mr: (-mr[0], self.topology.distance(mr[1], target), mr[1]),
        )[1]
        actual = self.replicas[src].peek_match(prompt, self.now)
        if actual <= local:
            return None
        d = self.fabric.price(
            prompt_len=len(prompt),
            local_matched=local,
            src_matched=actual,
            src=src,
            dst=target,
            now=self.now,
        )
        if d.choice != "ship":
            self.stats.ship_declined += 1
            self._trace_ship(session, d)
            return d
        # from here the argmin chose ship; a refusal below is a *failure*
        # (ship_failed), not a price decline, and the dispatch falls back to
        # re-prefill with d.choice untouched (executed stays False) so the
        # recorded prices still audit against the recorded choice
        exported = self.replicas[src].export_kv(prompt)
        if exported is None:        # store churned between peek and export
            self.stats.ship_failed += 1
            self._trace_ship(session, d, failed=True)
            return d
        tokens, payload = exported
        # import before booking anything: a target that refuses the bundle
        # (no store, cache_len too small) must leave no fabric reservation
        # and no phantom ship counters behind — it just re-prefills.  The
        # bundle is embargoed until the projected transfer end, which equals
        # what reserve() will book (nothing else touches the fabric between).
        if not self.replicas[target].import_kv(
            tokens, payload, ready_t=self.fabric.projected_end(self.now, d)
        ):
            self.stats.ship_failed += 1
            self._trace_ship(session, d, failed=True)
            return d
        self.fabric.reserve(self.now, d)
        d.executed = True
        self._trace_ship(session, d)
        # NB: ship effects necessarily precede admit() (the import is what
        # admit's prefill reuse must see); the headroom check above is what
        # keeps admit from raising, so an exception here means a replica
        # broke the has_capacity contract — the dispatch is already lost.
        s = self.stats
        s.ships += 1
        s.ship_segments += 1
        s.shipped_tokens += len(tokens)
        s.ship_cycles += d.ship_cycles
        s.reprefill_avoided += len(tokens) - local
        return d

    def _ship_paged(self, session: Session, target: int, prompt, local: int) -> "ShipDecision | None":
        """Page-granular multi-source ship (``ShipCostModel.page_size > 0``):
        every live holder contributes the page ranges it is nearest for
        (``kvship.plan_ship``), the whole plan is priced against re-prefill,
        and on a win each ``ShipSegment`` is executed in token order with its
        own delivery embargo (cumulative — the fabric is one serialized pipe,
        so segment *i* lands only after everything before it).

        An export hands over the source's full reference bundle — references
        are free; the *price* and the booked ``shipped_tokens`` only charge
        the pages the target does not hold, which is the page table's
        accounting (a re-imported held page costs zero bytes)."""
        advertised = [
            r
            for r, m in self.federation.holders(prompt, now=self.now).items()
            if r != target and m > local
        ]
        if not advertised:
            return None
        # live-confirm every candidate: stale advertisements must not place
        # pages on a source that can no longer export them
        holders = {}
        for r in advertised:
            m = self.replicas[r].peek_match(prompt, self.now)
            if m > local:
                holders[r] = m
        if not holders:
            return None
        d = self.fabric.price_plan(
            prompt_len=len(prompt),
            local_matched=local,
            holders=holders,
            dst=target,
            now=self.now,
        )
        if d.choice != "ship":
            self.stats.ship_declined += 1
            self._trace_ship(session, d)
            return d
        # export every segment's source before importing anything: a single
        # churned store fails the whole plan cleanly (no partial landing, no
        # fabric reservation) and the dispatch re-prefills
        exports = []
        for seg in d.segments:
            ex = self.replicas[seg.src].export_kv(prompt)
            if ex is None or len(ex[0]) < seg.end_tok:
                self.stats.ship_failed += 1
                self._trace_ship(session, d, failed=True)
                return d
            exports.append(ex)
        ready = max(self.now, self.fabric.busy_until)
        for seg, (tokens, payload) in zip(d.segments, exports):
            ready += seg.cycles  # serialized pipe: embargoes accumulate
            if not self.replicas[target].import_kv(tokens, payload, ready_t=ready):
                self.stats.ship_failed += 1
                self._trace_ship(session, d, failed=True)
                return d
        # ready now equals projected_end(now, d): sum(seg.cycles) is
        # d.ship_cycles, so the last embargo and the reservation agree
        self.fabric.reserve(self.now, d)
        d.executed = True
        self._trace_ship(session, d)
        s = self.stats
        s.ships += 1
        s.ship_segments += len(d.segments)
        s.shipped_tokens += d.tokens_to_move
        s.ship_cycles += d.ship_cycles
        s.reprefill_avoided += d.src_matched - local
        return d

    def _prefetch(self) -> None:
        """Speculative pre-dispatch shipping: for each replica within
        ``prefetch_margin`` admissions of its effective cap, move its hottest
        advertised prefix to the replica a shed from it would pick — priced
        like any ship (a congested fabric or a cold prefix declines), booked
        on the fabric, and deduped so one hot prefix is not re-shipped every
        sync.  At most one transfer per hot replica per sync keeps the
        speculation from starving real (dispatch-time) ships of the pipe."""
        cm = self.fabric.cm
        n = len(self.replicas)
        for r, rep in enumerate(self.replicas):
            cap = min(rep.capacity, self.fleet.cap(r))
            if cap <= 0 or rep.occupancy + self.prefetch_margin < cap:
                continue
            targets = [t for t in range(n) if t != r and self._has_headroom(t)]
            if not targets:
                continue
            # same key a shed uses: nearest, then least in flight
            target = min(
                targets,
                key=lambda t: (self.topology.distance(r, t), self.fleet.inflight[t], t),
            )
            for tokens, _stamp in rep.summary(1, self.now).prefixes:
                tokens = tuple(tokens)
                key = (r, target, tokens)
                if key in self._prefetched or len(tokens) < cm.min_ship_tokens:
                    continue
                local = self.replicas[target].peek_match(tokens, self.now)
                actual = rep.peek_match(tokens, self.now)
                if actual <= local:
                    continue
                d = self.fabric.price(
                    prompt_len=len(tokens), local_matched=local,
                    src_matched=actual, src=r, dst=target, now=self.now,
                )
                if d.choice != "ship":
                    continue
                exported = rep.export_kv(tokens)
                if exported is None:
                    continue
                etok, payload = exported
                if not self.replicas[target].import_kv(
                    etok, payload, ready_t=self.fabric.projected_end(self.now, d)
                ):
                    continue
                self.fabric.reserve(self.now, d)
                d.executed = True
                self._prefetched.add(key)
                if len(self._prefetched) > 1024:  # bounded dedupe memory
                    self._prefetched.clear()
                self.stats.prefetch_ships += 1
                self.stats.prefetch_tokens += d.tokens_to_move
                break

    def _drain_victims(self) -> None:
        """Re-home evicted prefix runs that were the fleet's last copy.

        Replicas with a ``set_victim_hook`` report each evicted run; at sync
        the router keeps only the ones no *other* replica still advertises
        in full, picks the sibling a shed from the evictor would pick, and
        ships there when the transfer is cheaper than the re-prefill the
        fleet would otherwise pay on the prefix's next appearance.  Runs some
        sibling still holds — or that price out — are simply dropped, which
        is exactly what happened before this path existed."""
        cm = self.fabric.cm
        n = len(self.replicas)
        while self._victims:
            src, tokens = self._victims.popleft()
            if len(tokens) < cm.min_ship_tokens or n < 2:
                continue
            held_elsewhere = any(
                r != src and m >= len(tokens)
                for r, m in self.federation.holders(tokens, now=self.now).items()
            )
            if held_elsewhere:
                continue
            target = min(
                (t for t in range(n) if t != src),
                key=lambda t: (self.topology.distance(src, t), self.fleet.inflight[t], t),
            )
            local = self.replicas[target].peek_match(tokens, self.now)
            if local >= len(tokens):
                continue
            d = self.fabric.price(
                prompt_len=len(tokens), local_matched=local,
                src_matched=len(tokens), src=src, dst=target, now=self.now,
            )
            if d.choice != "ship":
                continue
            # the evicting replica no longer holds the bytes — the hook fired
            # at eviction, so the run itself is the staged payload (the sim's
            # import derives KV from the token run; an engine without the
            # hook never reaches this path)
            if not self.replicas[target].import_kv(
                tokens, None, ready_t=self.fabric.projected_end(self.now, d)
            ):
                continue
            self.fabric.reserve(self.now, d)
            d.executed = True
            self.stats.victim_ships += 1
            self.stats.victim_tokens += d.tokens_to_move

    def _trace_ship(self, session: Session, d: ShipDecision, *, failed: bool = False) -> None:
        """Record one priced ship decision as a span (either outcome): the
        price itself as an instant child, and — when the transfer actually
        ran — ``ship.wait`` (fabric backlog) and ``ship.transfer`` (the
        reserved pipe interval, ending at ``fabric_end``) as child spans."""
        if not self.tracer:
            return
        now = self.now
        end = d.fabric_end if d.executed else now
        sp = self.tracer.span(
            "ship", session.sid, now, end,
            src=d.src, dst=d.dst, distance=d.distance, choice=d.choice,
            executed=d.executed, failed=failed,
        )
        self.tracer.span(
            "ship.price", session.sid, now, now, parent=sp,
            ship_total=d.ship_total, reprefill_cycles=d.reprefill_cycles,
            wait_cycles=d.wait_cycles, ship_cycles=d.ship_cycles,
            suffix_cycles=d.suffix_cycles, src_matched=d.src_matched,
            local_matched=d.local_matched,
        )
        if d.executed:
            start = d.fabric_end - d.ship_cycles
            self.tracer.span(
                "ship.wait", session.sid, now, start, parent=sp,
                cycles=start - now,
            )
            self.tracer.span(
                "ship.transfer", session.sid, start, d.fabric_end, parent=sp,
                cycles=d.ship_cycles, tokens=d.src_matched,
            )

    def dispatch(self) -> list[tuple[Session, int, int]]:
        """Drain dispatches until out of queue or headroom."""
        out = []
        while (d := self.dispatch_one()) is not None:
            out.append(d)
        return out

    # -- completion ------------------------------------------------------------
    def complete(self, session: Session, *, ttft: int | None = None) -> None:
        """Report a session finished on its replica; ``ttft`` (submit ->
        first token, in router-clock units) feeds the fleet controller's
        GCR loop."""
        session.finish_t = self.now
        if self.tracer:
            root = self.tracer.open_span(session.sid, "session")
            self.tracer.event(root, "retire", self.now, replica=session.replica)
            self.tracer.end(root, self.now)
        self.fleet.note_finish(session.replica)
        if ttft is not None:
            self.fleet.observe_ttft(session.replica, ttft)
