from .ops import linear_scan  # noqa: F401
