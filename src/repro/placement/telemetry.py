"""Per-domain placement/migration/handover counters.

One telemetry object rides with a placement-aware ``SlotCache`` and is
surfaced through ``SchedulerMetrics.placement`` so serving benchmarks can put
locality, spill behaviour, and migration spend next to the admission-side
counters they already report.  Everything is a plain counter — no wall clock,
no sampling — so runs stay deterministic and comparable.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class PlacementTelemetry:
    n_domains: int = 1
    placements: int = 0
    local_placements: int = 0
    sibling_spills: int = 0        # distance 1: same group, different domain
    cross_spills: int = 0          # distance 2: crossed a group boundary
    migration_cycles: int = 0
    releases: int = 0
    handover_samples: int = 0
    handover_cycles: int = 0
    # controller-coupled shedding: admissions re-homed to a sibling because
    # the derived home was saturated (shed-before-spill)
    sheds: int = 0
    # prefix-index coupling: how often homes were derived (vs caller-given)
    # and what fraction of prompt tokens the index had cached
    derived_homes: int = 0
    prefix_hit_tokens: int = 0
    prefix_lookup_tokens: int = 0
    per_domain_placements: dict = field(default_factory=dict)
    per_domain_occupancy: dict = field(default_factory=dict)  # live claims
    peak_occupancy: dict = field(default_factory=dict)
    # releases for domains with no live recorded placement (double release or
    # a release routed to the wrong domain); counted, never applied — the
    # derived-home tie-breaks read per_domain_occupancy and a negative entry
    # would bias them toward a domain that was never occupied
    unmatched_releases: int = 0

    @property
    def locality(self) -> float:
        return self.local_placements / max(1, self.placements)

    @property
    def spills(self) -> int:
        return self.sibling_spills + self.cross_spills

    @property
    def mean_handover(self) -> float:
        return self.handover_cycles / max(1, self.handover_samples)

    def record_placement(self, placement) -> None:
        self.placements += 1
        dom = placement.slot_domain
        self.per_domain_placements[dom] = self.per_domain_placements.get(dom, 0) + 1
        occ = self.per_domain_occupancy.get(dom, 0) + 1
        self.per_domain_occupancy[dom] = occ
        self.peak_occupancy[dom] = max(self.peak_occupancy.get(dom, 0), occ)
        if placement.distance == 0:
            self.local_placements += 1
        elif placement.distance == 1:
            self.sibling_spills += 1
        else:
            self.cross_spills += 1
        self.migration_cycles += placement.migration_cycles

    def record_release(self, slot_domain: int) -> None:
        self.releases += 1
        occ = self.per_domain_occupancy.get(slot_domain, 0)
        if occ <= 0:
            self.unmatched_releases += 1
            return
        self.per_domain_occupancy[slot_domain] = occ - 1

    def record_shed(self) -> None:
        self.sheds += 1

    def record_handover(self, latency) -> None:
        self.handover_samples += 1
        self.handover_cycles += int(latency)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of derived-home prompt tokens the index had cached."""
        return self.prefix_hit_tokens / max(1, self.prefix_lookup_tokens)

    def record_derived_home(self, matched_len: int, prompt_len: int) -> None:
        self.derived_homes += 1
        self.prefix_hit_tokens += matched_len
        self.prefix_lookup_tokens += prompt_len

    def fairness_factor(self) -> float:
        """Top-half share of placements across domains (same convention as
        ``SimResult.fairness_factor``; 1/n_domains-ish = balanced)."""
        counts = sorted(self.per_domain_placements.values(), reverse=True)
        tot = sum(counts)
        if not counts or tot == 0:
            return 1.0
        half = max(1, len(counts) // 2)
        return sum(counts[:half]) / tot

    def register_into(self, registry, prefix: str = "placement") -> None:
        """Expose this surface through a ``repro.obs.MetricsRegistry`` as
        thin live views (no counter moves; the registry reads through)."""
        registry.adopt(
            prefix, self,
            props=("locality", "spills", "mean_handover", "prefix_hit_rate"),
        )
        registry.gauge(f"{prefix}_fairness_factor", fn=self.fairness_factor)
