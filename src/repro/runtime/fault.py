"""Fault detection: heartbeats, straggler detection, failure injection.

At real pod scale these hooks sit in the per-host launcher agent; the control
plane is identical on the CPU container (time is injectable so tests are
deterministic).  Policies implemented:

  * **HeartbeatMonitor** — declares a worker dead after ``timeout`` without a
    beat; the training loop turns that into a checkpoint-restore + re-mesh
    (see ``ElasticTrainer``).
  * **StragglerDetector** — EWMA of per-worker step durations; a worker
    slower than ``factor`` x the fleet median is flagged.  Mitigation is the
    CNA move: a flagged worker's *data shard* is re-assigned to its pod peers
    (work moves within the locality domain; the straggler rejoins when its
    EWMA recovers — the secondary-queue readmission).
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field


class WorkerFailure(RuntimeError):
    def __init__(self, worker: int, reason: str):
        super().__init__(f"worker {worker}: {reason}")
        self.worker = worker
        self.reason = reason


@dataclass
class HeartbeatMonitor:
    n_workers: int
    timeout: float = 30.0
    clock: callable = time.monotonic
    last: dict = field(default_factory=dict)

    def __post_init__(self):
        now = self.clock()
        self.last = {w: now for w in range(self.n_workers)}

    def beat(self, worker: int):
        self.last[worker] = self.clock()

    def dead_workers(self) -> list[int]:
        now = self.clock()
        return [w for w, t in self.last.items() if now - t > self.timeout]

    def check(self):
        dead = self.dead_workers()
        if dead:
            raise WorkerFailure(dead[0], f"no heartbeat for {self.timeout}s")


@dataclass
class StragglerDetector:
    n_workers: int
    factor: float = 2.0
    alpha: float = 0.3          # EWMA smoothing
    min_samples: int = 3
    ewma: dict = field(default_factory=dict)
    count: dict = field(default_factory=dict)

    def record(self, worker: int, duration: float):
        prev = self.ewma.get(worker)
        self.ewma[worker] = duration if prev is None else (1 - self.alpha) * prev + self.alpha * duration
        self.count[worker] = self.count.get(worker, 0) + 1

    def stragglers(self) -> list[int]:
        ready = [w for w in self.ewma if self.count[w] >= self.min_samples]
        if len(ready) < 2:
            return []
        med = statistics.median(self.ewma[w] for w in ready)
        return [w for w in ready if self.ewma[w] > self.factor * med]

    def reassignment(self, n_hosts: int) -> dict[int, list[int]]:
        """Data-shard plan: straggler rows handed to same-pod peers first.

        Returns {host: [extra shard ids]} — the CNA locality rule: prefer a
        donor inside the straggler's pod (same 'socket'), fall back to any
        host (the fairness flush) if the whole pod is flagged."""
        lag = set(self.stragglers())
        healthy = [h for h in range(n_hosts) if h not in lag]
        if not healthy or not lag:
            return {}
        plan: dict[int, list[int]] = {h: [] for h in healthy}
        for s in sorted(lag):
            pod_peers = [h for h in healthy if h // max(1, n_hosts // 2) == s // max(1, n_hosts // 2)]
            donor = min(pod_peers or healthy, key=lambda h: len(plan[h]))
            plan[donor].append(s)
        return {h: v for h, v in plan.items() if v}
