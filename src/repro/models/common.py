"""Shared layers: norms, rotary embeddings, initializers, param declaration."""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .sharding import shard

Param = dict  # params are plain pytrees: dict leaves = jnp arrays
# logical-axes trees mirror the param tree with tuples of axis names.


class ParamBuilder:
    """Collects (shape, logical_axes, init) declarations, then materialises
    either real params (init) or abstract params (eval_shape for the dry-run).
    """

    def __init__(self, dtype=jnp.bfloat16):
        self.dtype = dtype
        self.shapes: dict = {}
        self.logical: dict = {}
        self.inits: dict = {}

    def declare(self, tree_path: str, shape, logical, init="normal", scale=None):
        assert tree_path not in self.shapes, tree_path
        self.shapes[tree_path] = tuple(shape)
        self.logical[tree_path] = tuple(logical)
        self.inits[tree_path] = (init, scale)

    def _init_leaf(self, key, path):
        shape = self.shapes[path]
        kind, scale = self.inits[path]
        if kind == "zeros":
            return jnp.zeros(shape, self.dtype)
        if kind == "ones":
            return jnp.ones(shape, self.dtype)
        if kind == "normal":
            s = scale if scale is not None else (1.0 / math.sqrt(shape[-2] if len(shape) >= 2 else shape[-1]))
            return (jax.random.normal(key, shape, jnp.float32) * s).astype(self.dtype)
        if kind == "uniform":
            s = scale or 1.0
            return jax.random.uniform(key, shape, jnp.float32, -s, s).astype(self.dtype)
        if kind == "rglru_a":
            # Λ such that a = sigmoid(Λ) in [0.9, 0.999]
            u = jax.random.uniform(key, shape, jnp.float32, 0.9, 0.999)
            return jnp.log(u / (1 - u)).astype(jnp.float32)
        if kind == "ssm_a":
            u = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(jnp.float32)
        if kind == "dt_bias":
            u = jax.random.uniform(key, shape, jnp.float32)
            dt = jnp.exp(u * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
            return (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32)
        raise ValueError(kind)

    @staticmethod
    def _nest(flat: dict) -> dict:
        out: dict = {}
        for path, v in flat.items():
            node = out
            parts = path.split("/")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = v
        return out

    def init(self, key) -> dict:
        keys = jax.random.split(key, len(self.shapes))
        flat = {p: self._init_leaf(k, p) for k, p in zip(keys, sorted(self.shapes))}
        return self._nest(flat)

    def abstract(self) -> dict:
        flat = {
            p: jax.ShapeDtypeStruct(
                self.shapes[p],
                jnp.float32 if self.inits[p][0] in ("rglru_a", "ssm_a", "dt_bias") else self.dtype,
            )
            for p in self.shapes
        }
        return self._nest(flat)

    def logical_tree(self) -> dict:
        return self._nest(dict(self.logical))


@jax.custom_vjp
def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    """Embedding lookup whose backward is partitioner-friendly.

    The plain gather's backward is a scatter-add producing a *full unsharded
    fp32* table gradient on every chip (17.6 GiB + an equal-sized all-reduce
    for nemotron's 256k x 18432 table).  The custom backward computes the
    gradient as a one-hot contraction — a dot the partitioner shards along
    (vocab->model, fsdp->data) like the table itself."""
    return table[tokens]


def _embed_fwd(table, tokens):
    # dtype token: residuals must be jax types, not dtypes
    return table[tokens], (tokens, jnp.zeros((0, table.shape[0]), table.dtype))


def _embed_bwd(res, dy):
    tokens, token_arr = res
    vocab = token_arr.shape[1]
    onehot = jax.nn.one_hot(tokens, vocab, dtype=dy.dtype)
    dtable = jnp.einsum("...v,...d->vd", onehot, dy)
    dtable = shard(dtable, "vocab", "fsdp")
    return dtable.astype(token_arr.dtype), None


embed_lookup.defvjp(_embed_fwd, _embed_bwd)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array | None = None, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    x = x * (1.0 + scale.astype(jnp.float32))
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dt)


def norm(kind: str, x, scale, bias=None):
    if kind == "rmsnorm":
        return rmsnorm(x, scale)
    return layernorm(x, scale, bias)


def rope_angles(positions: jax.Array, head_dim: int, theta: float):
    """positions: (...,) int32 -> cos/sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (B?, S, hd//2) broadcastable."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]  # (B, S, 1, hd//2)
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dt)


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab: int, mask=None):
    """logits (..., Vpad) fp32-safe CE; labels int32; mask optional weights.
    Vocab-parallel: the max/sum reductions over the sharded vocab axis lower
    to psums over 'model' (Megatron-style parallel CE)."""
    logits = logits.astype(jnp.float32)
    logits = shard(logits, "batch", None, "vocab")
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(shifted, labels[..., None].astype(jnp.int32), axis=-1)[..., 0] + m[..., 0]
    nll = lse - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
