"""Trace-style workload engine: deterministic multi-tenant request schedules
(per-tenant Zipf prefix mixes, phase-shifted diurnal waves, conversation
follow-ups, regional skew) that replay identically to every routing arm.
See ``repro.workload.trace`` for the model; ``repro.region`` consumes the
traces."""

from .trace import (  # noqa: F401
    DiurnalWave,
    TenantProfile,
    Trace,
    TraceGenerator,
    TraceRequest,
    output_tokens,
    prefix_tokens,
    uniform_tenants,
    with_flood,
)

__all__ = [
    "DiurnalWave",
    "TenantProfile",
    "Trace",
    "TraceGenerator",
    "TraceRequest",
    "output_tokens",
    "prefix_tokens",
    "uniform_tenants",
    "with_flood",
]
