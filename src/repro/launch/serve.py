"""Serving driver: continuous batching with the CNA admission scheduler.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --requests 32 --domains 2 --scheduler cna

Prints per-policy throughput/locality/fairness so the CNA-vs-FIFO trade-off
is visible on a real (reduced-config) model.  ``--derived-homes`` drops the
caller-supplied domain oracle: requests submit with ``domain=None`` and the
engine derives homes from the prefix index over a NUMA-placed slot cache
(pod topology over ``--domains``), with shared prompt prefixes so the index
has something to match.  ``--replicas N`` runs the router tier instead: N
engine replicas behind ``repro.router.ReplicaRouter`` — federated prefix
summaries steer each session to the replica already holding its prefix, and
per-engine ``PrefixKVStore`` reuse turns the steering into skipped prefill
positions (printed per replica).  KV shipping is on by default in the fleet
demo (``--no-kv-ship`` reverts to shed-and-re-prefill): every priced
ship-vs-reprefill decision prints one ``[ship?]`` line — the runnable
companion to docs/architecture.md's router walkthrough.  ``--fissile`` turns
on the contention-adaptive fast path (uncontended sessions dispatch home in
one step; the ``[router]`` line reports ``fast_dispatches``).

``--arrivals RATE`` switches the driver to a continuous Poisson arrival
process (RATE requests per engine tick, mixed prompt lengths) against the
bucketed/packed/AOT-warmed batched engine and prints wall-clock tokens/sec +
TTFT p50/p99 — the live demo of ``repro.serving.batching``.  Add
``--no-batching`` to feel the difference on the per-request engine.

``--regions N`` runs the region tier instead (jax-free): a deterministic
diurnal multi-tenant trace (``repro.workload``) replayed through
``repro.region`` — N regions of simulated fleets behind the federated
``RegionRouter``, against a region-oblivious least-loaded control — and
prints locality, admission-stall percentiles, and per-tenant stall
summaries.  ``--tenant-caps K`` adds the (tenant x fleet) fairness governor.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_reduced_config
from repro.models.registry import build_model
from repro.serving.engine import DecodeEngine, Request
from repro.serving.scheduler import CNAScheduler, FIFOScheduler


def _mk_obs(args):
    """--trace/--metrics: one Tracer + MetricsRegistry per driver run (both
    None-off, so the default path stays zero-cost)."""
    from repro.obs import MetricsRegistry, Tracer

    tracer = Tracer() if args.trace else None
    registry = MetricsRegistry() if args.metrics else None
    return tracer, registry


def _emit_obs(args, tracer, registry, trace_path=None):
    from repro.obs import flame, render_prometheus, to_jsonl

    if tracer is not None:
        path = trace_path or args.trace
        n = to_jsonl(tracer, path)
        print(f"[trace] wrote {n} spans to {path}")
        traces = tracer.traces()
        if traces:
            deepest = max(traces, key=lambda t: len(tracer.for_trace(t)))
            print(flame(tracer, deepest))
    if registry is not None:
        print("[metrics]")
        print(render_prometheus(registry))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--domains", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--scheduler", default="both", choices=["cna", "fifo", "both"])
    ap.add_argument("--fairness-threshold", type=lambda x: int(x, 0), default=0xF)
    ap.add_argument("--switch-cost", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--derived-homes", action="store_true",
                    help="submit domain=None and derive homes from the prefix "
                         "index over a placement-aware slot cache")
    ap.add_argument("--replicas", type=int, default=1,
                    help="front N engine replicas with the federated router "
                         "tier (repro.router) instead of a single engine")
    ap.add_argument("--sync-every", type=int, default=4,
                    help="router ticks between federation summary syncs")
    ap.add_argument("--no-kv-ship", action="store_true",
                    help="disable priced prefix-KV shipping in the fleet "
                         "demo (PR 4's shed-and-re-prefill behaviour)")
    ap.add_argument("--fissile", action="store_true",
                    help="enable the contention-adaptive fast path in the "
                         "fleet demo: uncontended arrivals dispatch to their "
                         "home replica in one step, contention inflates back "
                         "to full CNA admission")
    ap.add_argument("--regions", type=int, default=0, metavar="N",
                    help="run the region tier demo: a diurnal multi-tenant "
                         "trace over N regions of fleets (jax-free)")
    ap.add_argument("--tenants", type=int, default=4,
                    help="with --regions: tenant profiles in the trace mix")
    ap.add_argument("--horizon", type=int, default=4096,
                    help="with --regions: trace horizon in ticks")
    ap.add_argument("--tenant-caps", type=int, default=None, metavar="K",
                    help="with --regions: cap each (tenant x fleet) pair at "
                         "K in-flight sessions (the fairness governor)")
    ap.add_argument("--arrivals", type=float, default=None, metavar="RATE",
                    help="drive a continuous Poisson arrival process at RATE "
                         "requests/tick (mixed prompt lengths) and print "
                         "tokens/sec + TTFT p50/p99")
    ap.add_argument("--no-batching", action="store_true",
                    help="with --arrivals: use the per-request prefill engine "
                         "instead of the bucketed/packed batched one")
    ap.add_argument("--paged", action="store_true",
                    help="back the engine's KV with the refcounted page "
                         "table (copy-on-write prefix sharing); dense-"
                         "attention stacks only")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page with --paged (must divide "
                         "--cache-len)")
    ap.add_argument("--trace", metavar="OUT.jsonl", default=None,
                    help="record causal request spans (repro.obs.Tracer), "
                         "dump them as JSONL to OUT.jsonl and print one "
                         "ASCII flame summary for the deepest trace")
    ap.add_argument("--metrics", action="store_true",
                    help="register every stat surface into the unified "
                         "repro.obs.MetricsRegistry and print its "
                         "Prometheus-style rendering at exit")
    args = ap.parse_args(argv)

    if args.regions > 0:
        return serve_region(args)
    if args.arrivals is not None:
        return serve_arrivals(args)
    if args.replicas > 1:
        return serve_fleet(args)

    arch = args.arch.replace("-", "_").replace(".", "")
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    if args.derived_homes:
        # a small pool of shared prefixes (Zipf-free uniform draw keeps the
        # driver simple) + unique tails: the index has prefixes to re-match
        n_shared = max(2, args.prompt_len // 2)
        shared = [rng.integers(0, cfg.vocab, n_shared).astype(np.int32)
                  for _ in range(max(2, args.domains))]
        base = [
            Request(rid=i,
                    prompt=np.concatenate([
                        shared[int(rng.integers(0, len(shared)))],
                        rng.integers(0, cfg.vocab, args.prompt_len - n_shared).astype(np.int32),
                    ]),
                    max_new=args.max_new, domain=None)
            for i in range(args.requests)
        ]
    else:
        base = [
            Request(rid=i, prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
                    max_new=args.max_new, domain=int(rng.integers(0, args.domains)))
            for i in range(args.requests)
        ]

    from repro.core.topology import pod

    def engine_kwargs(mk_sched):
        kw = dict(paging=args.paged, page_size=args.page_size) if args.paged else {}
        if not args.derived_homes:
            return dict(scheduler=mk_sched(), **kw)
        return dict(scheduler=mk_sched(topology=pod(1, args.domains)),
                    placement="nearest_spill", prefix_index=True, **kw)

    policies = {"cna": lambda **kw: CNAScheduler(fairness_threshold=args.fairness_threshold, **kw),
                "fifo": lambda **kw: FIFOScheduler(**kw)}
    run = [args.scheduler] if args.scheduler != "both" else ["cna", "fifo"]
    registry = None
    if args.metrics:
        from repro.obs import MetricsRegistry

        registry = MetricsRegistry()
    for name in run:
        # a fresh tracer per policy: the two arms reuse request ids, and one
        # JSONL per arm keeps the traces causally clean
        tracer = None
        if args.trace:
            from repro.obs import Tracer

            tracer = Tracer()
        reqs = [Request(r.rid, r.prompt, r.max_new, r.domain) for r in base]
        eng = DecodeEngine(model, params, n_slots=args.slots, cache_len=args.cache_len,
                           domain_switch_cost=args.switch_cost, tracer=tracer,
                           **engine_kwargs(policies[name]))
        t0 = time.time()
        if args.derived_homes:
            mid = len(reqs) // 2
            eng.run(reqs[:mid])  # first wave warms the index from placements
            eng.run(reqs[mid:])  # second wave homes by matched prefixes
        else:
            eng.run(reqs)
        wall = time.time() - t0
        m = eng.scheduler.metrics
        tokens = sum(len(r.out) for r in reqs)
        extra = ""
        if eng.prefix_index is not None:
            tel = eng.slots.telemetry
            extra = (f" derived={tel.derived_homes} "
                     f"prefix_hit_rate={tel.prefix_hit_rate:.2f} "
                     f"placement_locality={tel.locality:.2f}")
        print(f"[{name}] requests={len(reqs)} tokens={tokens} sim_time={eng.sim_time} "
              f"locality={m.locality:.2f} switches={m.domain_switches} "
              f"fairness={m.fairness_factor():.3f} wall={wall:.1f}s "
              f"tok_per_simtick={tokens / max(1, eng.sim_time):.2f}{extra}")
        if args.paged:
            # the page-table gauges, one line — the same numbers --metrics
            # exports as {name}_engine_pages_* through the registry
            pt = eng.slots.table
            print(f"  [pages] total={pt.pages_total} shared={pt.pages_shared} "
                  f"free={pt.pages_free} kv_bytes_held={pt.kv_bytes_held} "
                  f"cow_copies={pt.cow_copies}")
        if registry is not None:
            eng.register_metrics(registry, prefix=f"{name}_engine")
        if tracer is not None:
            path = args.trace if len(run) == 1 else f"{name}.{args.trace}"
            _emit_obs(args, tracer, None, trace_path=path)
    _emit_obs(args, None, registry)
    return 0


def serve_region(args) -> int:
    """The --regions demo: the diurnal multi-tenant trace through the region
    tier (fleets-of-fleets, jax-free), paired against a region-oblivious
    least-loaded control on the identical schedule."""
    from repro.region import simulate_region
    from repro.workload import DiurnalWave, TraceGenerator, uniform_tenants

    tracer, registry = _mk_obs(args)
    gen = TraceGenerator(
        n_regions=args.regions,
        tenants=uniform_tenants(args.tenants, args.regions,
                                followup_p=0.4, suffix_len=24),
        seed=args.seed,
        wave=DiurnalWave(period=max(256, args.horizon // 3), amplitude=0.8),
        base_rate=0.03,
    )
    trace = gen.generate(horizon=args.horizon)
    print(f"[trace] {len(trace)} requests, {args.regions} regions, "
          f"{args.tenants} tenants, "
          f"{sum(1 for r in trace.requests if r.turn > 0)} follow-up turns")
    t0 = time.time()
    results = {}
    for arm in ("region", "least_loaded"):
        results[arm] = simulate_region(
            arm, trace, seed=args.seed,
            tenant_caps=args.tenant_caps if arm == "region" else None,
            tracer=tracer if arm == "region" else None,
            registry=registry if arm == "region" else None,
        )
    wall = time.time() - t0
    for arm, r in results.items():
        print(f"[{arm}] served={r.served} rejected={r.rejected} "
              f"locality={r.reuse_fraction:.2f} "
              f"reprefill_tokens={r.reprefill_tokens}/{r.routed_tokens} "
              f"stall_p50={r.admission_stall_p50:.0f} "
              f"stall_p99={r.admission_stall_p99:.0f} sheds={r.sheds} "
              f"deposits={r.deposits} per_fleet={r.per_fleet_served}")
    reg = results["region"]
    print("  [tenants]")
    for tenant, summary in sorted(reg.tenant_stalls.summary().items()):
        print(f"    tenant {tenant}: stall p50={summary['p50']:.0f} "
              f"p99={summary['p99']:.0f} n={summary['count']}")
    if reg.tenant_parked or reg.tenant_rejected:
        print(f"  [governor] parked={reg.tenant_parked} "
              f"unparked={reg.tenant_unparked} rejected={reg.tenant_rejected}")
    print(f"  (wall={wall:.1f}s)")
    _emit_obs(args, tracer, registry)
    return 0


def serve_arrivals(args) -> int:
    """The --arrivals demo: a continuous Poisson arrival process against the
    batched (bucketed/packed/AOT-warmed) engine, wall-clock measured.  TTFT
    is submit-to-first-token including queueing — what a serving SLO sees."""
    arch = args.arch.replace("-", "_").replace(".", "")
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    lens = rng.integers(2, args.cache_len - 1, args.requests)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, int(l)).astype(np.int32),
                max_new=args.max_new, domain=int(rng.integers(0, args.domains)))
        for i, l in enumerate(lens)
    ]
    arrivals = np.floor(
        np.cumsum(rng.exponential(1.0 / args.arrivals, args.requests))
    ).astype(int).tolist()

    batched = not args.no_batching
    tracer, registry = _mk_obs(args)
    t_build = time.time()
    eng = DecodeEngine(model, params, n_slots=args.slots, cache_len=args.cache_len,
                       scheduler=CNAScheduler(fairness_threshold=args.fairness_threshold),
                       domain_switch_cost=args.switch_cost, batching=batched,
                       tracer=tracer)
    warm = time.time() - t_build  # AOT bucket traces compile in here, not below

    submit_at, ttft = {}, {}
    i = tick = 0
    t0 = time.time()
    while i < len(reqs) or len(eng.scheduler) or eng.active_req:
        while i < len(reqs) and arrivals[i] <= tick:
            submit_at[reqs[i].rid] = time.time()
            eng.submit(reqs[i])
            i += 1
        eng.step()
        for r in reqs:
            if r.rid not in ttft and r.out:
                ttft[r.rid] = time.time() - submit_at[r.rid]
        tick += 1
    wall = time.time() - t0

    tokens = sum(len(r.out) for r in reqs)
    waits = np.array([ttft[r.rid] for r in reqs])
    cc = eng.compile_counts
    traces = cc["prefill"] + cc.get("packed_prefill", 0) + cc.get("cont_prefill", 0)
    mode = "batched" if batched else "per-request"
    print(f"[arrivals {mode}] rate={args.arrivals}/tick requests={len(reqs)} "
          f"tokens={tokens} tokens_per_sec={tokens / wall:.1f} "
          f"ttft_p50={np.percentile(waits, 50) * 1e3:.0f}ms "
          f"ttft_p99={np.percentile(waits, 99) * 1e3:.0f}ms "
          f"prefill_traces={traces} decode_traces={cc['decode']} "
          f"warmup={warm:.1f}s wall={wall:.1f}s")
    if registry is not None:
        eng.register_metrics(registry)
    _emit_obs(args, tracer, registry)
    return 0


def serve_fleet(args) -> int:
    """The --replicas demo: N reduced-config engines behind the router."""
    from repro.core.topology import pod
    from repro.router import EngineReplica, ReplicaRouter, Session
    from repro.serving.scheduler import CNAScheduler

    arch = args.arch.replace("-", "_").replace(".", "")
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    n_shared = max(2, args.prompt_len // 2)
    shared = [rng.integers(0, cfg.vocab, n_shared).astype(np.int32)
              for _ in range(max(2, args.replicas))]
    sessions = [
        Session(sid=i,
                prompt=tuple(int(t) for t in np.concatenate([
                    shared[int(rng.integers(0, len(shared)))],
                    rng.integers(0, cfg.vocab, args.prompt_len - n_shared).astype(np.int32),
                ])),
                decode_len=args.max_new)
        for i in range(args.requests)
    ]
    tracer, registry = _mk_obs(args)
    replicas = [
        EngineReplica(r, DecodeEngine(
            model, params, n_slots=args.slots, cache_len=args.cache_len,
            scheduler=CNAScheduler(fairness_threshold=args.fairness_threshold,
                                   topology=pod(1, args.domains)),
            placement="nearest_spill", prefix_index=True, prefix_kv=True,
            paging=args.paged, page_size=args.page_size,
            domain_switch_cost=args.switch_cost, tracer=tracer,
        ))
        for r in range(args.replicas)
    ]
    # the shared tracer nests each engine's "request" span under the router's
    # "session" span (same trace key), giving the one-trace-every-level view
    router = ReplicaRouter(replicas, sync_every=args.sync_every,
                           kv_ship=not args.no_kv_ship,
                           fissile=args.fissile, tracer=tracer)

    t0 = time.time()
    i = done = 0
    while done < len(sessions):
        router.tick()
        if i < len(sessions):
            router.submit(sessions[i])
            i += 1
        for session, target, _dist in router.dispatch():
            d = session.ship
            if d is not None:
                # one line per priced decision, the docs walkthrough's
                # runnable companion: what the argmin saw and what happened
                outcome = d.choice
                if d.choice == "ship" and not d.executed:
                    outcome = "ship (refused -> reprefill)"
                print(f"  [ship?] sid={session.sid} home={session.home} -> "
                      f"replica {target}: src={d.src} holds {d.src_matched} "
                      f"tok (target {d.local_matched}); "
                      f"ship={d.ship_total}cy vs reprefill="
                      f"{d.reprefill_cycles}cy -> {outcome}")
        for rep in replicas:
            for session, ttft in rep.step():
                router.complete(session, ttft=ttft)
                done += 1
    wall = time.time() - t0

    s = router.stats
    print(f"[router] replicas={args.replicas} sessions={len(sessions)} "
          f"reuse_frac={s.reuse_fraction:.2f} hit_rate={s.hit_rate:.2f} "
          f"reprefill_tokens={s.reprefill_tokens}/{s.routed_tokens} "
          f"sheds={s.sheds} ships={s.ships} shipped_tok={s.shipped_tokens} "
          f"reprefill_avoided={s.reprefill_avoided} syncs={s.syncs} "
          f"fast_dispatches={s.fast_dispatches} "
          f"dispatch_locality={router.metrics.locality:.2f} wall={wall:.1f}s")
    for rep in replicas:
        eng = rep.engine
        print(f"  [replica {rep.rid}] served={eng.scheduler.metrics.admitted} "
              f"prefill_positions={eng.prefill_positions} "
              f"reused_positions={eng.reused_positions} "
              f"prefix_hit_rate={eng.slots.telemetry.prefix_hit_rate:.2f} "
              f"cap={router.fleet.cap(rep.rid)}")
    if registry is not None:
        router.stats.register_into(registry)
        router.scheduler.metrics.register_into(registry, prefix="router_sched")
        if router.fabric is not None:
            router.fabric.stats.register_into(registry)
        for rep in replicas:
            rep.engine.register_metrics(registry, prefix=f"replica{rep.rid}")
    _emit_obs(args, tracer, registry)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
