"""Continuous-batching prefill: bucketed, packed, AOT-warmed.

The admission discipline (the paper's CNA queues) decides *who* enters;
this layer bounds *what each entry costs*:

  * **bucketed** — prompts pad to power-of-two length buckets, so the jit
    trace count is ``len(prompt_buckets(cache_len))`` (== log2(cache_len)
    for power-of-two cache lengths) regardless of traffic, and every trace
    is compiled ahead-of-time at engine construction (``warm``) so no
    compile ever lands in the serving loop.
  * **packed** — up to ``pack_width`` prompts ride one batched
    ``prefill_packed`` call; each row scatters to its decode slot via
    ``SlotCache.insert_row``.  On the ``attn_xla`` path a packed row is
    bitwise what the per-request ``prefill`` returns (masked pad columns
    contribute exact zeros; regression-tested).
  * **continuation** — prefix-KV resumes go through ``prefill_cont`` (whole
    suffixes at seeded per-row positions) instead of one ``decode_step``
    per suffix token, and *stay* bitwise-equal to the from-scratch path.

The planning core (``prompt_buckets`` / ``bucket_for`` / ``plan_packs``) is
pure python — docs/architecture.md runs it jax-free — and the module imports
jax lazily so the dependency-light lanes can import it too.
"""

from __future__ import annotations

import functools


# ---------------------------------------------------------------------------
# planning core (pure python, jax-free)
# ---------------------------------------------------------------------------

def prompt_buckets(cache_len: int) -> list[int]:
    """Power-of-two prompt-length buckets ``[2, 4, ...]`` up to the first
    bucket covering the longest admissible prompt (``cache_len - 1``; the
    engine rejects longer ones at submit).  For a power-of-two ``cache_len``
    this is exactly ``log2(cache_len)`` buckets — the jit trace budget the
    compile-count tests and the serving bench pin."""
    if cache_len < 2:
        raise ValueError(f"cache_len {cache_len} leaves no room for a prompt")
    out, b = [], 2
    while b < cache_len - 1:
        out.append(b)
        b *= 2
    out.append(b)
    return out


def bucket_for(length: int, buckets: list[int]) -> int:
    """Smallest bucket holding ``length`` tokens."""
    for b in buckets:
        if length <= b:
            return b
    raise ValueError(f"length {length} exceeds the largest bucket {buckets[-1]}")


def plan_packs(lengths, *, pack_width: int, buckets) -> list[tuple[int, list[int]]]:
    """Plan packed prefill calls over prompts of the given ``lengths``.

    Pure function of the queue snapshot: greedy in admission order (the
    scheduler's grant order *is* the fairness contract — re-sorting by
    length here would starve long prompts), ``pack_width`` rows per call,
    each call padded to the bucket of its longest member.  Returns
    ``[(bucket, row_indices), ...]``; indices into ``lengths``.  A pack may
    mix prompts whose individual buckets differ — padding them to the
    shared bucket is still bitwise-exact, only compute-wasteful, and the
    waste is bounded by the power-of-two bucket spacing."""
    packs, cur = [], []
    for i in range(len(lengths)):
        cur.append(i)
        if len(cur) == pack_width:
            packs.append(cur)
            cur = []
    if cur:
        packs.append(cur)
    return [
        (bucket_for(max(lengths[i] for i in rows), buckets), rows)
        for rows in packs
    ]


# ---------------------------------------------------------------------------
# jit plumbing (lazy jax)
# ---------------------------------------------------------------------------

def _import_jax():
    import jax
    import jax.numpy as jnp

    return jax, jnp


class CountingJit:
    """``jax.jit`` wrapper that counts traces and calls.

    The trace counter is a Python side effect *inside* the traced function,
    so it increments exactly once per (re)trace — the compile-count
    regression tests and the serving bench pin their trace-budget claims on
    it.  ``calls`` counts invocations (cached or not)."""

    def __init__(self, fn, **jit_kwargs):
        jax, _ = _import_jax()
        self.traces = 0
        self.calls = 0

        def counted(*args, **kwargs):
            self.traces += 1
            return fn(*args, **kwargs)

        self._fn = jax.jit(counted, **jit_kwargs)

    def __call__(self, *args, **kwargs):
        self.calls += 1
        return self._fn(*args, **kwargs)


class PrefillBatcher:
    """Owns the bucketed/packed prefill traces for one engine.

    All packed calls share a fixed row count (``pack_width``): partial packs
    pad with length-0 dummy rows rather than tracing a narrower batch, so
    the trace key varies only in the bucket.  ``warm`` compiles every bucket
    at construction; serving then never traces."""

    def __init__(self, model, *, cache_len: int, pack_width: int, cache_headroom: int = 8):
        gate = getattr(model, "supports_packed_prefill", None)
        if gate is None or not gate(cache_len):
            raise ValueError(
                "this arch cannot take the packed-prefill path bitwise-safely "
                "(recurrent/SSM/MoE/sliding-window/VLM state absorbs padded "
                "positions, or a bucket would leave the attn_xla dispatch of "
                "the per-request reference); run the engine with batching off"
            )
        jax, jnp = _import_jax()
        self.model = model
        self.cache_len = cache_len
        self.pack_width = pack_width
        self.buckets = prompt_buckets(cache_len)
        self.packed = CountingJit(
            functools.partial(model.prefill_packed, cache_headroom=cache_headroom)
        )
        self.cont = CountingJit(model.prefill_cont)
        # per-leaf batch-axis map (same convention as SlotCache.zeros) + a
        # zero (batch=1) row: the pad filler for partial continuation packs
        # and the warm template
        abs_cache = model.cache_abstract(pack_width, cache_len)
        logical = model.cache_logical(abs_cache)
        self.axes = jax.tree.map(
            lambda l: l.index("batch") if "batch" in l else None,
            logical,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(i, (str, type(None))) for i in x),
        )
        self.axes["pos"] = None
        single = model.cache_abstract(1, cache_len)
        self._zero_row = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), single)
        self._zero_row["pos"] = jnp.zeros((), jnp.int32)

    # -- packing ---------------------------------------------------------------
    def pack_tokens(self, prompts):
        """Right-pad ``prompts`` (<= pack_width of them) into one
        (pack_width, bucket) token array + true lengths; trailing rows are
        dummies (length 0)."""
        import numpy as np

        if len(prompts) > self.pack_width:
            raise ValueError(f"{len(prompts)} prompts exceed pack_width={self.pack_width}")
        b = bucket_for(max((len(p) for p in prompts), default=1), self.buckets)
        toks = np.zeros((self.pack_width, b), np.int32)
        lens = np.zeros((self.pack_width,), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = np.asarray(p, np.int32)
            lens[i] = len(p)
        return toks, lens

    def prefill(self, params, prompts):
        """One packed prefill call: (per-row logits, cache with per-row pos)."""
        toks, lens = self.pack_tokens(prompts)
        return self.packed(params, toks, lens)

    def continue_rows(self, params, rows, suffixes):
        """One continuation call: extend each (batch=1) seeded cache in
        ``rows`` by its suffix.  Rows must share the ``SlotCache.fit_single``
        shape (stored prefix caches do, by the store's deposit contract)."""
        import numpy as np

        if len(rows) != len(suffixes) or len(rows) > self.pack_width:
            raise ValueError("rows/suffixes mismatch or pack_width exceeded")
        b = bucket_for(max((len(s) for s in suffixes), default=1), self.buckets)
        toks = np.zeros((self.pack_width, b), np.int32)
        lens = np.zeros((self.pack_width,), np.int32)
        for i, s in enumerate(suffixes):
            toks[i, : len(s)] = np.asarray(s, np.int32)
            lens[i] = len(s)
        cache = self._stack(list(rows) + [self._zero_row] * (self.pack_width - len(rows)))
        return self.cont(params, cache, toks, lens)

    # -- row plumbing ----------------------------------------------------------
    def _stack(self, rows):
        """Stack ``pack_width`` (batch=1) caches into one batched cache."""
        jax, jnp = _import_jax()
        out = {}
        for key in rows[0]:
            if key == "pos":
                out["pos"] = jnp.stack(
                    [jnp.asarray(r["pos"], jnp.int32).reshape(()) for r in rows]
                )
                continue
            out[key] = jax.tree.map(
                lambda ax, *leaves: jnp.concatenate(
                    [jnp.asarray(l) for l in leaves], axis=ax
                ),
                self.axes[key],
                *[r[key] for r in rows],
            )
        return out

    def extract_row(self, cache, row: int):
        """Lane ``row`` of a packed cache as a standalone (batch=1) cache
        with the scalar ``pos`` the per-request ``prefill`` emits — what the
        prefix-KV store deposits and ``SlotCache.fit_single`` refits."""
        jax, _ = _import_jax()

        def take(ax, src):
            if ax is None:
                return src
            return jax.lax.dynamic_slice_in_dim(src, row, 1, axis=ax)

        out = {}
        for key in cache:
            if key == "pos":
                continue
            out[key] = jax.tree.map(take, self.axes[key], cache[key])
        out["pos"] = cache["pos"][row]
        return out

    # -- AOT warm ---------------------------------------------------------------
    def warm(self, params, *, cont: bool = False):
        """Compile every bucket trace ahead of time (and the continuation
        traces too when a prefix-KV store will feed them).  Construction-time
        cost; the serving loop then runs trace-free — the whole point of the
        bucketing."""
        import numpy as np

        cache = self._stack([self._zero_row] * self.pack_width) if cont else None
        for b in self.buckets:
            toks = np.zeros((self.pack_width, b), np.int32)
            lens = np.zeros((self.pack_width,), np.int32)
            self.packed(params, toks, lens)
            if cont:
                self.cont(params, cache, toks, lens)
