"""Flash-attention Pallas TPU kernel: causal/windowed GQA with online softmax.

TPU adaptation of the Flash-Attention recurrence (the paper's algorithm is a
GPU shared-memory design; here the blocking is driven by VMEM and the MXU):

  * grid = (B*H, n_q_blocks, n_kv_blocks); the *last* grid dim is the
    innermost sequential loop on TPU, so the running (m, l, acc) softmax state
    for one (head, q-block) lives in VMEM scratch across kv steps — the role
    a GPU kernel gives to registers/shared memory.
  * BlockSpecs tile q/out as (1, block_q, hd) and k/v as (1, block_k, hd) —
    block_q/block_k default to 128, matching the 128x128 MXU systolic tile
    and the (8,128) VREG lane layout.
  * GQA is handled by *index maps*: the kv BlockSpec maps q-head bh to kv head
    bh // group — no materialised repeat of K/V in HBM.
  * Fully-masked blocks are skipped with pl.when (the index space is still
    visited; on real hardware the skipped iterations cost only the grid
    bookkeeping since their DMAs are elided by Mosaic when the block is
    unused... conservatively we still fetch; a production variant would prune
    the grid).

Validated in interpret mode against ref.attention_ref over a shape/dtype
sweep (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref,  # inputs
    o_ref,                # output
    m_scr, l_scr, acc_scr,  # scratch: (block_q,), (block_q,), (block_q, hd)
    *,
    scale: float,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
    causal: bool,
    window: int,
    sq_valid: int,
    skv_valid: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level reachability (static per grid point at trace time via when)
    live = True
    if causal:
        live = k_start <= q_start + block_q - 1

    def body():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                  # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = (q_pos < sq_valid) & (k_pos < skv_valid)
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(jnp.where(m_prev > NEG_INF / 2, m_prev - m_new, NEG_INF))
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    if causal:
        # causal reachability depends only on static block ids when the grid
        # is not pruned — use a dynamic predicate (works in both modes)
        pl.when(k_start <= q_start + block_q - 1)(body)
    else:
        body()

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,  # (BH, Sq, hd)  — q heads flattened
    k: jax.Array,  # (BHkv, Skv, hd)
    v: jax.Array,
    *,
    group: int,
    causal: bool,
    window: int,
    block_q: int = 128,
    block_k: int = 128,
    sq_valid: int | None = None,
    skv_valid: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    bh, sq, hd = q.shape
    skv = k.shape[1]
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)
    n_q = sq // block_q
    n_kv = skv // block_k
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _fa_kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=n_kv,
        causal=causal,
        window=window,
        sq_valid=sq if sq_valid is None else sq_valid,
        skv_valid=skv if skv_valid is None else skv_valid,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki, _g=group: (b // _g, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki, _g=group: (b // _g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
