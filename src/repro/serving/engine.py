"""Continuous-batching decode engine.

One jit'd ``decode_step`` advances all active slots in one fused step
(per-slot positions); prefill runs per admitted request and its cache is
spliced into the claimed slot.  The admission order between waiting requests
is delegated to the scheduler (CNA or FIFO) — the engine reports its current
locality domain so the scheduler can apply the paper's same-socket
preference.

Greedy sampling (argmax) keeps the engine deterministic for tests; the
sampling hook is injectable.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kvcache import SlotCache
from .scheduler import CNAScheduler


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int
    domain: int = 0               # pod-locality domain of the prefix/KV home
    out: list = field(default_factory=list)
    submit_t: int = 0
    finish_t: int = -1

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class DecodeEngine:
    def __init__(
        self,
        model,
        params,
        *,
        n_slots: int = 4,
        cache_len: int = 256,
        scheduler=None,
        eos: int | None = None,
        domain_switch_cost: int = 4,
        topology=None,
        placement=None,
        slot_migration_cost: int = 2,
    ):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        # NB: schedulers define __len__, so `scheduler or default` would
        # silently replace an *empty* scheduler — compare to None explicitly.
        if scheduler is not None and topology is not None:
            raise ValueError(
                "pass topology via the scheduler (e.g. CNAScheduler(topology=...)); "
                "an explicit scheduler's topology would silently win otherwise"
            )
        self.scheduler = scheduler if scheduler is not None else CNAScheduler(topology=topology)
        self.eos = eos
        # placement: a repro.placement policy (name or instance) making the
        # slot cache NUMA-homed over the scheduler's topology — each request's
        # slot lands in (or nearest to) its KV/prefix home domain.
        if placement is not None and self.scheduler.topology is None:
            raise ValueError("placement needs a topology (e.g. CNAScheduler(topology=...))")
        self.slots = SlotCache.zeros(
            model, n_slots, cache_len,
            topology=self.scheduler.topology if placement is not None else None,
            policy=placement if placement is not None else "nearest_spill",
        )
        if self.slots.telemetry is not None:
            self.scheduler.metrics.placement = self.slots.telemetry
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)
        self.active_req: dict[int, Request] = {}
        # simulated cost accounting: a domain switch stalls the pipe while the
        # prefix/KV home moves across DCN (the paper's remote cache miss);
        # under a hierarchical topology the stall scales with the inter-domain
        # distance (cross-pod moves cost double a same-pod move).  A slot
        # placed off its home domain additionally stalls per unit of distance
        # while the prefix/KV blocks migrate to the slot's pool.
        self.domain_switch_cost = domain_switch_cost
        self.slot_migration_cost = slot_migration_cost
        self.sim_time = 0
        self._prefill = jax.jit(model.prefill)
        self._step = jax.jit(model.decode_step)

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request):
        req.submit_t = self.scheduler.now
        self.scheduler.submit(req, req.domain)

    def _admit(self):
        while self.slots.n_free and len(self.scheduler):
            req = self.scheduler.next_request()
            if req is None:
                break
            slot = self.slots.claim(req.rid, req.domain)
            stall = (
                self.domain_switch_cost * self.scheduler.last_admit_distance
                + self.slot_migration_cost * self.slots.last_distance
            )
            self.sim_time += stall
            # one handover sample per admission: the GCR feedback signal for
            # an adaptive max_active (no-op under a static/absent cap)
            self.scheduler.observe_handover(stall)
            logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(req.prompt)[None]})
            cache["pos"] = jnp.asarray(cache["pos"], jnp.int32)
            self.slots.insert(slot, cache)
            tok = int(jnp.argmax(logits[0]))
            req.out.append(tok)
            self.tokens = self.tokens.at[slot, 0].set(tok)
            self.active_req[slot] = req

    # -- decode ----------------------------------------------------------------
    def step(self):
        """One engine tick: admit, one fused decode step, retire finished."""
        self.scheduler.tick()
        self._admit()
        if not self.active_req:
            self.sim_time += 1
            return
        logits, new_cache = self._step(self.params, self.slots.cache, self.tokens)
        self.slots.cache = new_cache
        self.sim_time += 1
        nxt = jnp.argmax(logits, axis=-1)
        for slot, req in list(self.active_req.items()):
            tok = int(nxt[slot])
            req.out.append(tok)
            self.tokens = self.tokens.at[slot, 0].set(tok)
            hit_eos = self.eos is not None and tok == self.eos
            past_len = int(self.slots.cache["pos"][slot]) >= self.cache_len - 1
            if req.done or hit_eos or past_len:
                req.finish_t = self.scheduler.now
                self.slots.release(slot)
                del self.active_req[slot]

    def run(self, requests: list[Request], max_ticks: int = 10_000) -> list[Request]:
        for r in requests:
            self.submit(r)
        ticks = 0
        while (len(self.scheduler) or self.active_req) and ticks < max_ticks:
            self.step()
            ticks += 1
        return requests
