"""Shared helpers for the benchmark suite: CSV tables + claim checks."""

from __future__ import annotations

import sys
import time
from contextlib import contextmanager


def table(title: str, header: list[str], rows: list[list]):
    print(f"\n## {title}")
    print(",".join(str(h) for h in header))
    for r in rows:
        print(",".join(f"{x:.4g}" if isinstance(x, float) else str(x) for x in r))
    sys.stdout.flush()


# --smoke: tiny iteration counts so CI can exercise every benchmark's code
# path in seconds.  Claims still print but are not load-bearing at smoke
# scale (the curves need full durations); run.py only gates on them in a
# full run.
SMOKE = False

FAILED_CLAIMS: list[str] = []


def smoke(full, tiny):
    """Pick the full-scale or smoke-scale value for an iteration knob."""
    return tiny if SMOKE else full


def zipf_draws(n: int, n_items: int, skew: float, rng) -> list[int]:
    """n inverse-CDF draws over items weighted 1/(k+1)^skew (skew 0 =
    uniform).  The one Zipf sampler for every bench workload — domain mixes
    and shared-prefix pools must skew identically to be comparable."""
    weights = [1.0 / (k + 1) ** skew for k in range(n_items)]
    tot = sum(weights)
    out = []
    for _ in range(n):
        r = rng.random() * tot
        acc = 0.0
        for k, w in enumerate(weights):
            acc += w
            if r <= acc:
                out.append(k)
                break
        else:
            out.append(n_items - 1)
    return out


def claim(name: str, ok: bool, detail: str = ""):
    status = "PASS" if ok else "FAIL"
    if not ok:
        FAILED_CLAIMS.append(name)
    print(f"CLAIM [{status}] {name}  {detail}")
    return ok


def ascii_plot(title: str, xs, series: dict, *, width: int = 64, height: int = 16,
               logy: bool = False):
    """Paper-style ASCII line chart: one mark per series, shared y scale.

    ``series`` maps name -> list of y values (same length as ``xs``).  Keeps
    benchmark output self-contained (no matplotlib in the container)."""
    import math

    marks = "ox+*#@%&"
    ys_all = [y for ys in series.values() for y in ys if y is not None]
    if not ys_all:
        return
    f = (lambda v: math.log10(max(v, 1e-12))) if logy else (lambda v: v)
    lo, hi = min(f(y) for y in ys_all), max(f(y) for y in ys_all)
    span = (hi - lo) or 1.0
    grid = [[" "] * width for _ in range(height)]
    for si, (name, ys) in enumerate(series.items()):
        for i, y in enumerate(ys):
            if y is None:
                continue
            col = round(i * (width - 1) / max(1, len(xs) - 1))
            row = height - 1 - round((f(y) - lo) / span * (height - 1))
            grid[row][col] = marks[si % len(marks)]
    print(f"\n## {title}")
    ylab = "log10 " if logy else ""
    print(f"  y: {ylab}[{lo:.3g} .. {hi:.3g}]   x: {xs[0]} .. {xs[-1]}")
    for row in grid:
        print("  |" + "".join(row))
    print("  +" + "-" * width)
    legend = "   ".join(f"{marks[i % len(marks)]}={name}" for i, name in enumerate(series))
    print(f"   {legend}")
    sys.stdout.flush()


@contextmanager
def timed(name: str):
    t0 = time.time()
    yield
    print(f"({name}: {time.time() - t0:.1f}s)")


THREADS_2S = [1, 2, 4, 8, 16, 24, 36, 48, 70]
THREADS_4S = [1, 2, 4, 8, 16, 36, 72, 108, 142]
LOCK_SET = ["mcs", "cna", "cna_opt", "c-bo-mcs", "hmcs", "tas", "ticket", "hbo"]
MAIN_LOCKS = ["mcs", "cna", "cna_opt", "c-bo-mcs", "hmcs"]


# -- subprocess harness (mirrors tests/_subproc.py — keep the two in sync) ----
# Subprocesses must not inherit hardcoded machine paths, and must pin
# JAX_PLATFORMS=cpu: with libtpu installed but no TPU attached, an unpinned
# jax spends minutes probing TPU metadata endpoints.
import os as _os

REPO_ROOT = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))


def subproc_env() -> dict:
    env = dict(_os.environ)
    env["PYTHONPATH"] = _os.path.join(REPO_ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    return env
